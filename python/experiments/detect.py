"""Table II analogue: detection quality, FP32 vs 8-bit vs 8-bit + RoI mask.

Metric: single-class patch-objectness AP (area under PR) plus box-level AP
at IoU 0.5 from connected-component decoding — the reduction of the
paper's COCO Mask R-CNN AP that our synthetic substrate supports. The
reproduced claims: (i) quantizing the backbone costs ≈nothing (paper:
30.35 → 30.53 AP), and (ii) adding the RoI mask costs ≲0.1-0.4 while
skipping ~66% of pixels.

Run: ``python -m experiments.detect [--steps N]``
"""

import argparse

import numpy as np

from .common import average_precision, box_map, boxes_from_mask, print_table, save_table
from .detector import det_config, eval_frames, train_detector


def _patch_ap(results):
    scores = np.concatenate([r[0] for r in results])
    labels = np.concatenate([r[1] for r in results])
    return average_precision(scores, labels)


def run(steps=300, frames=96, seed=0):
    cfg = det_config()
    rows = []

    print("fp32 detector:")
    p_fp = train_detector(cfg, steps=steps, mode="fp32", seed=seed)
    r_fp = eval_frames(p_fp, cfg, frames, mode="fp32")
    ap_fp = _patch_ap(r_fp)
    rows.append(["ViTDet* (fp32)", "-", f"{ap_fp*100:.2f}"])

    print("8-bit QAT detector:")
    p_q = train_detector(cfg, steps=steps, mode="quant", seed=seed)
    r_q = eval_frames(p_q, cfg, frames, mode="quant")
    ap_q = _patch_ap(r_q)
    rows.append(["Opto-ViT* (8-bit)", "-", f"{ap_q*100:.2f}"])

    r_m = eval_frames(p_q, cfg, frames, mode="quant", roi_mask=True)
    ap_m = _patch_ap(r_m)
    skip = float(np.mean([r[3] for r in r_m]))
    rows.append([f"Opto-ViT* Mask", f"{skip:.2f}", f"{ap_m*100:.2f}"])

    header = ["backbone", "skip%", "patch AP"]
    print_table("Table II analogue — detection AP (synthetic)", header, rows)
    save_table("table2", "Table II analogue (synthetic detection)", header, rows)

    # Shape assertions (the paper's relative claims):
    assert abs(ap_fp - ap_q) < 0.05, f"quantization cost too high: {ap_fp} vs {ap_q}"
    assert ap_m > ap_q - 0.08, f"mask cost too high: {ap_q} vs {ap_m}"
    print(f"\nquantization delta: {(ap_fp-ap_q)*100:+.2f} AP; "
          f"mask delta: {(ap_q-ap_m)*100:+.2f} AP at {skip:.0%} skip")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--frames", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.steps, args.frames, args.seed)


if __name__ == "__main__":
    main()
