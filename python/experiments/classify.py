"""Table I analogue: classification accuracy, full-precision ViT vs 8-bit
Opto-ViT, across model scales, plus the RoI-masked row.

The paper's claim (Table I): 8-bit QAT stays within ~0.2-1.6% of the FP32
baseline across Tiny/Small/Base/Large, and input masking trades a further
small drop for a ~67% pixel skip. Scales here are depth/width-reduced
analogues sized for CPU build-time training; the *relative* FP-vs-INT8 and
mask-vs-no-mask deltas are the reproduced quantities (DESIGN.md).

Run: ``python -m experiments.classify [--steps N] [--eval-frames N]``
"""

import argparse

import numpy as np

from compile import model as M
from compile import train as T
from .common import print_table, save_table

# Scale ladder: (name, embed_dim, heads, depth) — reduced analogues of the
# paper's T/S/B/L ladder (same widening/deepening direction).
SCALES = [
    ("Tiny*", 96, 3, 2),
    ("Small*", 144, 3, 3),
    ("Base*", 192, 6, 4),
]


def run(steps=300, eval_frames=160, seed=0):
    rows = []
    for name, d, h, depth in SCALES:
        cfg = M.vit_config("tiny", 96, 10)  # base dict, then override scale
        cfg.update(embed_dim=d, num_heads=h, depth=depth)
        print(f"\n--- scale {name} (d={d}, h={h}, L={depth}) ---")
        print("fp32 training:")
        p_fp = T.train_backbone(cfg, steps=steps, mode="fp32", seed=seed, num_objects=(1, 4))
        acc_fp = T.backbone_accuracy(p_fp, cfg, frames=eval_frames, mode="fp32", num_objects=(1, 4))
        print("8-bit QAT training:")
        p_q = T.train_backbone(cfg, steps=steps, mode="quant", seed=seed, num_objects=(1, 4))
        acc_q = T.backbone_accuracy(p_q, cfg, frames=eval_frames, mode="quant", num_objects=(1, 4))
        rows.append([name, "96x96", "-", f"{acc_fp*100:.2f}%", f"{acc_q*100:.2f}%",
                     f"{(acc_fp-acc_q)*100:+.2f}%"])
        print(f"  {name}: fp32 {acc_fp:.4f}  int8 {acc_q:.4f}")

        if name == "Base*":
            # Masked row (Table I "Base Mask"): GT-box-derived patch pruning,
            # mirroring the paper's MGNet-mask operating point.
            def keep(patch_labels):
                return patch_labels > 0.5

            acc_m = T.backbone_accuracy(p_q, cfg, frames=eval_frames, mode="quant",
                                        keep_mask=keep, num_objects=(1, 4))
            # measure the skip ratio on the same distribution
            rng = np.random.default_rng(99)
            from compile import data as D
            skips = []
            for _ in range(64):
                _, _, masks = D.classification_batch(rng, 1, size=96, patch=16, num_objects=1)
                skips.append(1.0 - masks[0].mean())
            rows.append([f"{name} Mask", "96x96", f"{np.mean(skips):.2f}",
                         "-", f"{acc_m*100:.2f}%", f"{(acc_q-acc_m)*100:+.2f}% vs int8"])
            print(f"  {name} Mask: int8+mask {acc_m:.4f} (skip {np.mean(skips):.2f})")

    header = ["Model", "Resolution", "skip%", "Acc. FP32", "Acc. 8-bit", "delta"]
    print_table("Table I analogue — classification, FP32 vs 8-bit Opto-ViT", header, rows)
    save_table("table1", "Table I analogue (synthetic shapes)", header, rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--eval-frames", type=int, default=160)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.steps, args.eval_frames, args.seed)


if __name__ == "__main__":
    main()
