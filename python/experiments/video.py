"""Table III analogue: video object detection, mAP / mAP-50 / mAP-75.

Video sequences of moving shapes (the ImageNet-VID substitution); the
patch detector's thresholded objectness map is decoded to boxes per frame
and scored against ground truth at IoU 0.5 and 0.75. Rows mirror Table
III: full-precision, 8-bit Opto-ViT (small drop), 8-bit + mask (slight
further drop at ~68% pixel skip).

Run: ``python -m experiments.video [--steps N]``
"""

import argparse

import numpy as np

from .common import box_map, boxes_from_mask, print_table, save_table
from .detector import det_config, eval_frames, train_detector


def _video_map(results, cfg, thr_list=(0.5, 0.75), score_thr=0.0):
    """Mean over frames of box AP at each IoU threshold."""
    side = cfg["image_size"] // cfg["patch_size"]
    maps = {t: [] for t in thr_list}
    for scores, _, gt_boxes, _ in results:
        if gt_boxes is None:
            continue
        m2 = (scores > score_thr).reshape(side, side)
        comps = boxes_from_mask(m2, cfg["patch_size"])
        # score each predicted box by its mean patch objectness
        s2 = scores.reshape(side, side)
        preds = []
        for (x0, y0, x1, y1) in comps:
            px0, py0 = x0 // cfg["patch_size"], y0 // cfg["patch_size"]
            px1, py1 = x1 // cfg["patch_size"], y1 // cfg["patch_size"]
            preds.append(((x0, y0, x1, y1), float(s2[py0:py1, px0:px1].mean())))
        for t in thr_list:
            maps[t].append(box_map(preds, list(gt_boxes), t))
    return {t: float(np.mean(v)) if v else 0.0 for t, v in maps.items()}


def run(steps=300, frames=96, seed=0):
    cfg = det_config()
    rows = []

    print("fp32 detector:")
    p_fp = train_detector(cfg, steps=steps, mode="fp32", seed=seed)
    r_fp = eval_frames(p_fp, cfg, frames, mode="fp32", video=True)
    m_fp = _video_map(r_fp, cfg)
    rows.append(["ViTDet* (fp32)", "-", f"{np.mean(list(m_fp.values())):.4f}",
                 f"{m_fp[0.5]:.4f}", f"{m_fp[0.75]:.4f}"])

    print("8-bit QAT detector:")
    p_q = train_detector(cfg, steps=steps, mode="quant", seed=seed)
    r_q = eval_frames(p_q, cfg, frames, mode="quant", video=True)
    m_q = _video_map(r_q, cfg)
    rows.append(["Opto-ViT* (8-bit)", "-", f"{np.mean(list(m_q.values())):.4f}",
                 f"{m_q[0.5]:.4f}", f"{m_q[0.75]:.4f}"])

    r_m = eval_frames(p_q, cfg, frames, mode="quant", video=True, roi_mask=True)
    m_m = _video_map(r_m, cfg)
    skip = float(np.mean([r[3] for r in r_m]))
    rows.append([f"Opto-ViT* Mask", f"{skip:.2f}", f"{np.mean(list(m_m.values())):.4f}",
                 f"{m_m[0.5]:.4f}", f"{m_m[0.75]:.4f}"])

    header = ["model", "skip%", "mAP", "mAP-50", "mAP-75"]
    print_table("Table III analogue — video detection (synthetic sequences)", header, rows)
    save_table("table3", "Table III analogue (synthetic video)", header, rows)

    drop_q = m_fp[0.5] - m_q[0.5]
    drop_m = m_q[0.5] - m_m[0.5]
    print(f"\nquantization mAP-50 drop: {drop_q*100:+.2f}; mask drop: {drop_m*100:+.2f} "
          f"at {skip:.0%} skip")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--frames", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.steps, args.frames, args.seed)


if __name__ == "__main__":
    main()
