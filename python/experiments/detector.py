"""Patch-level detector used by the Table II/III analogues.

A ViTDet-style reduction: the ViT trunk runs on all (or RoI-kept) patches
and a linear head predicts per-patch objectness. Boxes are decoded from the
thresholded objectness map by connected components (common.boxes_from_mask)
— the single-class stand-in for the paper's Mask R-CNN head, with the same
property under study: only the *backbone* is quantized (the head stays
fp32, as in §IV-2).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile.quant import fake_quant
from compile.train import adam_init, adam_step, bce_with_logits


def det_config(d=128, h=4, depth=3, size=96):
    cfg = M.vit_config("tiny", size, 10)
    cfg.update(embed_dim=d, num_heads=h, depth=depth)
    return cfg


def init_detector(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "trunk": M.init_vit(k1, cfg),
        # objectness head (kept fp32 — electronic domain, §IV-2)
        "obj": M._dense_init(k2, cfg["embed_dim"], 1),
    }


def detector_forward(params, cfg, patches, pos_idx, valid, mode="quant"):
    """Per-patch objectness logits (n_kept,). The trunk mirrors
    vit_forward but reads out patch tokens instead of the cls token."""
    spec = M.PhotonicSpec() if hasattr(M, "PhotonicSpec") else None
    from compile.kernels import PhotonicSpec

    spec = PhotonicSpec()
    t = params["trunk"]
    tok = M._dense(patches, t["embed"], mode, spec)
    pos = jnp.take(t["pos"], pos_idx.astype(jnp.int32) + 1, axis=0)
    tok = tok + pos
    cls = t["cls"] + t["pos"][0:1]
    x = jnp.concatenate([cls, tok], axis=0)
    v = jnp.concatenate([jnp.ones((1,), valid.dtype), valid])
    x = x * v[:, None]
    for blk in t["blocks"]:
        x = M._encoder_block(x, blk, cfg["num_heads"], v, mode, spec)
    x = M._layernorm(x, t["ln_f"])
    # fp32 head on patch tokens:
    return (x[1:] @ params["obj"]["w"] + params["obj"]["b"])[:, 0]


def train_detector(cfg, steps=300, batch=8, lr=1e-3, seed=0, mode="quant", verbose=True):
    rng = np.random.default_rng(seed + 500)
    params = init_detector(jax.random.PRNGKey(seed + 500), cfg)
    n = cfg["num_patches"]
    pos_idx = jnp.arange(n, dtype=jnp.float32)
    valid = jnp.ones((n,), jnp.float32)

    def loss_fn(p, xs, ms):
        def one(x, m):
            return bce_with_logits(detector_forward(p, cfg, x, pos_idx, valid, mode), m)

        return jnp.mean(jax.vmap(one)(xs, ms))

    @jax.jit
    def step(p, opt, xs, ms):
        l, g = jax.value_and_grad(loss_fn)(p, xs, ms)
        p, opt = adam_step(p, g, opt, lr=lr)
        return p, opt, l

    opt = adam_init(params)
    for i in range(steps):
        xs, _, ms = D.classification_batch(
            rng, batch, size=cfg["image_size"], patch=cfg["patch_size"],
            num_objects=int(rng.integers(1, 4)))
        params, opt, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(ms))
        if verbose and (i % 50 == 0 or i == steps - 1):
            print(f"  detector step {i:4d} loss {float(loss):.4f}")
    return params


def eval_frames(params, cfg, frames, mode="quant", roi_mask=False, seed=123,
                video=False, num_objects=(1, 4)):
    """Yield (scores(n,), gt_patch_labels(n,), gt_boxes, skip) per frame."""
    rng = np.random.default_rng(seed)
    n = cfg["num_patches"]
    fwd = jax.jit(lambda x, pi, v: detector_forward(params, cfg, x, pi, v, mode))
    out = []

    def frame_iter():
        if video:
            per_seq = 16
            for _ in range(frames // per_seq + 1):
                seq = D.video_sequence(rng, per_seq, size=cfg["image_size"],
                                       patch=cfg["patch_size"],
                                       num_objects=int(rng.integers(*num_objects)))
                for item in seq:
                    yield item
        else:
            while True:
                xs, _, ms = D.classification_batch(
                    rng, 1, size=cfg["image_size"], patch=cfg["patch_size"],
                    num_objects=int(rng.integers(*num_objects)))
                scene = None
                # classification_batch has no boxes; regenerate with Scene for boxes
                yield xs[0], None, ms[0], None

    count = 0
    for item in frame_iter():
        if video:
            patches, boxes, labels, _ = item
        else:
            patches, boxes, labels, _ = item[0], None, item[2], None
        if roi_mask:
            # RoI pruning from (slightly dilated) GT labels — the trained-
            # MGNet operating point without entangling MGNet error here.
            side = int(np.sqrt(len(labels)))
            m2 = labels.reshape(side, side) > 0.5
            dil = m2.copy()
            dil[1:, :] |= m2[:-1, :]
            dil[:-1, :] |= m2[1:, :]
            dil[:, 1:] |= m2[:, :-1]
            dil[:, :-1] |= m2[:, 1:]
            kept_idx = np.flatnonzero(dil.reshape(-1))
            if len(kept_idx) == 0:
                kept_idx = np.array([0])
            skip = 1.0 - len(kept_idx) / len(labels)
            n_full = len(labels)
            xk = np.zeros((n_full, patches.shape[-1]), np.float32)
            pi = np.zeros((n_full,), np.float32)
            v = np.zeros((n_full,), np.float32)
            xk[: len(kept_idx)] = patches[kept_idx]
            pi[: len(kept_idx)] = kept_idx
            v[: len(kept_idx)] = 1.0
            s_k = np.asarray(fwd(jnp.asarray(xk), jnp.asarray(pi), jnp.asarray(v)))
            scores = np.full((n_full,), -20.0, np.float32)  # pruned = background
            scores[kept_idx] = s_k[: len(kept_idx)]
        else:
            skip = 0.0
            pos = np.arange(len(labels), dtype=np.float32)
            v = np.ones((len(labels),), np.float32)
            scores = np.asarray(fwd(jnp.asarray(patches), jnp.asarray(pos), jnp.asarray(v)))
        out.append((scores, labels, boxes, skip))
        count += 1
        if count >= frames:
            break
    return out
