"""Shared infrastructure for the Table I-III experiment analogues."""

import os

import numpy as np


def results_dir():
    d = os.environ.get("OPTOVIT_RESULTS", os.path.join(os.path.dirname(__file__), "..", "..", "results"))
    os.makedirs(d, exist_ok=True)
    return d


def print_table(title, header, rows):
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0)) for i, h in enumerate(header)]
    line = "  ".join(f"{h:<{w}}" for h, w in zip(header, widths))
    print(f"\n== {title} ==")
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(f"{str(c):<{w}}" for c, w in zip(r, widths)))


def save_table(name, title, header, rows):
    """Persist as tab-separated text for EXPERIMENTS.md."""
    path = os.path.join(results_dir(), f"{name}.tsv")
    with open(path, "w") as f:
        f.write(f"# {title}\n")
        f.write("\t".join(map(str, header)) + "\n")
        for r in rows:
            f.write("\t".join(map(str, r)) + "\n")
    print(f"saved {path}")


# ---------------------------------------------------------------------------
# Detection-style scoring (Tables II/III analogues)
# ---------------------------------------------------------------------------


def average_precision(scores, labels):
    """AP over per-patch objectness: area under the precision/recall curve
    (all-points interpolation)."""
    order = np.argsort(-np.asarray(scores))
    labels = np.asarray(labels)[order]
    tp = np.cumsum(labels)
    fp = np.cumsum(1 - labels)
    npos = labels.sum()
    if npos == 0:
        return 0.0
    recall = tp / npos
    precision = tp / np.maximum(tp + fp, 1e-9)
    # monotone precision envelope
    for i in range(len(precision) - 2, -1, -1):
        precision[i] = max(precision[i], precision[i + 1])
    ap = 0.0
    prev_r = 0.0
    for p, r in zip(precision, recall):
        ap += p * (r - prev_r)
        prev_r = r
    return float(ap)


def boxes_from_mask(mask2d, patch_px):
    """Connected components of a binary patch mask -> pixel boxes
    (4-connectivity flood fill)."""
    side = mask2d.shape[0]
    seen = np.zeros_like(mask2d, dtype=bool)
    boxes = []
    for sy in range(side):
        for sx in range(side):
            if mask2d[sy, sx] and not seen[sy, sx]:
                stack = [(sy, sx)]
                seen[sy, sx] = True
                ys, xs = [], []
                while stack:
                    y, x = stack.pop()
                    ys.append(y)
                    xs.append(x)
                    for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                        ny, nx = y + dy, x + dx
                        if 0 <= ny < side and 0 <= nx < side and mask2d[ny, nx] and not seen[ny, nx]:
                            seen[ny, nx] = True
                            stack.append((ny, nx))
                boxes.append(
                    (min(xs) * patch_px, min(ys) * patch_px,
                     (max(xs) + 1) * patch_px, (max(ys) + 1) * patch_px)
                )
    return boxes


def box_iou(a, b):
    ix0, iy0 = max(a[0], b[0]), max(a[1], b[1])
    ix1, iy1 = min(a[2], b[2]), min(a[3], b[3])
    if ix1 <= ix0 or iy1 <= iy0:
        return 0.0
    inter = (ix1 - ix0) * (iy1 - iy0)
    ar_a = (a[2] - a[0]) * (a[3] - a[1])
    ar_b = (b[2] - b[0]) * (b[3] - b[1])
    return inter / (ar_a + ar_b - inter)


def box_map(pred_boxes_scores, gt_boxes, iou_thr):
    """Single-class mAP at an IoU threshold: greedy matching of ranked
    predicted boxes to ground truth (COCO-style, one GT match each)."""
    preds = sorted(pred_boxes_scores, key=lambda bs: -bs[1])
    matched = [False] * len(gt_boxes)
    labels = []
    for box, _ in preds:
        hit = 0
        for gi, g in enumerate(gt_boxes):
            if not matched[gi] and box_iou(box, g) >= iou_thr:
                matched[gi] = True
                hit = 1
                break
        labels.append(hit)
    if not preds:
        return 0.0
    scores = [s for _, s in preds]
    # pad recall denominator with unmatched GT
    labels_arr = np.array(labels, dtype=float)
    npos = len(gt_boxes)
    if npos == 0:
        return 0.0
    order = np.argsort(-np.asarray(scores))
    labels_arr = labels_arr[order]
    tp = np.cumsum(labels_arr)
    fp = np.cumsum(1 - labels_arr)
    recall = tp / npos
    precision = tp / np.maximum(tp + fp, 1e-9)
    for i in range(len(precision) - 2, -1, -1):
        precision[i] = max(precision[i], precision[i + 1])
    ap = 0.0
    prev_r = 0.0
    for p, r in zip(precision, recall):
        ap += p * (r - prev_r)
        prev_r = r
    return float(ap)
