"""Build-time accuracy experiments: Table I/II/III analogues on the
synthetic moving-shapes workload (see DESIGN.md for the substitution
rationale — the paper's claims are *relative* FP-vs-INT8 and
mask-vs-no-mask deltas, which reproduce at small scale)."""
