"""L2 model tests: shapes, masking semantics, numerics-mode behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import PhotonicSpec, crosstalk_matrix


@pytest.fixture(scope="module")
def tiny():
    cfg = M.vit_config("tiny", 96, 10, depth=2)  # shallow for test speed
    params = M.init_vit(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def mg():
    cfg = M.mgnet_config(96)
    params = M.init_mgnet(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _inputs(cfg, n_kept, rng):
    patches = jnp.asarray(rng.normal(size=(n_kept, cfg["patch_dim"])).astype(np.float32))
    pos = jnp.arange(n_kept, dtype=jnp.float32)
    valid = jnp.ones((n_kept,), jnp.float32)
    return patches, pos, valid


def test_backbone_output_shape(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    logits = M.vit_forward(params, cfg, *_inputs(cfg, 18, rng))
    assert logits.shape == (10,)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_variant_table_matches_rust():
    # Hyperparameters must mirror rust/src/vit/config.rs exactly.
    assert M.VIT_VARIANTS["tiny"] == dict(embed_dim=192, num_heads=3, depth=12)
    assert M.VIT_VARIANTS["small"] == dict(embed_dim=384, num_heads=6, depth=12)
    assert M.VIT_VARIANTS["base"] == dict(embed_dim=768, num_heads=12, depth=12)
    assert M.VIT_VARIANTS["large"] == dict(embed_dim=1024, num_heads=16, depth=24)
    cfg = M.vit_config("tiny", 96, 10)
    assert cfg["num_patches"] == 36 and cfg["patch_dim"] == 768


def test_padding_invariance_fp32(tiny):
    # Bucket padding (zeroed, invalid slots) must not change the logits in
    # fp32 mode — the RoI bucket-routing contract.
    cfg, params = tiny
    rng = np.random.default_rng(1)
    patches, pos, valid = _inputs(cfg, 9, rng)
    base = M.vit_forward(params, cfg, patches, pos, valid, mode="fp32")
    pad = 9
    patches_p = jnp.concatenate([patches, jnp.full((pad, cfg["patch_dim"]), 7.7, jnp.float32)])
    pos_p = jnp.concatenate([pos, jnp.zeros((pad,), jnp.float32)])
    valid_p = jnp.concatenate([valid, jnp.zeros((pad,), jnp.float32)])
    padded = M.vit_forward(params, cfg, patches_p, pos_p, valid_p, mode="fp32")
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded), atol=1e-4)


def test_padding_near_invariance_quant(tiny):
    # In quant mode the per-tensor scales see the padded rows, so allow a
    # small tolerance (the serving pipeline relies on this being tight).
    cfg, params = tiny
    rng = np.random.default_rng(2)
    patches, pos, valid = _inputs(cfg, 9, rng)
    base = M.vit_forward(params, cfg, patches, pos, valid, mode="quant")
    patches_p = jnp.concatenate([patches, jnp.zeros((9, cfg["patch_dim"]), jnp.float32)])
    pos_p = jnp.concatenate([pos, jnp.zeros((9,), jnp.float32)])
    valid_p = jnp.concatenate([valid, jnp.zeros((9,), jnp.float32)])
    padded = M.vit_forward(params, cfg, patches_p, pos_p, valid_p, mode="quant")
    assert np.argmax(np.asarray(base)) == np.argmax(np.asarray(padded))
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded), atol=0.15)


def test_quant_close_to_fp32(tiny):
    # 8-bit QAT numerics track fp32 closely (the Table-I premise).
    cfg, params = tiny
    rng = np.random.default_rng(3)
    args = _inputs(cfg, 36, rng)
    fp = M.vit_forward(params, cfg, *args, mode="fp32")
    q = M.vit_forward(params, cfg, *args, mode="quant")
    rel = float(jnp.max(jnp.abs(fp - q)) / (jnp.max(jnp.abs(fp)) + 1e-9))
    assert rel < 0.25, f"rel {rel}"


def test_photonic_mode_runs_and_tracks_quant(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(4)
    args = _inputs(cfg, 9, rng)
    q = M.vit_forward(params, cfg, *args, mode="quant")
    spec = PhotonicSpec(crosstalk=crosstalk_matrix())
    ph = M.vit_forward(params, cfg, *args, mode="photonic", spec=spec)
    assert np.all(np.isfinite(np.asarray(ph)))
    # The optical path adds ADC/crosstalk noise but stays in the same regime.
    rel = float(jnp.max(jnp.abs(ph - q)) / (jnp.max(jnp.abs(q)) + 1e-9))
    assert rel < 1.0, f"rel {rel}"


def test_mgnet_scores_shape(mg):
    cfg, params = mg
    rng = np.random.default_rng(5)
    patches = jnp.asarray(rng.normal(size=(cfg["num_patches"], cfg["patch_dim"])).astype(np.float32))
    scores = M.mgnet_forward(params, cfg, patches)
    assert scores.shape == (36,)
    assert np.all(np.isfinite(np.asarray(scores)))


def test_mgnet_detection_config():
    cfg = M.mgnet_config(224, embed_dim=384, num_heads=6)
    assert cfg["num_patches"] == 196
    assert cfg["embed_dim"] == 384 and cfg["num_heads"] == 6


def test_params_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    path = tmp_path / "p.npz"
    M.save_params(path, params)
    loaded = M.load_params(path, params)
    a = M.flatten_params(params)
    b = M.flatten_params(loaded)
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_param_count_matches_rust(tiny):
    # flattened parameter element count == rust VitConfig::param_count()
    cfg = M.vit_config("tiny", 224, 1000)
    params = M.init_vit(jax.random.PRNGKey(0), cfg)
    total = sum(int(np.prod(v.shape)) for v in M.flatten_params(params).values())
    # rust: 5_717_416 for tiny@224 with 1000 classes (asserted 5-7M there).
    assert 5_000_000 < total < 7_000_000
