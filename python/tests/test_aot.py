"""AOT export tests: the HLO-text artifacts must be complete (no elided
constants) and structurally what the rust runtime expects."""

import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # --no-train keeps this fast; export structure is identical.
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--no-train"],
        check=True,
        capture_output=True,
    )
    return out


def test_all_artifacts_present(artifacts):
    names = {p.name for p in artifacts.glob("*.hlo.txt")}
    expected = {
        "mgnet_96.hlo.txt",
        "vit_tiny_96_n9.hlo.txt",
        "vit_tiny_96_n18.hlo.txt",
        "vit_tiny_96_n27.hlo.txt",
        "vit_tiny_96_n36.hlo.txt",
        "vit_tiny_96_photonic_n36.hlo.txt",
    }
    assert expected <= names, names


def test_no_elided_constants(artifacts):
    # The silent failure mode: as_hlo_text() without print_large_constants
    # renders weights as `{...}` which the rust parser reads as zeros.
    for p in artifacts.glob("*.hlo.txt"):
        text = p.read_text()
        assert "{...}" not in text, f"{p.name} has elided constants"


def test_entry_layouts(artifacts):
    mg = (artifacts / "mgnet_96.hlo.txt").read_text()
    assert "f32[36,768]" in mg.splitlines()[0], "MGNet entry must take (36,768) patches"
    bb = (artifacts / "vit_tiny_96_n18.hlo.txt").read_text()
    head = bb.splitlines()[0]
    assert "f32[18,768]" in head and "f32[18]" in head
    assert "->(f32[10]" in head.replace(" ", ""), head


def test_params_saved(artifacts):
    assert (artifacts / "params_mgnet_96.npz").exists()
    assert (artifacts / "params_vit_tiny_96.npz").exists()


def test_outputs_are_tuples(artifacts):
    # return_tuple=True => ROOT is a tuple; rust unwraps with to_tuple().
    text = (artifacts / "mgnet_96.hlo.txt").read_text()
    assert "ROOT" in text and "tuple(" in text
