"""Tests for the QAT quantization primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quant import calibrate_scale, fake_quant, fake_quant_fixed, qmax, ste_round


def test_qmax():
    assert qmax(8) == 127
    assert qmax(4) == 7


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    scale = calibrate_scale(x, 8)
    err = jnp.max(jnp.abs(fake_quant(x, 8) - x))
    assert float(err) <= float(scale) / 2 + 1e-7


def test_idempotent():
    x = jnp.asarray([0.5, -1.25, 2.0, 0.0], jnp.float32)
    once = fake_quant(x, 8)
    # A fixed-scale requantization of an already-quantized tensor is exact.
    scale = calibrate_scale(x, 8)
    again = fake_quant_fixed(once, scale, 8)
    np.testing.assert_allclose(np.asarray(once), np.asarray(again), atol=1e-7)


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(ste_round(x) * 3.0))(jnp.asarray([0.3, 1.7]))
    np.testing.assert_allclose(np.asarray(g), [3.0, 3.0])


def test_fake_quant_gradient_flows():
    # QAT requirement: gradients pass through the quantizer.
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, 8) ** 2))(jnp.asarray([0.5, -0.25]))
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


def test_zero_tensor_safe():
    x = jnp.zeros((16,), jnp.float32)
    out = fake_quant(x, 8)
    assert np.all(np.asarray(out) == 0)


def test_fixed_scale_clips():
    x = jnp.asarray([100.0, -100.0], jnp.float32)
    out = fake_quant_fixed(x, 0.01, 8)
    np.testing.assert_allclose(np.asarray(out), [1.27, -1.27], atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),
    st.lists(st.floats(min_value=-50, max_value=50, allow_nan=False), min_size=1, max_size=64),
)
def test_hypothesis_error_bound(bits, values):
    x = jnp.asarray(np.array(values, dtype=np.float32))
    scale = calibrate_scale(x, bits)
    err = jnp.max(jnp.abs(fake_quant(x, bits) - x))
    assert float(err) <= float(scale) / 2 + 1e-5


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=3, max_value=8))
def test_hypothesis_levels_used(bits):
    # The extreme values must map to the extreme grid points.
    x = jnp.asarray([1.0, -1.0, 0.0], jnp.float32)
    out = np.asarray(fake_quant(x, bits))
    np.testing.assert_allclose(out[0], 1.0, atol=1e-6)
    np.testing.assert_allclose(out[1], -1.0, atol=1e-6)


def test_more_bits_less_error():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    e4 = float(jnp.mean(jnp.abs(fake_quant(x, 4) - x)))
    e8 = float(jnp.mean(jnp.abs(fake_quant(x, 8) - x)))
    assert e8 < e4 / 4


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_quant_grid_size(bits):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    levels = np.unique(np.asarray(fake_quant(x, bits)))
    assert len(levels) <= 2 ** bits
