"""L1 kernel tests: pallas photonic matmul + decomposed attention vs the
pure-jnp oracles — the core correctness signal of the build path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    PhotonicSpec,
    crosstalk_matrix,
    decomposed_attention_head,
    photonic_matmul,
)
from compile.kernels.ref import attention_head_ref, ideal_matmul, photonic_matmul_ref


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# photonic matmul vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 32, 64),     # exactly one chunk
        (8, 64, 128),    # exact tiles
        (7, 100, 70),    # ragged both dims
        (37, 192, 192),  # ViT-Tiny projection shape
        (5, 33, 65),     # just past tile edges
        (13, 768, 192),  # FFN-down shape at masked n
    ],
)
def test_kernel_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    x = _rand(rng, m, k)
    w = _rand(rng, k, n, scale=0.1)
    spec = PhotonicSpec()
    got = photonic_matmul(x, w, spec)
    want = photonic_matmul_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_kernel_with_crosstalk_matches_ref():
    rng = np.random.default_rng(42)
    x = _rand(rng, 9, 96)
    w = _rand(rng, 96, 130, scale=0.1)
    spec = PhotonicSpec(crosstalk=crosstalk_matrix())
    got = photonic_matmul(x, w, spec)
    want = photonic_matmul_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_ideal_spec_recovers_exact_matmul():
    # With all physical effects off, the chunked kernel is exact fp32.
    rng = np.random.default_rng(7)
    x = _rand(rng, 11, 100)
    w = _rand(rng, 100, 70, scale=0.1)
    spec = PhotonicSpec(quantize_operands=False, quantize_readout=False)
    got = photonic_matmul(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ideal_matmul(x, w)), atol=1e-4)


def test_quantized_error_small_but_nonzero():
    rng = np.random.default_rng(11)
    x = _rand(rng, 37, 192)
    w = _rand(rng, 192, 192, scale=0.08)
    out = photonic_matmul_ref(x, w, PhotonicSpec())
    ideal = ideal_matmul(x, w)
    rel = float(jnp.sqrt(jnp.mean((out - ideal) ** 2)) / jnp.std(ideal))
    assert 0.0 < rel < 0.05, f"rel rmse {rel}"


def test_crosstalk_degrades_with_lower_q():
    # Lower Q -> broader resonances -> more inter-channel leakage -> larger
    # deviation from the ideal product (the §IV resolution story).
    rng = np.random.default_rng(13)
    x = _rand(rng, 16, 64)
    w = _rand(rng, 64, 64, scale=0.1)
    ideal = ideal_matmul(x, w)

    def err(q):
        spec = PhotonicSpec(crosstalk=crosstalk_matrix(q_factor=q))
        out = photonic_matmul_ref(x, w, spec)
        return float(jnp.sqrt(jnp.mean((out - ideal) ** 2)))

    assert err(1000) > err(5000) > 0


def test_crosstalk_matrix_properties():
    m = crosstalk_matrix()
    assert m.shape == (32, 32)
    np.testing.assert_allclose(np.diag(m), 1.0)
    assert np.all(m >= 0) and np.all(m[~np.eye(32, dtype=bool)] < 0.01)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=20),
    st.integers(min_value=1, max_value=100),
    st.integers(min_value=1, max_value=100),
)
def test_hypothesis_shapes(m, k, n):
    rng = np.random.default_rng(m + 31 * k + 977 * n)
    x = _rand(rng, m, k)
    w = _rand(rng, k, n, scale=0.2)
    spec = PhotonicSpec()
    got = photonic_matmul(x, w, spec)
    want = photonic_matmul_ref(x, w, spec)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# decomposed attention vs direct oracle (Eq. 2 identity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,dk", [(13, 192, 64), (37, 192, 64), (5, 128, 64)])
def test_decomposed_attention_identity(n, d, dk):
    rng = np.random.default_rng(n + d)
    q = _rand(rng, n, dk)
    w_k = _rand(rng, d, dk, scale=0.05)
    x = _rand(rng, n, d)
    v = _rand(rng, n, dk)
    got = decomposed_attention_head(q, w_k, x, v)
    want = attention_head_ref(q, w_k, x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_decomposed_attention_respects_mask():
    rng = np.random.default_rng(3)
    n, d, dk = 9, 128, 64
    q = _rand(rng, n, dk)
    w_k = _rand(rng, d, dk, scale=0.05)
    x = _rand(rng, n, d)
    v = _rand(rng, n, dk)
    valid = jnp.asarray([1, 1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    got = decomposed_attention_head(q, w_k, x, v, valid)
    want = attention_head_ref(q, w_k, x, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    # Changing a masked key/value must not change the output rows.
    v2 = v.at[6].set(99.0)
    x2 = x.at[6].set(-99.0)
    got2 = decomposed_attention_head(q, w_k, x2, v2, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2), atol=1e-3)


def test_softmax_rows_sum_via_uniform_v():
    # With V = all-ones, the attention output must be exactly 1 in every
    # coordinate (softmax rows sum to 1).
    rng = np.random.default_rng(5)
    n, d, dk = 7, 64, 32
    q = _rand(rng, n, dk)
    w_k = _rand(rng, d, dk, scale=0.05)
    x = _rand(rng, n, d)
    v = jnp.ones((n, dk), jnp.float32)
    got = decomposed_attention_head(q, w_k, x, v)
    np.testing.assert_allclose(np.asarray(got), 1.0, atol=1e-5)
