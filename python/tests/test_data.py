"""Synthetic-data generator tests (must mirror rust/src/sensor.rs)."""

import numpy as np

from compile import data as D


def test_classification_batch_shapes():
    rng = np.random.default_rng(0)
    xs, ys, ms = D.classification_batch(rng, 4, size=96, patch=16)
    assert xs.shape == (4, 36, 768)
    assert ys.shape == (4,) and ms.shape == (4, 36)
    assert xs.dtype == np.float32
    assert np.all((xs >= 0) & (xs <= 1))
    assert np.all((ys >= 0) & (ys < D.NUM_CLASSES))


def test_patchify_layout_matches_rust():
    # Channel-last within a patch: element 0..2 of patch 0 are the RGB of
    # pixel (0,0) — same as Frame::patchify in rust/src/sensor.rs.
    pixels = np.zeros((3, 32, 32), np.float32)
    pixels[0, 0, 0] = 0.1
    pixels[1, 0, 0] = 0.2
    pixels[2, 0, 0] = 0.3
    pixels[0, 0, 16] = 0.9  # first pixel of patch 1
    p = D.patchify(pixels, 16)
    assert p.shape == (4, 768)
    np.testing.assert_allclose(p[0, :3], [0.1, 0.2, 0.3])
    np.testing.assert_allclose(p[1, 0], 0.9)


def test_patch_labels_mark_overlaps():
    boxes = [(20, 20, 40, 40)]
    lab = D.patch_labels(boxes, 96, 16)
    side = 6
    assert lab[1 * side + 1] == 1.0 and lab[2 * side + 2] == 1.0
    assert lab[0] == 0.0
    assert 1 <= lab.sum() <= 16


def test_video_sequence_motion():
    rng = np.random.default_rng(1)
    seq = D.video_sequence(rng, 5, size=96)
    assert len(seq) == 5
    p0, _, _, _ = seq[0]
    p4, _, _, _ = seq[4]
    assert not np.allclose(p0, p4), "objects must move"


def test_scene_objects_stay_in_bounds():
    rng = np.random.default_rng(2)
    scene = D.Scene(96, 3, rng)
    for _ in range(100):
        scene.step()
        _, boxes, _ = scene.render(noise_sigma=0.0)
        for (x0, y0, x1, y1) in boxes:
            assert 0 <= x0 < x1 <= 96 and 0 <= y0 < y1 <= 96


def test_label_is_largest_object_class():
    rng = np.random.default_rng(3)
    scene = D.Scene(96, 3, rng)
    _, _, label = scene.render()
    largest = max(scene.objects, key=lambda o: o["half"])
    assert label == D.SHAPES.index(largest["shape"])
