"""AOT export: lower the L2 models to HLO-text artifacts for the rust runtime.

Interchange is **HLO text** — not ``lowered.compile()`` nor a serialized
``HloModuleProto``: jax ≥ 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser on the rust side reassigns ids (see /opt/xla-example/README.md).

Exports (artifact names are the contract with
``rust/src/coordinator/pipeline.rs``):

- ``mgnet_96``                    — MGNet region scorer, briefly trained on
                                    the synthetic moving-shapes workload.
- ``vit_tiny_96_n{9,18,27,36}``   — QAT backbone at each RoI bucket size,
                                    briefly trained on the same workload.
- ``vit_tiny_96_photonic_n36``    — backbone with every linear routed
                                    through the L1 pallas optical-core
                                    kernel (crosstalk + ADC readout).

Trained parameters are also saved to ``<out>/params_*.npz`` so the
Table I-III experiment analogues reuse them.

Usage: ``python -m compile.aot --out-dir ../artifacts [--no-train] [--quick]``
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import PhotonicSpec, crosstalk_matrix

BUCKETS_96 = (9, 18, 27, 36)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)/1e6:.2f} MB)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--no-train", action="store_true",
                    help="export with random weights (fast; serving metrics "
                    "like mask IoU become meaningless)")
    ap.add_argument("--quick", action="store_true",
                    help="shorter training (CI-sized)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()

    steps_mg = 0 if args.no_train else (120 if args.quick else 400)
    steps_bb = 0 if args.no_train else (120 if args.quick else 400)

    # ---------------- MGNet ----------------
    mg_cfg = M.mgnet_config(96)
    if steps_mg:
        print(f"training MGNet ({steps_mg} steps)...")
        mg_params = T.train_mgnet(mg_cfg, steps=steps_mg, seed=args.seed)
        miou = T.mgnet_miou(mg_params, mg_cfg)
        print(f"  MGNet mIoU vs GT masks: {miou:.3f}")
    else:
        mg_params = M.init_mgnet(jax.random.PRNGKey(args.seed), mg_cfg)
    M.save_params(os.path.join(args.out_dir, "params_mgnet_96.npz"), mg_params)

    patches_spec = jax.ShapeDtypeStruct((mg_cfg["num_patches"], mg_cfg["patch_dim"]), jnp.float32)
    export(M.make_mgnet_fn(mg_params, mg_cfg, mode="quant"), (patches_spec,),
           os.path.join(args.out_dir, "mgnet_96.hlo.txt"))

    # ---------------- Backbone (tiny @ 96) ----------------
    bb_cfg = M.vit_config("tiny", 96, 10)
    if steps_bb:
        print(f"training ViT-Tiny backbone ({steps_bb} steps, QAT)...")
        bb_params = T.train_backbone(bb_cfg, steps=steps_bb, seed=args.seed)
        acc = T.backbone_accuracy(bb_params, bb_cfg, frames=64)
        print(f"  backbone top-1 (synthetic shapes): {acc:.3f}")
    else:
        bb_params = M.init_vit(jax.random.PRNGKey(args.seed + 1), bb_cfg)
    M.save_params(os.path.join(args.out_dir, "params_vit_tiny_96.npz"), bb_params)

    for bucket in BUCKETS_96:
        specs = (
            jax.ShapeDtypeStruct((bucket, bb_cfg["patch_dim"]), jnp.float32),
            jax.ShapeDtypeStruct((bucket,), jnp.float32),
            jax.ShapeDtypeStruct((bucket,), jnp.float32),
        )
        export(M.make_backbone_fn(bb_params, bb_cfg, mode="quant"), specs,
               os.path.join(args.out_dir, f"vit_tiny_96_n{bucket}.hlo.txt"))

    # ---------------- Photonic-kernel flavor (full bucket) ----------------
    spec = PhotonicSpec(crosstalk=crosstalk_matrix())
    full = bb_cfg["num_patches"]
    specs = (
        jax.ShapeDtypeStruct((full, bb_cfg["patch_dim"]), jnp.float32),
        jax.ShapeDtypeStruct((full,), jnp.float32),
        jax.ShapeDtypeStruct((full,), jnp.float32),
    )
    export(M.make_backbone_fn(bb_params, bb_cfg, mode="photonic", spec=spec), specs,
           os.path.join(args.out_dir, f"vit_tiny_96_photonic_n{full}.hlo.txt"))

    print(f"artifacts complete in {time.time()-t0:.0f}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
