"""L2: the Opto-ViT JAX models — ViT backbone (T/S/B/L) and MGNet.

Pure-jax pytrees (no flax): params are nested dicts, forwards are plain
functions, so `jax.jit(...).lower()` produces one fused HLO per variant for
the rust runtime. The backbone consumes a *pruned* patch sequence —
`(n_kept, p*p*3)` patches + positional indices + validity mask — the RoI
contract with the L3 coordinator (masked patches never reach the model,
giving the paper's linear compute savings).

Three numerics modes:
- ``mode="fp32"``  — full-precision reference (Table I left columns).
- ``mode="quant"`` — 8-bit QAT fake-quant on weights & activations of the
  patch-embedding, MHSA and FFN modules (the paper's quantization scope).
- ``mode="photonic"`` — linear layers routed through the L1 pallas kernel
  (chunked WDM matmul with ADC readout quantization and optional
  crosstalk) — the full optical-core emulation.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import PhotonicSpec, photonic_matmul
from .quant import fake_quant

# ---------------------------------------------------------------------------
# Configs (must mirror rust/src/vit/config.rs)
# ---------------------------------------------------------------------------

VIT_VARIANTS = {
    "tiny": dict(embed_dim=192, num_heads=3, depth=12),
    "small": dict(embed_dim=384, num_heads=6, depth=12),
    "base": dict(embed_dim=768, num_heads=12, depth=12),
    "large": dict(embed_dim=1024, num_heads=16, depth=24),
}


def vit_config(variant, image_size, num_classes, patch_size=16, mlp_ratio=4, depth=None):
    v = dict(VIT_VARIANTS[variant])
    if depth is not None:
        v["depth"] = depth
    n_side = image_size // patch_size
    return dict(
        variant=variant,
        image_size=image_size,
        patch_size=patch_size,
        num_classes=num_classes,
        mlp_ratio=mlp_ratio,
        num_patches=n_side * n_side,
        patch_dim=patch_size * patch_size * 3,
        **v,
    )


def mgnet_config(image_size, embed_dim=192, num_heads=3, patch_size=16):
    """MGNet (§IV): one transformer block + cls-attention scorer + linear
    per-patch logits. embed 192/heads 3 for classification; 384/6 for
    detection."""
    n_side = image_size // patch_size
    return dict(
        image_size=image_size,
        patch_size=patch_size,
        embed_dim=embed_dim,
        num_heads=num_heads,
        num_patches=n_side * n_side,
        patch_dim=patch_size * patch_size * 3,
        mlp_ratio=4,
        depth=1,
    )


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    w = jax.random.normal(key, (fan_in, fan_out), jnp.float32) * (2.0 / (fan_in + fan_out)) ** 0.5
    return {"w": w, "b": jnp.zeros((fan_out,), jnp.float32)}


def _block_init(key, d, mlp_ratio):
    ks = jax.random.split(key, 6)
    return {
        "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "qkv": _dense_init(ks[0], d, 3 * d),
        "proj": _dense_init(ks[1], d, d),
        "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "fc1": _dense_init(ks[2], d, mlp_ratio * d),
        "fc2": _dense_init(ks[3], mlp_ratio * d, d),
    }


def init_vit(key, cfg):
    """Initialize a ViT parameter pytree."""
    d = cfg["embed_dim"]
    ks = jax.random.split(key, cfg["depth"] + 4)
    return {
        "embed": _dense_init(ks[0], cfg["patch_dim"], d),
        "cls": jax.random.normal(ks[1], (1, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[2], (cfg["num_patches"] + 1, d), jnp.float32) * 0.02,
        "blocks": [_block_init(ks[3 + i], d, cfg["mlp_ratio"]) for i in range(cfg["depth"])],
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "head": _dense_init(ks[-1], d, cfg["num_classes"]),
    }


def init_mgnet(key, cfg):
    """MGNet params: a 1-block ViT trunk + per-patch score head (Eq. 3)."""
    d = cfg["embed_dim"]
    ks = jax.random.split(key, 7)
    return {
        "embed": _dense_init(ks[0], cfg["patch_dim"], d),
        "cls": jax.random.normal(ks[1], (1, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[2], (cfg["num_patches"] + 1, d), jnp.float32) * 0.02,
        "block": _block_init(ks[3], d, cfg["mlp_ratio"]),
        # the extra self-attention scoring layer: its own W_Q / W_K
        "score_q": _dense_init(ks[4], d, d),
        "score_k": _dense_init(ks[5], d, d),
        # linear projection from cls-attention scores to per-patch logits
        "region": _dense_init(ks[6], cfg["num_patches"], cfg["num_patches"]),
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layernorm(x, p, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def _matmul(x, w, mode, spec):
    if mode == "photonic":
        return photonic_matmul(x, w, spec)
    if mode == "quant":
        return fake_quant(x, spec.bits) @ fake_quant(w, spec.bits)
    return x @ w


def _dense(x, p, mode, spec):
    return _matmul(x, p["w"], mode, spec) + p["b"]


def _attention(x, p, num_heads, valid, mode, spec):
    """MHSA over a (n, d) sequence with a key-side validity mask."""
    n, d = x.shape
    dk = d // num_heads
    qkv = _dense(x, p["qkv"], mode, spec)  # (n, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(n, num_heads, dk).transpose(1, 0, 2)  # (h, n, dk)
    k = k.reshape(n, num_heads, dk).transpose(1, 0, 2)
    v = v.reshape(n, num_heads, dk).transpose(1, 0, 2)
    s = jnp.einsum("hnd,hmd->hnm", q, k) / jnp.sqrt(jnp.asarray(dk, x.dtype))
    s = s + (1.0 - valid)[None, None, :] * -1e9
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hnm,hmd->hnd", p_attn, v)  # (h, n, dk)
    o = o.transpose(1, 0, 2).reshape(n, d)
    return _dense(o, p["proj"], mode, spec)


def _encoder_block(x, p, num_heads, valid, mode, spec):
    x = x + _attention(_layernorm(x, p["ln1"]), p, num_heads, valid, mode, spec)
    h = _dense(_layernorm(x, p["ln2"]), p["fc1"], mode, spec)
    h = jax.nn.gelu(h)
    return x + _dense(h, p["fc2"], mode, spec)


def vit_forward(params, cfg, patches, pos_idx, valid, mode="quant",
                spec: PhotonicSpec = PhotonicSpec()):
    """Backbone forward on a pruned patch sequence.

    patches: (n_kept, patch_dim) — RoI-surviving patches only.
    pos_idx: (n_kept,) float — original patch indices (for pos-embedding).
    valid:   (n_kept,) float — 1 for real patches, 0 for bucket padding.
    Returns logits (num_classes,).
    """
    tok = _dense(patches, params["embed"], mode, spec)  # (n_kept, d)
    pos = jnp.take(params["pos"], pos_idx.astype(jnp.int32) + 1, axis=0)
    tok = tok + pos
    cls = params["cls"] + params["pos"][0:1]
    x = jnp.concatenate([cls, tok], axis=0)  # (1 + n_kept, d)
    v = jnp.concatenate([jnp.ones((1,), valid.dtype), valid])
    # Zero padded token embeddings so they carry no content even pre-mask.
    x = x * v[:, None]
    for blk in params["blocks"]:
        x = _encoder_block(x, blk, cfg["num_heads"], v, mode, spec)
    x = _layernorm(x, params["ln_f"])
    if cfg.get("readout", "mean") == "cls":
        pooled = x[0:1]
    else:
        # Masked mean-pool over valid tokens: the readout that trains from
        # scratch in a few hundred steps (cls-token readout needs the
        # ImageNet-21k pretraining the paper starts from, which the offline
        # substitution cannot — see DESIGN.md §Deviations).
        pooled = jnp.sum(x * v[:, None], axis=0, keepdims=True) / jnp.sum(v)
    return _dense(pooled, params["head"], mode, spec)[0]


def mgnet_forward(params, cfg, patches, mode="quant",
                  spec: PhotonicSpec = PhotonicSpec()):
    """MGNet forward: full-frame patches -> per-patch region logits.

    Implements §IV exactly: one encoder block, then the cls-attention score
    ``S_cls = q_class K^T / sqrt(d)`` (Eq. 3), then a linear layer mapping
    the n attention scores to n per-patch logits. Thresholding happens in
    the coordinator (rust) so `t_reg` stays a serving-time knob.
    """
    n = cfg["num_patches"]
    tok = _dense(patches, params["embed"], mode, spec)
    tok = tok + params["pos"][1:]
    cls = params["cls"] + params["pos"][0:1]
    x = jnp.concatenate([cls, tok], axis=0)
    valid = jnp.ones((n + 1,), x.dtype)
    x = _encoder_block(x, params["block"], cfg["num_heads"], valid, mode, spec)
    x = _layernorm(x, params["ln_f"])
    # Eq. 3: q from the cls token, K from the patch tokens.
    q_cls = _dense(x[0:1], params["score_q"], mode, spec)  # (1, d)
    k_pat = _dense(x[1:], params["score_k"], mode, spec)  # (n, d)
    s_cls = (q_cls @ k_pat.T)[0] / jnp.sqrt(jnp.asarray(cfg["embed_dim"], x.dtype))
    # Linear projection to region scores (output dim = num patches).
    return s_cls @ params["region"]["w"] + params["region"]["b"]


# ---------------------------------------------------------------------------
# Export entry points (closed over trained/initialized params by aot.py)
# ---------------------------------------------------------------------------


def make_backbone_fn(params, cfg, mode="quant", spec=None):
    """Returns f(patches, pos_idx, valid) -> (logits,) for jit/lowering."""
    spec = spec or PhotonicSpec()

    def fn(patches, pos_idx, valid):
        return (vit_forward(params, cfg, patches, pos_idx, valid, mode, spec),)

    return fn


def make_mgnet_fn(params, cfg, mode="quant", spec=None):
    """Returns f(patches) -> (scores,) for jit/lowering."""
    spec = spec or PhotonicSpec()

    def fn(patches):
        return (mgnet_forward(params, cfg, patches, mode, spec),)

    return fn


# ---------------------------------------------------------------------------
# Parameter (de)serialization — flat .npz so experiments can reload
# ---------------------------------------------------------------------------


def flatten_params(params, prefix=""):
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            out.update(flatten_params(v, f"{prefix}{k}/"))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            out.update(flatten_params(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def save_params(path, params):
    np.savez(path, **flatten_params(params))


def load_params(path, template):
    """Reload params into the same pytree structure as `template`."""
    flat = dict(np.load(path))

    def rebuild(t, prefix=""):
        if isinstance(t, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            return [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(t)]
        return jnp.asarray(flat[prefix[:-1]])

    return rebuild(template)
