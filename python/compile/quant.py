"""Symmetric uniform quantization with straight-through-estimator QAT.

Paper §IV "Accuracy Analysis": 8-bit symmetric uniform quantization [45] of
weights and activations, quantization-aware training [43] with the STE [44],
and dynamic (max-abs) range calibration. Mirrors ``rust/src/quant.rs``.
"""

from functools import partial

import jax
import jax.numpy as jnp


@jax.custom_vjp
def ste_round(x):
    """round() whose gradient is identity (straight-through estimator)."""
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_fwd, _ste_bwd)


def qmax(bits: int) -> int:
    """Largest positive integer level of a symmetric ``bits``-bit grid."""
    return (1 << (bits - 1)) - 1


def calibrate_scale(x, bits: int = 8, eps: float = 1e-8):
    """Max-abs (dynamic) scale: ``real = scale * int``."""
    m = jnp.max(jnp.abs(x))
    return jnp.maximum(m, eps) / qmax(bits)


@partial(jax.jit, static_argnames=("bits",))
def fake_quant(x, bits: int = 8):
    """Quantize-dequantize with per-tensor dynamic scale and STE gradient.

    This is the QAT forward used in training and the exact numeric applied
    at inference (the photonic weight banks / ADC / DAC all operate on the
    same 8-bit grid).
    """
    scale = calibrate_scale(x, bits)
    q = jnp.clip(ste_round(x / scale), -qmax(bits), qmax(bits))
    return q * scale


def fake_quant_fixed(x, scale, bits: int = 8):
    """Quantize-dequantize with an externally supplied scale (e.g. the ADC
    full-scale range of a BPD readout chain)."""
    q = jnp.clip(ste_round(x / scale), -qmax(bits), qmax(bits))
    return q * scale


def quant_error_bound(x, bits: int = 8):
    """Worst-case |fake_quant(x) - x| = scale / 2 (half an LSB)."""
    return calibrate_scale(x, bits) / 2.0
