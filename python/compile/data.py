"""Synthetic vision workloads (python mirror of ``rust/src/sensor.rs``).

Scenes of moving geometric shapes over low-frequency backgrounds with exact
ground-truth boxes. Used at build time to (briefly) train MGNet and the
QAT backbone, and by the Table I-III experiment analogues. The distribution
matches the rust sensor (same shape vocabulary, size ranges, noise level),
so weights trained here are meaningful for frames generated there.
"""

import numpy as np

SHAPES = ("square", "disc", "cross")
NUM_CLASSES = len(SHAPES)


def _cover_mask(shape, size, cx, cy, half):
    """Boolean (size, size) coverage mask for one object."""
    yy, xx = np.mgrid[0:size, 0:size]
    dx = xx - cx
    dy = yy - cy
    if shape == "square":
        return (np.abs(dx) <= half) & (np.abs(dy) <= half)
    if shape == "disc":
        return dx * dx + dy * dy <= half * half
    # cross
    return ((np.abs(dx) <= half / 3.0) & (np.abs(dy) <= half)) | (
        (np.abs(dy) <= half / 3.0) & (np.abs(dx) <= half)
    )


class Scene:
    """One scene of moving objects; renders frames with ground truth."""

    def __init__(self, size, num_objects, rng):
        self.size = size
        self.rng = rng
        self.objects = []
        for _ in range(num_objects):
            half = rng.uniform(size * 0.12, size * 0.24)
            shape_idx = int(rng.integers(0, 3))
            # Class-correlated hue + jitter (mirrors rust/src/sensor.rs):
            # each class has a dominant channel, so the classification task
            # carries both shape and color cues — learnable within the
            # few-hundred-step build-time budget (DESIGN.md §Deviations).
            color = rng.uniform(0.05, 0.35, size=3).astype(np.float32)
            color[shape_idx] = rng.uniform(0.7, 1.0)
            self.objects.append(
                dict(
                    shape=SHAPES[shape_idx],
                    cx=rng.uniform(half, size - half),
                    cy=rng.uniform(half, size - half),
                    half=half,
                    vx=rng.uniform(-2.5, 2.5),
                    vy=rng.uniform(-2.5, 2.5),
                    color=color,
                )
            )
        gx, gy = rng.uniform(0.0, 0.15, size=2)
        yy, xx = np.mgrid[0:size, 0:size]
        bg = (0.1 + gx * xx / size + gy * yy / size).astype(np.float32)
        self.background = np.stack([bg, bg, bg])  # (3, H, W)

    def step(self):
        """Advance the physics one frame (ballistic motion, edge bounce)."""
        s = self.size
        for o in self.objects:
            o["cx"] += o["vx"]
            o["cy"] += o["vy"]
            if not (o["half"] <= o["cx"] <= s - o["half"]):
                o["vx"] = -o["vx"]
                o["cx"] = np.clip(o["cx"], o["half"], s - o["half"])
            if not (o["half"] <= o["cy"] <= s - o["half"]):
                o["vy"] = -o["vy"]
                o["cy"] = np.clip(o["cy"], o["half"], s - o["half"])

    def render(self, noise_sigma=0.01):
        """Render the current state.

        Returns ``(pixels (3,H,W) float32, boxes [(x0,y0,x1,y1)], label)``
        where ``label`` is the class of the largest object (as in the rust
        sensor).
        """
        s = self.size
        pixels = self.background.copy()
        boxes = []
        for o in self.objects:
            m = _cover_mask(o["shape"], s, o["cx"], o["cy"], o["half"])
            for c in range(3):
                pixels[c][m] = o["color"][c]
            x0 = int(max(o["cx"] - o["half"], 0))
            y0 = int(max(o["cy"] - o["half"], 0))
            x1 = int(min(o["cx"] + o["half"], s - 1))
            y1 = int(min(o["cy"] + o["half"], s - 1))
            boxes.append((x0, y0, max(x1, x0 + 1), max(y1, y0 + 1)))
        if noise_sigma > 0:
            pixels = pixels + self.rng.normal(0.0, noise_sigma, pixels.shape).astype(
                np.float32
            )
        pixels = np.clip(pixels, 0.0, 1.0).astype(np.float32)
        largest = max(self.objects, key=lambda o: o["half"])
        label = SHAPES.index(largest["shape"])
        return pixels, boxes, label


def patchify(pixels, patch):
    """(3,H,W) -> (n_patches, patch*patch*3), channels-last within a patch
    (must match ``Frame::patchify`` in rust/src/sensor.rs)."""
    _, h, w = pixels.shape
    side = h // patch
    # (3, side, p, side, p) -> (side, side, p, p, 3)
    x = pixels.reshape(3, side, patch, side, patch)
    x = x.transpose(1, 3, 2, 4, 0)
    return x.reshape(side * side, patch * patch * 3)


def patch_labels(boxes, size, patch):
    """Binary per-patch labels: 1 if the patch overlaps any box (the paper's
    MGNet ground-truth rule)."""
    side = size // patch
    lab = np.zeros(side * side, dtype=np.float32)
    for (x0, y0, x1, y1) in boxes:
        px0, py0 = x0 // patch, y0 // patch
        px1 = min((x1 - 1) // patch, side - 1)
        py1 = min((y1 - 1) // patch, side - 1)
        for py in range(py0, py1 + 1):
            for px in range(px0, px1 + 1):
                lab[py * side + px] = 1.0
    return lab


def classification_batch(rng, batch, size=96, patch=16, num_objects=1):
    """A batch for classification training.

    Returns ``patches (B, n, p*p*3)``, ``labels (B,)`` int, and patch-level
    masks ``(B, n)``.
    """
    xs, ys, ms = [], [], []
    for _ in range(batch):
        scene = Scene(size, num_objects, rng)
        scene.step()
        pixels, boxes, label = scene.render()
        xs.append(patchify(pixels, patch))
        ys.append(label)
        ms.append(patch_labels(boxes, size, patch))
    return np.stack(xs), np.array(ys, dtype=np.int32), np.stack(ms)


def video_sequence(rng, frames, size=96, patch=16, num_objects=2):
    """A video sequence: list of (patches, boxes, patch_labels, label)."""
    scene = Scene(size, num_objects, rng)
    out = []
    for _ in range(frames):
        scene.step()
        pixels, boxes, label = scene.render()
        out.append(
            (patchify(pixels, patch), boxes, patch_labels(boxes, size, patch), label)
        )
    return out
