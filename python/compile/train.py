"""Hand-rolled training utilities (optax is unavailable offline).

Provides Adam, the QAT losses, and short build-time training loops for
MGNet (BCE on box-derived patch labels — the paper's §IV recipe) and the
classification backbone (cross-entropy with QAT fake-quant in the forward).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def bce_with_logits(logits, labels):
    """Binary cross-entropy on logits (MGNet's region loss, §IV)."""
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def softmax_xent(logits, label):
    logz = jax.nn.logsumexp(logits)
    return logz - logits[label]


# ---------------------------------------------------------------------------
# MGNet training (build-time; a few hundred steps suffice on the synthetic
# moving-shapes distribution)
# ---------------------------------------------------------------------------


def train_mgnet(cfg, steps=300, batch=8, lr=1e-3, seed=0, mode="quant", verbose=True):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = M.init_mgnet(key, cfg)

    def loss_fn(p, xs, labs):
        def one(x, lab):
            return bce_with_logits(M.mgnet_forward(p, cfg, x, mode=mode), lab)

        return jnp.mean(jax.vmap(one)(xs, labs))

    @jax.jit
    def step(p, opt, xs, labs):
        l, g = jax.value_and_grad(loss_fn)(p, xs, labs)
        p, opt = adam_step(p, g, opt, lr=lr)
        return p, opt, l

    opt = adam_init(params)
    t0 = time.time()
    for i in range(steps):
        xs, _, masks = D.classification_batch(
            rng, batch, size=cfg["image_size"], patch=cfg["patch_size"],
            num_objects=int(rng.integers(1, 4)))
        params, opt, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(masks))
        if verbose and (i % 50 == 0 or i == steps - 1):
            print(f"  mgnet step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    return params


def mgnet_miou(params, cfg, frames=64, threshold=0.5, seed=1, mode="quant"):
    """Mask quality: mean IoU of thresholded scores vs GT patch labels."""
    rng = np.random.default_rng(seed)
    fwd = jax.jit(lambda x: M.mgnet_forward(params, cfg, x, mode=mode))
    ious = []
    for _ in range(frames):
        xs, _, masks = D.classification_batch(
            rng, 1, size=cfg["image_size"], patch=cfg["patch_size"],
            num_objects=int(rng.integers(1, 4)))
        scores = np.asarray(fwd(jnp.asarray(xs[0])))
        pred = 1.0 / (1.0 + np.exp(-scores)) > threshold
        gt = masks[0] > 0.5
        inter = np.logical_and(pred, gt).sum()
        union = np.logical_or(pred, gt).sum()
        ious.append(1.0 if union == 0 else inter / union)
    return float(np.mean(ious))


# ---------------------------------------------------------------------------
# Backbone training (classification on the synthetic shapes distribution)
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads, max_norm=1.0):
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def train_backbone(cfg, steps=300, batch=16, lr=1e-3, seed=0, mode="quant",
                   verbose=True, warmup=30, num_objects=1):
    """From-scratch QAT training: linear warmup + global-norm clipping +
    mean-pool readout (see model.vit_forward) — the recipe that converges
    within a few hundred CPU steps on the synthetic workload."""
    rng = np.random.default_rng(seed + 100)
    key = jax.random.PRNGKey(seed + 100)
    params = M.init_vit(key, cfg)
    n = cfg["num_patches"]
    pos_idx = jnp.arange(n, dtype=jnp.float32)
    valid = jnp.ones((n,), jnp.float32)

    def loss_fn(p, xs, ys):
        def one(x, y):
            logits = M.vit_forward(p, cfg, x, pos_idx, valid, mode=mode)
            return softmax_xent(logits, y)

        return jnp.mean(jax.vmap(one)(xs, ys))

    @jax.jit
    def step(p, opt, xs, ys, lr_t):
        l, g = jax.value_and_grad(loss_fn)(p, xs, ys)
        g = clip_by_global_norm(g)
        p, opt = adam_step(p, g, opt, lr=lr_t)
        return p, opt, l

    opt = adam_init(params)
    t0 = time.time()
    for i in range(steps):
        lr_t = lr * min(1.0, (i + 1) / warmup)
        xs, ys, _ = D.classification_batch(
            rng, batch, size=cfg["image_size"], patch=cfg["patch_size"],
            num_objects=num_objects if isinstance(num_objects, int) else int(rng.integers(*num_objects)))
        params, opt, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(ys), lr_t)
        if verbose and (i % 50 == 0 or i == steps - 1):
            print(f"  backbone step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    return params


def backbone_accuracy(params, cfg, frames=128, seed=7, mode="quant", keep_mask=None,
                      num_objects=1):
    """Top-1 accuracy on held-out synthetic frames. `keep_mask` optionally
    simulates RoI pruning: a callable (patch_labels -> kept bool array)."""
    rng = np.random.default_rng(seed)
    n = cfg["num_patches"]

    fwd = jax.jit(lambda x, pi, v: M.vit_forward(params, cfg, x, pi, v, mode=mode))
    correct = 0
    for _ in range(frames):
        xs, ys, masks = D.classification_batch(
            rng, 1, size=cfg["image_size"], patch=cfg["patch_size"],
            num_objects=num_objects if isinstance(num_objects, int) else int(rng.integers(*num_objects)))
        x = xs[0]
        if keep_mask is not None:
            kept = keep_mask(masks[0])
            idx = np.flatnonzero(kept)
            if len(idx) == 0:
                idx = np.array([int(np.argmax(masks[0]))])
            xk = np.zeros_like(x)
            pi = np.zeros((n,), np.float32)
            v = np.zeros((n,), np.float32)
            xk[: len(idx)] = x[idx]
            pi[: len(idx)] = idx
            v[: len(idx)] = 1.0
            x, pos, val = xk, pi, v
        else:
            pos = np.arange(n, dtype=np.float32)
            val = np.ones((n,), np.float32)
        logits = np.asarray(fwd(jnp.asarray(x), jnp.asarray(pos), jnp.asarray(val)))
        correct += int(np.argmax(logits) == ys[0])
    return correct / frames
