"""Pure-jnp correctness oracles for the L1 kernels.

Each oracle re-implements the kernel semantics with explicit python loops /
dense jnp ops — no pallas — so pytest can assert the kernels bit-match their
specification, and so accuracy experiments can run the same physics without
the pallas interpreter overhead.
"""

import jax.numpy as jnp
import numpy as np

from ..quant import fake_quant, fake_quant_fixed
from .photonic_matmul import ARMS, WAVELENGTHS, PhotonicSpec, _adc_scale


def ideal_matmul(x, w):
    """The mathematical ground truth (fp32 ``x @ w``)."""
    return x @ w


def photonic_matmul_ref(x, w, spec: PhotonicSpec = PhotonicSpec()):
    """Chunked WDM matmul oracle: identical physics to the pallas kernel,
    expressed as an explicit loop over k-chunks and column tiles."""
    m, k = x.shape
    _, n = w.shape
    if spec.quantize_operands:
        x = fake_quant(x, spec.bits)
        w = fake_quant(w, spec.bits)
    x_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / ((1 << (spec.bits - 1)) - 1)
    w_scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / ((1 << (spec.bits - 1)) - 1)
    adc = _adc_scale(x_scale, w_scale, spec.bits)

    mix = spec.crosstalk if spec.crosstalk is not None else np.eye(WAVELENGTHS, dtype=np.float32)
    mix = jnp.asarray(mix, dtype=x.dtype)

    kp = -(-k // WAVELENGTHS) * WAVELENGTHS
    np_ = -(-n // ARMS) * ARMS
    xq = jnp.zeros((m, kp), x.dtype).at[:, :k].set(x)
    wq = jnp.zeros((kp, np_), w.dtype).at[:k, :n].set(w)

    out = jnp.zeros((m, np_), x.dtype)
    for kc in range(kp // WAVELENGTHS):
        xc = xq[:, kc * WAVELENGTHS:(kc + 1) * WAVELENGTHS]
        xe = xc @ mix.T  # wavelength crosstalk
        for ct in range(np_ // ARMS):
            wc = wq[kc * WAVELENGTHS:(kc + 1) * WAVELENGTHS, ct * ARMS:(ct + 1) * ARMS]
            partial = xe @ wc  # per-arm BPD accumulation
            if spec.quantize_readout:
                partial = fake_quant_fixed(partial, adc, spec.bits)  # ADC
            out = out.at[:, ct * ARMS:(ct + 1) * ARMS].add(partial)
    return out[:, :n]


def attention_head_ref(q, w_k, x, v, valid=None):
    """Direct-flow attention oracle for one head (fp32):
    ``K = X @ W_k``; ``S = Q K^T / sqrt(dk)``; ``P = softmax(S)``;
    ``O = P V``. The decomposed kernel must match this exactly — Eq. 2 is
    an algebraic identity.
    """
    dk = q.shape[-1]
    k_mat = x @ w_k
    s = (q @ k_mat.T) / jnp.sqrt(jnp.asarray(dk, q.dtype))
    if valid is not None:
        s = s + (1.0 - valid)[None, :] * -1e9
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v
