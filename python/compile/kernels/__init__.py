"""Layer-1 Pallas kernels emulating the Opto-ViT optical core."""

from .attention import decomposed_attention_head  # noqa: F401
from .photonic_matmul import PhotonicSpec, crosstalk_matrix, photonic_matmul  # noqa: F401
