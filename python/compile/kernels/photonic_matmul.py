"""L1 Pallas kernel: the photonic WDM matrix-multiply core (Fig. 4 / Fig. 6).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's 32-VCSEL ×
64-arm chunked VVM maps onto a Pallas grid over (row-tile, col-tile, k-chunk)
with a 32×64 weight block resident per step — the MR bank — and an f32
accumulator standing in for the per-arm BPD charge. The physical effects are
carried along:

- **DAC quantization** of activations and weights (8-bit symmetric) happens
  *outside* the kernel (the wrapper), like the real DACs ahead of the
  VCSELs/tuning circuits.
- **Wavelength crosstalk**: each 32-wide input chunk is mixed by the 32×32
  matrix ``M`` (``M[i][j] = phi(i,j)``, the same operator as
  ``rust/src/photonics/crosstalk.rs``) before meeting the weights.
- **ADC quantization** of each 64-wide chunk partial sum (the per-cycle BPD
  readout) with a fixed full-scale, then exact digital accumulation.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ``ref.py``.
"""

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..quant import fake_quant, fake_quant_fixed

# Optical core geometry (paper §III): 32 wavelength channels × 64 arms.
WAVELENGTHS = 32
ARMS = 64


@dataclass(frozen=True)
class PhotonicSpec:
    """Physical-effect configuration for the emulated optical core."""

    #: bit width of the DAC/weight-bank/ADC grids
    bits: int = 8
    #: quantize operands (DAC) before the optical product
    quantize_operands: bool = True
    #: quantize each chunk partial sum (ADC readout). The full-scale is
    #: sized for worst-case int8 dot products over a 32-chunk.
    quantize_readout: bool = True
    #: 32×32 crosstalk mixing matrix (None = ideal optics). Build one with
    #: :func:`crosstalk_matrix`.
    crosstalk: Optional[np.ndarray] = None


def crosstalk_matrix(q_factor: float = 5000.0, spacing_nm: float = 1.2,
                     center_nm: float = 1550.0, n: int = WAVELENGTHS) -> np.ndarray:
    """The WDM crosstalk operator: ``M[i][j] = phi(i,j)``, ``M[i][i] = 1``.

    Must match ``CrosstalkModel::mixing_matrix`` in
    ``rust/src/photonics/crosstalk.rs`` (squared-Lorentzian kernel, C-band
    plan). The kernel applies ``x_chunk @ M.T`` so that output channel i
    collects ``sum_j phi(i,j) x_j``.
    """
    lam = center_nm + spacing_nm * (np.arange(n) - (n - 1) / 2.0)
    delta = lam / (2.0 * q_factor)
    m = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i == j:
                m[i, j] = 1.0
            else:
                l1 = delta[i] ** 2 / ((lam[i] - lam[j]) ** 2 + delta[i] ** 2)
                m[i, j] = l1 * l1
    return m.astype(np.float32)


def _adc_scale(x_scale, w_scale, bits):
    """ADC full-scale for a 32-element chunk dot product, sized at 1/16 of
    the absolute worst case — the programmable-gain operating point that
    minimizes quantization+clipping error for zero-mean activations (the
    full-scale sweep lives in EXPERIMENTS.md; Opto-ViT calibrates the BPD
    TIA gain per tensor the same way)."""
    qm = (1 << (bits - 1)) - 1
    worst = WAVELENGTHS * (qm * x_scale) * (qm * w_scale)
    return worst / 16.0 / qm


def _kernel(x_ref, w_ref, mix_ref, scale_ref, o_ref, *, bits, quantize_readout):
    """Pallas body: one (row-tile × 64-col) output block accumulated over
    k-chunks. Grid = (m_tiles, n_tiles, k_chunks); k is the innermost,
    sequential dimension, mirroring the per-cycle chunk schedule."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Wavelength mixing: channel i of the effective input collects
    # phi(i, j) * x_j  (M.T multiply; M == I for ideal optics).
    xe = x_ref[...] @ mix_ref[...].T
    partial = xe @ w_ref[...]
    if quantize_readout:
        partial = fake_quant_fixed(partial, scale_ref[0, 0], bits)
    o_ref[...] += partial


def photonic_matmul(x, w, spec: PhotonicSpec = PhotonicSpec(), row_tile: int = 8):
    """``x @ w`` through the emulated optical core.

    x: (m, k) activations; w: (k, n) weights. Shapes are padded to the
    32/64 chunk grid, exactly like the zero-padded slots of Fig. 6.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"

    if spec.quantize_operands:
        x = fake_quant(x, spec.bits)
        w = fake_quant(w, spec.bits)

    x_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / ((1 << (spec.bits - 1)) - 1)
    w_scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / ((1 << (spec.bits - 1)) - 1)
    adc = _adc_scale(x_scale, w_scale, spec.bits).reshape(1, 1)

    mix = spec.crosstalk if spec.crosstalk is not None else np.eye(WAVELENGTHS, dtype=np.float32)
    mix = jnp.asarray(mix, dtype=x.dtype)

    # Pad to the chunk grid.
    row_tile = min(row_tile, max(m, 1))
    mp = -(-m // row_tile) * row_tile
    kp = -(-k // WAVELENGTHS) * WAVELENGTHS
    np_ = -(-n // ARMS) * ARMS
    xq = jnp.zeros((mp, kp), x.dtype).at[:m, :k].set(x)
    wq = jnp.zeros((kp, np_), w.dtype).at[:k, :n].set(w)

    grid = (mp // row_tile, np_ // ARMS, kp // WAVELENGTHS)
    out = pl.pallas_call(
        partial(_kernel, bits=spec.bits, quantize_readout=spec.quantize_readout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, WAVELENGTHS), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((WAVELENGTHS, ARMS), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((WAVELENGTHS, WAVELENGTHS), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, ARMS), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xq, wq, mix, adc)
    return out[:m, :n]
