"""L1 Pallas kernel: decomposed attention head (Eq. 2 / Fig. 5 dataflow).

``Q·K^T = (Q·W_K^T)·X^T`` — all stationary operands (W_K^T, X^T) are known
at kernel start, so K is never materialized in HBM. The whole head runs as a
single VMEM-resident block (sequence lengths after RoI masking are small:
n ≤ 197), mirroring how the five-core pipeline keeps the head's operands
resident across C1..C5 without buffering intermediates.

The 1/sqrt(dk) scaling is folded into the stationary W_K^T operand before
the kernel — exactly the paper's trick of tuning the bank with
``W_K^T / sqrt(dk)`` to avoid a division step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _head_kernel(q_ref, wkt_ref, xt_ref, v_ref, valid_ref, o_ref):
    # C1 output Q streams in; C2: A1 = Q @ W_K^T (W_K^T pre-scaled).
    a1 = q_ref[...] @ wkt_ref[...]
    # C3: S = A1 @ X^T.
    s = a1 @ xt_ref[...]
    # Mask out padded (invalid) key slots before the softmax.
    s = s + (1.0 - valid_ref[...]) * -1e9
    # EPU: row softmax (numerically stabilized).
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    # C4/C5: O = P @ V.
    o_ref[...] = p @ v_ref[...]


def decomposed_attention_head(q, w_k, x, v, valid=None):
    """One attention head via the decomposed dataflow.

    q: (n, dk); w_k: (d, dk); x: (n, d); v: (n, dk); valid: (n,) 1/0 mask
    over key slots (None = all valid). Returns (n, dk).
    """
    n, dk = q.shape
    if valid is None:
        valid = jnp.ones((n,), q.dtype)
    # Fold the attention scale into the stationary operand (paper §III-B).
    wkt = (w_k / jnp.sqrt(jnp.asarray(dk, q.dtype))).T  # (dk, d)
    xt = x.T  # (d, n)
    valid_row = valid.reshape(1, n)
    return pl.pallas_call(
        _head_kernel,
        out_shape=jax.ShapeDtypeStruct((n, dk), q.dtype),
        interpret=True,
    )(q, wkt, xt, v, valid_row)
