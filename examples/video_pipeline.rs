//! End-to-end driver (EXPERIMENTS.md §E2E): serve a synthetic video stream
//! through the full three-layer stack — sensor thread → bounded queue →
//! MGNet → RoI mask → bucket router → ViT backbone — and report latency,
//! throughput, mask quality, accuracy, and the modeled accelerator energy,
//! with and without RoI masking. Serving goes through the **session API**:
//! a `Server` owns one pipeline (and one backend instance) per worker
//! thread, and this driver opens a single synthetic-sensor `Session` on it
//! (see `examples/multi_camera.rs` for many sessions sharing one server).
//!
//! The fourth argument picks the execution backend:
//! `pjrt` (default) runs the compiled HLO artifacts, `host` runs the
//! pure-Rust reference compute with no artifacts at all, and `sim` adds
//! modeled photonic-core latency on top of the host numerics. The fifth
//! argument sets the bucket-major micro-batch size (frames per
//! `execute_batch` dispatch; 1 = per-frame).
//!
//! ```bash
//! make artifacts   # only needed for the pjrt backend
//! cargo run --release --example video_pipeline -- [frames] [seed] [workers] [pjrt|host|sim] [batch]
//! ```

use std::time::Duration;

use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::EngineConfig;
use optovit::coordinator::pipeline::{Pipeline, PipelineConfig, ServeOptions};
use optovit::coordinator::server::{spawn_synthetic_sensor, Server, SessionOptions};
use optovit::runtime::{AnyFactory, BackendFactory, BackendKind};
use optovit::util::table::{si_energy, si_time, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let frames: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(42);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let kind: BackendKind = args
        .get(4)
        .map(|s| s.parse())
        .transpose()
        .map_err(anyhow::Error::msg)?
        .unwrap_or(BackendKind::Pjrt);
    let batch: usize = args.get(5).and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let mut factory = AnyFactory::new(kind, "artifacts");
    factory.host.num_classes = PipelineConfig::tiny_96().num_classes;
    let opts = ServeOptions {
        sensor_seed: seed,
        batch: BatchPolicy::batched(batch, Duration::from_micros(500)),
        ..ServeOptions::frames(frames)
    };

    let mut rows = Vec::new();
    for use_mask in [true, false] {
        let mut cfg = PipelineConfig::tiny_96();
        cfg.use_mask = use_mask;
        let label = if use_mask { "MGNet + RoI mask" } else { "no mask (all patches)" };
        println!(
            "== serving {frames} frames ({workers} worker(s), {kind} backend, batch {batch}): {label} =="
        );
        // Session API: one server (N worker pipelines), one
        // synthetic-sensor session on it, drained in order; the aggregate
        // report equals the session's.
        let ecfg = EngineConfig::for_serving(&cfg, &opts, workers);
        let image_size = cfg.image_size;
        let server = {
            let cfg = cfg.clone();
            let factory = factory.clone();
            Server::start(
                move |wid| Pipeline::with_backend(cfg.clone(), factory.create(wid)?),
                ecfg,
            )?
        };
        let session = server.session(SessionOptions::named(label))?;
        let (submitter, stream) = session.split();
        let sensor = spawn_synthetic_sensor(
            submitter,
            server.watch(),
            image_size,
            opts.num_objects,
            opts.sensor_seed,
            opts.num_frames,
        );
        stream.finish()?;
        sensor.join().ok();
        let (report, metrics) = server.shutdown()?;
        println!("  backend           {}", report.backend);
        println!("  wall throughput   {:.1} fps", report.wall_fps);
        println!("  mean micro-batch  {:.2} frames/dispatch", report.mean_batch);
        println!(
            "  mean latency      {}{}",
            si_time(report.mean_latency_s),
            if report.backend == "sim" { " (modeled photonic-core)" } else { "" }
        );
        println!("  mean kept         {:.1}/36 patches", report.mean_kept_patches);
        println!("  mask IoU          {:.3}", report.mean_mask_iou);
        println!("  top-1 accuracy    {:.3}", report.top1_accuracy);
        println!("  modeled energy    {}/frame", si_energy(report.mean_energy_j));
        println!("  modeled KFPS/W    {:.1}", report.modeled_kfps_per_watt);
        println!("  frames dropped    {}", report.dropped);
        if workers > 1 {
            for w in &report.per_worker {
                println!(
                    "  worker {}          {} frames, {:.0}% utilized",
                    w.worker,
                    w.frames,
                    w.utilization * 100.0
                );
            }
        }
        println!("\nper-stage host latency:");
        let mut t = Table::new(vec!["stage", "mean", "max"]);
        for (s, mean, max, _) in metrics.stage_rows() {
            t.row(vec![s, si_time(mean), si_time(max)]);
        }
        print!("{}\n", t.render());
        rows.push((label, report));
    }

    let (_, masked) = &rows[0];
    let (_, full) = &rows[1];
    println!("== RoI masking effect (the paper's headline mechanism) ==");
    println!(
        "energy saving   {:.1}% ({} -> {})",
        (1.0 - masked.mean_energy_j / full.mean_energy_j) * 100.0,
        si_energy(full.mean_energy_j),
        si_energy(masked.mean_energy_j)
    );
    println!(
        "efficiency      {:.1} -> {:.1} modeled KFPS/W (paper reference point: 100.4)",
        full.modeled_kfps_per_watt, masked.modeled_kfps_per_watt
    );
    println!(
        "accuracy        {:.3} -> {:.3} (paper: <1.6% drop; chance-level on host/sim's untrained weights)",
        full.top1_accuracy, masked.top1_accuracy
    );
    Ok(())
}
