//! RoI threshold sweep: how the MGNet sigmoid threshold `t_reg` trades
//! mask quality (IoU vs ground truth), pixel skip ratio, accelerator
//! energy, and end-to-end accuracy — the serving-time knob the paper
//! leaves to the deployment.
//!
//! ```bash
//! make artifacts   # only needed for the pjrt backend
//! cargo run --release --example roi_sweep -- [frames] [pjrt|host|sim]
//! ```

// The sweep uses the in-thread `serve` path (the degenerate one-session
// case) on purpose: each operating point wants one pipeline, one thread,
// no pool — see `examples/multi_camera.rs` for the session-oriented
// multi-tenant surface.
use optovit::coordinator::pipeline::{serve, Pipeline, PipelineConfig, ServeOptions};
use optovit::runtime::{AnyFactory, BackendFactory, BackendKind};
use optovit::util::table::{si_energy, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let frames: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    let kind: BackendKind = args
        .get(2)
        .map(|s| s.parse())
        .transpose()
        .map_err(anyhow::Error::msg)?
        .unwrap_or(BackendKind::Pjrt);
    let mut factory = AnyFactory::new(kind, "artifacts");
    factory.host.num_classes = PipelineConfig::tiny_96().num_classes;

    println!("== t_reg sweep ({frames} frames each, {kind} backend) ==\n");
    let mut t = Table::new(vec![
        "t_reg", "kept/36", "skip%", "mask IoU", "top-1", "energy/frame", "KFPS/W",
    ]);
    for thr in [0.3f32, 0.4, 0.5, 0.6, 0.7, 0.8] {
        let mut cfg = PipelineConfig::tiny_96();
        cfg.region_threshold = thr;
        let mut pipeline = Pipeline::with_backend(cfg, factory.create(0)?)?;
        let opts = ServeOptions { sensor_seed: 1234, ..ServeOptions::frames(frames) };
        // Drain the result stream into its terminal report.
        let r = serve(&mut pipeline, &opts)?.finish()?;
        t.row(vec![
            format!("{thr:.1}"),
            format!("{:.1}", r.mean_kept_patches),
            format!("{:.0}%", (1.0 - r.mean_kept_patches / 36.0) * 100.0),
            format!("{:.3}", r.mean_mask_iou),
            format!("{:.3}", r.top1_accuracy),
            si_energy(r.mean_energy_j),
            format!("{:.1}", r.modeled_kfps_per_watt),
        ]);
    }
    print!("{}", t.render());
    println!("\nhigher t_reg -> more aggressive pruning -> more energy saved, until the");
    println!("mask starts eating object patches and accuracy falls off.");
    Ok(())
}
