//! Accelerator design-space exploration on top of the architecture model:
//! Table IV competitors, reference platforms, and Opto-ViT sensitivity to
//! its own design knobs (core count, ADC energy, tuning technology).
//!
//! Runs entirely on the analytic models — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example accelerator_comparison
//! ```

use optovit::baselines;
use optovit::energy::components::ComponentModels;
use optovit::energy::AcceleratorModel;
use optovit::util::table::Table;
use optovit::vit::{MgnetConfig, VitConfig, VitVariant};

fn optovit_kfpsw(model: &AcceleratorModel) -> f64 {
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    let mg = MgnetConfig::classification(96);
    let kept = (cfg.num_patches() as f64 * 0.33).round() as usize;
    model.masked_report("ref", &cfg, &mg, kept).kfps_per_watt()
}

fn main() {
    println!("== Table IV + platforms ==\n");
    let mut t = Table::new(vec!["design", "KFPS/W"]);
    for r in baselines::table_iv() {
        t.row(vec![r.name, format!("{:.2}", r.kfps_per_watt)]);
    }
    for p in baselines::reference_platforms() {
        t.row(vec![p.name.to_string(), format!("{:.2}", p.kfps_per_watt)]);
    }
    print!("{}", t.render());

    println!("\n== Opto-ViT design-knob sensitivity (KFPS/W at the reference point) ==\n");
    let base = AcceleratorModel::default();
    let mut t = Table::new(vec!["variant", "KFPS/W", "delta"]);
    let ref_kfpsw = optovit_kfpsw(&base);
    t.row(vec!["default (5 cores, EO tuning)".into(), format!("{ref_kfpsw:.1}"), "ref".into()]);

    // Thermo-optic tuning: the design point the VCSEL-input choice avoids.
    let mut thermo = base;
    thermo.components = ComponentModels::thermo_optic();
    let k = optovit_kfpsw(&thermo);
    t.row(vec![
        "thermo-optic tuning (heaters)".into(),
        format!("{k:.1}"),
        format!("{:+.0}%", (k / ref_kfpsw - 1.0) * 100.0),
    ]);

    // ADC energy sensitivity (the dominant share in Fig. 8).
    for scale in [0.5, 2.0] {
        let mut m = base;
        m.components.adc.energy_pj *= scale;
        let k = optovit_kfpsw(&m);
        t.row(vec![
            format!("ADC energy x{scale}"),
            format!("{k:.1}"),
            format!("{:+.0}%", (k / ref_kfpsw - 1.0) * 100.0),
        ]);
    }

    // 4-bit converters (half the energy, matching lower-precision designs).
    let mut m4 = base;
    m4.components.adc.energy_pj *= 0.4;
    m4.components.dac.energy_pj *= 0.4;
    let k = optovit_kfpsw(&m4);
    t.row(vec![
        "4-bit ADC/DAC energy point".into(),
        format!("{k:.1}"),
        format!("{:+.0}%", (k / ref_kfpsw - 1.0) * 100.0),
    ]);
    print!("{}", t.render());

    println!("\nthe ADC rows confirm the paper's pie-chart conclusion: data conversion,");
    println!("not optics, is the energy wall — 'further shifting processing toward the");
    println!("analog domain' is where the next factor comes from.");
}
