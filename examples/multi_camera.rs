//! Multi-camera serving (EXPERIMENTS.md §E2E, session edition): N
//! synthetic sensors → N sessions → **one** shared server — the
//! near-sensor deployment shape, one accelerator serving continuous
//! traffic from a fleet of cameras.
//!
//! Each camera opens its own `Session` on the server and feeds it from its
//! own sensor thread; frames from all cameras interleave through the
//! shared worker pool and the per-bucket micro-batch lanes, so same-bucket
//! frames from *different* cameras amortize one backbone dispatch
//! (watch `mean batch` exceed 1 as you add cameras). Admission is weighted
//! round-robin — camera 0 is given weight 2 to show a priority tenant
//! taking a larger share without starving the rest — and every camera
//! streams its own in-order results and gets its own report next to the
//! server-wide aggregate.
//!
//! The fleet also demonstrates per-session QoS: camera 0 is the **SLO
//! tenant** (50 ms submit→emit SLO — its frames carry deadlines that
//! flush micro-batch lanes early, and its `slo miss`/`p99` columns score
//! the result), while the last camera is the **bulk tenant**, rate-capped
//! by an admission quota (token bucket) whose rejections land in the
//! distinct `q-drop` column instead of `dropped`.
//!
//! Precision is a per-tenant serving contract too: the SLO tenant pins
//! INT8 (full operating-point fidelity), the bulk tenant pins INT4 (its
//! frames ride the cheap converter scale — watch its share of the
//! `tiers` column and the lower aggregate energy/frame), and every other
//! camera serves [`PrecisionPolicy::Auto`], letting MGNet's ROI density
//! pick INT8 or INT4 frame by frame. Micro-batch groups stay tier-pure:
//! an INT4 frame never rides an INT8 group's weight programming.
//!
//! On the `sim` backend the fleet additionally runs on **degrading
//! optics**: a seeded fault schedule accumulates MR thermal drift fast
//! enough to push workers accuracy-at-risk within the run, so the
//! health-aware dispatcher routes the SLO tenant around them, counts
//! every frame served on degraded optics in the `at-risk` column, and
//! schedules recalibration windows (watch `recals` in the per-worker
//! lines) while the rest of the pool keeps serving.
//!
//! The pool is also **elastic**: every sensor thread opens fire at once,
//! so fleet start-up is a burst — an `AutoScaler` ticks against the live
//! server while the cameras drain, growing the pool (up to 2x the
//! starting `--workers`) while the burst backlog holds the per-worker
//! queue-depth gauge high and retiring workers once the fleet quiesces.
//! The scale-event log prints after the per-session reports; retired
//! workers keep their final rows in the aggregate.
//!
//! ```bash
//! cargo run --release --example multi_camera -- [cameras] [frames] [workers] [pjrt|host|sim] [batch]
//! # artifact-free: cargo run --release --example multi_camera -- 3 60 2 host 4
//! # degraded optics: cargo run --release --example multi_camera -- 3 60 2 sim 4
//! # visible elasticity: many cameras, small starting pool:
//! #   cargo run --release --example multi_camera -- 8 120 1 host 4
//! ```

use std::time::Duration;

use optovit::coordinator::autoscale::{AutoScaler, ScaleAction, ScalePolicy};
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::clock::Clock;
use optovit::coordinator::engine::EngineConfig;
use optovit::coordinator::pipeline::{Pipeline, PipelineConfig, ServeOptions};
use optovit::coordinator::server::{spawn_synthetic_sensor, Quota, Server, SessionOptions};
use optovit::quant::{PrecisionPolicy, PrecisionTier};
use optovit::runtime::{AnyFactory, BackendFactory, BackendKind, FaultPlan};
use optovit::util::table::{si_energy, si_time, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let cameras: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3).max(1);
    let frames: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2).max(1);
    let kind: BackendKind = args
        .get(4)
        .map(|s| s.parse())
        .transpose()
        .map_err(anyhow::Error::msg)?
        .unwrap_or(BackendKind::Host);
    let batch: usize = args.get(5).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);

    let pipe_cfg = PipelineConfig::tiny_96();
    let mut factory = AnyFactory::new(kind, "artifacts");
    factory.host.num_classes = pipe_cfg.num_classes;
    if kind == BackendKind::Sim {
        // Degraded-optics demo: drift fast enough (5e-3 nm/s vs the
        // ~1e-4 nm/s a thermally stabilized deployment sees) that the
        // fleet visibly degrades and recalibrates within a short run.
        factory = factory.with_faults(FaultPlan {
            seed: 7,
            drift_nm_per_s: 5e-3,
            clock: Clock::system(),
        });
        println!("sim backend: degrading optics enabled (seeded fault schedule, 5e-3 nm/s drift)");
    }

    let opts = ServeOptions {
        batch: BatchPolicy::batched(batch, Duration::from_micros(500)),
        ..ServeOptions::frames(frames)
    };
    let mut ecfg = EngineConfig::for_serving(&pipe_cfg, &opts, workers);
    // Elastic pool: the autoscaler may grow the fleet to 2x the starting
    // size while the start-up burst queues.
    let max_workers = workers * 2;
    ecfg.max_workers = max_workers;

    println!(
        "== {cameras} camera(s) → {cameras} session(s) → one elastic \
         {workers}..{max_workers}-worker server ({kind} backend, batch {batch}) =="
    );
    let server = {
        let cfg = pipe_cfg.clone();
        let factory = factory.clone();
        Server::start(move |wid| Pipeline::with_backend(cfg.clone(), factory.create(wid)?), ecfg)?
    };

    // One session + one sensor thread per camera; camera 0 is the
    // priority SLO tenant (admission weight 2 + a 50 ms submit→emit SLO),
    // the last camera is the bulk tenant (rate-capped admission quota).
    let image_size = pipe_cfg.image_size;
    let mut fleet = Vec::with_capacity(cameras);
    for cam in 0..cameras {
        let weight = if cam == 0 { 2 } else { 1 };
        let mut sopts = SessionOptions::named(format!("camera-{cam}")).with_weight(weight);
        if cam == 0 {
            sopts = sopts
                .with_slo(Duration::from_millis(50))
                .with_precision(PrecisionPolicy::Fixed(PrecisionTier::Int8));
        } else if cam == cameras - 1 {
            // Bulk tenant: at most ~200 admissions/s sustained, burst 8
            // (quota rejections count `q-drop`, never `dropped`), served
            // entirely at the cheap INT4 operating point.
            sopts = sopts
                .with_quota(Quota::rate(200.0, 8))
                .with_precision(PrecisionPolicy::Fixed(PrecisionTier::Int4));
        } else {
            // Mid-fleet cameras let ROI density pick the tier per frame.
            sopts = sopts.with_precision(PrecisionPolicy::Auto);
        }
        let session = server.session(sopts)?;
        let (submitter, stream) = session.split();
        let sensor = spawn_synthetic_sensor(
            submitter,
            server.watch(),
            image_size,
            2,
            1000 + cam as u64, // distinct scene per camera
            frames,
        );
        // Each camera drains its own in-order stream.
        let drain = std::thread::spawn(move || stream.finish());
        fleet.push((cam, weight, sensor, drain));
    }

    let mut t = Table::new(vec![
        "camera", "weight", "frames", "tiers 4/8/32", "dropped", "q-drop", "shed", "slo miss",
        "at-risk", "fps", "latency", "p99", "mean batch", "IoU",
    ]);
    // While the fleet drains its start-up burst, an autoscaler ticks
    // against the live server on the serving clock: the whole-fleet
    // arrival spike holds the queue-depth gauge high → scale-ups toward
    // `max_workers`; once cameras finish, the pool quiesces → scale-downs
    // back to the floor. The stop flag is set before any error
    // propagates so the scaler thread can never deadlock the scope join.
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        scope.spawn(|| {
            let mut scaler = AutoScaler::new(
                ScalePolicy { min_workers: workers, max_workers, ..ScalePolicy::default() },
                server.clock(),
            );
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let _ = scaler.tick(&server);
                std::thread::sleep(Duration::from_millis(50));
            }
        });
        let joined = (|| -> anyhow::Result<()> {
            for (cam, weight, sensor, drain) in fleet {
                sensor.join().ok();
                let report =
                    drain.join().map_err(|_| anyhow::anyhow!("camera {cam} drain panicked"))??;
                t.row(vec![
                    format!("camera-{cam}"),
                    weight.to_string(),
                    report.frames.to_string(),
                    format!(
                        "{}/{}/{}",
                        report.tier_frames[0], report.tier_frames[1], report.tier_frames[2]
                    ),
                    report.dropped.to_string(),
                    report.dropped_quota.to_string(),
                    report.dropped_shed.to_string(),
                    report.slo_miss.to_string(),
                    report.accuracy_at_risk.to_string(),
                    format!("{:.1}", report.wall_fps),
                    si_time(report.mean_latency_s),
                    si_time(report.p99_latency_s),
                    format!("{:.2}", report.mean_batch),
                    format!("{:.3}", report.mean_mask_iou),
                ]);
            }
            Ok(())
        })();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        joined
    })?;
    println!("\nper-session reports (every stream delivered in order):");
    print!("{}", t.render());

    let events = server.scale_events();
    println!(
        "\nautoscaler: {} live worker(s) at close, {} scale event(s)",
        server.live_workers(),
        events.len()
    );
    if events.is_empty() {
        println!("  (pool held steady at {workers} — try more cameras or fewer starting workers)");
    }
    for e in &events {
        let action = match &e.action {
            ScaleAction::Up => "scale-up".to_string(),
            ScaleAction::Down => "scale-down".to_string(),
            ScaleAction::ShedOn { below_weight } => format!("shed <{below_weight}"),
            ScaleAction::ShedOff => "shed-off".to_string(),
        };
        println!("  t={:>7} {:<10} → {} worker(s)  {}", si_time(e.at_s), action, e.workers, e.detail);
    }

    let (agg, metrics) = server.shutdown()?;
    println!("\n== server-wide aggregate ==");
    println!("frames served      {}", agg.frames);
    println!("wall throughput    {:.1} fps", agg.wall_fps);
    println!("mean micro-batch   {:.2} frames/dispatch (cross-session amortization)", agg.mean_batch);
    println!("mean latency       {}", si_time(agg.mean_latency_s));
    println!("modeled energy     {}/frame", si_energy(agg.mean_energy_j));
    println!(
        "precision tiers    {} int4 / {} int8 / {} fp32 frames",
        agg.tier_frames[0], agg.tier_frames[1], agg.tier_frames[2]
    );
    println!("frames dropped     {}", agg.dropped);
    println!("quota rejections   {} (bulk tenant's rate cap)", agg.dropped_quota);
    println!("SLO misses         {} (camera 0's 50 ms SLO)", agg.slo_miss);
    if agg.accuracy_at_risk > 0 {
        println!("accuracy-at-risk   {} frames served on degraded optics", agg.accuracy_at_risk);
    }
    println!("p99 session lat.   {}", si_time(agg.p99_latency_s));
    for w in &agg.per_worker {
        println!(
            "worker {}           {} frames, {:.0}% utilized, health {:.2}, {} recal(s), \
             {} at-risk{}{}",
            w.worker,
            w.frames,
            w.utilization * 100.0,
            w.health,
            w.recals,
            w.at_risk_frames,
            w.core.map(|c| format!(", core {c}")).unwrap_or_default(),
            if w.retired { " [retired by scale-down]" } else { "" }
        );
    }
    println!("\nper-stage latency (merged across workers):");
    let mut st = Table::new(vec!["stage", "mean", "max"]);
    for (s, mean, max, _) in metrics.stage_rows() {
        st.row(vec![s, si_time(mean), si_time(max)]);
    }
    print!("{}", st.render());
    Ok(())
}
