//! Quickstart: one frame through the full Opto-ViT stack.
//!
//! ```bash
//! make artifacts            # once: lower the models to HLO artifacts
//! cargo run --release --example quickstart
//! ```
//!
//! The flow mirrors the library README: synthesize a sensor frame, run
//! MGNet to get a patch mask, prune, run the backbone on the pruned
//! sequence, and ask the architecture model what the frame costs on the
//! photonic accelerator.

use optovit::coordinator::pipeline::{Pipeline, PipelineConfig};
use optovit::runtime::PjrtBackend;
use optovit::sensor::VideoSource;
use optovit::util::table::{si_energy, si_time};

fn main() -> anyhow::Result<()> {
    // 1. A synthetic near-sensor video feed (96x96 RGB, moving shapes).
    let mut sensor = VideoSource::new(96, 2, 7);

    // 2. The serving pipeline: MGNet -> RoI mask -> bucket router -> ViT,
    //    over the PJRT backend (swap in `HostBackend`/`SimBackend` to run
    //    without artifacts — see `optovit serve --backend`).
    let mut pipeline =
        Pipeline::with_backend(PipelineConfig::tiny_96(), PjrtBackend::new("artifacts")?)?;
    println!("compiling artifacts (one-time)...");
    pipeline.warmup()?;

    // 3. One frame, end to end.
    let frame = sensor.next_frame();
    let gt = frame.gt_mask(16);
    let result = pipeline.process_frame(&frame)?;

    println!("\nframe {}:", result.frame_index);
    println!("  kept patches      {} / 36 (bucket {})", result.mask.kept(), result.bucket);
    println!("  pixel skip        {:.0}%", result.mask.skip_ratio() * 100.0);
    println!("  mask IoU vs GT    {:.3}", result.mask.iou(&gt));
    println!("  predicted class   {} (label {})", result.predicted_class(), frame.label);
    println!("  host latency      {}", si_time(result.latency_s));
    println!("  modeled energy    {}/frame on the photonic core", si_energy(result.modeled_energy_j));
    println!("  modeled KFPS/W    {:.1}", 1.0 / result.modeled_energy_j / 1000.0);

    // 4. Batch-first execution: a slice of frames goes through the same
    //    pipeline bucket-major — frames sharing a bucket ride one
    //    `Backend::execute_batch` dispatch, and followers amortize the
    //    modeled weight-programming energy.
    let frames: Vec<_> = (0..4).map(|_| sensor.next_frame()).collect();
    let batch = pipeline.process_batch(&frames)?;
    println!("\nmicro-batch of {} frames:", batch.len());
    for r in &batch {
        println!(
            "  frame {}: bucket {:>2}, {}/frame modeled",
            r.frame_index,
            r.bucket,
            si_energy(r.modeled_energy_j)
        );
    }
    Ok(())
}
