//! Fig. 5 ablation: the five-core pipelined flow vs a single-core serial
//! execution, and core-count scaling — quantifying how much the pipeline
//! (tuning hidden behind compute, heads overlapped across cores) buys.

use optovit::arch::core::{CoreParams, OpticalCore};
use optovit::arch::scheduler::AttentionSchedule;
use optovit::arch::workload::Workload;
use optovit::util::bench::time_fn;
use optovit::util::table::{si_time, Table};
use optovit::vit::{VitConfig, VitVariant};

fn main() {
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    let n = cfg.seq_len();

    println!("== Fig. 5 ablation: pipelined 5-core flow vs serial baseline ==\n");
    let params = CoreParams::default();
    let core = OpticalCore::new(params);

    // Serial lower bound: all matmuls on one core, every tuning event
    // exposed (no ping-pong, no overlap).
    let w = Workload::vit(&cfg, cfg.num_patches(), true);
    let serial_ns = core.serial_time_ns(&core.workload_cost(&w));

    let single_frame =
        AttentionSchedule::decomposed(&cfg, n, params, 1).schedule(params.num_cores).1;
    let steady = AttentionSchedule::steady_state_frame_ns(&cfg, n, params, true);

    let mut t = Table::new(vec!["configuration", "per-frame time", "speedup vs serial"]);
    t.row(vec![
        "serial, 1 core, exposed tuning".to_string(),
        si_time(serial_ns * 1e-9),
        "1.00x".to_string(),
    ]);
    t.row(vec![
        "5-core pipeline, single frame".to_string(),
        si_time(single_frame.makespan_ns * 1e-9),
        format!("{:.2}x", serial_ns / single_frame.makespan_ns),
    ]);
    t.row(vec![
        "5-core pipeline, steady state".to_string(),
        si_time(steady * 1e-9),
        format!("{:.2}x", serial_ns / steady),
    ]);
    print!("{}", t.render());

    println!("\n== core-count scaling (steady-state frame time, Tiny-96) ==");
    let mut t = Table::new(vec!["cores", "frame time", "mean core util"]);
    for cores in [5usize, 6, 8, 10] {
        let p = CoreParams { num_cores: cores, ..params };
        let st = AttentionSchedule::decomposed(&cfg, n, p, 2).schedule(cores).1;
        let frame = AttentionSchedule::steady_state_frame_ns(&cfg, n, p, true);
        t.row(vec![
            cores.to_string(),
            si_time(frame * 1e-9),
            format!("{:.2}", st.mean_core_utilization),
        ]);
    }
    print!("{}", t.render());

    println!("\n== tuning-time sensitivity (steady state, 5 cores) ==");
    let mut t = Table::new(vec!["tune_ns", "frame time", "exposed tuning/frame"]);
    for tune in [40.0, 100.0, 250.0, 500.0, 1000.0] {
        let p = CoreParams { tune_ns: tune, ..params };
        let frame = AttentionSchedule::steady_state_frame_ns(&cfg, n, p, true);
        let st = AttentionSchedule::decomposed(&cfg, n, p, 1).schedule(5).1;
        t.row(vec![
            format!("{tune:.0}"),
            si_time(frame * 1e-9),
            si_time(st.exposed_tune_ns * 1e-9),
        ]);
    }
    print!("{}", t.render());

    let timing = time_fn("schedule build+run (Tiny-96, 1 frame)", 1, 10, || {
        AttentionSchedule::decomposed(&cfg, n, params, 1).schedule(5).1.makespan_ns
    });
    println!("\n{}", timing.summary());
}
