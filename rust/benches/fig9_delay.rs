//! Fig. 9 reproduction: per-frame processing-delay breakdown — optical
//! stage (incl. ADC/DAC and exposed tuning), electronic processing unit,
//! and buffer-memory latency — for the 4×2 model/resolution grid, plus the
//! Tiny-96 pie shares.

use optovit::energy::AcceleratorModel;
use optovit::util::bench::time_fn;
use optovit::util::table::{si_time, Table};
use optovit::vit::{VitConfig, VitVariant};

fn main() {
    let m = AcceleratorModel::default();
    println!("== Fig. 9: delay breakdown per frame (steady-state pipeline) ==\n");
    let mut t = Table::new(vec!["model", "res", "total", "Optical(+ADC/DAC)", "EPU", "Memory"]);
    for v in VitVariant::ALL {
        for res in [224usize, 96] {
            let cfg = VitConfig::variant(v, res, 1000);
            let r = m.frame_report(&format!("{v}-{res}"), &cfg, cfg.num_patches(), true);
            let d = r.delay;
            t.row(vec![
                v.name().to_string(),
                res.to_string(),
                si_time(d.total_s()),
                si_time(d.optical_s),
                si_time(d.epu_s),
                si_time(d.memory_s),
            ]);
        }
    }
    print!("{}", t.render());

    println!("\n== Fig. 9 pie: Tiny-96 stage shares ==");
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    let r = m.frame_report("tiny-96", &cfg, cfg.num_patches(), true);
    let mut t = Table::new(vec!["stage", "share %"]);
    for (name, s) in r.delay.shares() {
        t.row(vec![name.to_string(), format!("{:.1}", s * 100.0)]);
    }
    print!("{}", t.render());
    println!(
        "\npaper claims: optical stage dominates; memory latency exceeds EPU — measured: \
         optical {:.1}%, memory {:.1}%, EPU {:.1}%",
        r.delay.optical_s / r.delay.total_s() * 100.0,
        r.delay.memory_s / r.delay.total_s() * 100.0,
        r.delay.epu_s / r.delay.total_s() * 100.0,
    );

    let timing = time_fn("fig9 full grid (8 reports, DES schedule)", 1, 5, || {
        let mut acc = 0.0;
        for v in VitVariant::ALL {
            for res in [224usize, 96] {
                let cfg = VitConfig::variant(v, res, 1000);
                acc += m.frame_report("x", &cfg, cfg.num_patches(), true).delay.total_s();
            }
        }
        acc
    });
    println!("\n{}", timing.summary());
}
