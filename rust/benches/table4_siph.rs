//! Table IV reproduction: KFPS/W comparison against six SiPh accelerators
//! under a consistent area constraint, with Opto-ViT as the reference.

use optovit::baselines;
use optovit::util::bench::time_fn;
use optovit::util::table::Table;

fn main() {
    println!("== Table IV: comparison with SOTA SiPh accelerators ==\n");
    println!(
        "(common workload: RoI-masked ViT-Tiny @ 96^2 + MGNet = {} MMACs)\n",
        baselines::reference_workload_macs() / 1_000_000
    );
    let rows = baselines::table_iv();
    let mut t = Table::new(vec!["design", "node (nm)", "KFPS/W", "Opto-ViT improv."]);
    for r in &rows {
        let imp = if r.name == "Opto-ViT" {
            "ref".to_string()
        } else {
            format!("{:+.1}%", r.improvement_pct)
        };
        t.row(vec![r.name.clone(), r.node.clone(), format!("{:.2}", r.kfps_per_watt), imp]);
    }
    print!("{}", t.render());

    let ours = rows.last().unwrap().kfps_per_watt;
    println!("\npaper:    Opto-ViT 100.4 KFPS/W; beats all but Lightator's best case");
    println!("measured: Opto-ViT {ours:.1} KFPS/W");
    for r in &rows[..rows.len() - 1] {
        let verdict = if ours > r.kfps_per_watt { "win" } else { "lose" };
        println!("  vs {:<11} {:>8.2} KFPS/W -> {}", r.name, r.kfps_per_watt, verdict);
    }

    let timing = time_fn("table IV build", 2, 20, || baselines::table_iv().len());
    println!("\n{}", timing.summary());
}
