//! §IV "MR Resolution Analysis" reproduction: achievable weight resolution
//! vs Q-factor under crosstalk + fabrication-process variation; the paper's
//! claim is Q ≈ 5000 → at least 8-bit resolution with FPV tolerance.

use optovit::photonics::fpv::FpvModel;
use optovit::photonics::{ChannelGrid, CrosstalkModel, MrGeometry};
use optovit::util::bench::time_fn;
use optovit::util::rng::Rng;
use optovit::util::table::Table;

fn main() {
    let fpv = FpvModel::default();
    let geometry = MrGeometry::default();

    println!("== resolution vs Q (32-channel C-band plan, FPV residual) ==\n");
    let qs: Vec<f64> = (1..=20).map(|k| k as f64 * 1000.0).collect();
    let rows = fpv.q_sweep(geometry, 32, &qs);
    let mut t = Table::new(vec!["Q", "crosstalk bits", "FPV bits", "effective bits"]);
    let mut best = (0.0, f64::NEG_INFINITY);
    for r in &rows {
        if r.effective_bits > best.1 {
            best = (r.q_factor, r.effective_bits);
        }
        t.row(vec![
            format!("{:.0}", r.q_factor),
            format!("{:.2}", r.crosstalk_bits),
            format!("{:.2}", r.fpv_bits),
            format!("{:.2}", r.effective_bits),
        ]);
    }
    print!("{}", t.render());
    let at5000 = rows.iter().find(|r| r.q_factor == 5000.0).unwrap();
    println!(
        "\npaper claim: Q ~ 5000 achieves >= 8-bit  |  measured: {:.2} bits at Q=5000 \
         (peak {:.2} bits at Q={:.0})",
        at5000.effective_bits, best.1, best.0
    );

    println!("\n== channel-spacing sensitivity at Q=5000 ==");
    let mut t = Table::new(vec!["spacing (nm)", "crosstalk bits"]);
    for &sp in &[0.4, 0.8, 1.2, 1.6, 2.4] {
        let grid = ChannelGrid::uniform(32, 1550.0 - sp * 15.5, sp);
        let m = CrosstalkModel::new(grid, 5000.0);
        t.row(vec![format!("{sp:.1}"), format!("{:.2}", m.resolution_bits())]);
    }
    print!("{}", t.render());

    println!("\n== >200-copy FPV Monte-Carlo (the fabricated-chip experiment) ==");
    let mut rng = Rng::new(2024);
    let samples = fpv.sample_instances(&geometry, 1550.0, 220, &mut rng);
    let sigma: f64 = {
        let m = samples.iter().map(|s| s.lambda_shift_nm).sum::<f64>() / samples.len() as f64;
        (samples.iter().map(|s| (s.lambda_shift_nm - m).powi(2)).sum::<f64>()
            / samples.len() as f64)
            .sqrt()
    };
    println!(
        "220 instances: residual resonance jitter sigma = {:.2} pm (model {:.2} pm)",
        sigma * 1000.0,
        fpv.residual_sigma_lambda_nm(&geometry, 1550.0) * 1000.0
    );

    let timing = time_fn("full Q-sweep (20 points, 32 ch)", 2, 10, || {
        fpv.q_sweep(geometry, 32, &qs).len()
    });
    println!("\n{}", timing.summary());
}
