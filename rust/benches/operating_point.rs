//! Queueing-co-sim operating-point bench: cores × batch × offered load.
//!
//! Sweeps the discrete-event queueing simulator ([`optovit::cosim`]) over
//! the modeled accelerator and emits a machine-readable `BENCH_cosim.json`
//! with latency/queueing percentiles, achieved throughput, and KFPS/W at
//! each grid point — the Fig. 9/11-style operating-point curves, now with
//! the load-dependent waiting term the closed-form schedule cannot see.
//!
//! ```bash
//! cargo bench --bench operating_point -- \
//!     [--cores 5,6,8] [--batch 1,4] [--load 0.4,0.75,0.95] \
//!     [--frames 400] [--tokens 18] [--seed 7] [--out BENCH_cosim.json]
//! ```
//!
//! (declared `harness = false`: this bench carries its own `main`.)
//!
//! Arrivals are seeded-exponential (Poisson) bursts of `--batch` frames,
//! so every point is deterministic for a fixed `--seed`. KFPS/W folds the
//! micro-batch's weight-programming amortization into mean energy/frame:
//! the first frame of each burst pays the MR weight-bank programming
//! (weight-side DAC conversions + stationary weight bytes), followers
//! reuse the programmed banks.

use anyhow::Result;
use optovit::arch::{CoreParams, OpticalCore, Workload};
use optovit::cli::Args;
use optovit::coordinator::stats::kfps_per_watt;
use optovit::cosim::{simulate, OperatingPoint, OperatingPointReport};
use optovit::energy::AcceleratorModel;
use optovit::util::bench::CountingAlloc;
use optovit::util::table::{si_energy, si_time, Table};
use optovit::vit::{VitConfig, VitVariant};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

struct Row {
    report: OperatingPointReport,
    mean_energy_j: f64,
    kfps_per_watt: f64,
}

/// Mean modeled energy per frame in a `batch`-frame burst: the first
/// frame programs the MR weight banks, followers reuse them.
fn mean_energy_j(m: &AcceleratorModel, cfg: &VitConfig, n_tokens: usize, batch: usize) -> f64 {
    let core = OpticalCore::new(m.cores);
    let w = Workload::vit(cfg, n_tokens, true);
    let cost = core.workload_cost(&w);
    let first = m.energy_of_cost(&cost, w.elementwise.total()).total_j();
    let mut follow_cost = cost;
    follow_cost.weight_dac_conversions = 0;
    follow_cost.weight_bytes = 0;
    let follow = m.energy_of_cost(&follow_cost, w.elementwise.total()).total_j();
    (first + (batch - 1) as f64 * follow) / batch as f64
}

fn fmt_json(frames: usize, tokens: usize, seed: u64, rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"operating_point\",\n");
    out.push_str(&format!("  \"frames\": {frames},\n"));
    out.push_str(&format!("  \"tokens\": {tokens},\n"));
    out.push_str(&format!("  \"arrival_seed\": {seed},\n"));
    out.push_str("  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let p = &r.report;
        out.push_str(&format!(
            "    {{\"cores\": {}, \"batch\": {}, \"load\": {:.3}, \
             \"saturation_kfps\": {:.3}, \"offered_kfps\": {:.3}, \
             \"achieved_kfps\": {:.3}, \"mean_latency_ns\": {:.3}, \
             \"p50_latency_ns\": {:.3}, \"p99_latency_ns\": {:.3}, \
             \"mean_queueing_ns\": {:.3}, \"p99_queueing_ns\": {:.3}, \
             \"peak_in_flight\": {}, \"mean_energy_j\": {:.6e}, \
             \"kfps_per_watt\": {:.3}}}{}\n",
            p.cores,
            p.batch,
            p.load,
            p.saturation_kfps,
            p.offered_kfps,
            p.achieved_kfps,
            p.mean_latency_ns,
            p.p50_latency_ns,
            p.p99_latency_ns,
            p.mean_queueing_ns,
            p.p99_queueing_ns,
            p.peak_in_flight,
            r.mean_energy_j,
            r.kfps_per_watt,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let cores_list = args.get_usize_list("cores", &[5, 6, 8]).map_err(anyhow::Error::msg)?;
    let batches = args.get_usize_list("batch", &[1, 4]).map_err(anyhow::Error::msg)?;
    let loads: Vec<f64> = match args.get("load") {
        None => vec![0.4, 0.75, 0.95],
        Some(v) => v
            .split(',')
            .map(|s| s.trim().parse::<f64>().map_err(|e| format!("--load: {e}")))
            .collect::<std::result::Result<_, _>>()
            .map_err(anyhow::Error::msg)?,
    };
    let frames = args.get_usize("frames", 400).map_err(anyhow::Error::msg)?.max(1);
    let tokens = args.get_usize("tokens", 18).map_err(anyhow::Error::msg)?.max(1);
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;
    let out_path = args.get_or("out", "BENCH_cosim.json").to_string();
    for &l in &loads {
        if !(l > 0.0 && l.is_finite()) {
            anyhow::bail!("--load: offered load must be finite and positive, got {l}");
        }
    }
    for &c in &cores_list {
        if c < 5 {
            anyhow::bail!("--cores: the five-core pipeline flow needs at least 5, got {c}");
        }
    }

    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    println!(
        "== operating_point: {frames} frames/point, cores {cores_list:?}, \
         batch {batches:?}, load {loads:?}, {tokens} tokens ==\n"
    );

    let mut rows = Vec::new();
    for &cores in &cores_list {
        let params = CoreParams { num_cores: cores, ..CoreParams::default() };
        let model = AcceleratorModel { cores: params, ..AcceleratorModel::default() };
        for &batch in &batches {
            let energy = mean_energy_j(&model, &cfg, tokens, batch);
            for &load in &loads {
                let op = OperatingPoint {
                    cores,
                    batch,
                    load,
                    frames,
                    n_tokens: tokens,
                    arrival_seed: Some(seed),
                };
                let report = simulate(&cfg, &op);
                println!(
                    "cores {cores}, batch {batch}, load {load:.2}: \
                     {:.2} KFPS achieved (sat {:.2}), p99 {}, queueing {} mean",
                    report.achieved_kfps,
                    report.saturation_kfps,
                    si_time(report.p99_latency_ns * 1e-9),
                    si_time(report.mean_queueing_ns * 1e-9),
                );
                rows.push(Row {
                    report,
                    mean_energy_j: energy,
                    kfps_per_watt: kfps_per_watt(energy),
                });
            }
        }
    }

    println!("\n== operating-point summary ==");
    let mut t = Table::new(vec![
        "cores", "batch", "load", "offered", "achieved", "p50", "p99", "queue p99", "peak",
        "energy/frame", "KFPS/W",
    ]);
    for r in &rows {
        let p = &r.report;
        t.row(vec![
            p.cores.to_string(),
            p.batch.to_string(),
            format!("{:.2}", p.load),
            format!("{:.2}k", p.offered_kfps),
            format!("{:.2}k", p.achieved_kfps),
            si_time(p.p50_latency_ns * 1e-9),
            si_time(p.p99_latency_ns * 1e-9),
            si_time(p.p99_queueing_ns * 1e-9),
            p.peak_in_flight.to_string(),
            si_energy(r.mean_energy_j),
            format!("{:.2}", r.kfps_per_watt),
        ]);
    }
    print!("{}", t.render());

    let json = fmt_json(frames, tokens, seed, &rows);
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path}");
    Ok(())
}
