//! Mixed-precision serving sweep over the modeled photonic substrate.
//!
//! Sweeps `--policies int8,int4,auto` × `--batch 1` (defaults) through
//! `coordinator::engine`, and emits a machine-readable
//! `BENCH_precision.json` (per-tier frame counts, modeled energy/frame,
//! modeled KFPS/W, fp32 top-1 agreement, and the energy saving vs. the
//! uniform-int8 row at the same batch size) so the tentpole claim —
//! ROI-driven `auto` serves strictly cheaper than uniform int8 without
//! leaving the int8 agreement envelope — is trackable across PRs.
//!
//! ```bash
//! cargo bench --bench precision_sweep -- \
//!     [--policies int8,int4,auto,fp32] [--batch 1,4] [--batch-wait-us 500] \
//!     [--frames 240] [--workers 1] [--backend sim|host] \
//!     [--agreement true|false] [--out BENCH_precision.json] [--seed 42]
//! ```
//!
//! (declared `harness = false`: this bench carries its own `main`.)
//!
//! The default backend is `sim`: tier economics are *modeled* (per-tier
//! DAC/ADC/VCSEL energy and MR weight-programming in
//! `energy::AcceleratorModel`), so the sweep needs no compiled artifacts
//! and its energy column is deterministic. `--agreement true` (default)
//! arms the pipeline's fp32 electronic-reference probe; probe compute is
//! never charged to the frames, so the energy column is unaffected.

use anyhow::Result;
use optovit::cli::Args;
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::serve_sharded;
use optovit::coordinator::pipeline::{PipelineConfig, ServeOptions, ServeReport};
use optovit::quant::{PrecisionPolicy, PrecisionTier};
use optovit::runtime::{AnyFactory, BackendKind, HostConfig};
use optovit::util::table::{si_energy, Table};

struct Row {
    policy: PrecisionPolicy,
    batch: usize,
    report: ServeReport,
}

/// The savings denominator: the uniform-int8 row at the same batch size
/// (`None` when the sweep never ran one, e.g. `--policies int4`).
fn int8_energy(rows: &[Row], batch: usize) -> Option<f64> {
    rows.iter()
        .find(|r| r.batch == batch && r.policy == PrecisionPolicy::Fixed(PrecisionTier::Int8))
        .map(|r| r.report.mean_energy_j)
}

fn agreement_field(report: &ServeReport, tier: PrecisionTier) -> String {
    match report.tier_agreement(tier) {
        Some(a) => format!("{a:.4}"),
        None => "null".to_string(),
    }
}

fn fmt_json(frames: u64, backend: &str, rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"precision_sweep\",\n");
    out.push_str(&format!("  \"backend\": \"{backend}\",\n"));
    out.push_str(&format!("  \"frames\": {frames},\n"));
    out.push_str("  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let saving = int8_energy(rows, r.batch)
            .filter(|&base| base > 0.0)
            .map(|base| 1.0 - r.report.mean_energy_j / base);
        out.push_str(&format!(
            "    {{\"policy\": \"{}\", \"batch\": {}, \"tier_frames\": [{}, {}, {}], \
             \"wall_fps\": {:.3}, \"mean_energy_j\": {:.6e}, \
             \"modeled_kfps_per_watt\": {:.3}, \"agreement_int4\": {}, \
             \"agreement_int8\": {}, \"energy_saving_vs_int8\": {}}}{}\n",
            r.policy,
            r.batch,
            r.report.tier_frames[0],
            r.report.tier_frames[1],
            r.report.tier_frames[2],
            r.report.wall_fps,
            r.report.mean_energy_j,
            r.report.modeled_kfps_per_watt,
            agreement_field(&r.report, PrecisionTier::Int4),
            agreement_field(&r.report, PrecisionTier::Int8),
            saving.map(|s| format!("{s:.4}")).unwrap_or_else(|| "null".to_string()),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let policy_list = args.get_or("policies", "int8,int4,auto").to_string();
    let batch_sizes = args.get_usize_list("batch", &[1]).map_err(anyhow::Error::msg)?;
    let batch_wait = args.get_duration_us("batch-wait-us", 500).map_err(anyhow::Error::msg)?;
    let frames = args.get_u64("frames", 240).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 1).map_err(anyhow::Error::msg)?.max(1);
    let out_path = args.get_or("out", "BENCH_precision.json").to_string();
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    let agreement = args.get_or("agreement", "true") == "true";
    let backend_arg =
        args.get_choice("backend", &["sim", "host"], "sim").map_err(anyhow::Error::msg)?;
    let kind = match backend_arg.as_str() {
        "host" => BackendKind::Host,
        _ => BackendKind::Sim,
    };

    let policies: Vec<PrecisionPolicy> = policy_list
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse::<PrecisionPolicy>())
        .collect::<std::result::Result<_, _>>()
        .map_err(anyhow::Error::msg)?;

    let mut cfg = PipelineConfig::tiny_96();
    cfg.fp32_reference = agreement;
    let mut factory = AnyFactory::new(kind, "artifacts".to_string());
    factory.host = HostConfig { num_classes: cfg.num_classes, ..HostConfig::default() };

    println!(
        "== precision_sweep: {frames} frames/point, policies [{policy_list}], \
         batch {batch_sizes:?}, backend {kind}, agreement {agreement} ==\n"
    );

    let mut rows = Vec::new();
    for &b in &batch_sizes {
        for &policy in &policies {
            let opts = ServeOptions {
                sensor_seed: seed,
                batch: BatchPolicy::batched(b, batch_wait),
                precision: policy,
                ..ServeOptions::frames(frames)
            };
            let (report, _metrics) = serve_sharded(&cfg, &factory, workers, &opts)?;
            println!(
                "policy {policy}, batch {b}: tiers [{}, {}, {}], {}/frame, {:.1} KFPS/W",
                report.tier_frames[0],
                report.tier_frames[1],
                report.tier_frames[2],
                si_energy(report.mean_energy_j),
                report.modeled_kfps_per_watt,
            );
            rows.push(Row { policy, batch: b, report });
        }
    }

    println!("\n== precision summary ==");
    let mut t = Table::new(vec![
        "policy", "batch", "int4", "int8", "fp32", "energy/frame", "KFPS/W", "agree-4", "agree-8",
        "saving",
    ]);
    for r in &rows {
        let saving = int8_energy(&rows, r.batch)
            .filter(|&base| base > 0.0)
            .map(|base| format!("{:+.1}%", (1.0 - r.report.mean_energy_j / base) * 100.0))
            .unwrap_or_else(|| "-".to_string());
        let agree = |tier| {
            r.report
                .tier_agreement(tier)
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "-".to_string())
        };
        t.row(vec![
            r.policy.to_string(),
            r.batch.to_string(),
            r.report.tier_frames[0].to_string(),
            r.report.tier_frames[1].to_string(),
            r.report.tier_frames[2].to_string(),
            si_energy(r.report.mean_energy_j),
            format!("{:.1}", r.report.modeled_kfps_per_watt),
            agree(PrecisionTier::Int4),
            agree(PrecisionTier::Int8),
            saving,
        ]);
    }
    print!("{}", t.render());

    let json = fmt_json(frames, kind.as_str(), &rows);
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path}");
    Ok(())
}
