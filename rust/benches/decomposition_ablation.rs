//! Eq. 2 ablation: the matrix-decomposed attention dataflow vs the direct
//! (naive Q·K^T) flow, swept over token count and tuning latency.
//!
//! Reproduction finding (EXPERIMENTS.md): the decomposition removes the
//! K^T tuning stall and the K buffer round-trip, but costs h× more optical
//! MACs on the score MatMul. It wins in the paper's regime — slow tuning
//! and RoI-masked (small) token counts — and *loses* at large n with fast
//! tuning. This bench prints the full regime map plus the buffer-traffic
//! savings, which hold everywhere.

use optovit::arch::core::CoreParams;
use optovit::arch::scheduler::AttentionSchedule;
use optovit::arch::workload::Workload;
use optovit::util::bench::time_fn;
use optovit::util::table::Table;
use optovit::vit::{VitConfig, VitVariant};

fn main() {
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);

    println!("== Eq. 2 regime map: attention-phase makespan, decomposed vs direct ==");
    println!("(cells: decomposed/direct makespan ratio; <1 = decomposition wins)\n");
    let tokens = [5usize, 9, 13, 19, 37];
    let tunes = [40.0, 100.0, 250.0, 500.0, 1000.0];
    let mut t = Table::new(
        std::iter::once("tune_ns \\ n".to_string())
            .chain(tokens.iter().map(|n| n.to_string()))
            .collect::<Vec<_>>(),
    );
    for &tune in &tunes {
        let p = CoreParams { tune_ns: tune, ..CoreParams::default() };
        let mut row = vec![format!("{tune:.0}")];
        for &n in &tokens {
            let d = AttentionSchedule::attention_only(&cfg, n, p, 1, false).schedule(5).1;
            let dc = AttentionSchedule::attention_only(&cfg, n, p, 1, true).schedule(5).1;
            row.push(format!("{:.3}", dc.makespan_ns / d.makespan_ns));
        }
        t.row(row);
    }
    print!("{}", t.render());

    println!("\n== exposed tuning time per frame (n = 13, RoI-masked) ==");
    let mut t = Table::new(vec!["tune_ns", "direct (us)", "decomposed (us)"]);
    for &tune in &tunes {
        let p = CoreParams { tune_ns: tune, ..CoreParams::default() };
        let d = AttentionSchedule::attention_only(&cfg, 13, p, 1, false).schedule(5).1;
        let dc = AttentionSchedule::attention_only(&cfg, 13, p, 1, true).schedule(5).1;
        t.row(vec![
            format!("{tune:.0}"),
            format!("{:.2}", d.exposed_tune_ns / 1000.0),
            format!("{:.2}", dc.exposed_tune_ns / 1000.0),
        ]);
    }
    print!("{}", t.render());

    println!("\n== MAC and buffering cost (whole network, Tiny-96) ==");
    let direct = Workload::vit(&cfg, cfg.num_patches(), false);
    let decomp = Workload::vit(&cfg, cfg.num_patches(), true);
    let mut t = Table::new(vec!["flow", "total MACs", "intermediate tunings", "K buffered?"]);
    t.row(vec![
        "direct".to_string(),
        direct.total_macs().to_string(),
        direct.intermediate_tunings().to_string(),
        "yes (h*n*dk per block)".to_string(),
    ]);
    t.row(vec![
        "decomposed (Eq. 2)".to_string(),
        decomp.total_macs().to_string(),
        decomp.intermediate_tunings().to_string(),
        "no".to_string(),
    ]);
    print!("{}", t.render());
    println!(
        "\ndecomposition MAC overhead: {:+.1}%; intermediate tunings removed: {}",
        (decomp.total_macs() as f64 / direct.total_macs() as f64 - 1.0) * 100.0,
        direct.intermediate_tunings() - decomp.intermediate_tunings()
    );

    let p = CoreParams::default();
    let timing = time_fn("regime map cell (schedule pair)", 1, 10, || {
        let d = AttentionSchedule::attention_only(&cfg, 13, p, 1, false).schedule(5).1;
        let dc = AttentionSchedule::attention_only(&cfg, 13, p, 1, true).schedule(5).1;
        d.makespan_ns + dc.makespan_ns
    });
    println!("\n{}", timing.summary());
}
