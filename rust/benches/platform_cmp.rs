//! §IV "Performance Comparison Vs. Common Computing Platforms": Opto-ViT
//! vs Xilinx VCK190 and NVIDIA A100 (INT8), per the configurations of [54].

use optovit::baselines;
use optovit::util::bench::time_fn;
use optovit::util::table::Table;

fn main() {
    println!("== platform comparison (same ViT, INT8 everywhere) ==\n");
    let ours = baselines::optovit_kfps_per_watt();
    let mut t = Table::new(vec!["platform", "KFPS/W", "Opto-ViT advantage"]);
    t.row(vec!["Opto-ViT (this work)".to_string(), format!("{ours:.2}"), "ref".to_string()]);
    for p in baselines::reference_platforms() {
        t.row(vec![
            p.name.to_string(),
            format!("{:.2}", p.kfps_per_watt),
            format!("{:.0}x", ours / p.kfps_per_watt),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\npaper: 100.4 vs 1.42 (VCK190) and 0.86 (A100) KFPS/W — two to three orders \
         of magnitude; measured advantage: {:.0}x / {:.0}x",
        ours / 1.42,
        ours / 0.86
    );

    let timing = time_fn("platform table", 2, 50, || baselines::optovit_kfps_per_watt());
    println!("\n{}", timing.summary());
}
