//! Worker- and batch-scaling bench for the sharded serving engine.
//!
//! Sweeps `--workers 1,2,4` × `--batch 1` (defaults) through
//! `coordinator::engine`, and emits a machine-readable `BENCH_serve.json`
//! (wall-FPS, mean latency, allocations/frame from the counting allocator,
//! modeled energy/frame, micro-batch size, and speedup vs. the
//! 1-worker/batch-1 point) so the perf trajectory is trackable across PRs.
//!
//! ```bash
//! cargo bench --bench serve_scaling -- \
//!     [--workers 1,2,4] [--batch 1,4,8] [--batch-wait-us 500] \
//!     [--frames 240] [--backend auto|pjrt|host] \
//!     [--host-depth N] [--out BENCH_serve.json] [--artifacts artifacts]
//! ```
//!
//! (declared `harness = false`: this bench carries its own `main`.)
//!
//! The execution substrate comes from the shared `runtime::Backend`
//! abstraction — no bench-private compute fallback. `--backend auto`
//! (default) drives real PJRT pipelines when compiled artifacts are
//! present and the pure-Rust `HostBackend` otherwise, so the host-side
//! scaling behaviour is measurable on any machine; the JSON records which
//! backend produced the numbers. `--batch B` sets the per-worker
//! bucket-major micro-batch size (frames per `Backend::execute_batch`
//! dispatch); each JSON row records the requested size and the observed
//! frame-weighted mean.

use anyhow::Result;
use optovit::cli::Args;
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::serve_sharded;
use optovit::coordinator::pipeline::{PipelineConfig, ServeOptions, ServeReport};
use optovit::runtime::{AnyFactory, BackendKind, HostConfig};
use optovit::util::bench::{alloc_count, CountingAlloc};
use optovit::util::table::{si_energy, si_time, Table};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

struct Row {
    workers: usize,
    batch: usize,
    report: ServeReport,
    allocs_per_frame: f64,
}

/// The `speedup_vs_1` denominator: the 1-worker/batch-1 row wherever it
/// appears in the sweep, falling back to the first row only when no such
/// point was requested.
fn baseline_fps(rows: &[Row]) -> f64 {
    rows.iter()
        .find(|r| r.workers == 1 && r.batch == 1)
        .or_else(|| rows.first())
        .map(|r| r.report.wall_fps)
        .unwrap_or(0.0)
}

fn fmt_json(frames: u64, backend: &str, rows: &[Row]) -> String {
    let base_fps = baseline_fps(rows);
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"serve_scaling\",\n");
    out.push_str(&format!("  \"backend\": \"{backend}\",\n"));
    out.push_str(&format!("  \"frames\": {frames},\n"));
    out.push_str("  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let speedup = if base_fps > 0.0 { r.report.wall_fps / base_fps } else { 0.0 };
        out.push_str(&format!(
            "    {{\"workers\": {}, \"batch\": {}, \"mean_batch\": {:.2}, \
             \"wall_fps\": {:.3}, \"mean_latency_s\": {:.6e}, \
             \"mean_energy_j\": {:.6e}, \"allocs_per_frame\": {:.1}, \"dropped\": {}, \
             \"speedup_vs_1\": {:.3}}}{}\n",
            r.workers,
            r.batch,
            r.report.mean_batch,
            r.report.wall_fps,
            r.report.mean_latency_s,
            r.report.mean_energy_j,
            r.allocs_per_frame,
            r.report.dropped,
            speedup,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let worker_counts = args.get_usize_list("workers", &[1, 2, 4]).map_err(anyhow::Error::msg)?;
    let batch_sizes = args.get_usize_list("batch", &[1]).map_err(anyhow::Error::msg)?;
    let batch_wait = args.get_duration_us("batch-wait-us", 500).map_err(anyhow::Error::msg)?;
    let frames = args.get_u64("frames", 240).map_err(anyhow::Error::msg)?;
    let out_path = args.get_or("out", "BENCH_serve.json").to_string();
    let artifact_dir = args.get_or("artifacts", "artifacts").to_string();
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    let backend_arg = args
        .get_choice("backend", &["auto", "pjrt", "host"], "auto")
        .map_err(anyhow::Error::msg)?;
    let host_depth = args.get_usize("host-depth", 0).map_err(anyhow::Error::msg)?;

    let cfg = PipelineConfig::tiny_96();
    let have_artifacts = std::path::Path::new(&artifact_dir)
        .join(format!("{}.hlo.txt", cfg.mgnet_artifact()))
        .exists();
    let kind = match backend_arg.as_str() {
        "pjrt" => BackendKind::Pjrt,
        "host" => BackendKind::Host,
        // "auto": real inference only when the pjrt substrate is compiled
        // in AND artifacts exist; otherwise fall back to host reference
        // compute (an explicit `--backend pjrt` still errors clearly at
        // factory-create time when the feature is off).
        _ => {
            if cfg!(feature = "pjrt") && have_artifacts {
                BackendKind::Pjrt
            } else {
                BackendKind::Host
            }
        }
    };
    let mut factory = AnyFactory::new(kind, artifact_dir);
    factory.host = HostConfig {
        num_classes: cfg.num_classes,
        depth_limit: (host_depth > 0).then_some(host_depth),
        ..HostConfig::default()
    };
    println!(
        "== serve_scaling: {frames} frames/point, workers {worker_counts:?}, \
         batch {batch_sizes:?}, backend {kind} ==\n"
    );

    let opts_for = |b: usize, n: u64| ServeOptions {
        sensor_seed: seed,
        batch: BatchPolicy::batched(b, batch_wait),
        ..ServeOptions::frames(n)
    };

    let mut rows = Vec::new();
    for &w in &worker_counts {
        for &b in &batch_sizes {
            // Backend construction + warmup allocate (per worker, per
            // run), so a single-run count would inflate allocs/frame and
            // scale with --workers. Two runs at different frame counts
            // cancel the fixed setup cost in the difference, leaving the
            // per-frame slope.
            let calib_frames = frames / 4;
            let a0 = alloc_count();
            let calib = if calib_frames >= 8 && calib_frames < frames {
                Some(serve_sharded(&cfg, &factory, w, &opts_for(b, calib_frames))?.0)
            } else {
                None
            };
            let a1 = alloc_count();
            let (report, _metrics) = serve_sharded(&cfg, &factory, w, &opts_for(b, frames))?;
            let a2 = alloc_count();
            let allocs_per_frame = match &calib {
                Some(c) if report.frames > c.frames => {
                    let slope = (a2 - a1) as f64 - (a1 - a0) as f64;
                    (slope / (report.frames - c.frames) as f64).max(0.0)
                }
                // Short sweeps fall back to the raw per-run count
                // (includes the fixed setup cost — fine for a smoke run).
                _ if report.frames > 0 => (a2 - a1) as f64 / report.frames as f64,
                _ => 0.0,
            };
            println!(
                "workers {w}, batch {b}: {:.1} fps, {} mean latency, mean batch {:.2}, \
                 {:.0} allocs/frame, {} dropped",
                report.wall_fps,
                si_time(report.mean_latency_s),
                report.mean_batch,
                allocs_per_frame,
                report.dropped
            );
            rows.push(Row { workers: w, batch: b, report, allocs_per_frame });
        }
    }

    println!("\n== scaling summary ==");
    let base = baseline_fps(&rows);
    let mut t = Table::new(vec![
        "workers", "batch", "mean batch", "wall fps", "speedup", "mean latency", "energy/frame",
    ]);
    for r in &rows {
        t.row(vec![
            r.workers.to_string(),
            r.batch.to_string(),
            format!("{:.2}", r.report.mean_batch),
            format!("{:.1}", r.report.wall_fps),
            format!("{:.2}x", if base > 0.0 { r.report.wall_fps / base } else { 0.0 }),
            si_time(r.report.mean_latency_s),
            si_energy(r.report.mean_energy_j),
        ]);
    }
    print!("{}", t.render());

    let json = fmt_json(frames, kind.as_str(), &rows);
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path}");
    Ok(())
}
