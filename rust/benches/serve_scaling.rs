//! Worker-scaling bench for the sharded serving engine.
//!
//! Sweeps `--workers 1,2,4` (default) through `coordinator::engine`, and
//! emits a machine-readable `BENCH_serve.json` (wall-FPS, mean latency,
//! allocations/frame from the counting allocator, modeled energy/frame,
//! and speedup vs. 1 worker) so the perf trajectory is trackable across
//! PRs.
//!
//! ```bash
//! cargo bench --bench serve_scaling -- \
//!     [--workers 1,2,4] [--frames 240] [--out BENCH_serve.json] [--artifacts artifacts]
//! ```
//!
//! (declared `harness = false`: this bench carries its own `main`.)
//!
//! With compiled artifacts present the sweep drives real PJRT pipelines;
//! otherwise it falls back to a synthetic host-compute worker with the
//! same sensor → patchify → mask → route → backbone structure, so the
//! host-side scaling behaviour is measurable on any machine.

use anyhow::Result;
use optovit::cli::Args;
use optovit::coordinator::engine::{self, serve_sharded, EngineConfig, FrameWorker};
use optovit::coordinator::pipeline::{FrameResult, FrameScratch, PipelineConfig, ServeReport};
use optovit::coordinator::{BucketRouter, StageMetrics};
use optovit::energy::AcceleratorModel;
use optovit::sensor::Frame;
use optovit::util::bench::{alloc_count, CountingAlloc};
use optovit::util::table::{si_energy, si_time, Table};
use optovit::vit::{MgnetConfig, VitConfig};
use std::time::Instant;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Host-compute stand-in for a PJRT pipeline: same staging hot path
/// (shared `FrameScratch` code), with a deterministic arithmetic backbone
/// whose cost scales with the routed bucket.
struct SyntheticWorker {
    scratch: FrameScratch,
    router: BucketRouter,
    model: AcceleratorModel,
    vit: VitConfig,
    mgnet: MgnetConfig,
    metrics: StageMetrics,
    score_buf: Vec<f32>,
    /// Backbone work passes per frame (tunes per-frame cost into the
    /// ~millisecond range a compiled Tiny backbone occupies).
    work_iters: usize,
}

impl SyntheticWorker {
    fn new(cfg: &PipelineConfig, work_iters: usize) -> Self {
        let vit = cfg.vit_config();
        SyntheticWorker {
            scratch: FrameScratch::for_config(cfg),
            router: BucketRouter::new(cfg.buckets.clone()),
            model: AcceleratorModel::default(),
            vit,
            mgnet: cfg.mgnet_config(),
            metrics: StageMetrics::new(),
            score_buf: vec![0.0; vit.num_patches()],
            work_iters,
        }
    }
}

impl FrameWorker for SyntheticWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        let t_start = Instant::now();
        let patch_px = self.vit.patch_size;
        let side = frame.size / patch_px;
        let patch_dim = self.vit.patch_dim();

        self.scratch.stage_patchify(frame, patch_px);

        // Brightness-contrast score per patch: a cheap MGNet stand-in that
        // still tracks the moving objects over the dim background.
        for (p, score) in self.score_buf.iter_mut().enumerate() {
            let row = &self.scratch.patches()[p * patch_dim..(p + 1) * patch_dim];
            let mean: f32 = row.iter().sum::<f32>() / patch_dim as f32;
            *score = (mean - 0.35) * 12.0;
        }
        self.scratch.stage_mask(side, &self.score_buf, 0.5);

        let bucket = self.scratch.stage_route(&self.router, patch_dim);
        let kept = self.scratch.kept().len();

        // Deterministic arithmetic "backbone" over the staged bucket.
        let staged = self.scratch.bucket_patches(bucket, patch_dim);
        let mut logits = vec![0.0f32; 10];
        for it in 0..self.work_iters {
            let mut acc = 0.0f32;
            for (i, &x) in staged.iter().enumerate() {
                acc += x * ((i % 7) as f32 - 3.0);
            }
            logits[it % 10] += acc * 1e-3;
        }
        std::hint::black_box(&logits);

        let energy_j = self.model.masked_energy(&self.vit, &self.mgnet, kept).total_j();
        let latency = t_start.elapsed().as_secs_f64();
        self.metrics.record_stage("total", latency);
        self.metrics.record_frame(energy_j, kept);
        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask: self.scratch.mask().clone(),
            bucket,
            modeled_energy_j: energy_j,
            latency_s: latency,
        })
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }
}

struct Row {
    workers: usize,
    report: ServeReport,
    allocs_per_frame: f64,
}

/// The `speedup_vs_1` denominator: the 1-worker row wherever it appears in
/// the sweep, falling back to the first row only when no 1-worker point
/// was requested.
fn baseline_fps(rows: &[Row]) -> f64 {
    rows.iter()
        .find(|r| r.workers == 1)
        .or_else(|| rows.first())
        .map(|r| r.report.wall_fps)
        .unwrap_or(0.0)
}

fn fmt_json(frames: u64, mode: &str, rows: &[Row]) -> String {
    let base_fps = baseline_fps(rows);
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"serve_scaling\",\n");
    out.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    out.push_str(&format!("  \"frames\": {frames},\n"));
    out.push_str("  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let speedup = if base_fps > 0.0 { r.report.wall_fps / base_fps } else { 0.0 };
        out.push_str(&format!(
            "    {{\"workers\": {}, \"wall_fps\": {:.3}, \"mean_latency_s\": {:.6e}, \
             \"mean_energy_j\": {:.6e}, \"allocs_per_frame\": {:.1}, \"dropped\": {}, \
             \"speedup_vs_1\": {:.3}}}{}\n",
            r.workers,
            r.report.wall_fps,
            r.report.mean_latency_s,
            r.report.mean_energy_j,
            r.allocs_per_frame,
            r.report.dropped,
            speedup,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let worker_counts = args.get_usize_list("workers", &[1, 2, 4]).map_err(anyhow::Error::msg)?;
    let frames = args.get_u64("frames", 240).map_err(anyhow::Error::msg)?;
    let out_path = args.get_or("out", "BENCH_serve.json").to_string();
    let artifact_dir = args.get_or("artifacts", "artifacts").to_string();
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;

    let cfg = PipelineConfig::tiny_96();
    let have_artifacts = std::path::Path::new(&artifact_dir)
        .join(format!("{}.hlo.txt", cfg.mgnet_artifact()))
        .exists();
    let mode = if have_artifacts { "pjrt" } else { "synthetic" };
    println!(
        "== serve_scaling: {frames} frames/point, workers {worker_counts:?}, mode {mode} ==\n"
    );

    let mut rows = Vec::new();
    for &w in &worker_counts {
        let a0 = alloc_count();
        let (report, _metrics) = if have_artifacts {
            serve_sharded(&cfg, &artifact_dir, w, 4, seed, 2, frames)?
        } else {
            let vit = cfg.vit_config();
            let mut ecfg = EngineConfig::new(w, vit.patch_size, cfg.image_size);
            ecfg.sensor_seed = seed;
            engine::run(|_wid| Ok(SyntheticWorker::new(&cfg, 150)), &ecfg, frames, |_r| {})?
        };
        let allocs = alloc_count() - a0;
        let allocs_per_frame =
            if report.frames > 0 { allocs as f64 / report.frames as f64 } else { 0.0 };
        println!(
            "workers {w}: {:.1} fps, {} mean latency, {:.0} allocs/frame, {} dropped",
            report.wall_fps,
            si_time(report.mean_latency_s),
            allocs_per_frame,
            report.dropped
        );
        rows.push(Row { workers: w, report, allocs_per_frame });
    }

    println!("\n== scaling summary ==");
    let base = baseline_fps(&rows);
    let mut t = Table::new(vec!["workers", "wall fps", "speedup", "mean latency", "energy/frame"]);
    for r in &rows {
        t.row(vec![
            r.workers.to_string(),
            format!("{:.1}", r.report.wall_fps),
            format!("{:.2}x", if base > 0.0 { r.report.wall_fps / base } else { 0.0 }),
            si_time(r.report.mean_latency_s),
            si_energy(r.report.mean_energy_j),
        ]);
    }
    print!("{}", t.render());

    let json = fmt_json(frames, mode, &rows);
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path}");
    Ok(())
}
