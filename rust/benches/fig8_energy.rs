//! Fig. 8 reproduction: per-frame energy breakdown (Tuning, VCSEL, BPD,
//! ADC, DAC, Memory, EPU) for {Tiny, Small, Base, Large} × {224², 96²},
//! plus the Tiny-96 pie-chart shares.

use optovit::energy::AcceleratorModel;
use optovit::util::bench::time_fn;
use optovit::util::table::{si_energy, Table};
use optovit::vit::{VitConfig, VitVariant};

fn main() {
    let m = AcceleratorModel::default();
    println!("== Fig. 8: energy breakdown per frame (decomposed flow, unmasked) ==\n");
    let mut t = Table::new(vec![
        "model", "res", "total", "Tuning", "VCSEL", "BPD", "ADC", "DAC", "Memory", "EPU",
    ]);
    for v in VitVariant::ALL {
        for res in [224usize, 96] {
            let cfg = VitConfig::variant(v, res, 1000);
            let e = m.frame_energy(&cfg, cfg.num_patches(), true);
            t.row(vec![
                v.name().to_string(),
                res.to_string(),
                si_energy(e.total_j()),
                si_energy(e.tuning_j),
                si_energy(e.vcsel_j),
                si_energy(e.bpd_j),
                si_energy(e.adc_j),
                si_energy(e.dac_j),
                si_energy(e.memory_j),
                si_energy(e.epu_j),
            ]);
        }
    }
    print!("{}", t.render());

    println!("\n== Fig. 8 pie: Tiny-96 component shares ==");
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    let e96 = m.frame_energy(&cfg, cfg.num_patches(), true);
    let mut t = Table::new(vec!["component", "share %"]);
    let mut shares = e96.shares();
    shares.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, s) in &shares {
        t.row(vec![name.to_string(), format!("{:.1}", s * 100.0)]);
    }
    print!("{}", t.render());
    println!(
        "\npaper claim: ADC is the largest share — measured top component: {} ({:.1}%)",
        shares[0].0,
        shares[0].1 * 100.0
    );

    let timing = time_fn("fig8 full grid (8 reports)", 2, 10, || {
        let mut acc = 0.0;
        for v in VitVariant::ALL {
            for res in [224usize, 96] {
                let cfg = VitConfig::variant(v, res, 1000);
                acc += m.frame_energy(&cfg, cfg.num_patches(), true).total_j();
            }
        }
        acc
    });
    println!("\n{}", timing.summary());
}
