//! Fig. 10 reproduction: energy with and without the MGNet RoI front end
//! for the baseline (Base) backbone at 224² and 96², across RoI keep
//! ratios — energy savings scale with the number of skipped patches.

use optovit::energy::AcceleratorModel;
use optovit::util::bench::time_fn;
use optovit::util::table::{si_energy, Table};
use optovit::vit::{MgnetConfig, VitConfig, VitVariant};

fn main() {
    let m = AcceleratorModel::default();
    println!("== Fig. 10: baseline ViT energy, with vs without MGNet RoI ==\n");
    for res in [224usize, 96] {
        let cfg = VitConfig::variant(VitVariant::Base, res, 1000);
        let mg = MgnetConfig::classification(res);
        let full = m.frame_energy(&cfg, cfg.num_patches(), true);
        println!("-- input {res}x{res} ({} patches) --", cfg.num_patches());
        let mut t = Table::new(vec![
            "operating point", "kept patches", "skip% (pixel)", "energy/frame", "saving %",
        ]);
        t.row(vec![
            "no MGNet (all patches)".to_string(),
            cfg.num_patches().to_string(),
            "0.00".to_string(),
            si_energy(full.total_j()),
            "ref".to_string(),
        ]);
        for keep in [0.75, 0.50, 0.33, 0.25, 0.15] {
            let kept = ((cfg.num_patches() as f64) * keep).round().max(1.0) as usize;
            let r = m.masked_energy(&cfg, &mg, kept);
            let sav = (1.0 - r.total_j() / full.total_j()) * 100.0;
            t.row(vec![
                format!("MGNet keep {:.0}%", keep * 100.0),
                kept.to_string(),
                format!("{:.2}", 1.0 - kept as f64 / cfg.num_patches() as f64),
                si_energy(r.total_j()),
                format!("{sav:.1}"),
            ]);
        }
        print!("{}", t.render());
        let best = m.masked_energy(&cfg, &mg, ((cfg.num_patches() as f64) * 0.15) as usize);
        println!(
            "max saving at this resolution: {:.1}% (paper: up to 84% across operating points)\n",
            (1.0 - best.total_j() / full.total_j()) * 100.0
        );
    }

    let cfg = VitConfig::variant(VitVariant::Base, 224, 1000);
    let mg = MgnetConfig::classification(224);
    let timing = time_fn("masked_energy (Base-224)", 1, 50, || {
        m.masked_energy(&cfg, &mg, 65).total_j()
    });
    println!("{}", timing.summary());
}
