//! Fig. 2(a,b) reproduction: MR through-port spectra under weight
//! imprinting, and multi-MR weight banks on one arm.

use optovit::photonics::{ChannelGrid, CrosstalkModel, MicroRing, MrGeometry};
use optovit::util::bench::time_fn;
use optovit::util::table::Table;

fn main() {
    let geometry = MrGeometry::default();
    let ring = MicroRing::at_wavelength(geometry, 5000.0, 1550.0);

    println!("== Fig. 2(a): through-port transmission vs detuning (Q=5000) ==");
    println!("(weight imprinting: detune the resonance so T(lambda_sig) = w)\n");
    let mut t = Table::new(vec!["weight", "detune (pm)", "T at signal", "heater dT (K)"]);
    for &w in &[0.05, 0.25, 0.5, 0.75, 0.95] {
        let det = ring.detuning_for_weight(w);
        t.row(vec![
            format!("{w:.2}"),
            format!("{:.2}", det * 1000.0),
            format!("{:.4}", ring.transmission(ring.lambda_res_nm, det)),
            format!("{:.2}", ring.temperature_for_detuning(det)),
        ]);
    }
    print!("{}", t.render());

    println!("\n== Fig. 2(a) spectrum: T(lambda) around resonance ==");
    let mut t = Table::new(vec!["lambda - lambda_res (pm)", "T"]);
    let d = ring.delta_nm();
    for k in -8..=8 {
        let off = k as f64 * d / 2.0;
        t.row(vec![
            format!("{:+.1}", off * 1000.0),
            format!("{:.4}", ring.transmission(ring.lambda_res_nm + off, 0.0)),
        ]);
    }
    print!("{}", t.render());

    println!("\n== Fig. 2(b): 32-MR arm — per-channel weight imprinting ==");
    let grid = ChannelGrid::c_band(32);
    let model = CrosstalkModel::new(grid, 5000.0);
    let mut t = Table::new(vec!["channel", "lambda (nm)", "phi(adjacent)", "phi(2 away)"]);
    for &i in &[0usize, 8, 16, 24, 31] {
        let adj = if i + 1 < 32 { model.phi(i, i + 1) } else { model.phi(i, i - 1) };
        let two = if i + 2 < 32 { model.phi(i, i + 2) } else { model.phi(i, i - 2) };
        t.row(vec![
            i.to_string(),
            format!("{:.2}", model.grid.wavelengths_nm[i]),
            format!("{adj:.3e}"),
            format!("{two:.3e}"),
        ]);
    }
    print!("{}", t.render());

    let timing = time_fn("spectrum eval (1k points)", 2, 20, || {
        let mut acc = 0.0;
        for k in 0..1000 {
            acc += ring.transmission(1549.0 + k as f64 * 0.002, 0.0);
        }
        acc
    });
    println!("\n{}", timing.summary());
}
