//! Fleet-elasticity storm bench: open-loop arrival scenarios swept
//! through hundreds of synthetic camera sessions against the session
//! server, **with and without** the SLO-driven autoscaler, emitting the
//! machine-readable `BENCH_storm.json` (p99-vs-offered-load sample
//! curves, scale-event logs, shed/drop/miss totals) so the elasticity
//! trajectory is trackable across PRs.
//!
//! ```bash
//! cargo bench --bench serve_storm -- \
//!     [--sessions 200] [--duration 60] [--workers 2] [--max-workers 8] \
//!     [--batch 8] [--service-ms 500] [--slo-ms 1500] [--seed 42] \
//!     [--out BENCH_storm.json]
//! ```
//!
//! (declared `harness = false`: this bench carries its own `main`.)
//!
//! Every sweep is **deterministic**: `loadgen::run_scenario` owns a
//! manual clock, arrival schedules are precomputed (seeded where
//! random), and workers model service time by sleeping on the serving
//! clock — wall time only affects how fast the sweep runs, never what
//! it measures. The four scenario shapes: a capacity-crossing **step**,
//! a **10x burst**, a **diurnal** sine, and seeded-**Poisson** jitter.
//! The fixed arm shows the failure mode (p99 blow-up, SLO misses); the
//! autoscaled arm shows the controller riding the same storm (scale-ups
//! into the burst, shedding at the cap, scale-downs after).

use anyhow::Result;
use optovit::cli::Args;
use optovit::coordinator::autoscale::{ScaleAction, ScalePolicy};
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::EngineConfig;
use optovit::coordinator::loadgen::{run_scenario, Scenario, StormConfig, StormOutcome};
use optovit::util::table::{si_time, Table};

struct Row {
    autoscaled: bool,
    outcome: StormOutcome,
}

fn event_counts(outcome: &StormOutcome) -> (usize, usize, usize) {
    let ups = outcome.scale_events.iter().filter(|e| e.action == ScaleAction::Up).count();
    let downs = outcome.scale_events.iter().filter(|e| e.action == ScaleAction::Down).count();
    let sheds = outcome
        .scale_events
        .iter()
        .filter(|e| matches!(e.action, ScaleAction::ShedOn { .. }))
        .count();
    (ups, downs, sheds)
}

fn max_p99(outcome: &StormOutcome) -> f64 {
    outcome.samples.iter().map(|s| s.p99_s).fold(0.0, f64::max)
}

fn fmt_json(sessions: usize, duration_s: f64, rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"serve_storm\",\n");
    out.push_str(&format!("  \"sessions\": {sessions},\n"));
    out.push_str(&format!("  \"duration_s\": {duration_s},\n"));
    out.push_str("  \"sweeps\": [\n");
    for (i, row) in rows.iter().enumerate() {
        let o = &row.outcome;
        let (ups, downs, sheds) = event_counts(o);
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"autoscale\": {}, \"frames\": {}, \
             \"dropped\": {}, \"dropped_quota\": {}, \"dropped_shed\": {}, \
             \"slo_miss\": {}, \"final_workers\": {}, \
             \"scale_ups\": {ups}, \"scale_downs\": {downs}, \"shed_events\": {sheds},\n",
            o.scenario,
            row.autoscaled,
            o.frames,
            o.dropped,
            o.dropped_quota,
            o.dropped_shed,
            o.slo_miss,
            o.live_workers,
        ));
        out.push_str("     \"samples\": [\n");
        for (j, s) in o.samples.iter().enumerate() {
            out.push_str(&format!(
                "       {{\"t_s\": {:.1}, \"offered_fps\": {:.3}, \"achieved_fps\": {:.3}, \
                 \"p99_s\": {:.6}, \"workers\": {}, \"queue_depth\": {}, \"shed_below\": {}}}{}\n",
                s.t_s,
                s.offered_fps,
                s.achieved_fps,
                s.p99_s,
                s.live_workers,
                s.queue_depth,
                s.shed_below,
                if j + 1 < o.samples.len() { "," } else { "" }
            ));
        }
        out.push_str("     ],\n");
        out.push_str("     \"scale_events\": [\n");
        for (j, e) in o.scale_events.iter().enumerate() {
            let action = match &e.action {
                ScaleAction::Up => "up".to_string(),
                ScaleAction::Down => "down".to_string(),
                ScaleAction::ShedOn { below_weight } => format!("shed_below_{below_weight}"),
                ScaleAction::ShedOff => "shed_off".to_string(),
            };
            out.push_str(&format!(
                "       {{\"at_s\": {:.3}, \"action\": \"{action}\", \"workers\": {}}}{}\n",
                e.at_s,
                e.workers,
                if j + 1 < o.scale_events.len() { "," } else { "" }
            ));
        }
        out.push_str("     ]\n");
        out.push_str(&format!("    }}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let sessions = args.get_usize("sessions", 200).map_err(anyhow::Error::msg)?.max(1);
    let duration_s = args.get_f64("duration", 60.0).map_err(anyhow::Error::msg)?.max(10.0);
    let workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?.max(1);
    let max_workers = args.get_usize("max-workers", 8).map_err(anyhow::Error::msg)?.max(workers);
    let batch = args.get_usize("batch", 8).map_err(anyhow::Error::msg)?.max(1);
    let service_ms = args.get_f64("service-ms", 500.0).map_err(anyhow::Error::msg)?;
    let service = std::time::Duration::from_secs_f64(service_ms.clamp(0.0, 1_000.0) / 1000.0);
    let slo_ms = args.get_f64("slo-ms", 1500.0).map_err(anyhow::Error::msg)?;
    let slo = std::time::Duration::from_secs_f64(slo_ms.max(1.0) / 1000.0);
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    let out_path = args.get_or("out", "BENCH_storm.json").to_string();

    // Modeled capacity: one micro-batch of `batch` frames per worker per
    // 1 s tick. The scenario rates are written against it: base load at
    // half the starting pool's capacity, storms crossing the elastic
    // ceiling so the autoscaled arm has real work (and the shed ladder a
    // reason to fire).
    let cap0 = (workers * batch) as f64;
    let base = cap0 / 2.0;
    let third = duration_s / 3.0;
    let scenarios = [
        Scenario::step("step", sessions, duration_s, base, cap0 * 2.0, third),
        Scenario::burst("burst10x", sessions, duration_s, base, 10.0, third, third + duration_s / 6.0),
        Scenario::diurnal("diurnal", sessions, duration_s, cap0, 0.75, duration_s),
        Scenario::poisson("poisson", sessions, duration_s, cap0 * 0.75, seed),
    ];
    let policy = ScalePolicy {
        min_workers: workers,
        max_workers,
        shed_after: 3,
        ..ScalePolicy::default()
    };

    println!(
        "== serve_storm: {sessions} sessions, {duration_s:.0} s/scenario, \
         {workers}..{max_workers} workers x batch {batch}, service {} ==\n",
        si_time(service.as_secs_f64())
    );
    let mut rows = Vec::new();
    for scenario in &scenarios {
        for autoscaled in [false, true] {
            let mut cfg = EngineConfig::new(workers, 16, 96);
            cfg.batch = BatchPolicy::batched(batch, std::time::Duration::from_millis(1));
            cfg.queue_depth = 64;
            cfg.max_workers = if autoscaled { max_workers } else { 0 };
            cfg.warmup_timeout_s = 24.0 * 3600.0;
            cfg.stall_timeout_s = 24.0 * 3600.0;
            let storm = StormConfig {
                tick: std::time::Duration::from_secs(1),
                sample_every: 5,
                service,
                slo: Some(slo),
                autoscale: autoscaled.then(|| policy.clone()),
            };
            let outcome = run_scenario(cfg, &storm, scenario)?;
            let (ups, downs, sheds) = event_counts(&outcome);
            println!(
                "{:<9} {}: {} frames, {} shed, {} slo miss, max p99 {}, \
                 {} ups / {} downs / {} shed events, {} workers at close",
                outcome.scenario,
                if autoscaled { "autoscaled" } else { "fixed     " },
                outcome.frames,
                outcome.dropped_shed,
                outcome.slo_miss,
                si_time(max_p99(&outcome)),
                ups,
                downs,
                sheds,
                outcome.live_workers,
            );
            rows.push(Row { autoscaled, outcome });
        }
    }

    println!("\n== storm summary ==");
    let mut t = Table::new(vec![
        "scenario", "mode", "frames", "dropped", "shed", "slo miss", "max p99", "workers",
        "ups/downs",
    ]);
    for row in &rows {
        let o = &row.outcome;
        let (ups, downs, _) = event_counts(o);
        t.row(vec![
            o.scenario.clone(),
            if row.autoscaled { "autoscaled" } else { "fixed" }.to_string(),
            o.frames.to_string(),
            o.dropped.to_string(),
            o.dropped_shed.to_string(),
            o.slo_miss.to_string(),
            si_time(max_p99(o)),
            o.live_workers.to_string(),
            format!("{ups}/{downs}"),
        ]);
    }
    print!("{}", t.render());

    let json = fmt_json(sessions, duration_s, &rows);
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path}");
    Ok(())
}
