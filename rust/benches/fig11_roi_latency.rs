//! Fig. 11 reproduction: processing latency with and without MGNet RoI
//! selection, same grid as Fig. 10 — latency reduction tracks (and
//! slightly exceeds) the energy reduction, per the paper.

use optovit::energy::AcceleratorModel;
use optovit::util::bench::time_fn;
use optovit::util::table::{si_time, Table};
use optovit::vit::{MgnetConfig, VitConfig, VitVariant};

fn main() {
    let m = AcceleratorModel::default();
    println!("== Fig. 11: baseline ViT latency, with vs without MGNet RoI ==\n");
    for res in [224usize, 96] {
        let cfg = VitConfig::variant(VitVariant::Base, res, 1000);
        let mg = MgnetConfig::classification(res);
        let full = m.frame_report("full", &cfg, cfg.num_patches(), true);
        println!("-- input {res}x{res} --");
        let mut t = Table::new(vec![
            "operating point", "kept", "latency/frame", "reduction %",
        ]);
        t.row(vec![
            "no MGNet".to_string(),
            cfg.num_patches().to_string(),
            si_time(full.delay.total_s()),
            "ref".to_string(),
        ]);
        for keep in [0.75, 0.50, 0.33, 0.25, 0.15] {
            let kept = ((cfg.num_patches() as f64) * keep).round().max(1.0) as usize;
            let r = m.masked_report("mask", &cfg, &mg, kept);
            let red = (1.0 - r.delay.total_s() / full.delay.total_s()) * 100.0;
            t.row(vec![
                format!("MGNet keep {:.0}%", keep * 100.0),
                kept.to_string(),
                si_time(r.delay.total_s()),
                format!("{red:.1}"),
            ]);
        }
        print!("{}", t.render());
        println!();
    }

    let cfg = VitConfig::variant(VitVariant::Base, 96, 1000);
    let mg = MgnetConfig::classification(96);
    let timing = time_fn("masked delay report (Base-96)", 1, 5, || {
        m.masked_report("x", &cfg, &mg, 12).delay.total_s()
    });
    println!("{}", timing.summary());
}
