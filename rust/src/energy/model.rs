//! The accelerator-level energy/delay model: Figs. 8, 9, 10, 11.
//!
//! Combines the optical-core cost model ([`crate::arch::core`]), the Fig. 5
//! scheduler, and the component constants into the per-frame breakdowns the
//! paper reports. The Fig. 8/9 grid is `{Tiny, Small, Base, Large} ×
//! {224², 96²}`; Figs. 10/11 add the MGNet + RoI-masked operating points.

use super::components::ComponentModels;
use crate::arch::core::{CoreParams, MatMulCost, OpticalCore};
use crate::arch::scheduler::AttentionSchedule;
use crate::arch::workload::Workload;
use crate::quant::PrecisionTier;
use crate::vit::{MgnetConfig, VitConfig};

/// Per-component energy for one forward pass (joules).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub tuning_j: f64,
    pub vcsel_j: f64,
    pub bpd_j: f64,
    pub adc_j: f64,
    pub dac_j: f64,
    pub memory_j: f64,
    pub epu_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.tuning_j + self.vcsel_j + self.bpd_j + self.adc_j + self.dac_j + self.memory_j
            + self.epu_j
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.tuning_j += o.tuning_j;
        self.vcsel_j += o.vcsel_j;
        self.bpd_j += o.bpd_j;
        self.adc_j += o.adc_j;
        self.dac_j += o.dac_j;
        self.memory_j += o.memory_j;
        self.epu_j += o.epu_j;
    }

    /// `(component, fraction)` pairs — the Fig. 8 pie chart.
    pub fn shares(&self) -> Vec<(&'static str, f64)> {
        let t = self.total_j();
        if t <= 0.0 {
            return Vec::new();
        }
        vec![
            ("Tuning", self.tuning_j / t),
            ("VCSEL", self.vcsel_j / t),
            ("BPD", self.bpd_j / t),
            ("ADC", self.adc_j / t),
            ("DAC", self.dac_j / t),
            ("Memory", self.memory_j / t),
            ("EPU", self.epu_j / t),
        ]
    }
}

/// Per-stage delay for one forward pass (seconds). The paper groups ADC/DAC
/// delay into the optical stage (Fig. 9 caption).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DelayBreakdown {
    /// Optical processing incl. ADC/DAC and (exposed) tuning.
    pub optical_s: f64,
    /// Electronic processing unit (softmax/GELU/norm/adds).
    pub epu_s: f64,
    /// Buffer-memory transfer time.
    pub memory_s: f64,
}

impl DelayBreakdown {
    pub fn total_s(&self) -> f64 {
        self.optical_s + self.epu_s + self.memory_s
    }

    pub fn add(&mut self, o: &DelayBreakdown) {
        self.optical_s += o.optical_s;
        self.epu_s += o.epu_s;
        self.memory_s += o.memory_s;
    }

    pub fn shares(&self) -> Vec<(&'static str, f64)> {
        let t = self.total_s();
        if t <= 0.0 {
            return Vec::new();
        }
        vec![
            ("Optical(+ADC/DAC)", self.optical_s / t),
            ("EPU", self.epu_s / t),
            ("Memory", self.memory_s / t),
        ]
    }
}

/// Full per-frame report.
#[derive(Debug, Clone)]
pub struct FrameReport {
    pub label: String,
    pub energy: EnergyBreakdown,
    pub delay: DelayBreakdown,
    /// Kept-patch count the report was evaluated at.
    pub kept_patches: usize,
    pub total_patches: usize,
}

impl FrameReport {
    /// Frames per second per watt — the paper's headline metric.
    /// `KFPS/W = 1 / (J/frame) / 1000`.
    pub fn kfps_per_watt(&self) -> f64 {
        1.0 / self.energy.total_j() / 1000.0
    }

    /// Throughput at the modeled latency (frames/s), single frame in flight.
    pub fn fps(&self) -> f64 {
        1.0 / self.delay.total_s()
    }

    pub fn pixel_skip_ratio(&self) -> f64 {
        1.0 - self.kept_patches as f64 / self.total_patches as f64
    }
}

/// Tuning points each MR is swept through during recalibration to
/// re-locate its drifted resonance (binary search over the 8-bit
/// detuning range).
pub const RECAL_SWEEP_STEPS: usize = 16;
/// Thermo-optic settling window per sweep step (µs) — the heater time
/// constant bounds how fast the search can step.
pub const RECAL_SETTLE_US_PER_STEP: f64 = 10.0;

/// The Opto-ViT accelerator model: five optical cores + EPU + buffers.
#[derive(Debug, Clone, Copy)]
pub struct AcceleratorModel {
    pub cores: CoreParams,
    pub components: ComponentModels,
}

impl Default for AcceleratorModel {
    fn default() -> Self {
        AcceleratorModel { cores: CoreParams::default(), components: ComponentModels::default() }
    }
}

impl AcceleratorModel {
    /// Energy of a raw cost bundle (workload already mapped to cores).
    pub fn energy_of_cost(&self, c: &MatMulCost, elementwise_elems: u64) -> EnergyBreakdown {
        self.energy_of_cost_scaled(c, elementwise_elems, 1.0)
    }

    /// [`Self::energy_of_cost`] with the converter traffic scaled by a
    /// precision tier (`converter_scale = bits / 8`): the component
    /// figures are calibrated at 8 bits, and the bit-width-proportional
    /// terms — DAC/ADC conversion energy, VCSEL symbol energy, MR
    /// weight-programming (tuning-value DACs + retune), and the memory
    /// bytes moved — all shrink (or grow, for the fp32 reference) with
    /// the tier. BPD sampling, heater hold power, and EPU work are
    /// bit-width-independent and stay fixed, so a lower tier's total is
    /// strictly smaller but never collapses to zero. `scale = 1.0`
    /// reproduces the unscaled figures exactly.
    pub fn energy_of_cost_scaled(
        &self,
        c: &MatMulCost,
        elementwise_elems: u64,
        scale: f64,
    ) -> EnergyBreakdown {
        let m = &self.components;
        let cycle_ns = self.cores.cycle_ns;
        // Tuning: per-MR retune energy (bit-width-scaled: fewer tuning
        // levels to resolve) + hold power over the compute time (fixed).
        let hold_j = m.tuning.hold_uw_per_mr * 1e-6 // W per MR
            * (self.cores.mrs_per_bank() * self.cores.num_cores) as f64
            * (c.cycles as f64 * cycle_ns * 1e-9);
        let tuning_j =
            c.weight_dac_conversions as f64 * scale * m.tuning.energy_pj_per_mr * 1e-12 + hold_j;
        // VCSEL symbols: mean activation drive over one cycle; drive
        // energy scales with the symbol resolution.
        let vcsel_j =
            c.vcsel_symbols as f64 * scale * m.vcsel.mean_symbol_energy_pj(cycle_ns) * 1e-12;
        let bpd_j = c.adc_conversions as f64 * m.bpd.sample_energy_pj * 1e-12;
        let adc_j = c.adc_conversions as f64 * scale * m.adc.energy_pj * 1e-12;
        // DACs: weight-side (tuning values) + input-side (VCSEL drive).
        let dac_j = (c.weight_dac_conversions as f64 + c.vcsel_symbols as f64)
            * scale
            * m.dac.energy_pj
            * 1e-12;
        let memory_j = (c.weight_bytes as f64 + c.input_bytes as f64 + c.output_bytes as f64)
            * scale
            * m.memory.energy_pj_per_byte
            * 1e-12;
        let epu_j = elementwise_elems as f64 * m.epu.energy_pj_per_elem * 1e-12
            + c.partial_sum_adds as f64 * m.epu.energy_pj_per_add * 1e-12;
        EnergyBreakdown { tuning_j, vcsel_j, bpd_j, adc_j, dac_j, memory_j, epu_j }
    }

    /// Energy breakdown for a [`Workload`] (Fig. 8 engine).
    pub fn energy(&self, w: &Workload) -> EnergyBreakdown {
        self.energy_scaled(w, 1.0)
    }

    /// [`Self::energy`] at a converter-traffic scale (see
    /// [`Self::energy_of_cost_scaled`]).
    fn energy_scaled(&self, w: &Workload, scale: f64) -> EnergyBreakdown {
        let core = OpticalCore::new(self.cores);
        let cost = core.workload_cost(w);
        self.energy_of_cost_scaled(&cost, w.elementwise.total(), scale)
    }

    /// Delay breakdown for a [`Workload`] (Fig. 9 engine).
    ///
    /// Optical time comes from the Fig. 5 pipeline schedule (steady-state,
    /// tuning overlapped); EPU and memory time are modeled as partially
    /// hidden behind optics — the paper reports them as the *exposed*
    /// serial fractions.
    pub fn delay(&self, cfg: &VitConfig, w: &Workload) -> DelayBreakdown {
        let optical_ns =
            AttentionSchedule::steady_state_frame_ns(cfg, w.seq_len, self.cores, w.decomposed);
        let m = &self.components;
        let core = OpticalCore::new(self.cores);
        let cost = core.workload_cost(w);
        // EPU work not on the schedule's critical path is the GELU/norm
        // stream; count its full serial time (the schedule already overlaps
        // softmax, so this is conservative but matches Fig. 9's grouping).
        // Partial-sum accumulation runs in per-arm accumulator registers at
        // ADC line rate — pipelined with the optical stage, so it costs
        // energy (see `energy_of_cost`) but no additional latency.
        let epu_ns = w.elementwise.total() as f64 / m.epu.elems_per_ns;
        let bytes = (cost.weight_bytes + cost.input_bytes + cost.output_bytes) as f64;
        let memory_ns = bytes / m.memory.bandwidth_bytes_per_ns
            + w.matmuls.len() as f64 * m.memory.burst_latency_ns;
        DelayBreakdown {
            optical_s: optical_ns * 1e-9,
            epu_s: epu_ns * 1e-9,
            memory_s: memory_ns * 1e-9,
        }
    }

    /// Full report for a backbone at a kept-patch count (Figs. 8-11 rows).
    pub fn frame_report(
        &self,
        label: &str,
        cfg: &VitConfig,
        kept_patches: usize,
        decomposed: bool,
    ) -> FrameReport {
        let w = Workload::vit(cfg, kept_patches, decomposed);
        FrameReport {
            label: label.to_string(),
            energy: self.energy(&w),
            delay: self.delay(cfg, &w),
            kept_patches,
            total_patches: cfg.num_patches(),
        }
    }

    /// Energy-only variant of [`Self::frame_report`]: skips the (orders of
    /// magnitude more expensive) discrete-event delay schedule. Use this on
    /// hot paths that only need joules (Fig. 8/10 engines, Table IV,
    /// per-frame serving accounting) — see EXPERIMENTS.md §Perf.
    pub fn frame_energy(&self, cfg: &VitConfig, kept_patches: usize, decomposed: bool) -> EnergyBreakdown {
        let w = Workload::vit(cfg, kept_patches, decomposed);
        self.energy(&w)
    }

    /// Energy-only variant of [`Self::masked_report`].
    pub fn masked_energy(
        &self,
        backbone: &VitConfig,
        mgnet: &MgnetConfig,
        kept_patches: usize,
    ) -> EnergyBreakdown {
        self.masked_energy_tiered(backbone, mgnet, kept_patches, PrecisionTier::Int8)
    }

    /// [`Self::frame_energy`] at a precision tier: the backbone's
    /// converter traffic is scaled by the tier's bit width (see
    /// [`Self::energy_of_cost_scaled`]). INT8 is exactly the unscaled
    /// figure.
    pub fn frame_energy_tiered(
        &self,
        cfg: &VitConfig,
        kept_patches: usize,
        decomposed: bool,
        tier: PrecisionTier,
    ) -> EnergyBreakdown {
        let w = Workload::vit(cfg, kept_patches, decomposed);
        self.energy_scaled(&w, tier.converter_scale())
    }

    /// [`Self::masked_energy`] at a precision tier. The MGNet front end
    /// always runs at INT8 — it *decides* the tier, so it cannot itself
    /// run below the fidelity the decision needs — and only the backbone
    /// share is tier-scaled.
    pub fn masked_energy_tiered(
        &self,
        backbone: &VitConfig,
        mgnet: &MgnetConfig,
        kept_patches: usize,
        tier: PrecisionTier,
    ) -> EnergyBreakdown {
        let mg_cfg = mgnet.as_vit();
        let mut e = self.frame_energy(&mg_cfg, mg_cfg.num_patches(), true);
        e.add(&self.frame_energy_tiered(backbone, kept_patches, true, tier));
        e
    }

    /// The share of one forward's modeled **delay** that a bucket-major
    /// batch pays only once: streaming the stationary weights from buffer
    /// memory into the MR banks. Frames after the first in a same-shape
    /// batch reuse the programmed banks, so their memory stage shrinks by
    /// exactly this amount — the photonic analogue of the dispatch
    /// overhead batched execution amortizes.
    pub fn weight_stream_delay_s(
        &self,
        cfg: &VitConfig,
        kept_patches: usize,
        decomposed: bool,
    ) -> f64 {
        self.weight_stream_delay_s_tiered(cfg, kept_patches, decomposed, PrecisionTier::Int8)
    }

    /// [`Self::weight_stream_delay_s`] at a precision tier: a 4-bit
    /// weight set is half the bytes of the 8-bit baseline, so streaming
    /// it into the MR banks takes proportionally less time (and the fp32
    /// reference proportionally more). INT8 is exactly the unscaled
    /// figure.
    pub fn weight_stream_delay_s_tiered(
        &self,
        cfg: &VitConfig,
        kept_patches: usize,
        decomposed: bool,
        tier: PrecisionTier,
    ) -> f64 {
        let w = Workload::vit(cfg, kept_patches, decomposed);
        let core = OpticalCore::new(self.cores);
        let cost = core.workload_cost(&w);
        cost.weight_bytes as f64 * tier.converter_scale()
            / self.components.memory.bandwidth_bytes_per_ns
            * 1e-9
    }

    /// The share of one forward's modeled **energy** that a bucket-major
    /// batch pays only once: MR weight-bank programming (weight-side DAC
    /// conversions + per-MR retune energy) and the weight memory traffic
    /// feeding it. Strictly a subset of [`Self::frame_energy`]'s total, so
    /// a follower frame's discounted energy can never go negative.
    pub fn weight_program_energy_j(
        &self,
        cfg: &VitConfig,
        kept_patches: usize,
        decomposed: bool,
    ) -> f64 {
        self.weight_program_energy_j_tiered(cfg, kept_patches, decomposed, PrecisionTier::Int8)
    }

    /// [`Self::weight_program_energy_j`] at a precision tier: the
    /// weight-side DAC conversions, per-MR retune energy, and weight
    /// memory traffic all carry the tier's bit width. Scales with the
    /// same factor as the tiered frame energy's weight-programming share,
    /// so a follower frame's discounted energy still can never go
    /// negative at any tier.
    pub fn weight_program_energy_j_tiered(
        &self,
        cfg: &VitConfig,
        kept_patches: usize,
        decomposed: bool,
        tier: PrecisionTier,
    ) -> f64 {
        let w = Workload::vit(cfg, kept_patches, decomposed);
        let core = OpticalCore::new(self.cores);
        let cost = core.workload_cost(&w);
        let m = &self.components;
        tier.converter_scale()
            * (cost.weight_dac_conversions as f64
                * (m.tuning.energy_pj_per_mr + m.dac.energy_pj)
                * 1e-12
                + cost.weight_bytes as f64 * m.memory.energy_pj_per_byte * 1e-12)
    }

    /// Modeled cost `(time_s, energy_j)` of recalibrating a degraded
    /// worker's optics: every MR is swept through
    /// [`RECAL_SWEEP_STEPS`] tuning points to re-locate its drifted
    /// resonance (each step one bank-tune plus one thermo-optic settle
    /// window), then the full weight set is re-streamed and programmed.
    /// Built from the same primitives as the batching discounts
    /// ([`Self::weight_stream_delay_s`], [`Self::weight_program_energy_j`])
    /// so recal is always strictly costlier than one weight program.
    pub fn recalibration_cost(&self, cfg: &VitConfig) -> (f64, f64) {
        let steps = RECAL_SWEEP_STEPS as f64;
        let kept = cfg.num_patches();
        let sweep_s = steps
            * (self.components.tuning.bank_tune_ns * 1e-9 + RECAL_SETTLE_US_PER_STEP * 1e-6);
        let time_s = sweep_s + self.weight_stream_delay_s(cfg, kept, true);
        let energy_j = (steps + 1.0) * self.weight_program_energy_j(cfg, kept, true);
        (time_s, energy_j)
    }

    /// Report for backbone + MGNet front end at a given RoI keep count
    /// (the Figs. 10/11 "with MGNet" series): MGNet always sees the full
    /// frame; the backbone sees only kept patches.
    pub fn masked_report(
        &self,
        label: &str,
        backbone: &VitConfig,
        mgnet: &MgnetConfig,
        kept_patches: usize,
    ) -> FrameReport {
        let mg_cfg = mgnet.as_vit();
        let mg_w = Workload::vit(&mg_cfg, mg_cfg.num_patches(), true);
        let bb = self.frame_report(label, backbone, kept_patches, true);
        let mut energy = self.energy(&mg_w);
        energy.add(&bb.energy);
        let mut delay = self.delay(&mg_cfg, &mg_w);
        delay.add(&bb.delay);
        FrameReport {
            label: label.to_string(),
            energy,
            delay,
            kept_patches,
            total_patches: backbone.num_patches(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::{VitVariant};

    fn model() -> AcceleratorModel {
        AcceleratorModel::default()
    }

    fn report(v: VitVariant, res: usize, kept: Option<usize>) -> FrameReport {
        let cfg = VitConfig::variant(v, res, 10);
        let k = kept.unwrap_or(cfg.num_patches());
        model().frame_report(&format!("{v}-{res}"), &cfg, k, true)
    }

    #[test]
    fn adc_is_largest_energy_component() {
        // Fig. 8 pie (Tiny-96): ADC dominates despite analog compute.
        let r = report(VitVariant::Tiny, 96, None);
        let shares = r.energy.shares();
        let adc = shares.iter().find(|(n, _)| *n == "ADC").unwrap().1;
        for (name, s) in &shares {
            if *name != "ADC" {
                assert!(adc > *s, "ADC {adc} <= {name} {s}");
            }
        }
    }

    #[test]
    fn optical_is_largest_delay_component() {
        // Fig. 9 pie (Tiny-96): optical stage dominates latency...
        let r = report(VitVariant::Tiny, 96, None);
        let d = r.delay;
        assert!(d.optical_s > d.epu_s && d.optical_s > d.memory_s, "{d:?}");
        // ...and memory latency exceeds the EPU's.
        assert!(d.memory_s > d.epu_s, "{d:?}");
    }

    #[test]
    fn energy_ordering_across_models_and_sizes() {
        // Fig. 8 trend: smaller network and smaller input → less energy.
        let order = [
            report(VitVariant::Tiny, 96, None).energy.total_j(),
            report(VitVariant::Small, 96, None).energy.total_j(),
            report(VitVariant::Base, 96, None).energy.total_j(),
            report(VitVariant::Large, 96, None).energy.total_j(),
        ];
        for w in order.windows(2) {
            assert!(w[0] < w[1], "{order:?}");
        }
        assert!(
            report(VitVariant::Base, 96, None).energy.total_j()
                < report(VitVariant::Base, 224, None).energy.total_j()
        );
    }

    #[test]
    fn energy_magnitudes_sane() {
        // Tiny-96 in the tens of uJ; Large-224 in the mJ range (log-scale
        // spread of Fig. 8).
        let t = report(VitVariant::Tiny, 96, None).energy.total_j();
        let l = report(VitVariant::Large, 224, None).energy.total_j();
        assert!((5e-6..8e-5).contains(&t), "tiny-96 {t} J");
        assert!((5e-4..3e-2).contains(&l), "large-224 {l} J");
        assert!(l / t > 50.0, "spread {}", l / t);
    }

    #[test]
    fn masking_saves_energy_despite_mgnet_overhead() {
        // Fig. 10: MGNet adds overhead but net energy drops. 67% pixel skip.
        let m = model();
        let cfg = VitConfig::variant(VitVariant::Base, 224, 1000);
        let mg = MgnetConfig::classification(224);
        let full = m.frame_report("full", &cfg, cfg.num_patches(), true);
        let kept = (cfg.num_patches() as f64 * 0.33).round() as usize;
        let masked = m.masked_report("masked", &cfg, &mg, kept);
        assert!(masked.energy.total_j() < full.energy.total_j());
        let savings = 1.0 - masked.energy.total_j() / full.energy.total_j();
        assert!(savings > 0.3, "savings {savings}");
    }

    #[test]
    fn masking_reduces_latency() {
        // Fig. 11 mirror of the energy test.
        let m = model();
        let cfg = VitConfig::variant(VitVariant::Base, 224, 1000);
        let mg = MgnetConfig::classification(224);
        let full = m.frame_report("full", &cfg, cfg.num_patches(), true);
        let kept = (cfg.num_patches() as f64 * 0.33).round() as usize;
        let masked = m.masked_report("masked", &cfg, &mg, kept);
        assert!(masked.delay.total_s() < full.delay.total_s());
    }

    #[test]
    fn kfps_per_watt_headline_magnitude() {
        // The paper's reference point is 100.4 KFPS/W (Tiny-96-class
        // operation with RoI masking). Require the same order of magnitude;
        // exact calibration is recorded in EXPERIMENTS.md.
        let m = model();
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let mg = MgnetConfig::classification(96);
        let kept = (cfg.num_patches() as f64 * 0.33).round() as usize;
        let r = m.masked_report("tiny-96-masked", &cfg, &mg, kept);
        let kfpsw = r.kfps_per_watt();
        assert!((30.0..300.0).contains(&kfpsw), "KFPS/W {kfpsw}");
    }

    #[test]
    fn thermo_optic_tuning_dominates_if_selected() {
        let mut m = model();
        m.components = ComponentModels::thermo_optic();
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let w = Workload::vit(&cfg, cfg.num_patches(), true);
        let e = m.energy(&w);
        // With heater hold power the tuning share must exceed the ADC share —
        // the design-space point the paper's VCSEL-input choice argues against.
        assert!(e.tuning_j > e.adc_j, "{e:?}");
    }

    #[test]
    fn weight_program_overhead_is_a_strict_subset() {
        // The batched-dispatch discount must be positive yet strictly
        // smaller than the full per-frame figures it is subtracted from.
        let m = model();
        for (v, res, kept) in [
            (VitVariant::Tiny, 96, 12),
            (VitVariant::Tiny, 96, 36),
            (VitVariant::Base, 224, 65),
        ] {
            let cfg = VitConfig::variant(v, res, 10);
            let e_over = m.weight_program_energy_j(&cfg, kept, true);
            let e_full = m.frame_energy(&cfg, kept, true).total_j();
            assert!(e_over > 0.0, "{v}-{res}: overhead energy must be positive");
            assert!(
                e_over < e_full,
                "{v}-{res}: overhead {e_over} must be below frame energy {e_full}"
            );
            let d_over = m.weight_stream_delay_s(&cfg, kept, true);
            let d_full = m.frame_report("x", &cfg, kept, true).delay.total_s();
            assert!(d_over > 0.0, "{v}-{res}: overhead delay must be positive");
            assert!(
                d_over < d_full,
                "{v}-{res}: overhead {d_over} must be below frame delay {d_full}"
            );
        }
    }

    #[test]
    fn recalibration_costs_more_than_one_weight_program() {
        let m = model();
        for (v, res) in [(VitVariant::Tiny, 96), (VitVariant::Base, 224)] {
            let cfg = VitConfig::variant(v, res, 10);
            let (t, e) = m.recalibration_cost(&cfg);
            let kept = cfg.num_patches();
            assert!(t > m.weight_stream_delay_s(&cfg, kept, true), "{v}-{res}: time {t}");
            assert!(e > m.weight_program_energy_j(&cfg, kept, true), "{v}-{res}: energy {e}");
            // Sanity: a recal window is sub-second at these bank sizes.
            assert!(t < 1.0, "{v}-{res}: recal time {t}s");
        }
    }

    #[test]
    fn tiered_energy_orders_int4_int8_fp32_and_int8_is_exact() {
        let m = model();
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let mg = MgnetConfig::classification(96);
        for kept in [9, 18, 36] {
            let e4 = m.frame_energy_tiered(&cfg, kept, true, PrecisionTier::Int4).total_j();
            let e8 = m.frame_energy_tiered(&cfg, kept, true, PrecisionTier::Int8).total_j();
            let e32 = m.frame_energy_tiered(&cfg, kept, true, PrecisionTier::Fp32).total_j();
            assert!(e4 < e8 && e8 < e32, "kept {kept}: {e4} / {e8} / {e32}");
            // INT8 is the calibration point: bit-identical to the
            // untiered figure (the pre-tier serving path's energy).
            assert_eq!(e8, m.frame_energy(&cfg, kept, true).total_j());
            assert_eq!(
                m.masked_energy_tiered(&cfg, &mg, kept, PrecisionTier::Int8).total_j(),
                m.masked_energy(&cfg, &mg, kept).total_j()
            );
            // The bit-width-independent floor (BPD, hold, EPU) keeps the
            // INT4 figure well above half of INT8.
            assert!(e4 > e8 * 0.5, "kept {kept}: int4 {e4} vs int8/2 {}", e8 * 0.5);
        }
    }

    #[test]
    fn tiered_masked_energy_scales_only_the_backbone_share() {
        // The MGNet front end always runs INT8, so the INT4 saving on the
        // masked figure is exactly the backbone-only saving.
        let m = model();
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let mg = MgnetConfig::classification(96);
        let kept = 18;
        let saved_masked = m.masked_energy_tiered(&cfg, &mg, kept, PrecisionTier::Int8).total_j()
            - m.masked_energy_tiered(&cfg, &mg, kept, PrecisionTier::Int4).total_j();
        let saved_backbone = m.frame_energy_tiered(&cfg, kept, true, PrecisionTier::Int8).total_j()
            - m.frame_energy_tiered(&cfg, kept, true, PrecisionTier::Int4).total_j();
        assert!(saved_masked > 0.0);
        assert!((saved_masked - saved_backbone).abs() < 1e-18, "{saved_masked} vs {saved_backbone}");
    }

    #[test]
    fn tiered_weight_programming_scales_with_bit_width() {
        let m = model();
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let kept = 18;
        let d8 = m.weight_stream_delay_s_tiered(&cfg, kept, true, PrecisionTier::Int8);
        assert_eq!(d8, m.weight_stream_delay_s(&cfg, kept, true));
        assert_eq!(m.weight_stream_delay_s_tiered(&cfg, kept, true, PrecisionTier::Int4), d8 * 0.5);
        assert_eq!(m.weight_stream_delay_s_tiered(&cfg, kept, true, PrecisionTier::Fp32), d8 * 4.0);
        let e8 = m.weight_program_energy_j_tiered(&cfg, kept, true, PrecisionTier::Int8);
        assert_eq!(e8, m.weight_program_energy_j(&cfg, kept, true));
        assert_eq!(m.weight_program_energy_j_tiered(&cfg, kept, true, PrecisionTier::Int4), e8 * 0.5);
        // The follower discount stays a strict subset at every tier.
        for tier in PrecisionTier::ALL {
            let over = m.weight_program_energy_j_tiered(&cfg, kept, true, tier);
            let full = m.frame_energy_tiered(&cfg, kept, true, tier).total_j();
            assert!(over > 0.0 && over < full, "{tier}: {over} vs {full}");
        }
    }

    #[test]
    fn pixel_skip_ratio() {
        let r = report(VitVariant::Base, 224, Some(65));
        assert!((r.pixel_skip_ratio() - (1.0 - 65.0 / 196.0)).abs() < 1e-12);
    }
}
