//! Per-component energy/delay constants.
//!
//! The paper characterizes these with fabricated-MR measurements co-simulated
//! against 45 nm CMOS interface circuits (NCSU FreePDK45 + Cadence Spectre +
//! Synopsys DC). Offline we pin each component to representative published
//! 45 nm-class numbers; the *relative* structure (ADC-dominated energy,
//! optics-dominated delay, memory > EPU latency) is what Figs. 8-9 assert,
//! and it emerges from op counts × these constants.

use crate::photonics::bpd::Bpd;
use crate::photonics::Vcsel;

/// A data converter (ADC or DAC).
#[derive(Debug, Clone, Copy)]
pub struct Converter {
    pub bits: u32,
    /// Energy per conversion (pJ).
    pub energy_pj: f64,
    /// Conversion latency (ns) — also sets the sample period at 1 GS/s.
    pub delay_ns: f64,
}

/// MR tuning circuit (electro-optic, per-MR DAC-driven).
#[derive(Debug, Clone, Copy)]
pub struct TuningModel {
    /// Energy to retune one MR to a new weight (pJ).
    pub energy_pj_per_mr: f64,
    /// Bank retune latency (ns) — all MRs in a bank tune in parallel.
    pub bank_tune_ns: f64,
    /// Static hold power per MR while computing (uW) — small for
    /// electro-optic tuning, dominant if thermo-optic is selected.
    pub hold_uw_per_mr: f64,
}

/// Buffer memory (on-chip SRAM).
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// Access energy per byte (pJ/B) — 45 nm SRAM ~0.16-0.25 pJ/B.
    pub energy_pj_per_byte: f64,
    /// Sustained bandwidth (bytes/ns = GB/s).
    pub bandwidth_bytes_per_ns: f64,
    /// Fixed access latency per burst (ns).
    pub burst_latency_ns: f64,
}

/// Electronic processing unit: the Softmax-GELU reuse unit of [38] plus the
/// partial-sum adders.
#[derive(Debug, Clone, Copy)]
pub struct EpuModel {
    /// Energy per processed element (pJ) for softmax/GELU/norm.
    pub energy_pj_per_elem: f64,
    /// Energy per partial-sum addition (pJ).
    pub energy_pj_per_add: f64,
    /// Throughput (elements per ns) — 8 lanes at 1 GHz by default. Must
    /// match `arch::scheduler::EPU_ELEMS_PER_NS`.
    pub elems_per_ns: f64,
}

/// The full component set.
#[derive(Debug, Clone, Copy)]
pub struct ComponentModels {
    pub adc: Converter,
    pub dac: Converter,
    pub vcsel: Vcsel,
    pub bpd: Bpd,
    pub tuning: TuningModel,
    pub memory: MemoryModel,
    pub epu: EpuModel,
}

impl Default for ComponentModels {
    fn default() -> Self {
        ComponentModels {
            // 8-bit 1 GS/s SAR ADC, 45 nm class: ~1.0 pJ/conversion
            // (Murmann ADC survey envelope for that node/speed).
            adc: Converter { bits: 8, energy_pj: 0.95, delay_ns: 1.0 },
            // 8-bit current-steering DAC: ~0.2 pJ/conversion.
            dac: Converter { bits: 8, energy_pj: 0.2, delay_ns: 0.5 },
            vcsel: Vcsel::default(),
            bpd: Bpd::default(),
            // Electro-optic (carrier-depletion) ring tuning: ~0.05 pJ per
            // retune (ring modulators switch at tens of fJ/bit; the weight
            // DAC + driver dominate), 250 ns bank settle (DAC settling +
            // ring relaxation, thermal trim assist; must match `CoreParams::tune_ns`),
            // negligible hold power.
            tuning: TuningModel { energy_pj_per_mr: 0.05, bank_tune_ns: 250.0, hold_uw_per_mr: 0.5 },
            memory: MemoryModel {
                energy_pj_per_byte: 0.17,
                bandwidth_bytes_per_ns: 80.0,
                burst_latency_ns: 2.0,
            },
            epu: EpuModel { energy_pj_per_elem: 0.8, energy_pj_per_add: 0.05, elems_per_ns: 8.0 },
        }
    }
}

impl ComponentModels {
    /// Thermo-optic variant: slow microsecond tuning with milliwatt hold
    /// power — the design point the paper's VCSEL-input choice avoids.
    pub fn thermo_optic() -> Self {
        let mut m = Self::default();
        m.tuning = TuningModel {
            energy_pj_per_mr: 90.0,
            bank_tune_ns: 4_000.0,
            hold_uw_per_mr: 1_000.0, // 1 mW/MR heater hold
        };
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_costs_more_than_dac() {
        let m = ComponentModels::default();
        assert!(m.adc.energy_pj > m.dac.energy_pj);
    }

    #[test]
    fn tuning_slower_than_cycle() {
        // The architecture rests on tuning being the slow step worth hiding.
        let m = ComponentModels::default();
        assert!(m.tuning.bank_tune_ns > m.adc.delay_ns);
    }

    #[test]
    fn thermo_optic_is_much_worse() {
        let eo = ComponentModels::default();
        let to = ComponentModels::thermo_optic();
        assert!(to.tuning.bank_tune_ns > 10.0 * eo.tuning.bank_tune_ns);
        assert!(to.tuning.hold_uw_per_mr > 100.0 * eo.tuning.hold_uw_per_mr);
    }

    #[test]
    fn epu_rate_matches_scheduler_constant() {
        // scheduler.rs uses a literal 8.0 elements/ns; keep them in lock-step.
        let m = ComponentModels::default();
        assert_eq!(m.epu.elems_per_ns, 8.0);
    }
}
