//! Energy and latency accounting (the circuit level of Fig. 7's bottom-up
//! framework, standing in for the paper's Cadence Spectre / Design Compiler
//! characterization).
//!
//! - [`components`] — per-event energy/delay constants for every component
//!   in the Fig. 8 breakdown: MR tuning, VCSEL, BPD, ADC, DAC, buffer
//!   memory, and the electronic processing unit.
//! - [`model`] — combines the [`crate::arch`] cost model with the component
//!   constants into per-network energy (Fig. 8/10) and delay (Fig. 9/11)
//!   breakdowns.

pub mod components;
pub mod model;

pub use components::ComponentModels;
pub use model::{AcceleratorModel, DelayBreakdown, EnergyBreakdown, FrameReport};
