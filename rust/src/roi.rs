//! Region-of-interest patch masks (§IV "Region of Interest Selection").
//!
//! MGNet emits per-patch scores; thresholding with `t_reg` yields a binary
//! 2-D mask. Masked patches are pruned *before* the first encoder block, so
//! every downstream computation for that patch is skipped — the property
//! that makes ViTs especially RoI-friendly (each patch's compute is
//! independent).

use crate::util::rng::Rng;

/// A binary patch mask over an `side × side` patch grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PatchMask {
    pub side: usize,
    /// Row-major keep flags.
    pub keep: Vec<bool>,
}

impl PatchMask {
    /// All patches kept.
    pub fn full(side: usize) -> Self {
        PatchMask { side, keep: vec![true; side * side] }
    }

    /// From per-patch scores: keep where `sigmoid(score) > t_reg` (§IV Eq. 3
    /// onward; scores here are pre-sigmoid logits).
    pub fn from_scores(side: usize, scores: &[f32], t_reg: f32) -> Self {
        let mut m = PatchMask { side, keep: Vec::with_capacity(side * side) };
        m.fill_from_scores(side, scores, t_reg);
        m
    }

    /// In-place variant of [`PatchMask::from_scores`] that reuses the
    /// existing `keep` buffer — allocation-free once capacity is warm
    /// (the serving hot path).
    pub fn fill_from_scores(&mut self, side: usize, scores: &[f32], t_reg: f32) {
        assert_eq!(scores.len(), side * side, "score grid mismatch");
        self.side = side;
        self.keep.clear();
        self.keep.extend(scores.iter().map(|&s| sigmoid(s) > t_reg));
    }

    /// In-place variant of [`PatchMask::full`]: keep everything, reusing
    /// the existing buffer.
    pub fn fill_full(&mut self, side: usize) {
        self.side = side;
        self.keep.clear();
        self.keep.resize(side * side, true);
    }

    /// Ground-truth mask from bounding boxes (pixel coords): a patch is 1 if
    /// it overlaps any box fully or partially (the paper's labeling rule).
    pub fn from_boxes(side: usize, patch_px: usize, boxes: &[BoundingBox]) -> Self {
        let mut keep = vec![false; side * side];
        for (idx, k) in keep.iter_mut().enumerate() {
            let py = (idx / side) * patch_px;
            let px = (idx % side) * patch_px;
            let (x0, y0, x1, y1) = (px, py, px + patch_px, py + patch_px);
            *k = boxes.iter().any(|b| b.intersects(x0, y0, x1, y1));
        }
        PatchMask { side, keep }
    }

    pub fn num_patches(&self) -> usize {
        self.keep.len()
    }

    pub fn kept(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count()
    }

    /// Pixel-skip ratio (the paper's `skip%` column).
    pub fn skip_ratio(&self) -> f64 {
        1.0 - self.kept() as f64 / self.num_patches() as f64
    }

    /// Indices of kept patches in row-major order.
    pub fn kept_indices(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.keep.len());
        self.kept_indices_into(&mut out);
        out
    }

    /// Append kept-patch indices into `out` (cleared first). Allocation-free
    /// when `out` already has capacity for `num_patches()` indices.
    pub fn kept_indices_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.keep.iter().enumerate().filter(|(_, &k)| k).map(|(i, _)| i));
    }

    /// Intersection-over-union against another mask (the paper's mIoU
    /// metric for MGNet mask quality).
    pub fn iou(&self, other: &PatchMask) -> f64 {
        assert_eq!(self.keep.len(), other.keep.len());
        let mut inter = 0usize;
        let mut union = 0usize;
        for (&a, &b) in self.keep.iter().zip(&other.keep) {
            inter += (a && b) as usize;
            union += (a || b) as usize;
        }
        if union == 0 {
            1.0
        } else {
            inter as f64 / union as f64
        }
    }

    /// Random mask with approximately `keep_prob` density (test workloads).
    pub fn random(side: usize, keep_prob: f64, rng: &mut Rng) -> Self {
        PatchMask { side, keep: (0..side * side).map(|_| rng.chance(keep_prob)).collect() }
    }

    /// Gather kept patches from a row-major patch tensor
    /// `(num_patches, patch_dim)` into a dense `(kept, patch_dim)` buffer.
    pub fn gather_patches(&self, patches: &[f32], patch_dim: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.kept() * patch_dim);
        self.gather_patches_into(patches, patch_dim, &mut out);
        out
    }

    /// [`PatchMask::gather_patches`] into a caller-owned buffer (cleared
    /// first) — allocation-free once `out` has capacity for
    /// `kept() * patch_dim` values. Iterates `keep` directly: the old
    /// implementation routed through `kept_indices()`, allocating a fresh
    /// index `Vec` on every call — a hidden per-frame heap hit on any
    /// masked gather path.
    pub fn gather_patches_into(&self, patches: &[f32], patch_dim: usize, out: &mut Vec<f32>) {
        assert_eq!(patches.len(), self.num_patches() * patch_dim);
        out.clear();
        for (idx, &kept) in self.keep.iter().enumerate() {
            if kept {
                out.extend_from_slice(&patches[idx * patch_dim..(idx + 1) * patch_dim]);
            }
        }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Axis-aligned pixel-space bounding box.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundingBox {
    pub x0: usize,
    pub y0: usize,
    pub x1: usize,
    pub y1: usize,
}

impl BoundingBox {
    pub fn new(x0: usize, y0: usize, x1: usize, y1: usize) -> Self {
        assert!(x1 > x0 && y1 > y0, "degenerate box");
        BoundingBox { x0, y0, x1, y1 }
    }

    fn intersects(&self, x0: usize, y0: usize, x1: usize, y1: usize) -> bool {
        self.x0 < x1 && x0 < self.x1 && self.y0 < y1 && y0 < self.y1
    }

    /// IoU between two boxes (used by the detection-experiment scoring).
    pub fn iou(&self, o: &BoundingBox) -> f64 {
        let ix0 = self.x0.max(o.x0);
        let iy0 = self.y0.max(o.y0);
        let ix1 = self.x1.min(o.x1);
        let iy1 = self.y1.min(o.y1);
        if ix1 <= ix0 || iy1 <= iy0 {
            return 0.0;
        }
        let inter = ((ix1 - ix0) * (iy1 - iy0)) as f64;
        let a = ((self.x1 - self.x0) * (self.y1 - self.y0)) as f64;
        let b = ((o.x1 - o.x0) * (o.y1 - o.y0)) as f64;
        inter / (a + b - inter)
    }

    pub fn area(&self) -> usize {
        (self.x1 - self.x0) * (self.y1 - self.y0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_keeps_everything() {
        let m = PatchMask::full(6);
        assert_eq!(m.kept(), 36);
        assert_eq!(m.skip_ratio(), 0.0);
    }

    #[test]
    fn score_thresholding() {
        // logit 2 -> sigmoid ~0.88 kept; logit -2 -> ~0.12 dropped at t=0.5.
        let scores = vec![2.0f32, -2.0, 2.0, -2.0];
        let m = PatchMask::from_scores(2, &scores, 0.5);
        assert_eq!(m.keep, vec![true, false, true, false]);
        assert_eq!(m.skip_ratio(), 0.5);
    }

    #[test]
    fn box_mask_marks_partial_overlap() {
        // 96x96 image, 16-px patches (6x6 grid); box covering pixels
        // (20..40, 20..40) touches patches (1,1)..(2,2).
        let m = PatchMask::from_boxes(6, 16, &[BoundingBox::new(20, 20, 40, 40)]);
        assert!(m.keep[1 * 6 + 1] && m.keep[1 * 6 + 2] && m.keep[2 * 6 + 1] && m.keep[2 * 6 + 2]);
        assert!(!m.keep[0]);
        assert_eq!(m.kept(), 4);
    }

    #[test]
    fn fill_variants_match_constructors() {
        let scores = vec![2.0f32, -2.0, 2.0, -2.0];
        let mut m = PatchMask::full(6);
        m.fill_from_scores(2, &scores, 0.5);
        assert_eq!(m, PatchMask::from_scores(2, &scores, 0.5));
        m.fill_full(3);
        assert_eq!(m, PatchMask::full(3));
    }

    #[test]
    fn kept_indices_into_reuses_buffer() {
        let m = PatchMask { side: 2, keep: vec![true, false, false, true] };
        let mut buf = vec![7usize; 9];
        m.kept_indices_into(&mut buf);
        assert_eq!(buf, vec![0, 3]);
        assert_eq!(m.kept_indices(), vec![0, 3]);
    }

    #[test]
    fn iou_self_is_one() {
        let mut rng = Rng::new(3);
        let m = PatchMask::random(8, 0.4, &mut rng);
        assert_eq!(m.iou(&m), 1.0);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        let a = PatchMask { side: 2, keep: vec![true, false, false, false] };
        let b = PatchMask { side: 2, keep: vec![false, true, false, false] };
        assert_eq!(a.iou(&b), 0.0);
    }

    #[test]
    fn gather_selects_rows() {
        let m = PatchMask { side: 2, keep: vec![true, false, false, true] };
        let patches: Vec<f32> = (0..8).map(|x| x as f32).collect(); // 4 patches × dim 2
        let g = m.gather_patches(&patches, 2);
        assert_eq!(g, vec![0.0, 1.0, 6.0, 7.0]);
    }

    #[test]
    fn gather_into_reuses_buffer_and_matches_gather() {
        let mut rng = Rng::new(11);
        let m = PatchMask::random(6, 0.4, &mut rng);
        let dim = 3;
        let patches: Vec<f32> = (0..m.num_patches() * dim).map(|x| x as f32).collect();
        let mut out = Vec::with_capacity(m.num_patches() * dim);
        m.gather_patches_into(&patches, dim, &mut out);
        assert_eq!(out, m.gather_patches(&patches, dim));
        // Re-gathering into the warmed buffer clears before appending —
        // no duplicated rows, same result.
        m.gather_patches_into(&patches, dim, &mut out);
        assert_eq!(out.len(), m.kept() * dim);
        assert_eq!(out, m.gather_patches(&patches, dim));
    }

    #[test]
    fn bbox_iou() {
        let a = BoundingBox::new(0, 0, 10, 10);
        let b = BoundingBox::new(5, 5, 15, 15);
        let iou = a.iou(&b);
        assert!((iou - 25.0 / 175.0).abs() < 1e-12);
        assert_eq!(a.iou(&a), 1.0);
    }

    #[test]
    fn empty_masks_iou_defined() {
        let a = PatchMask { side: 2, keep: vec![false; 4] };
        assert_eq!(a.iou(&a), 1.0);
    }
}
