//! Vertical-Cavity Surface-Emitting Laser (VCSEL) input model.
//!
//! Opto-ViT's key input-path choice (§III): activations are encoded directly
//! into VCSEL drive amplitudes, rather than tuned onto input MRs. Driving a
//! VCSEL is faster and cheaper than thermally tuning a ring, and one emitted
//! signal fans out to all 64 arms — the paper's argument for the
//! VCSEL-per-wavelength front end.

/// A directly modulated VCSEL channel.
#[derive(Debug, Clone, Copy)]
pub struct Vcsel {
    /// Threshold current (mA) below which no lasing occurs.
    pub threshold_ma: f64,
    /// Slope efficiency (mW optical per mA drive above threshold).
    pub slope_eff_mw_per_ma: f64,
    /// Maximum drive current (mA).
    pub max_drive_ma: f64,
    /// Drive voltage (V) for energy accounting.
    pub drive_voltage_v: f64,
    /// Modulation bandwidth (GHz) — bounds the symbol rate.
    pub bandwidth_ghz: f64,
}

impl Default for Vcsel {
    fn default() -> Self {
        // Edge-class low-power 1550-nm VCSEL: ~0.2 mA threshold, ~0.8 mW/mA,
        // ~15 GHz bandwidth, ~1.8 V drive — the near-sensor operating point
        // the paper's energy budget assumes (VCSEL drive well below ADC
        // conversion energy per symbol).
        Vcsel {
            threshold_ma: 0.2,
            slope_eff_mw_per_ma: 0.8,
            max_drive_ma: 1.5,
            drive_voltage_v: 1.8,
            bandwidth_ghz: 15.0,
        }
    }
}

impl Vcsel {
    /// Optical output power (mW) for a drive current (mA). L-I curve is
    /// linear above threshold, clamped at `max_drive_ma`.
    pub fn optical_power_mw(&self, drive_ma: f64) -> f64 {
        let d = drive_ma.clamp(0.0, self.max_drive_ma);
        if d <= self.threshold_ma {
            0.0
        } else {
            (d - self.threshold_ma) * self.slope_eff_mw_per_ma
        }
    }

    /// Drive current (mA) that encodes a normalized activation `a` in
    /// `[0, 1]` as a fraction of full-scale optical power.
    pub fn drive_for_activation(&self, a: f64) -> f64 {
        let a = a.clamp(0.0, 1.0);
        self.threshold_ma + a * (self.max_drive_ma - self.threshold_ma)
    }

    /// Electrical energy (pJ) to emit one symbol of duration `symbol_ns`
    /// at activation level `a` (drive current × voltage × time).
    pub fn symbol_energy_pj(&self, a: f64, symbol_ns: f64) -> f64 {
        let i_ma = self.drive_for_activation(a);
        // mA * V * ns = pJ
        i_ma * self.drive_voltage_v * symbol_ns
    }

    /// Mean symbol energy (pJ) over uniformly distributed activations —
    /// the number the architecture-level energy model uses per VCSEL symbol.
    pub fn mean_symbol_energy_pj(&self, symbol_ns: f64) -> f64 {
        self.symbol_energy_pj(0.5, symbol_ns)
    }

    /// Shortest symbol time (ns) the modulation bandwidth supports.
    pub fn min_symbol_ns(&self) -> f64 {
        1.0 / self.bandwidth_ghz
    }

    /// Wall-plug efficiency at activation `a`: optical out / electrical in.
    pub fn wall_plug_efficiency(&self, a: f64) -> f64 {
        let i = self.drive_for_activation(a);
        let p_opt = self.optical_power_mw(i);
        let p_el = i * self.drive_voltage_v;
        if p_el <= 0.0 {
            0.0
        } else {
            p_opt / p_el
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn li_curve_threshold() {
        let v = Vcsel::default();
        assert_eq!(v.optical_power_mw(0.1), 0.0);
        assert!(v.optical_power_mw(1.0) > 0.0);
    }

    #[test]
    fn activation_encoding_monotone() {
        let v = Vcsel::default();
        let p0 = v.optical_power_mw(v.drive_for_activation(0.1));
        let p1 = v.optical_power_mw(v.drive_for_activation(0.9));
        assert!(p1 > p0);
    }

    #[test]
    fn full_scale_uses_max_drive() {
        let v = Vcsel::default();
        assert!((v.drive_for_activation(1.0) - v.max_drive_ma).abs() < 1e-12);
    }

    #[test]
    fn symbol_energy_scale() {
        let v = Vcsel::default();
        // ~1 ns symbol at mid drive: ~1-3 pJ — far below MR thermal tuning.
        let e = v.mean_symbol_energy_pj(1.0);
        assert!((0.5..5.0).contains(&e), "energy {e} pJ");
    }

    #[test]
    fn efficiency_below_unity() {
        let v = Vcsel::default();
        for &a in &[0.1, 0.5, 1.0] {
            let eff = v.wall_plug_efficiency(a);
            assert!((0.0..1.0).contains(&eff));
        }
    }

    #[test]
    fn bandwidth_limits_symbol() {
        let v = Vcsel::default();
        assert!((v.min_symbol_ns() - 1.0 / 15.0).abs() < 1e-12);
    }
}
