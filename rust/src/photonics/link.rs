//! Optical link-budget analysis for the WDM arm.
//!
//! The paper motivates photonics with its "innovative solutions to fan-in
//! and fan-out challenges" (§II); the flip side is the optical power
//! budget: each VCSEL's light is split across 64 arms, passes 32 MR weight
//! cells (each with insertion loss and its own drop fraction), and must
//! still land on the BPD above the sensitivity needed for 8-bit readout.
//! This module checks that the §III core geometry closes the link — and
//! exposes where it stops closing (more arms, lossier MRs, higher bit
//! depth), which bounds how far the architecture scales.

use super::bpd::Bpd;
use super::Vcsel;

/// Loss/geometry parameters of one optical path (VCSEL → arm → BPD).
#[derive(Debug, Clone, Copy)]
pub struct LinkBudget {
    /// Number of arms the input fans out to (1×N splitter tree).
    pub fanout_arms: usize,
    /// Excess loss per 1×2 splitter stage (dB) — tree depth = log2(N).
    pub splitter_excess_db: f64,
    /// Per-MR through-path insertion loss (dB) — off-resonance ripple.
    pub mr_insertion_db: f64,
    /// MRs per arm the signal passes (one per wavelength channel).
    pub mrs_per_arm: usize,
    /// Waveguide propagation loss (dB/cm).
    pub propagation_db_per_cm: f64,
    /// Arm length (cm).
    pub arm_length_cm: f64,
    /// Laser-to-chip coupling loss (dB).
    pub coupling_db: f64,
}

impl Default for LinkBudget {
    fn default() -> Self {
        // §III core: 64 arms, 32 channels; typical SiPh numbers:
        // 0.1 dB splitter excess, 0.05 dB MR insertion, 2 dB/cm, 1.5 dB
        // vertical coupling.
        LinkBudget {
            fanout_arms: 64,
            splitter_excess_db: 0.1,
            mr_insertion_db: 0.05,
            mrs_per_arm: 32,
            propagation_db_per_cm: 2.0,
            arm_length_cm: 0.3,
            coupling_db: 1.5,
        }
    }
}

impl LinkBudget {
    /// Splitter tree depth (1×2 stages) for the fan-out.
    pub fn splitter_stages(&self) -> u32 {
        (self.fanout_arms as f64).log2().ceil() as u32
    }

    /// Total link loss in dB, *excluding* the intrinsic 1/N fan-out split
    /// (that part carries signal to the other arms; it is not dissipation
    /// from the system's point of view, but it is from one arm's).
    pub fn excess_loss_db(&self) -> f64 {
        self.coupling_db
            + self.splitter_stages() as f64 * self.splitter_excess_db
            + self.mrs_per_arm as f64 * self.mr_insertion_db
            + self.propagation_db_per_cm * self.arm_length_cm
    }

    /// Total per-arm loss including the 1/N split (dB).
    pub fn total_loss_db(&self) -> f64 {
        self.excess_loss_db() + 10.0 * (self.fanout_arms as f64).log10()
    }

    /// Optical power (mW) reaching one arm's BPD per unit VCSEL power (mW).
    pub fn arm_transmission(&self) -> f64 {
        10f64.powf(-self.total_loss_db() / 10.0)
    }

    /// Minimum BPD photocurrent (mA) for `bits`-bit shot-noise-limited
    /// readout in one `integration_ns` sample: SNR must exceed
    /// `6.02·bits + 1.76` dB.
    pub fn required_photocurrent_ma(&self, bpd: &Bpd, bits: u32, integration_ns: f64) -> f64 {
        let target_db = 6.02 * bits as f64 + 1.76;
        // Binary search the monotone SNR(i) curve.
        let (mut lo, mut hi) = (1e-9f64, 1e3f64);
        for _ in 0..200 {
            let mid = (lo * hi).sqrt();
            if bpd.shot_noise_snr_db(mid, integration_ns) < target_db {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    }

    /// Does the link close? Returns the margin in dB (positive = closes).
    ///
    /// The quantity the ADC digitizes is the **accumulated MAC** — the BPD
    /// sums all `mrs_per_arm` wavelength channels — so the shot-noise
    /// requirement applies to that sum, not to one channel. With typical
    /// activations/weights the mean per-channel modulation depth is ~0.25
    /// (product of two ~uniform [0,1] encodings).
    pub fn margin_db(&self, vcsel: &Vcsel, bpd: &Bpd, bits: u32, integration_ns: f64) -> f64 {
        const MEAN_MODULATION: f64 = 0.25;
        let p_launch = vcsel.optical_power_mw(vcsel.max_drive_ma);
        let p_arm = p_launch * self.arm_transmission();
        let p_mac = p_arm * self.mrs_per_arm as f64 * MEAN_MODULATION;
        let i_need = self.required_photocurrent_ma(bpd, bits, integration_ns);
        let p_need = i_need / bpd.responsivity_a_per_w; // mW for that current
        10.0 * (p_mac / p_need).log10()
    }

    /// Largest arm count at which the link still closes with ≥`margin_db`
    /// of headroom (the scaling wall of the fan-out argument).
    pub fn max_arms(&self, vcsel: &Vcsel, bpd: &Bpd, bits: u32, integration_ns: f64, margin_db: f64) -> usize {
        let mut arms = 1usize;
        loop {
            let next = arms * 2;
            let lb = LinkBudget { fanout_arms: next, ..*self };
            if lb.margin_db(vcsel, bpd, bits, integration_ns) < margin_db || next > 1 << 20 {
                return arms;
            }
            arms = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parts() -> (Vcsel, Bpd) {
        (Vcsel::default(), Bpd::default())
    }

    #[test]
    fn paper_geometry_closes_at_8_bits() {
        // The §III core (64 arms, 32 MRs/arm) must close the link for
        // 8-bit readout at the 1 ns ADC integration window.
        let (v, b) = parts();
        let lb = LinkBudget::default();
        let m = lb.margin_db(&v, &b, 8, 1.0);
        assert!(m > 0.0, "link does not close: margin {m} dB");
    }

    #[test]
    fn loss_components_add_up() {
        let lb = LinkBudget::default();
        assert_eq!(lb.splitter_stages(), 6);
        let excess = 1.5 + 6.0 * 0.1 + 32.0 * 0.05 + 2.0 * 0.3;
        assert!((lb.excess_loss_db() - excess).abs() < 1e-12);
        assert!(lb.total_loss_db() > lb.excess_loss_db());
    }

    #[test]
    fn transmission_is_a_fraction() {
        let lb = LinkBudget::default();
        let t = lb.arm_transmission();
        assert!(t > 0.0 && t < 1.0);
    }

    #[test]
    fn more_arms_less_margin() {
        let (v, b) = parts();
        let small = LinkBudget { fanout_arms: 16, ..LinkBudget::default() };
        let big = LinkBudget { fanout_arms: 256, ..LinkBudget::default() };
        assert!(small.margin_db(&v, &b, 8, 1.0) > big.margin_db(&v, &b, 8, 1.0));
    }

    #[test]
    fn higher_precision_needs_more_light() {
        let (v, b) = parts();
        let lb = LinkBudget::default();
        assert!(lb.margin_db(&v, &b, 4, 1.0) > lb.margin_db(&v, &b, 10, 1.0));
    }

    #[test]
    fn paper_design_sits_at_the_scaling_wall() {
        // Reproduction finding: 64 arms is the *largest* power-of-two arm
        // count a 1 mW-class edge VCSEL drives at 8-bit/1 ns shot-noise
        // readout — the paper's geometry sits right at the fan-out wall.
        let (v, b) = parts();
        let lb = LinkBudget::default();
        let max = lb.max_arms(&v, &b, 8, 1.0, 0.0);
        assert!((64..=256).contains(&max), "max arms {max}");
    }

    #[test]
    fn required_current_monotone_in_bits() {
        let (_, b) = parts();
        let lb = LinkBudget::default();
        let i8 = lb.required_photocurrent_ma(&b, 8, 1.0);
        let i10 = lb.required_photocurrent_ma(&b, 10, 1.0);
        assert!(i10 > i8);
        assert!(i8 > 0.0);
    }
}
