//! Inter-channel crosstalk and the weight-resolution bound (paper §IV,
//! "MR Resolution Analysis", after Duong et al. [41]).
//!
//! In a WDM arm, every MR partially "sees" the neighbouring channels. The
//! paper quantifies the noise the j-th MR injects into the i-th channel as
//!
//! `phi(i,j) = delta^2 / ((lambda_i - lambda_j)^2 + delta^2)`,  `delta = lambda / (2 Q)`
//!
//! total noise `P_noise[i] = sum_{j != i} phi(i,j) * P_in[j]`, and for unit
//! input power the achievable resolution is `1 / max_i |P_noise[i]|` levels.

use super::mr::MrGeometry;

/// A WDM channel plan: `n` equally spaced wavelengths.
#[derive(Debug, Clone)]
pub struct ChannelGrid {
    /// Channel centre wavelengths in nm, ascending.
    pub wavelengths_nm: Vec<f64>,
}

impl ChannelGrid {
    /// Equally spaced grid: `n` channels starting at `start_nm`, spaced
    /// `spacing_nm` apart (the paper's core uses 32 channels).
    pub fn uniform(n: usize, start_nm: f64, spacing_nm: f64) -> Self {
        ChannelGrid {
            wavelengths_nm: (0..n).map(|i| start_nm + i as f64 * spacing_nm).collect(),
        }
    }

    /// Grid that fills one free spectral range of the given ring geometry —
    /// the densest plan that avoids mode-order aliasing.
    pub fn within_fsr(n: usize, center_nm: f64, geometry: &MrGeometry) -> Self {
        let fsr = geometry.fsr_nm(center_nm);
        let spacing = fsr / n as f64;
        let start = center_nm - fsr / 2.0 + spacing / 2.0;
        Self::uniform(n, start, spacing)
    }

    /// The accelerator's C-band channel plan: 1.2 nm spacing centred on
    /// 1550 nm (32 channels span ~38 nm). This is the spacing consistent
    /// with the paper's measured 8-bit resolution at Q ≈ 5000; it requires
    /// per-sub-bank mode-order management since it exceeds one 5-µm-ring FSR
    /// (documented in DESIGN.md).
    pub fn c_band(n: usize) -> Self {
        let spacing = 1.2;
        let start = 1550.0 - spacing * (n as f64 - 1.0) / 2.0;
        Self::uniform(n, start, spacing)
    }

    pub fn len(&self) -> usize {
        self.wavelengths_nm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wavelengths_nm.is_empty()
    }

    pub fn spacing_nm(&self) -> f64 {
        if self.wavelengths_nm.len() < 2 {
            return 0.0;
        }
        self.wavelengths_nm[1] - self.wavelengths_nm[0]
    }
}

/// Crosstalk model over a channel grid for rings of a given Q.
#[derive(Debug, Clone)]
pub struct CrosstalkModel {
    pub grid: ChannelGrid,
    pub q_factor: f64,
}

impl CrosstalkModel {
    pub fn new(grid: ChannelGrid, q_factor: f64) -> Self {
        CrosstalkModel { grid, q_factor }
    }

    /// Lorentzian half-width for channel `i`: `delta_i = lambda_i / (2 Q)`.
    pub fn delta_nm(&self, i: usize) -> f64 {
        self.grid.wavelengths_nm[i] / (2.0 * self.q_factor)
    }

    /// First-order Lorentzian leakage — the literal §IV formula:
    /// `phi(i,j) = delta^2 / ((lambda_i - lambda_j)^2 + delta^2)`.
    pub fn phi_first_order(&self, i: usize, j: usize) -> f64 {
        let d = self.delta_nm(i);
        let dl = self.grid.wavelengths_nm[i] - self.grid.wavelengths_nm[j];
        d * d / (dl * dl + d * d)
    }

    /// `phi(i,j)`: fractional *power* leakage of channel `j` into the MR
    /// serving channel `i`. `phi(i,i) = 1` (the ring fully engages its own
    /// channel); callers exclude the diagonal for noise.
    ///
    /// The default kernel is the **squared Lorentzian** — the add-drop
    /// power transfer the paper's fabricated-MR measurements follow. The
    /// single-pole first-order form (§IV's printed formula) over-predicts
    /// far-channel leakage and cannot reach 8 bits at Q ≈ 5000 on any
    /// physical channel plan; the measured (squared) kernel reproduces the
    /// paper's headline. See [`Self::phi_first_order`] and DESIGN.md.
    pub fn phi(&self, i: usize, j: usize) -> f64 {
        let l = self.phi_first_order(i, j);
        l * l
    }

    /// Noise power on each channel for the given input power vector:
    /// `P_noise[i] = sum_{j != i} phi(i,j) * P_in[j]`.
    pub fn noise_power(&self, p_in: &[f64]) -> Vec<f64> {
        let n = self.grid.len();
        assert_eq!(p_in.len(), n, "input power vector length mismatch");
        (0..n)
            .map(|i| (0..n).filter(|&j| j != i).map(|j| self.phi(i, j) * p_in[j]).sum())
            .collect()
    }

    /// Worst-case noise for unit input power on every channel.
    pub fn worst_case_noise(&self) -> f64 {
        let ones = vec![1.0; self.grid.len()];
        self.noise_power(&ones).into_iter().fold(0.0, f64::max)
    }

    /// Achievable resolution in levels: `1 / max |P_noise|` (paper §IV,
    /// with `P_in = 1`).
    pub fn resolution_levels(&self) -> f64 {
        let n = self.worst_case_noise();
        if n <= 0.0 {
            f64::INFINITY
        } else {
            1.0 / n
        }
    }

    /// Achievable resolution in bits: `log2(resolution_levels)`.
    pub fn resolution_bits(&self) -> f64 {
        self.resolution_levels().log2()
    }

    /// The full crosstalk mixing matrix `M` (row i = receiving channel):
    /// `M[i][i] = 1`, `M[i][j] = phi(i,j)` for `j != i`. The L1 Pallas
    /// kernel applies this same matrix when emulating noisy optics, so the
    /// device model and the compute path share one operator.
    pub fn mixing_matrix(&self) -> Vec<Vec<f64>> {
        let n = self.grid.len();
        (0..n)
            .map(|i| (0..n).map(|j| if i == j { 1.0 } else { self.phi(i, j) }).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(q: f64) -> CrosstalkModel {
        // 32 channels, 0.8 nm spacing (100 GHz ITU grid) around 1550 nm.
        CrosstalkModel::new(ChannelGrid::uniform(32, 1537.6, 0.8), q)
    }

    #[test]
    fn phi_is_one_on_diagonal_and_decays() {
        let m = model(5000.0);
        assert!((m.phi(5, 5) - 1.0).abs() < 1e-12);
        assert!(m.phi(5, 6) > m.phi(5, 7));
        assert!(m.phi(5, 6) < 0.2);
    }

    #[test]
    fn phi_nearly_symmetric() {
        let m = model(5000.0);
        // delta differs slightly between channels, so only near-symmetry.
        let a = m.phi(3, 10);
        let b = m.phi(10, 3);
        assert!((a - b).abs() / a < 0.05);
    }

    #[test]
    fn noise_peaks_mid_grid() {
        let m = model(5000.0);
        let noise = m.noise_power(&vec![1.0; 32]);
        let edge = noise[0];
        let mid = noise[16];
        assert!(mid > edge, "mid {mid} edge {edge}");
    }

    #[test]
    fn resolution_improves_with_q() {
        let lo = model(1000.0).resolution_bits();
        let hi = model(10000.0).resolution_bits();
        assert!(hi > lo, "hi {hi} lo {lo}");
    }

    #[test]
    fn resolution_improves_with_spacing() {
        let narrow = CrosstalkModel::new(ChannelGrid::uniform(32, 1540.0, 0.4), 5000.0);
        let wide = CrosstalkModel::new(ChannelGrid::uniform(32, 1540.0, 1.6), 5000.0);
        assert!(wide.resolution_bits() > narrow.resolution_bits());
    }

    #[test]
    fn grid_within_fsr_spacing() {
        let g = ChannelGrid::within_fsr(32, 1550.0, &MrGeometry::default());
        assert_eq!(g.len(), 32);
        let fsr = MrGeometry::default().fsr_nm(1550.0);
        assert!((g.spacing_nm() - fsr / 32.0).abs() < 1e-9);
    }

    #[test]
    fn mixing_matrix_rows() {
        let m = model(5000.0);
        let mat = m.mixing_matrix();
        assert_eq!(mat.len(), 32);
        assert!((mat[4][4] - 1.0).abs() < 1e-12);
        assert!((mat[4][5] - m.phi(4, 5)).abs() < 1e-12);
    }

    #[test]
    fn single_channel_has_no_crosstalk() {
        let m = CrosstalkModel::new(ChannelGrid::uniform(1, 1550.0, 0.8), 5000.0);
        assert_eq!(m.worst_case_noise(), 0.0);
        assert!(m.resolution_levels().is_infinite());
    }
}
