//! Microring fault models, their accuracy impact, and clock-driven
//! degradation schedules.
//!
//! Fabricated MR banks fail in characteristic ways: stuck heaters/DACs pin
//! a weight cell, thermal drift shifts a whole bank, and a dead VCSEL kills
//! a wavelength channel. The paper's >200-copy measurement campaign exists
//! to screen exactly these; this module injects them into the weight-bank
//! abstraction so the test-suite (and the fault_injection example) can
//! quantify how many faults the 8-bit budget absorbs — the robustness
//! question ROBIN [26] asks of binary designs, answered here for Opto-ViT.
//!
//! Two layers:
//!
//! - [`FaultyBank`] — a *static* fault population on one weight bank
//!   (screening-campaign view: how many effective bits survive).
//! - [`FaultSchedule`] / [`DegradationState`] — a *dynamic*, seeded
//!   timeline of degradation (thermal drift accumulation, crosstalk
//!   growth, stuck-cell and dead-lane onsets) that a serving worker's
//!   backend evaluates against elapsed `Clock` time. The continuous
//!   [`DegradationState::health`] score in `[0, 1]` is what the
//!   health-aware dispatcher routes on (see `coordinator::server`).

use super::mr::{MicroRing, MrGeometry};
use crate::util::rng::Rng;

/// A fault affecting one MR weight cell or one channel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Cell (row = channel, col = arm) stuck at a transmission value.
    StuckWeight { channel: usize, arm: usize, value: f32 },
    /// Whole wavelength channel dead (VCSEL failure): contributes zero.
    DeadChannel { channel: usize },
    /// Uniform resonance drift of the bank: multiplicative weight error.
    BankDrift { gain: f32 },
}

/// A 32×64 weight bank with injected faults.
#[derive(Debug, Clone)]
pub struct FaultyBank {
    pub wavelengths: usize,
    pub arms: usize,
    pub faults: Vec<Fault>,
}

impl FaultyBank {
    pub fn new(wavelengths: usize, arms: usize) -> Self {
        FaultyBank { wavelengths, arms, faults: Vec::new() }
    }

    pub fn inject(&mut self, fault: Fault) -> &mut Self {
        self.faults.push(fault);
        self
    }

    /// Sample a random fault population: each cell independently stuck with
    /// probability `p_stuck`, each channel dead with probability `p_dead`.
    /// At most **one** fault lands on any cell: a dead channel (VCSEL
    /// failure) takes precedence over stuck cells in its row, so a cell is
    /// either dead-by-channel, stuck, or clean — never both.
    ///
    /// **Sampling order (stable contract).** The variate sequence drawn
    /// from `rng` is fixed regardless of outcomes, so seeded fault
    /// populations survive refactors of the injection logic: for each
    /// channel in index order, draw 1 dead-trial variate, then for each
    /// arm in index order draw a stuck-trial variate and a stuck-value
    /// variate **unconditionally** (the value is discarded when the trial
    /// fails or the channel is dead). Total draws are always
    /// `wavelengths * (1 + 2 * arms)`. The regression test
    /// `random_population_is_stable_across_refactors` pins one population.
    pub fn random(wavelengths: usize, arms: usize, p_stuck: f64, p_dead: f64, rng: &mut Rng) -> Self {
        let mut bank = Self::new(wavelengths, arms);
        for ch in 0..wavelengths {
            let dead = rng.chance(p_dead);
            if dead {
                bank.inject(Fault::DeadChannel { channel: ch });
            }
            for arm in 0..arms {
                let stuck = rng.chance(p_stuck);
                let value = rng.next_f32();
                if stuck && !dead {
                    bank.inject(Fault::StuckWeight { channel: ch, arm, value });
                }
            }
        }
        bank
    }

    /// Apply the fault population to an ideal weight matrix
    /// (`wavelengths × arms`, row-major, values in [-1, 1] normalized).
    pub fn apply(&self, weights: &[f32]) -> Vec<f32> {
        assert_eq!(weights.len(), self.wavelengths * self.arms);
        let mut w = weights.to_vec();
        for f in &self.faults {
            match *f {
                Fault::StuckWeight { channel, arm, value } => {
                    w[channel * self.arms + arm] = value;
                }
                Fault::DeadChannel { channel } => {
                    for arm in 0..self.arms {
                        w[channel * self.arms + arm] = 0.0;
                    }
                }
                Fault::BankDrift { gain } => {
                    for x in w.iter_mut() {
                        *x *= gain;
                    }
                }
            }
        }
        w
    }

    /// RMS weight error introduced by the faults on a given matrix.
    pub fn rms_error(&self, weights: &[f32]) -> f64 {
        let w = self.apply(weights);
        let mse: f64 = weights
            .iter()
            .zip(&w)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / weights.len() as f64;
        mse.sqrt()
    }

    /// Effective bits of the faulty bank: `-log2(rms_error)` against a
    /// full-scale of 1 (coarse but comparable with the crosstalk metric).
    pub fn effective_bits(&self, weights: &[f32]) -> f64 {
        let e = self.rms_error(weights);
        if e <= 0.0 {
            f64::INFINITY
        } else {
            -(e.log2())
        }
    }
}

// --- clock-driven degradation -------------------------------------------

/// Effective-bits level mapped to health 1.0 (the paper's 8-bit weight
/// budget: a bank at or above it is as good as new).
pub const HEALTH_FULL_BITS: f64 = 8.0;
/// Effective-bits level mapped to health 0.0 (below ~4 bits the bank
/// serves numerically meaningless weights).
pub const HEALTH_FLOOR_BITS: f64 = 4.0;
/// Health below which frames served by the worker are counted
/// *accuracy-at-risk* (≈ under 7 effective weight bits).
pub const AT_RISK_HEALTH: f64 = 0.75;
/// Mission window (seconds of worker uptime) over which a schedule's
/// discrete fault onsets are drawn.
pub const SCHEDULE_WINDOW_S: f64 = 600.0;
/// Cap on seeded stuck-cell onsets per schedule.
const MAX_STUCK_EVENTS: usize = 6;
/// Cap on seeded dead-lane onsets per schedule.
const MAX_DEAD_EVENTS: usize = 2;
/// Fraction of neighbour-channel power coupled in per unit of
/// linewidth-normalized drift (crosstalk grows as drifting resonances
/// crowd their neighbours).
const CROSSTALK_PER_LINEWIDTH: f64 = 0.02;

/// Seeded, pure (clock-independent) degradation timeline for one worker's
/// optics. The schedule never mutates: callers evaluate
/// [`FaultSchedule::state_at`] at an elapsed-seconds offset, so the same
/// schedule replayed over the same `ManualClock` steps yields bit-identical
/// degradation — the determinism the `rust/tests/faults.rs` gate relies on.
///
/// **Sampling order (stable contract, mirrors [`FaultyBank::random`]).**
/// From `Rng::new(seed)`: 1 stuck-count variate, 1 dead-count variate,
/// then `MAX_STUCK_EVENTS` stuck-onset variates and `MAX_DEAD_EVENTS`
/// dead-onset variates, all drawn unconditionally (surplus onsets beyond
/// the drawn counts are discarded). Onsets are uniform over
/// [`SCHEDULE_WINDOW_S`] and sorted ascending.
#[derive(Debug, Clone)]
pub struct FaultSchedule {
    /// Seed the timeline was drawn from (diagnostic).
    pub seed: u64,
    /// MR thermal drift accumulation rate (nm of resonance shift per
    /// second of uptime; ≈0.069 nm/K via [`MicroRing::thermal_shift_nm_per_k`]).
    pub drift_nm_per_s: f64,
    /// Bank geometry the health estimate is normalized against.
    pub wavelengths: usize,
    pub arms: usize,
    /// Sorted stuck-cell onset times (seconds of uptime).
    stuck_onsets_s: Vec<f64>,
    /// Sorted dead-VCSEL-lane onset times (seconds of uptime).
    dead_onsets_s: Vec<f64>,
}

impl FaultSchedule {
    /// Draw a schedule for the paper's 32×64 bank geometry.
    pub fn seeded(seed: u64, drift_nm_per_s: f64) -> Self {
        Self::seeded_for_bank(seed, drift_nm_per_s, 32, 64)
    }

    /// Draw a schedule for an explicit bank geometry (see the type-level
    /// sampling-order contract).
    pub fn seeded_for_bank(
        seed: u64,
        drift_nm_per_s: f64,
        wavelengths: usize,
        arms: usize,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let n_stuck = rng.below(MAX_STUCK_EVENTS + 1);
        let n_dead = rng.below(MAX_DEAD_EVENTS + 1);
        let mut stuck_onsets_s: Vec<f64> =
            (0..MAX_STUCK_EVENTS).map(|_| rng.uniform(0.0, SCHEDULE_WINDOW_S)).collect();
        let mut dead_onsets_s: Vec<f64> =
            (0..MAX_DEAD_EVENTS).map(|_| rng.uniform(0.0, SCHEDULE_WINDOW_S)).collect();
        stuck_onsets_s.sort_by(f64::total_cmp);
        stuck_onsets_s.truncate(n_stuck);
        dead_onsets_s.sort_by(f64::total_cmp);
        dead_onsets_s.truncate(n_dead);
        FaultSchedule {
            seed,
            drift_nm_per_s: drift_nm_per_s.max(0.0),
            wavelengths: wavelengths.max(1),
            arms: arms.max(1),
            stuck_onsets_s,
            dead_onsets_s,
        }
    }

    /// The degradation accumulated after `elapsed_s` seconds of uptime
    /// (clamped at 0): continuous drift plus every discrete onset whose
    /// time has passed. Pure — recalibration is modeled by the *caller*
    /// resetting its elapsed-time epoch, not by mutating the schedule.
    pub fn state_at(&self, elapsed_s: f64) -> DegradationState {
        let t = elapsed_s.max(0.0);
        let drift_nm = self.drift_nm_per_s * t;
        let ring = reference_ring();
        let crosstalk_growth = (drift_nm / ring.delta_nm() * CROSSTALK_PER_LINEWIDTH).min(0.2);
        DegradationState {
            drift_nm,
            crosstalk_growth,
            stuck_cells: self.stuck_onsets_s.iter().filter(|&&o| o <= t).count(),
            dead_lanes: self
                .dead_onsets_s
                .iter()
                .filter(|&&o| o <= t)
                .count()
                .min(self.wavelengths),
            wavelengths: self.wavelengths,
            arms: self.arms,
        }
    }
}

/// The reference ring the health estimate converts drift through:
/// default geometry, Q = 5000, C-band 1550 nm — the same operating point
/// as the screening campaign in `examples/fault_injection`.
fn reference_ring() -> MicroRing {
    MicroRing::at_wavelength(MrGeometry::default(), 5000.0, 1550.0)
}

/// Degradation accumulated by one worker's optics at a point in time —
/// what [`FaultSchedule::state_at`] returns and the serving stack's
/// `BackendHealth` is derived from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradationState {
    /// Accumulated MR resonance drift (nm).
    pub drift_nm: f64,
    /// Extra neighbour-channel power fraction coupled in by that drift.
    pub crosstalk_growth: f64,
    /// Stuck weight cells so far.
    pub stuck_cells: usize,
    /// Dead VCSEL lanes so far.
    pub dead_lanes: usize,
    /// Bank geometry the error estimate is normalized against.
    pub wavelengths: usize,
    pub arms: usize,
}

impl DegradationState {
    /// A pristine bank (health exactly 1.0).
    pub fn healthy(wavelengths: usize, arms: usize) -> Self {
        DegradationState {
            drift_nm: 0.0,
            crosstalk_growth: 0.0,
            stuck_cells: 0,
            dead_lanes: 0,
            wavelengths: wavelengths.max(1),
            arms: arms.max(1),
        }
    }

    /// Estimated RMS weight error (full-scale 1), combining the four
    /// degradation channels as independent error sources:
    /// drift × the reference ring's weight sensitivity, stuck cells at the
    /// expected U[-1,1]-vs-U[0,1) mismatch (2/3 mean square), dead lanes
    /// zeroing whole rows (1/3 mean square per cell), and crosstalk growth
    /// as a gain error on the 1/√3 RMS weight.
    pub fn estimated_rms_error(&self) -> f64 {
        let cells = (self.wavelengths * self.arms).max(1) as f64;
        let sens = reference_ring().weight_sensitivity(0.5);
        let drift = sens * self.drift_nm;
        let stuck = (self.stuck_cells as f64 * (2.0 / 3.0) / cells).sqrt();
        let dead = (self.dead_lanes as f64 * self.arms as f64 * (1.0 / 3.0) / cells).sqrt();
        let xt = self.crosstalk_growth * (1.0f64 / 3.0).sqrt();
        (drift * drift + stuck * stuck + dead * dead + xt * xt).sqrt()
    }

    /// Effective weight bits at this degradation level
    /// (`-log2(estimated_rms_error)`; infinite when pristine).
    pub fn effective_bits(&self) -> f64 {
        let e = self.estimated_rms_error();
        if e <= 0.0 {
            f64::INFINITY
        } else {
            -e.log2()
        }
    }

    /// Continuous health score in `[0, 1]`: 1.0 at or above
    /// [`HEALTH_FULL_BITS`] effective bits, 0.0 at or below
    /// [`HEALTH_FLOOR_BITS`], linear in effective bits between.
    pub fn health(&self) -> f64 {
        let bits = self.effective_bits();
        if bits.is_infinite() {
            return 1.0;
        }
        ((bits - HEALTH_FLOOR_BITS) / (HEALTH_FULL_BITS - HEALTH_FLOOR_BITS)).clamp(0.0, 1.0)
    }

    /// Whether frames served at this level should be counted
    /// accuracy-at-risk (health below [`AT_RISK_HEALTH`]).
    pub fn at_risk(&self) -> bool {
        self.health() < AT_RISK_HEALTH
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal(rng: &mut Rng) -> Vec<f32> {
        let mut w = vec![0.0f32; 32 * 64];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        w
    }

    #[test]
    fn no_faults_no_error() {
        let mut rng = Rng::new(1);
        let w = ideal(&mut rng);
        let bank = FaultyBank::new(32, 64);
        assert_eq!(bank.apply(&w), w);
        assert!(bank.effective_bits(&w).is_infinite());
    }

    #[test]
    fn stuck_weight_changes_one_cell() {
        let mut rng = Rng::new(2);
        let w = ideal(&mut rng);
        let mut bank = FaultyBank::new(32, 64);
        bank.inject(Fault::StuckWeight { channel: 3, arm: 7, value: 0.5 });
        let out = bank.apply(&w);
        assert_eq!(out[3 * 64 + 7], 0.5);
        let diffs = out.iter().zip(&w).filter(|(a, b)| a != b).count();
        assert!(diffs <= 1);
    }

    #[test]
    fn dead_channel_zeroes_row() {
        let mut rng = Rng::new(3);
        let w = ideal(&mut rng);
        let mut bank = FaultyBank::new(32, 64);
        bank.inject(Fault::DeadChannel { channel: 5 });
        let out = bank.apply(&w);
        assert!(out[5 * 64..6 * 64].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn drift_scales_everything() {
        let mut rng = Rng::new(4);
        let w = ideal(&mut rng);
        let mut bank = FaultyBank::new(32, 64);
        bank.inject(Fault::BankDrift { gain: 0.9 });
        let out = bank.apply(&w);
        for (a, b) in w.iter().zip(&out) {
            assert!((a * 0.9 - b).abs() < 1e-6);
        }
    }

    #[test]
    fn more_faults_fewer_bits() {
        let mut rng = Rng::new(5);
        let w = ideal(&mut rng);
        let light = FaultyBank::random(32, 64, 0.001, 0.0, &mut rng);
        let heavy = FaultyBank::random(32, 64, 0.05, 0.03, &mut rng);
        assert!(light.effective_bits(&w) > heavy.effective_bits(&w));
    }

    #[test]
    fn screening_threshold_for_8_bits() {
        // How clean must the bank be to preserve ~8 effective bits?
        // (a stuck-cell rate around 1e-4 or below)
        let mut rng = Rng::new(6);
        let w = ideal(&mut rng);
        let mut worst: f64 = f64::INFINITY;
        for seed in 0..16 {
            let mut r = Rng::new(1000 + seed);
            let bank = FaultyBank::random(32, 64, 1e-4, 0.0, &mut r);
            worst = worst.min(bank.effective_bits(&w));
        }
        assert!(worst > 5.0, "worst effective bits {worst}");
    }

    /// Pins one seeded population exactly. If the sampling order documented
    /// on [`FaultyBank::random`] changes, this fails — that contract is what
    /// keeps fault-injection campaigns reproducible across refactors.
    #[test]
    fn random_population_is_stable_across_refactors() {
        let mut rng = Rng::new(0x51CD);
        let bank = FaultyBank::random(4, 3, 0.3, 0.25, &mut rng);
        assert_eq!(
            bank.faults,
            vec![
                Fault::DeadChannel { channel: 0 },
                Fault::StuckWeight { channel: 2, arm: 2, value: 0.45618567 },
                Fault::StuckWeight { channel: 3, arm: 0, value: 0.2933382 },
                Fault::StuckWeight { channel: 3, arm: 1, value: 0.6635391 },
                Fault::StuckWeight { channel: 3, arm: 2, value: 0.05909135 },
            ]
        );
    }

    #[test]
    fn at_most_one_fault_per_cell_even_at_high_rates() {
        let mut rng = Rng::new(7);
        let bank = FaultyBank::random(16, 8, 0.9, 0.5, &mut rng);
        let mut dead_channels = std::collections::BTreeSet::new();
        let mut stuck_cells = std::collections::BTreeSet::new();
        for f in &bank.faults {
            match *f {
                Fault::DeadChannel { channel } => {
                    assert!(dead_channels.insert(channel), "channel {channel} dead twice");
                }
                Fault::StuckWeight { channel, arm, .. } => {
                    assert!(stuck_cells.insert((channel, arm)), "cell ({channel},{arm}) stuck twice");
                }
                Fault::BankDrift { .. } => unreachable!("random() never injects drift"),
            }
        }
        // Dead channels take precedence: no stuck cell in a dead row.
        for &(ch, _) in &stuck_cells {
            assert!(!dead_channels.contains(&ch), "stuck cell in dead channel {ch}");
        }
        assert!(!dead_channels.is_empty() && !stuck_cells.is_empty());
    }

    /// The variate draw count must not depend on fault outcomes: two
    /// generators that sample wildly different populations stay in
    /// lockstep afterwards.
    #[test]
    fn draw_count_is_independent_of_outcomes() {
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        let _ = FaultyBank::random(8, 4, 0.9, 0.9, &mut a);
        let _ = FaultyBank::random(8, 4, 0.0, 0.0, &mut b);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn schedule_is_deterministic_and_pure() {
        let a = FaultSchedule::seeded(42, 1e-4);
        let b = FaultSchedule::seeded(42, 1e-4);
        for t in [0.0, 17.5, 300.0, 599.9, 1200.0] {
            assert_eq!(a.state_at(t), b.state_at(t));
        }
        // Evaluation doesn't mutate: asking twice gives the same answer.
        assert_eq!(a.state_at(300.0), a.state_at(300.0));
    }

    #[test]
    fn degradation_is_monotone_in_time() {
        let s = FaultSchedule::seeded(3, 2e-4);
        let mut prev = s.state_at(0.0);
        for t in 1..=60 {
            let cur = s.state_at(t as f64 * 15.0);
            assert!(cur.drift_nm >= prev.drift_nm);
            assert!(cur.crosstalk_growth >= prev.crosstalk_growth);
            assert!(cur.stuck_cells >= prev.stuck_cells);
            assert!(cur.dead_lanes >= prev.dead_lanes);
            assert!(cur.health() <= prev.health() + 1e-12);
            prev = cur;
        }
        // Past the mission window everything discrete has fired.
        let end = s.state_at(SCHEDULE_WINDOW_S + 1.0);
        assert_eq!(end.stuck_cells, s.state_at(f64::MAX).stuck_cells);
    }

    #[test]
    fn health_score_brackets() {
        let fresh = DegradationState::healthy(32, 64);
        assert_eq!(fresh.health(), 1.0);
        assert!(!fresh.at_risk());

        // Heavy degradation pins health to the floor.
        let wrecked = DegradationState {
            drift_nm: 0.5,
            crosstalk_growth: 0.2,
            stuck_cells: 512,
            dead_lanes: 16,
            wavelengths: 32,
            arms: 64,
        };
        assert_eq!(wrecked.health(), 0.0);
        assert!(wrecked.at_risk());

        // A single stuck cell on a 32×64 bank keeps ~8+ bits: healthy.
        let one = DegradationState { stuck_cells: 1, ..DegradationState::healthy(32, 64) };
        assert!(one.effective_bits() > HEALTH_FULL_BITS - 3.0);
        assert!(one.health() > wrecked.health());
    }

    #[test]
    fn recalibration_resets_via_epoch() {
        // Recal is modeled by the caller rewinding elapsed time to zero;
        // the schedule itself stays pure.
        let s = FaultSchedule::seeded(11, 5e-4);
        let late = s.state_at(400.0);
        let fresh = s.state_at(0.0);
        assert!(fresh.health() >= late.health());
        assert_eq!(fresh.drift_nm, 0.0);
    }
}
