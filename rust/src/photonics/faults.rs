//! Microring fault models and their accuracy impact.
//!
//! Fabricated MR banks fail in characteristic ways: stuck heaters/DACs pin
//! a weight cell, thermal drift shifts a whole bank, and a dead VCSEL kills
//! a wavelength channel. The paper's >200-copy measurement campaign exists
//! to screen exactly these; this module injects them into the weight-bank
//! abstraction so the test-suite (and the fault_injection example) can
//! quantify how many faults the 8-bit budget absorbs — the robustness
//! question ROBIN [26] asks of binary designs, answered here for Opto-ViT.

use crate::util::rng::Rng;

/// A fault affecting one MR weight cell or one channel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Cell (row = channel, col = arm) stuck at a transmission value.
    StuckWeight { channel: usize, arm: usize, value: f32 },
    /// Whole wavelength channel dead (VCSEL failure): contributes zero.
    DeadChannel { channel: usize },
    /// Uniform resonance drift of the bank: multiplicative weight error.
    BankDrift { gain: f32 },
}

/// A 32×64 weight bank with injected faults.
#[derive(Debug, Clone)]
pub struct FaultyBank {
    pub wavelengths: usize,
    pub arms: usize,
    pub faults: Vec<Fault>,
}

impl FaultyBank {
    pub fn new(wavelengths: usize, arms: usize) -> Self {
        FaultyBank { wavelengths, arms, faults: Vec::new() }
    }

    pub fn inject(&mut self, fault: Fault) -> &mut Self {
        self.faults.push(fault);
        self
    }

    /// Sample a random fault population: each cell independently stuck with
    /// probability `p_stuck`, each channel dead with probability `p_dead`.
    pub fn random(wavelengths: usize, arms: usize, p_stuck: f64, p_dead: f64, rng: &mut Rng) -> Self {
        let mut bank = Self::new(wavelengths, arms);
        for ch in 0..wavelengths {
            if rng.chance(p_dead) {
                bank.inject(Fault::DeadChannel { channel: ch });
                continue;
            }
            for arm in 0..arms {
                if rng.chance(p_stuck) {
                    bank.inject(Fault::StuckWeight {
                        channel: ch,
                        arm,
                        value: rng.next_f32(),
                    });
                }
            }
        }
        bank
    }

    /// Apply the fault population to an ideal weight matrix
    /// (`wavelengths × arms`, row-major, values in [-1, 1] normalized).
    pub fn apply(&self, weights: &[f32]) -> Vec<f32> {
        assert_eq!(weights.len(), self.wavelengths * self.arms);
        let mut w = weights.to_vec();
        for f in &self.faults {
            match *f {
                Fault::StuckWeight { channel, arm, value } => {
                    w[channel * self.arms + arm] = value;
                }
                Fault::DeadChannel { channel } => {
                    for arm in 0..self.arms {
                        w[channel * self.arms + arm] = 0.0;
                    }
                }
                Fault::BankDrift { gain } => {
                    for x in w.iter_mut() {
                        *x *= gain;
                    }
                }
            }
        }
        w
    }

    /// RMS weight error introduced by the faults on a given matrix.
    pub fn rms_error(&self, weights: &[f32]) -> f64 {
        let w = self.apply(weights);
        let mse: f64 = weights
            .iter()
            .zip(&w)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / weights.len() as f64;
        mse.sqrt()
    }

    /// Effective bits of the faulty bank: `-log2(rms_error)` against a
    /// full-scale of 1 (coarse but comparable with the crosstalk metric).
    pub fn effective_bits(&self, weights: &[f32]) -> f64 {
        let e = self.rms_error(weights);
        if e <= 0.0 {
            f64::INFINITY
        } else {
            -(e.log2())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal(rng: &mut Rng) -> Vec<f32> {
        let mut w = vec![0.0f32; 32 * 64];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        w
    }

    #[test]
    fn no_faults_no_error() {
        let mut rng = Rng::new(1);
        let w = ideal(&mut rng);
        let bank = FaultyBank::new(32, 64);
        assert_eq!(bank.apply(&w), w);
        assert!(bank.effective_bits(&w).is_infinite());
    }

    #[test]
    fn stuck_weight_changes_one_cell() {
        let mut rng = Rng::new(2);
        let w = ideal(&mut rng);
        let mut bank = FaultyBank::new(32, 64);
        bank.inject(Fault::StuckWeight { channel: 3, arm: 7, value: 0.5 });
        let out = bank.apply(&w);
        assert_eq!(out[3 * 64 + 7], 0.5);
        let diffs = out.iter().zip(&w).filter(|(a, b)| a != b).count();
        assert!(diffs <= 1);
    }

    #[test]
    fn dead_channel_zeroes_row() {
        let mut rng = Rng::new(3);
        let w = ideal(&mut rng);
        let mut bank = FaultyBank::new(32, 64);
        bank.inject(Fault::DeadChannel { channel: 5 });
        let out = bank.apply(&w);
        assert!(out[5 * 64..6 * 64].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn drift_scales_everything() {
        let mut rng = Rng::new(4);
        let w = ideal(&mut rng);
        let mut bank = FaultyBank::new(32, 64);
        bank.inject(Fault::BankDrift { gain: 0.9 });
        let out = bank.apply(&w);
        for (a, b) in w.iter().zip(&out) {
            assert!((a * 0.9 - b).abs() < 1e-6);
        }
    }

    #[test]
    fn more_faults_fewer_bits() {
        let mut rng = Rng::new(5);
        let w = ideal(&mut rng);
        let light = FaultyBank::random(32, 64, 0.001, 0.0, &mut rng);
        let heavy = FaultyBank::random(32, 64, 0.05, 0.03, &mut rng);
        assert!(light.effective_bits(&w) > heavy.effective_bits(&w));
    }

    #[test]
    fn screening_threshold_for_8_bits() {
        // How clean must the bank be to preserve ~8 effective bits?
        // (a stuck-cell rate around 1e-4 or below)
        let mut rng = Rng::new(6);
        let w = ideal(&mut rng);
        let mut worst: f64 = f64::INFINITY;
        for seed in 0..16 {
            let mut r = Rng::new(1000 + seed);
            let bank = FaultyBank::random(32, 64, 1e-4, 0.0, &mut r);
            worst = worst.min(bank.effective_bits(&w));
        }
        assert!(worst > 5.0, "worst effective bits {worst}");
    }
}
