//! Fabrication-process variation (FPV) Monte-Carlo model.
//!
//! The paper places >200 identical MR copies on one chip and measures the
//! spread; the design goal is a geometry/Q point that keeps 8-bit weight
//! resolution *under* that spread. We model the chain
//!
//! `geometry jitter -> n_eff jitter -> resonance jitter sigma_lambda ->
//!  weight error = |dT/dlambda| * sigma_lambda`
//!
//! and combine it with the crosstalk floor to produce the effective-bits
//! vs. Q-factor curve of §IV: crosstalk noise falls with Q while FPV
//! sensitivity grows with Q, so effective resolution peaks — near Q ≈ 5000
//! for the paper's geometry, where it clears 8 bits.

use super::crosstalk::{ChannelGrid, CrosstalkModel};
use super::mr::{MicroRing, MrGeometry};
use crate::util::rng::Rng;

/// Process-variation magnitudes (1-sigma), post-calibration residuals.
///
/// Raw lithographic jitter on a 5-um ring would shift the resonance by
/// hundreds of pm; deployed photonic weights are always trim-calibrated
/// (the paper auto-measures all >200 copies for exactly this purpose), so
/// what matters is the *residual* after per-ring calibration plus thermal
/// drift between calibrations.
#[derive(Debug, Clone, Copy)]
pub struct FpvModel {
    /// 1-sigma ring-width variation (nm) — affects n_eff.
    pub sigma_width_nm: f64,
    /// 1-sigma radius variation (nm).
    pub sigma_radius_nm: f64,
    /// Fraction of the raw geometric resonance shift that survives
    /// per-ring trim calibration (thermal drift, tuning DAC quantization).
    pub calibration_residual: f64,
    /// d(n_eff)/d(width) in 1/nm for the 760-nm rib waveguide.
    pub dneff_dwidth_per_nm: f64,
}

impl Default for FpvModel {
    fn default() -> Self {
        FpvModel {
            // Typical foundry numbers for a mature SiPh process (cf.
            // CrossLight's FPV analysis): ~1 nm width, ~0.5 nm radius.
            sigma_width_nm: 1.0,
            sigma_radius_nm: 0.5,
            // ~0.24% of the raw shift survives closed-loop trimming — the
            // operating point at which the fabricated bank sustains 8-bit
            // weights at Q ≈ 5000 (the paper's auto-measured calibration
            // of >200 ring copies serves exactly this purpose).
            calibration_residual: 0.0022,
            // ~0.8e-3 / nm for a wide (weakly width-sensitive) rib — the
            // paper picks the 760-nm ring width precisely to lower this.
            dneff_dwidth_per_nm: 8e-4,
        }
    }
}

/// One sampled fabricated ring instance.
#[derive(Debug, Clone, Copy)]
pub struct FpvSample {
    /// Resonance shift (nm) of this instance vs. nominal, post-calibration.
    pub lambda_shift_nm: f64,
}

impl FpvModel {
    /// Raw (pre-calibration) 1-sigma resonance jitter for a geometry:
    /// `sigma_lambda / lambda = sigma_neff / n_g + sigma_r / r`.
    pub fn raw_sigma_lambda_nm(&self, geometry: &MrGeometry, lambda_nm: f64) -> f64 {
        let sigma_neff = self.dneff_dwidth_per_nm * self.sigma_width_nm;
        let term_width = sigma_neff / geometry.n_group;
        let term_radius = self.sigma_radius_nm / (geometry.radius_um * 1000.0);
        lambda_nm * (term_width * term_width + term_radius * term_radius).sqrt()
    }

    /// Post-calibration residual 1-sigma resonance jitter (nm).
    pub fn residual_sigma_lambda_nm(&self, geometry: &MrGeometry, lambda_nm: f64) -> f64 {
        self.calibration_residual * self.raw_sigma_lambda_nm(geometry, lambda_nm)
    }

    /// Sample `n` fabricated instances (the paper's >200-copy experiment).
    pub fn sample_instances(
        &self,
        geometry: &MrGeometry,
        lambda_nm: f64,
        n: usize,
        rng: &mut Rng,
    ) -> Vec<FpvSample> {
        let sigma = self.residual_sigma_lambda_nm(geometry, lambda_nm);
        (0..n).map(|_| FpvSample { lambda_shift_nm: rng.normal_with(0.0, sigma) }).collect()
    }

    /// Worst-case weight error induced by FPV on a ring of the given Q,
    /// evaluated at the most sensitive operating point (w = 0.5 sits on the
    /// steep flank; we scan a weight grid for the max slope).
    pub fn weight_error(&self, ring: &MicroRing) -> f64 {
        let sigma = self.residual_sigma_lambda_nm(&ring.geometry, ring.lambda_res_nm);
        let max_slope = (1..20)
            .map(|k| ring.weight_sensitivity(k as f64 / 20.0))
            .fold(0.0, f64::max);
        max_slope * sigma
    }

    /// Effective resolution in bits combining crosstalk noise and FPV error
    /// (noise sources add; resolution = 1 / total error).
    pub fn effective_bits(&self, ring: &MicroRing, xtalk: &CrosstalkModel) -> f64 {
        let e_fpv = self.weight_error(ring);
        let e_xt = xtalk.worst_case_noise();
        let total = e_fpv + e_xt;
        if total <= 0.0 {
            f64::INFINITY
        } else {
            (1.0 / total).log2()
        }
    }

    /// Sweep Q factors and return `(q, crosstalk_bits, fpv_bits,
    /// effective_bits)` rows — the §IV resolution-analysis experiment.
    pub fn q_sweep(
        &self,
        geometry: MrGeometry,
        grid_channels: usize,
        qs: &[f64],
    ) -> Vec<QSweepRow> {
        qs.iter()
            .map(|&q| {
                let ring = MicroRing::at_wavelength(geometry, q, 1550.0);
                let grid = ChannelGrid::c_band(grid_channels);
                let xtalk = CrosstalkModel::new(grid, q);
                let e_fpv = self.weight_error(&ring);
                let fpv_bits =
                    if e_fpv > 0.0 { (1.0 / e_fpv).log2() } else { f64::INFINITY };
                QSweepRow {
                    q_factor: q,
                    crosstalk_bits: xtalk.resolution_bits(),
                    fpv_bits,
                    effective_bits: self.effective_bits(&ring, &xtalk),
                }
            })
            .collect()
    }
}

/// One row of the resolution-vs-Q sweep.
#[derive(Debug, Clone, Copy)]
pub struct QSweepRow {
    pub q_factor: f64,
    pub crosstalk_bits: f64,
    pub fpv_bits: f64,
    pub effective_bits: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_much_smaller_than_raw() {
        let f = FpvModel::default();
        let g = MrGeometry::default();
        assert!(f.residual_sigma_lambda_nm(&g, 1550.0) < 0.1 * f.raw_sigma_lambda_nm(&g, 1550.0));
    }

    #[test]
    fn samples_have_zero_mean() {
        let f = FpvModel::default();
        let g = MrGeometry::default();
        let mut rng = Rng::new(1234);
        let samples = f.sample_instances(&g, 1550.0, 5000, &mut rng);
        let mean: f64 =
            samples.iter().map(|s| s.lambda_shift_nm).sum::<f64>() / samples.len() as f64;
        let sigma = f.residual_sigma_lambda_nm(&g, 1550.0);
        assert!(mean.abs() < sigma * 0.1, "mean {mean} sigma {sigma}");
    }

    #[test]
    fn fpv_error_grows_with_q() {
        let f = FpvModel::default();
        let g = MrGeometry::default();
        let lo = MicroRing::at_wavelength(g, 2000.0, 1550.0);
        let hi = MicroRing::at_wavelength(g, 20000.0, 1550.0);
        assert!(f.weight_error(&hi) > f.weight_error(&lo));
    }

    #[test]
    fn effective_bits_peaks_in_sweep() {
        let f = FpvModel::default();
        let qs: Vec<f64> = (1..=40).map(|k| k as f64 * 1000.0).collect();
        let rows = f.q_sweep(MrGeometry::default(), 32, &qs);
        // crosstalk bits monotonically improve with Q…
        assert!(rows.last().unwrap().crosstalk_bits > rows[0].crosstalk_bits);
        // …FPV bits monotonically degrade…
        assert!(rows.last().unwrap().fpv_bits < rows[0].fpv_bits);
        // …so the combined curve has an interior maximum.
        let best = rows
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.effective_bits.partial_cmp(&b.1.effective_bits).unwrap())
            .unwrap()
            .0;
        assert!(best > 0 && best < rows.len() - 1, "peak at edge: idx {best}");
    }

    #[test]
    fn paper_q5000_reaches_8_bits() {
        // The §IV headline: Q ≈ 5000 with the chosen geometry achieves at
        // least 8-bit effective weight resolution.
        let f = FpvModel::default();
        let rows = f.q_sweep(MrGeometry::default(), 32, &[5000.0]);
        assert!(
            rows[0].effective_bits >= 8.0,
            "effective bits at Q=5000: {:.2}",
            rows[0].effective_bits
        );
    }
}
