//! Balanced photodetector (BPD) accumulation model.
//!
//! At the end of each waveguide arm a BPD sums the optical power across all
//! wavelength channels — the analog accumulate of the optical MAC (§II,
//! Fig. 4). Balanced detection lets a signed weight be represented as the
//! difference between two rails.

/// A balanced photodetector at the end of one arm.
#[derive(Debug, Clone, Copy)]
pub struct Bpd {
    /// Responsivity (A/W) at 1550 nm.
    pub responsivity_a_per_w: f64,
    /// 3-dB bandwidth (GHz) — photodetection is never the bottleneck
    /// (the paper cites >100 GHz detection rates).
    pub bandwidth_ghz: f64,
    /// Dark current (nA), sets the noise/precision floor together with the
    /// TIA that follows.
    pub dark_current_na: f64,
    /// Energy per accumulate-and-sample event (pJ), including the TIA.
    pub sample_energy_pj: f64,
}

impl Default for Bpd {
    fn default() -> Self {
        Bpd {
            responsivity_a_per_w: 1.0,
            bandwidth_ghz: 100.0,
            dark_current_na: 10.0,
            sample_energy_pj: 0.2,
        }
    }
}

impl Bpd {
    /// Photocurrent (mA) for total incident optical power (mW) on the
    /// positive rail minus the negative rail.
    pub fn photocurrent_ma(&self, p_plus_mw: f64, p_minus_mw: f64) -> f64 {
        self.responsivity_a_per_w * (p_plus_mw - p_minus_mw)
    }

    /// Accumulate per-channel powers (the optical dot product): the BPD sums
    /// incoherently across wavelengths.
    pub fn accumulate(&self, channel_powers_mw: &[f64]) -> f64 {
        let total: f64 = channel_powers_mw.iter().sum();
        self.photocurrent_ma(total, 0.0)
    }

    /// Minimum integration time (ns) per sample given bandwidth.
    pub fn min_sample_ns(&self) -> f64 {
        1.0 / self.bandwidth_ghz
    }

    /// Shot-noise-limited SNR for mean photocurrent `i_ma` over integration
    /// time `t_ns` (for the precision analysis: must exceed the 8-bit
    /// requirement of ~48 dB + margin).
    pub fn shot_noise_snr_db(&self, i_ma: f64, t_ns: f64) -> f64 {
        const Q_E: f64 = 1.602e-19;
        let i = i_ma * 1e-3;
        let t = t_ns * 1e-9;
        if i <= 0.0 {
            return 0.0;
        }
        // SNR = I*t / sqrt(2 q I t) in electron counts
        let electrons = i * t / Q_E;
        let snr = electrons / (2.0 * electrons).sqrt();
        20.0 * snr.log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_subtraction() {
        let b = Bpd::default();
        assert!(b.photocurrent_ma(2.0, 0.5) > 0.0);
        assert!(b.photocurrent_ma(0.5, 2.0) < 0.0);
        assert_eq!(b.photocurrent_ma(1.0, 1.0), 0.0);
    }

    #[test]
    fn accumulate_sums_channels() {
        let b = Bpd::default();
        let i = b.accumulate(&[0.1; 32]);
        assert!((i - b.photocurrent_ma(3.2, 0.0)).abs() < 1e-12);
    }

    #[test]
    fn snr_supports_8_bits_at_1ghz() {
        let b = Bpd::default();
        // 1 mA photocurrent, 1 ns integration: SNR must clear 8-bit ~50 dB.
        let snr = b.shot_noise_snr_db(1.0, 1.0);
        assert!(snr > 50.0, "snr {snr} dB");
    }

    #[test]
    fn faster_than_electronics() {
        let b = Bpd::default();
        assert!(b.min_sample_ns() < 0.1);
    }
}
