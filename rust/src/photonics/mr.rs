//! Microring resonator (MR) device model.
//!
//! An all-pass microring weight cell: a ring of radius `r` coupled to a bus
//! waveguide. Near a resonance the through-port transmission is a Lorentzian
//! dip. Imprinting a weight means thermally/electro-optically detuning the
//! resonance so the transmission at the (fixed) signal wavelength equals the
//! desired weight — exactly the mechanism of the paper's Fig. 2(a).
//!
//! Geometry defaults follow §IV: input waveguide 400 nm, ring waveguide
//! 760 nm, radius 5 um, Q ≈ 5000, C-band operation.

/// Physical geometry of a fabricated MR (paper §IV values by default).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MrGeometry {
    /// Ring radius in micrometres.
    pub radius_um: f64,
    /// Ring waveguide width in nanometres.
    pub ring_width_nm: f64,
    /// Input (bus) waveguide width in nanometres.
    pub input_width_nm: f64,
    /// Effective refractive index of the ring mode.
    pub n_eff: f64,
    /// Group index (for FSR and thermo-optic shift).
    pub n_group: f64,
}

impl Default for MrGeometry {
    fn default() -> Self {
        // Paper §IV: 400 nm input waveguide, 760 nm ring waveguide, r = 5 um.
        // n_eff/n_group typical for a 760-nm-wide silicon rib waveguide at
        // 1550 nm (Bogaerts et al., "Silicon microring resonators").
        MrGeometry {
            radius_um: 5.0,
            ring_width_nm: 760.0,
            input_width_nm: 400.0,
            n_eff: 2.36,
            n_group: 4.2,
        }
    }
}

impl MrGeometry {
    /// Ring circumference in micrometres.
    pub fn circumference_um(&self) -> f64 {
        2.0 * std::f64::consts::PI * self.radius_um
    }

    /// Resonant wavelength (nm) for mode order `m`:
    /// `lambda_res = n_eff * L / m` (paper §II).
    pub fn resonant_wavelength_nm(&self, mode_order: u32) -> f64 {
        self.n_eff * self.circumference_um() * 1000.0 / mode_order as f64
    }

    /// Mode order whose resonance lands closest to `target_nm`.
    pub fn mode_order_near(&self, target_nm: f64) -> u32 {
        let m = self.n_eff * self.circumference_um() * 1000.0 / target_nm;
        m.round().max(1.0) as u32
    }

    /// Free spectral range (nm) near `lambda_nm`:
    /// `FSR = lambda^2 / (n_g * L)`.
    pub fn fsr_nm(&self, lambda_nm: f64) -> f64 {
        lambda_nm * lambda_nm / (self.n_group * self.circumference_um() * 1000.0)
    }
}

/// An MR weight cell: geometry + loaded Q + extinction, operated at a
/// specific resonance.
#[derive(Debug, Clone, Copy)]
pub struct MicroRing {
    pub geometry: MrGeometry,
    /// Loaded quality factor. Paper finds Q ≈ 5000 is required for 8-bit
    /// weight resolution with FPV tolerance.
    pub q_factor: f64,
    /// Resonant wavelength (nm) the cell is nominally tuned to.
    pub lambda_res_nm: f64,
    /// Minimum through-port transmission on resonance (extinction floor).
    pub t_min: f64,
}

/// Silicon thermo-optic coefficient dn/dT (1/K).
pub const SILICON_DN_DT: f64 = 1.86e-4;

impl MicroRing {
    /// Construct a ring at the resonance nearest `target_nm`.
    pub fn at_wavelength(geometry: MrGeometry, q_factor: f64, target_nm: f64) -> Self {
        let m = geometry.mode_order_near(target_nm);
        let lambda = geometry.resonant_wavelength_nm(m);
        MicroRing { geometry, q_factor, lambda_res_nm: lambda, t_min: 0.01 }
    }

    /// Lorentzian half-width-at-half-maximum `delta = lambda / (2 Q)`
    /// (paper §IV, the same `delta` used in the crosstalk model).
    pub fn delta_nm(&self) -> f64 {
        self.lambda_res_nm / (2.0 * self.q_factor)
    }

    /// Through-port power transmission at wavelength `lambda_nm` when the
    /// ring is detuned by `detune_nm` from its nominal resonance:
    ///
    /// `T = 1 - (1 - t_min) * delta^2 / ((lambda - lambda_res)^2 + delta^2)`
    pub fn transmission(&self, lambda_nm: f64, detune_nm: f64) -> f64 {
        let d = self.delta_nm();
        let off = lambda_nm - (self.lambda_res_nm + detune_nm);
        let lorentz = d * d / (off * off + d * d);
        1.0 - (1.0 - self.t_min) * lorentz
    }

    /// Detuning (nm) that imprints weight `w` (in `[t_min, 1)`) on a signal
    /// at the nominal resonance wavelength. Inverse of [`Self::transmission`]
    /// evaluated at `lambda = lambda_res`:
    ///
    /// `detune = delta * sqrt((1 - t_min)/(1 - w) - 1)`
    pub fn detuning_for_weight(&self, w: f64) -> f64 {
        let w = w.clamp(self.t_min, 1.0 - 1e-9);
        let d = self.delta_nm();
        let lorentz = (1.0 - w) / (1.0 - self.t_min);
        d * (1.0 / lorentz - 1.0).sqrt()
    }

    /// Local slope |dT/dlambda| (1/nm) at the operating point for weight `w`.
    /// This is the FPV sensitivity: a resonance jitter `sigma_nm` produces a
    /// weight error of about `slope * sigma_nm`. Sharper rings (higher Q)
    /// have a proportionally larger slope — the paper's argument for why
    /// very high Q *hurts* under fabrication variation.
    pub fn weight_sensitivity(&self, w: f64) -> f64 {
        let d = self.delta_nm();
        let x = self.detuning_for_weight(w); // operating offset from resonance
        // T(x) = 1 - (1-t_min) d^2/(x^2+d^2);  dT/dx = (1-t_min) * 2 d^2 x /(x^2+d^2)^2
        let denom = x * x + d * d;
        (1.0 - self.t_min) * 2.0 * d * d * x / (denom * denom)
    }

    /// Thermo-optic resonance shift per kelvin (nm/K):
    /// `dlambda/dT = lambda * (dn/dT) / n_g`.
    pub fn thermal_shift_nm_per_k(&self) -> f64 {
        self.lambda_res_nm * SILICON_DN_DT / self.geometry.n_group
    }

    /// Temperature change (K) needed to realise `detune_nm`.
    pub fn temperature_for_detuning(&self, detune_nm: f64) -> f64 {
        detune_nm / self.thermal_shift_nm_per_k()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> MicroRing {
        MicroRing::at_wavelength(MrGeometry::default(), 5000.0, 1550.0)
    }

    #[test]
    fn resonance_near_target() {
        let r = ring();
        assert!((r.lambda_res_nm - 1550.0).abs() < r.geometry.fsr_nm(1550.0));
    }

    #[test]
    fn fsr_for_5um_ring_is_about_18nm() {
        let g = MrGeometry::default();
        let fsr = g.fsr_nm(1550.0);
        assert!((15.0..22.0).contains(&fsr), "fsr {fsr}");
    }

    #[test]
    fn transmission_dips_on_resonance() {
        let r = ring();
        let on = r.transmission(r.lambda_res_nm, 0.0);
        let off = r.transmission(r.lambda_res_nm + 10.0 * r.delta_nm(), 0.0);
        assert!(on <= r.t_min + 1e-9, "on-resonance {on}");
        assert!(off > 0.95, "far-off-resonance {off}");
    }

    #[test]
    fn weight_roundtrip() {
        let r = ring();
        for &w in &[0.02, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let det = r.detuning_for_weight(w);
            let t = r.transmission(r.lambda_res_nm, det);
            assert!((t - w).abs() < 1e-9, "w {w} -> t {t}");
        }
    }

    #[test]
    fn sensitivity_scales_with_q() {
        let lo = MicroRing { q_factor: 2000.0, ..ring() };
        let hi = MicroRing { q_factor: 20000.0, ..ring() };
        // At the same weight, the sharper ring is more sensitive to
        // wavelength jitter (in absolute nm terms).
        assert!(hi.weight_sensitivity(0.5) > lo.weight_sensitivity(0.5));
    }

    #[test]
    fn delta_matches_q_definition() {
        let r = ring();
        assert!((r.delta_nm() - r.lambda_res_nm / (2.0 * 5000.0)).abs() < 1e-12);
    }

    #[test]
    fn thermal_tuning_sane() {
        let r = ring();
        // ~70 pm/K is the textbook number for silicon rings at 1550 nm.
        let s = r.thermal_shift_nm_per_k();
        assert!((0.04..0.12).contains(&s), "shift {s} nm/K");
        let dt = r.temperature_for_detuning(r.delta_nm());
        assert!(dt > 0.0 && dt < 10.0, "dT {dt} K for one linewidth");
    }
}
