//! Photonic device models: the bottom of the paper's bottom-up evaluation
//! framework (Fig. 7).
//!
//! The paper fabricated >200 identical microrings on a 10×10 mm² chip,
//! measured them, and reduced the measurements to the analytic models of
//! §IV ("MR Resolution Analysis"). We implement exactly those models:
//!
//! - [`mr`] — Lorentzian microring transmission, resonance geometry, tuning.
//! - [`crosstalk`] — inter-channel noise `phi(i,j) = delta^2 / ((lambda_i -
//!   lambda_j)^2 + delta^2)` and the resolution bound `1 / max|P_noise|`.
//! - [`fpv`] — Monte-Carlo fabrication-process variation over MR geometry.
//! - [`vcsel`] — VCSEL drive/efficiency model for the optical inputs.
//! - [`bpd`] — balanced photodetector accumulation model.
//! - [`faults`] — static fault populations ([`FaultyBank`]) **and** the
//!   clock-driven degradation layer the serving stack routes on.
//!
//! # Fault → health flow (degraded-optics serving)
//!
//! ```text
//! FaultSchedule::seeded(seed_w, rate)      per worker w, pure timeline
//!        │ state_at(elapsed since recal epoch)
//!        ▼
//! DegradationState { drift_nm, crosstalk_growth, stuck, dead }
//!        │ estimated_rms_error → effective bits → health ∈ [0,1]
//!        ▼
//! SimBackend::health() ──▶ BackendHealth ──▶ worker HealthSlot (atomics)
//!        │                                        │
//!        │ recalibrate(): epoch ← now,            ▼
//!        │ cost = AcceleratorModel::      dispatcher: route critical
//!        │        recalibration_cost     traffic off at-risk workers,
//!        ▼                               drain + recal below threshold
//! worker rejoins healthy                 (see coordinator::server)
//! ```

pub mod bpd;
pub mod crosstalk;
pub mod faults;
pub mod fpv;
pub mod link;
pub mod mr;
pub mod vcsel;

pub use crosstalk::{ChannelGrid, CrosstalkModel};
pub use faults::{
    AT_RISK_HEALTH, DegradationState, Fault, FaultSchedule, FaultyBank, HEALTH_FLOOR_BITS,
    HEALTH_FULL_BITS,
};
pub use fpv::{FpvModel, FpvSample};
pub use link::LinkBudget;
pub use mr::{MicroRing, MrGeometry};
pub use vcsel::Vcsel;
