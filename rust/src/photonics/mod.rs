//! Photonic device models: the bottom of the paper's bottom-up evaluation
//! framework (Fig. 7).
//!
//! The paper fabricated >200 identical microrings on a 10×10 mm² chip,
//! measured them, and reduced the measurements to the analytic models of
//! §IV ("MR Resolution Analysis"). We implement exactly those models:
//!
//! - [`mr`] — Lorentzian microring transmission, resonance geometry, tuning.
//! - [`crosstalk`] — inter-channel noise `phi(i,j) = delta^2 / ((lambda_i -
//!   lambda_j)^2 + delta^2)` and the resolution bound `1 / max|P_noise|`.
//! - [`fpv`] — Monte-Carlo fabrication-process variation over MR geometry.
//! - [`vcsel`] — VCSEL drive/efficiency model for the optical inputs.
//! - [`bpd`] — balanced photodetector accumulation model.

pub mod bpd;
pub mod crosstalk;
pub mod faults;
pub mod fpv;
pub mod link;
pub mod mr;
pub mod vcsel;

pub use crosstalk::{ChannelGrid, CrosstalkModel};
pub use faults::{Fault, FaultyBank};
pub use fpv::{FpvModel, FpvSample};
pub use link::LinkBudget;
pub use mr::{MicroRing, MrGeometry};
pub use vcsel::Vcsel;
