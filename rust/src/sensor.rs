//! Synthetic CMOS image sensor and video workload generator.
//!
//! The paper's near-sensor deployment consumes live camera frames
//! (ImageNet-VID sequences for the video evaluation). Offline we generate an
//! equivalent workload: scenes of moving geometric objects over textured
//! backgrounds, with exact ground-truth bounding boxes — which is precisely
//! what MGNet trains against (box-derived patch labels) and what the
//! detection-style experiments score against.
//!
//! Frames are produced in planar RGB `f32` in `[0, 1]`, shape
//! `(3, size, size)` row-major, matching the L2 model's input layout.

use crate::roi::{BoundingBox, PatchMask};
use crate::util::rng::Rng;

/// Object shape vocabulary (also the class label in classification runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    Square,
    Disc,
    Cross,
}

impl Shape {
    pub const ALL: [Shape; 3] = [Shape::Square, Shape::Disc, Shape::Cross];

    pub fn class_id(&self) -> usize {
        match self {
            Shape::Square => 0,
            Shape::Disc => 1,
            Shape::Cross => 2,
        }
    }
}

/// One moving object in a scene.
#[derive(Debug, Clone)]
pub struct SceneObject {
    pub shape: Shape,
    /// Center position (pixels, f64 for smooth motion).
    pub cx: f64,
    pub cy: f64,
    /// Half-size (pixels).
    pub half: f64,
    /// Velocity (pixels/frame).
    pub vx: f64,
    pub vy: f64,
    /// RGB color.
    pub color: [f32; 3],
}

impl SceneObject {
    pub fn bbox(&self, size: usize) -> BoundingBox {
        let x0 = (self.cx - self.half).max(0.0) as usize;
        let y0 = (self.cy - self.half).max(0.0) as usize;
        let x1 = ((self.cx + self.half).min(size as f64 - 1.0) as usize).max(x0 + 1);
        let y1 = ((self.cy + self.half).min(size as f64 - 1.0) as usize).max(y0 + 1);
        BoundingBox::new(x0, y0, x1, y1)
    }

    fn covers(&self, x: usize, y: usize) -> bool {
        let dx = x as f64 - self.cx;
        let dy = y as f64 - self.cy;
        match self.shape {
            Shape::Square => dx.abs() <= self.half && dy.abs() <= self.half,
            Shape::Disc => dx * dx + dy * dy <= self.half * self.half,
            Shape::Cross => {
                (dx.abs() <= self.half / 3.0 && dy.abs() <= self.half)
                    || (dy.abs() <= self.half / 3.0 && dx.abs() <= self.half)
            }
        }
    }
}

/// One rendered frame + ground truth.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Planar RGB, `3 * size * size`, values in `[0, 1]`.
    pub pixels: Vec<f32>,
    pub size: usize,
    pub boxes: Vec<BoundingBox>,
    /// Class of the dominant (largest) object.
    pub label: usize,
    /// Monotone frame index within its sequence.
    pub index: u64,
    /// Execution precision policy for this frame. Sensors emit the
    /// default (fixed INT8); session submission re-stamps it with the
    /// tenant's `SessionOptions::precision`, and `Auto` resolves to a
    /// concrete tier in the pipeline once the ROI mask is known.
    pub precision: crate::quant::PrecisionPolicy,
}

impl Frame {
    /// Ground-truth patch mask for a given patch size (the paper's labeling
    /// rule: patch = 1 if it overlaps any box).
    pub fn gt_mask(&self, patch_px: usize) -> PatchMask {
        PatchMask::from_boxes(self.size / patch_px, patch_px, &self.boxes)
    }

    /// Extract non-overlapping flattened patches: output shape
    /// `(n_patches, patch_px*patch_px*3)`, channels-last within a patch
    /// (matching the L2 embedding layout).
    pub fn patchify(&self, patch_px: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.patchify_into(patch_px, &mut out);
        out
    }

    /// [`Frame::patchify`] into a caller-owned buffer — allocation-free once
    /// the buffer has capacity for `n_patches * patch_dim` values, which is
    /// what keeps the serving hot path off the heap.
    pub fn patchify_into(&self, patch_px: usize, out: &mut Vec<f32>) {
        let side = self.size / patch_px;
        let pd = patch_px * patch_px * 3;
        out.clear();
        out.resize(side * side * pd, 0.0);
        let plane = self.size * self.size;
        for py in 0..side {
            for px in 0..side {
                let base = (py * side + px) * pd;
                for dy in 0..patch_px {
                    for dx in 0..patch_px {
                        let y = py * patch_px + dy;
                        let x = px * patch_px + dx;
                        for c in 0..3 {
                            out[base + (dy * patch_px + dx) * 3 + c] =
                                self.pixels[c * plane + y * self.size + x];
                        }
                    }
                }
            }
        }
    }
}

/// A synthetic video source: objects move ballistically and bounce off the
/// frame edges; background is a static low-frequency texture plus per-frame
/// sensor read noise.
#[derive(Debug)]
pub struct VideoSource {
    pub size: usize,
    objects: Vec<SceneObject>,
    background: Vec<f32>,
    noise_sigma: f32,
    rng: Rng,
    frame_index: u64,
}

impl VideoSource {
    /// A scene with `num_objects` random objects. Deterministic per seed.
    pub fn new(size: usize, num_objects: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let objects = (0..num_objects)
            .map(|_| {
                let half = rng.uniform(size as f64 * 0.12, size as f64 * 0.24);
                let shape = Shape::ALL[rng.below(3)];
                // Class-correlated hue + jitter (mirrors python data.py):
                // each class has a dominant channel, keeping the build-time
                // classification task learnable (DESIGN.md §Deviations).
                let mut color = [
                    rng.uniform(0.05, 0.35) as f32,
                    rng.uniform(0.05, 0.35) as f32,
                    rng.uniform(0.05, 0.35) as f32,
                ];
                color[shape.class_id()] = rng.uniform(0.7, 1.0) as f32;
                SceneObject {
                    shape,
                    cx: rng.uniform(half, size as f64 - half),
                    cy: rng.uniform(half, size as f64 - half),
                    half,
                    vx: rng.uniform(-2.5, 2.5),
                    vy: rng.uniform(-2.5, 2.5),
                    color,
                }
            })
            .collect();
        // Low-frequency background texture (sum of two gradients).
        let mut background = vec![0.0f32; 3 * size * size];
        let gx = rng.uniform(0.0, 0.15);
        let gy = rng.uniform(0.0, 0.15);
        for c in 0..3 {
            for y in 0..size {
                for x in 0..size {
                    background[c * size * size + y * size + x] = (0.1
                        + gx * x as f64 / size as f64
                        + gy * y as f64 / size as f64)
                        as f32;
                }
            }
        }
        VideoSource { size, objects, background, noise_sigma: 0.01, rng, frame_index: 0 }
    }

    /// Advance the scene one timestep and render.
    pub fn next_frame(&mut self) -> Frame {
        let size = self.size;
        // Physics step with edge bounce.
        for o in &mut self.objects {
            o.cx += o.vx;
            o.cy += o.vy;
            if o.cx < o.half || o.cx > size as f64 - o.half {
                o.vx = -o.vx;
                o.cx = o.cx.clamp(o.half, size as f64 - o.half);
            }
            if o.cy < o.half || o.cy > size as f64 - o.half {
                o.vy = -o.vy;
                o.cy = o.cy.clamp(o.half, size as f64 - o.half);
            }
        }
        let mut pixels = self.background.clone();
        let plane = size * size;
        for o in &self.objects {
            let bb = o.bbox(size);
            for y in bb.y0..=bb.y1.min(size - 1) {
                for x in bb.x0..=bb.x1.min(size - 1) {
                    if o.covers(x, y) {
                        for c in 0..3 {
                            pixels[c * plane + y * size + x] = o.color[c];
                        }
                    }
                }
            }
        }
        // Sensor read noise.
        for p in pixels.iter_mut() {
            *p = (*p + self.noise_sigma * self.rng.normal() as f32).clamp(0.0, 1.0);
        }
        let label = self
            .objects
            .iter()
            .max_by(|a, b| a.half.total_cmp(&b.half))
            .map(|o| o.shape.class_id())
            .unwrap_or(0);
        let boxes = self.objects.iter().map(|o| o.bbox(size)).collect();
        let idx = self.frame_index;
        self.frame_index += 1;
        Frame {
            pixels,
            size,
            boxes,
            label,
            index: idx,
            precision: crate::quant::PrecisionPolicy::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_have_valid_pixels() {
        let mut src = VideoSource::new(96, 2, 42);
        let f = src.next_frame();
        assert_eq!(f.pixels.len(), 3 * 96 * 96);
        assert!(f.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = VideoSource::new(64, 2, 7);
        let mut b = VideoSource::new(64, 2, 7);
        assert_eq!(a.next_frame().pixels, b.next_frame().pixels);
    }

    #[test]
    fn objects_stay_in_bounds_over_time() {
        let mut src = VideoSource::new(96, 3, 11);
        for _ in 0..200 {
            let f = src.next_frame();
            for b in &f.boxes {
                assert!(b.x1 <= 96 && b.y1 <= 96);
            }
        }
    }

    #[test]
    fn gt_mask_covers_objects_only() {
        let mut src = VideoSource::new(96, 1, 13);
        let f = src.next_frame();
        let m = f.gt_mask(16);
        // With one modest object, the mask keeps a minority of patches.
        assert!(m.kept() >= 1);
        assert!(m.skip_ratio() > 0.3, "skip {}", m.skip_ratio());
    }

    #[test]
    fn patchify_shapes_and_content() {
        let mut src = VideoSource::new(32, 1, 17);
        let f = src.next_frame();
        let patches = f.patchify(16);
        assert_eq!(patches.len(), 4 * 16 * 16 * 3);
        // First pixel of patch 0 equals pixel (0,0) channels.
        let plane = 32 * 32;
        assert_eq!(patches[0], f.pixels[0]);
        assert_eq!(patches[1], f.pixels[plane]);
        assert_eq!(patches[2], f.pixels[2 * plane]);
    }

    #[test]
    fn patchify_into_reuses_buffer() {
        let mut src = VideoSource::new(32, 1, 17);
        let a = src.next_frame();
        let b = src.next_frame();
        let mut buf = Vec::new();
        a.patchify_into(16, &mut buf);
        assert_eq!(buf, a.patchify(16));
        b.patchify_into(16, &mut buf);
        assert_eq!(buf, b.patchify(16));
    }

    #[test]
    fn motion_changes_frames() {
        let mut src = VideoSource::new(64, 2, 19);
        let a = src.next_frame();
        let b = src.next_frame();
        assert_ne!(a.pixels, b.pixels);
        assert_eq!(b.index, a.index + 1);
    }
}
