//! Architecture-level simulation of the Opto-ViT accelerator (§III).
//!
//! - [`workload`] — the MatMul/elementwise inventory of a ViT forward pass,
//!   parameterized by the post-RoI patch count (what the optics must do).
//! - [`core`] — the optical processing core cycle model: 32 wavelength
//!   channels × 64 arms, chunked VVM (Fig. 4/6).
//! - [`mapping`] — matrix splitting onto cores: chunk schedules and
//!   partial-sum plans (Fig. 6).
//! - [`scheduler`] — the five-core matrix-decompositional pipeline of
//!   Fig. 5, as a discrete-event simulation.
//!
//! The scheduler's per-frame task graph is also the input to the
//! queueing co-sim ([`crate::cosim`]), which replays it per *arrival*
//! against persistent per-core availability, so serving can model
//! waiting time under load — at zero load the replay reproduces
//! [`scheduler::AttentionSchedule::steady_state_frame_ns`] bitwise.

pub mod area;
pub mod core;
pub mod mapping;
pub mod scheduler;
pub mod workload;

pub use area::{AreaModel, Floorplan};
pub use core::{CoreParams, MatMulCost, OpticalCore};
pub use mapping::{ChunkPlan, MappingPlan};
pub use scheduler::{AttentionSchedule, PipelineScheduler, ScheduleStats};
pub use workload::{ElementwiseOps, MatMulOp, MatMulKind, Workload};
