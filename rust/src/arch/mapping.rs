//! Matrix splitting and hardware mapping (Fig. 6).
//!
//! Produces the explicit chunk schedule for a MatMul: which 32-element input
//! segment meets which 32×64 weight block in which time slot, and how the
//! partial sums recombine. The serving runtime uses this plan to drive the
//! emulated optical core; the property tests verify every (row, k, col)
//! element is covered exactly once — the invariant behind Fig. 6's
//! color-coded schedule.

use super::core::CoreParams;

/// One scheduled chunk: input segment `k_range` of row `row` hits weight
/// block (`k_range` × `col_range`) on core `core` in slot `slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPlan {
    pub row: usize,
    /// Start (inclusive) of the k segment.
    pub k_start: usize,
    /// End (exclusive) of the k segment.
    pub k_end: usize,
    /// Start (inclusive) of the output-column tile.
    pub col_start: usize,
    /// End (exclusive) of the output-column tile.
    pub col_end: usize,
    /// Which optical core executes this chunk.
    pub core: usize,
    /// Time slot index on that core (each slot = one cycle).
    pub slot: u64,
    /// Whether a bank re-tune precedes this chunk on its core.
    pub retune: bool,
}

/// Complete mapping of a `(m×k)·(k×n)` MatMul onto `num_cores` cores.
#[derive(Debug, Clone)]
pub struct MappingPlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub chunks: Vec<ChunkPlan>,
    pub params: CoreParams,
}

impl MappingPlan {
    /// Weight-stationary plan: column tiles are distributed round-robin
    /// across cores; within a core, for each (col_tile, k_chunk) the bank is
    /// tuned once and all `m` rows stream through (Fig. 6).
    pub fn weight_stationary(m: usize, k: usize, n: usize, params: CoreParams) -> Self {
        assert!(m > 0 && k > 0 && n > 0, "degenerate matmul {m}x{k}x{n}");
        let w = params.wavelengths;
        let a = params.arms;
        let k_chunks = k.div_ceil(w);
        let col_tiles = n.div_ceil(a);
        let mut chunks = Vec::with_capacity(m * k_chunks * col_tiles);
        let mut next_slot = vec![0u64; params.num_cores];
        for ct in 0..col_tiles {
            let core = ct % params.num_cores;
            let col_start = ct * a;
            let col_end = n.min(col_start + a);
            for kc in 0..k_chunks {
                let k_start = kc * w;
                let k_end = k.min(k_start + w);
                for row in 0..m {
                    let slot = next_slot[core];
                    next_slot[core] += 1;
                    chunks.push(ChunkPlan {
                        row,
                        k_start,
                        k_end,
                        col_start,
                        col_end,
                        core,
                        slot,
                        retune: row == 0, // bank re-tuned at the start of each (ct, kc) sweep
                    });
                }
            }
        }
        MappingPlan { m, k, n, chunks, params }
    }

    /// Number of tuning events in the plan.
    pub fn tune_events(&self) -> usize {
        self.chunks.iter().filter(|c| c.retune).count()
    }

    /// Makespan in slots across cores (ignoring tuning overlap).
    pub fn makespan_slots(&self) -> u64 {
        let mut per_core = vec![0u64; self.params.num_cores];
        for c in &self.chunks {
            per_core[c.core] = per_core[c.core].max(c.slot + 1);
        }
        per_core.into_iter().max().unwrap_or(0)
    }

    /// Verify the plan covers every (row, k, col) cell exactly once.
    /// Returns the first violation description, if any.
    pub fn validate_coverage(&self) -> Option<String> {
        // Count coverage with a dense grid over (row, k_chunk, col_tile):
        // chunk boundaries are aligned so cell-level coverage reduces to
        // chunk-level coverage × range checks.
        let w = self.params.wavelengths;
        let a = self.params.arms;
        let k_chunks = self.k.div_ceil(w);
        let col_tiles = self.n.div_ceil(a);
        let mut seen = vec![0u32; self.m * k_chunks * col_tiles];
        for c in &self.chunks {
            if c.k_end <= c.k_start || c.col_end <= c.col_start {
                return Some(format!("empty chunk {c:?}"));
            }
            if c.k_end > self.k || c.col_end > self.n || c.row >= self.m {
                return Some(format!("chunk out of bounds {c:?}"));
            }
            if c.k_start % w != 0 || c.col_start % a != 0 {
                return Some(format!("misaligned chunk {c:?}"));
            }
            let kc = c.k_start / w;
            let ct = c.col_start / a;
            let idx = (c.row * k_chunks + kc) * col_tiles + ct;
            seen[idx] += 1;
        }
        for (idx, &cnt) in seen.iter().enumerate() {
            if cnt != 1 {
                return Some(format!("cell {idx} covered {cnt} times"));
            }
        }
        // No two chunks may share (core, slot).
        let mut occupancy = std::collections::HashSet::new();
        for c in &self.chunks {
            if !occupancy.insert((c.core, c.slot)) {
                return Some(format!("slot collision at core {} slot {}", c.core, c.slot));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> CoreParams {
        CoreParams::default()
    }

    #[test]
    fn plan_covers_exact_fit() {
        let p = MappingPlan::weight_stationary(8, 64, 128, params());
        assert!(p.validate_coverage().is_none());
        assert_eq!(p.chunks.len(), 8 * 2 * 2);
        assert_eq!(p.tune_events(), 4);
    }

    #[test]
    fn plan_covers_ragged_dims() {
        let p = MappingPlan::weight_stationary(7, 100, 70, params());
        assert!(p.validate_coverage().is_none(), "{:?}", p.validate_coverage());
        // 4 k-chunks (100/32), 2 col tiles (70/64).
        assert_eq!(p.tune_events(), 8);
    }

    #[test]
    fn multi_core_distributes_col_tiles() {
        let p = MappingPlan::weight_stationary(4, 32, 64 * 5, params());
        let cores_used: std::collections::HashSet<usize> =
            p.chunks.iter().map(|c| c.core).collect();
        assert_eq!(cores_used.len(), 5);
        // Perfect balance: makespan = per-core slots.
        assert_eq!(p.makespan_slots(), 4);
    }

    #[test]
    fn retune_first_row_only() {
        let p = MappingPlan::weight_stationary(5, 32, 64, params());
        let retunes: Vec<_> = p.chunks.iter().filter(|c| c.retune).collect();
        assert_eq!(retunes.len(), 1);
        assert_eq!(retunes[0].row, 0);
    }

    #[test]
    #[should_panic]
    fn degenerate_matmul_panics() {
        MappingPlan::weight_stationary(0, 32, 64, params());
    }

    #[test]
    fn makespan_matches_single_core_cycles() {
        let mut prm = params();
        prm.num_cores = 1;
        let p = MappingPlan::weight_stationary(7, 100, 70, prm);
        // All chunks on one core => makespan == chunk count.
        assert_eq!(p.makespan_slots(), p.chunks.len() as u64);
    }
}
