//! Silicon-area model (the Table-IV "consistent area constraint").
//!
//! The paper reconstructs all competing accelerators "ensured a consistent
//! area constraint across all accelerators (approximately 20-60 mm²)".
//! This module prices Opto-ViT's own floorplan from published per-component
//! footprints so the constraint is checkable, and so design-space sweeps
//! (more cores, more arms) stay honest about area.

use super::core::CoreParams;
use crate::photonics::MrGeometry;

/// Per-component footprints (mm² unless noted).
#[derive(Debug, Clone, Copy)]
pub struct AreaModel {
    /// MR cell pitch-limited footprint (ring + heater + contacts), mm².
    pub mr_mm2: f64,
    /// VCSEL + driver footprint, mm².
    pub vcsel_mm2: f64,
    /// BPD + TIA footprint, mm².
    pub bpd_mm2: f64,
    /// 8-bit 1 GS/s SAR ADC footprint (45 nm), mm².
    pub adc_mm2: f64,
    /// 8-bit DAC footprint, mm².
    pub dac_mm2: f64,
    /// SRAM density, mm² per KiB (45 nm ~0.0025 mm²/KiB incl. periphery).
    pub sram_mm2_per_kib: f64,
    /// EPU (softmax/GELU unit + adders) footprint, mm².
    pub epu_mm2: f64,
    /// Waveguide routing + splitter overhead per core, mm².
    pub routing_mm2_per_core: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            // 5-um ring + thermal isolation trench + contacts ≈ 25×25 um
            // (the paper's 10×10 mm² test chip held >200 cells comfortably).
            mr_mm2: 625e-6,
            vcsel_mm2: 0.002,   // flip-chip pad + driver
            bpd_mm2: 0.0012,    // Ge PD + TIA
            adc_mm2: 0.012,     // Murmann-survey class 45 nm SAR
            dac_mm2: 0.004,
            sram_mm2_per_kib: 0.0025,
            epu_mm2: 0.35,      // softmax/GELU reuse unit of [38] + adders
            routing_mm2_per_core: 0.8,
        }
    }
}

/// Floorplan totals for one accelerator configuration.
#[derive(Debug, Clone, Copy)]
pub struct Floorplan {
    pub photonics_mm2: f64,
    pub converters_mm2: f64,
    pub memory_mm2: f64,
    pub epu_mm2: f64,
    pub total_mm2: f64,
}

impl AreaModel {
    /// Floorplan for `cores` (ping-pong banks ⇒ 2 MR banks per core) with
    /// `sram_kib` of buffer memory.
    pub fn floorplan(&self, cores: &CoreParams, sram_kib: f64) -> Floorplan {
        let banks_per_core = 2.0; // ping-pong pair (DESIGN.md §Deviations)
        let mrs = cores.num_cores as f64 * banks_per_core * cores.mrs_per_bank() as f64;
        let vcsels = (cores.num_cores * cores.wavelengths) as f64;
        let bpds = (cores.num_cores * cores.arms) as f64;
        let adcs = bpds; // one per arm
        // weight DACs (per MR) are shared per bank column in practice:
        // one DAC per arm per bank + input DACs per VCSEL.
        let dacs = cores.num_cores as f64 * banks_per_core * cores.arms as f64 + vcsels;
        let photonics = mrs * self.mr_mm2
            + vcsels * self.vcsel_mm2
            + bpds * self.bpd_mm2
            + cores.num_cores as f64 * self.routing_mm2_per_core;
        let converters = adcs * self.adc_mm2 + dacs * self.dac_mm2;
        let memory = sram_kib * self.sram_mm2_per_kib;
        let total = photonics + converters + memory + self.epu_mm2;
        Floorplan {
            photonics_mm2: photonics,
            converters_mm2: converters,
            memory_mm2: memory,
            epu_mm2: self.epu_mm2,
            total_mm2: total,
        }
    }

    /// The paper's own configuration: 5 cores, enough SRAM for ViT-Tiny
    /// weights + activations (≈ 8 MiB).
    pub fn optovit_floorplan(&self) -> Floorplan {
        self.floorplan(&CoreParams::default(), 8.0 * 1024.0)
    }
}

/// Sanity bound from the MR geometry: the cell pitch must exceed the ring
/// diameter plus isolation.
pub fn min_mr_cell_mm2(geometry: &MrGeometry) -> f64 {
    let d_um = 2.0 * geometry.radius_um + 10.0; // ring + 5 um isolation each side
    (d_um * 1e-3) * (d_um * 1e-3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optovit_fits_the_table_iv_constraint() {
        // 20-60 mm² is the paper's consistent-area band.
        let fp = AreaModel::default().optovit_floorplan();
        assert!(
            (5.0..60.0).contains(&fp.total_mm2),
            "total {} mm² outside the band",
            fp.total_mm2
        );
    }

    #[test]
    fn components_sum_to_total() {
        let fp = AreaModel::default().optovit_floorplan();
        let sum = fp.photonics_mm2 + fp.converters_mm2 + fp.memory_mm2 + fp.epu_mm2;
        assert!((sum - fp.total_mm2).abs() < 1e-9);
    }

    #[test]
    fn mr_cell_respects_geometry_bound() {
        let m = AreaModel::default();
        assert!(m.mr_mm2 >= min_mr_cell_mm2(&MrGeometry::default()));
    }

    #[test]
    fn area_scales_with_cores() {
        let m = AreaModel::default();
        let five = m.floorplan(&CoreParams::default(), 8192.0);
        let ten = m.floorplan(&CoreParams { num_cores: 10, ..CoreParams::default() }, 8192.0);
        assert!(ten.total_mm2 > five.total_mm2);
        // photonics + converters roughly double; memory/EPU fixed
        assert!(ten.photonics_mm2 > 1.9 * five.photonics_mm2);
    }

    #[test]
    fn converters_are_a_visible_share() {
        // The ADC/DAC area echo of the energy story: conversion is a
        // first-class cost, not an afterthought.
        let fp = AreaModel::default().optovit_floorplan();
        assert!(fp.converters_mm2 / fp.total_mm2 > 0.05);
    }
}
