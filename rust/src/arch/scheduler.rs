//! Five-core matrix-decompositional pipeline scheduler (Fig. 5).
//!
//! The paper's dataflow for one attention head:
//!
//! ```text
//! t0: C1←tune W_Q      C2←tune W_K^T/√dk   C3←tune X^T    C5←tune W_V
//!     C1: Q = X·W_Q  → C2: A1 = Q·W_K^T  → C3: S = A1·X^T → EPU: P = softmax(S)
//!     C5: V = X·W_V                         C4←tune P  →  C4: O = P·V
//! ```
//!
//! All MR-bank (stationary) operands of C1/C2/C3/C5 are known at operation
//! start, so their tuning overlaps; only C4's tuning waits on the softmax.
//! In the *direct* flow, the scores MatMul must tune `K^T` — an operand that
//! exists only after `K = X·W_K` completes — serializing tune-after-compute
//! and forcing K to be buffered. The scheduler makes that contrast
//! quantitative (the `decomposition_ablation` bench).
//!
//! Implemented as deterministic list scheduling over a task DAG with one
//! queue per resource (5 optical cores + the electronic unit): a task's
//! tuning starts when its tuning operand is ready and its core is free; its
//! compute starts when tuning is done and all streamed operands are ready.

use super::core::{CoreParams, OpticalCore};
use crate::vit::VitConfig;

/// Execution resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// Optical core index (0..num_cores).
    Core(usize),
    /// The electronic processing unit (softmax/GELU/norm/adds).
    Epu,
}

/// Task identifier = index into the schedule's task vector.
pub type TaskId = usize;

/// Dependency list with inline storage for the common 0/1/2-dep cases —
/// the schedule builder creates tens of thousands of these per grid build,
/// and almost all were 1-element heap `Vec`s (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub enum Deps {
    None,
    One(TaskId),
    Two(TaskId, TaskId),
    Many(Vec<TaskId>),
}

impl Deps {
    pub fn from_vec(mut v: Vec<TaskId>) -> Self {
        match v.len() {
            0 => Deps::None,
            1 => Deps::One(v[0]),
            2 => Deps::Two(v[0], v[1]),
            _ => Deps::Many(std::mem::take(&mut v)),
        }
    }

    pub fn from_slice(v: &[TaskId]) -> Self {
        match v.len() {
            0 => Deps::None,
            1 => Deps::One(v[0]),
            2 => Deps::Two(v[0], v[1]),
            _ => Deps::Many(v.to_vec()),
        }
    }

    pub fn for_each(&self, mut f: impl FnMut(TaskId)) {
        match self {
            Deps::None => {}
            Deps::One(a) => f(*a),
            Deps::Two(a, b) => {
                f(*a);
                f(*b);
            }
            Deps::Many(v) => v.iter().copied().for_each(f),
        }
    }

    pub fn to_vec(&self) -> Vec<TaskId> {
        let mut out = Vec::new();
        self.for_each(|d| out.push(d));
        out
    }
}

/// Compact task label: avoids per-task `String` allocation on the
/// schedule-construction hot path (EXPERIMENTS.md §Perf: building the
/// Fig. 9 grid allocated ~100k strings per iteration before this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskName {
    pub frame: u32,
    pub block: u32,
    /// Head index, or `u32::MAX` for block-level tasks.
    pub head: u32,
    pub kind: &'static str,
}

impl std::fmt::Display for TaskName {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.head == u32::MAX {
            write!(f, "f{}.b{}.{}", self.frame, self.block, self.kind)
        } else {
            write!(f, "f{}.b{}.h{}.{}", self.frame, self.block, self.head, self.kind)
        }
    }
}

/// One schedulable task: optional tuning phase + compute phase on a resource.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: TaskName,
    pub resource: Resource,
    /// Bank re-tune duration (0 for EPU tasks or retune-free reuse).
    pub tune_ns: f64,
    /// Compute duration.
    pub compute_ns: f64,
    /// Tasks whose *completion* gates the start of tuning (the stationary
    /// operand is one of their outputs). Empty = operand known at t=0.
    pub tune_after: Deps,
    /// Tasks whose completion gates the start of compute (streamed operands).
    pub compute_after: Deps,
}

/// Scheduled timing for one task.
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskTiming {
    pub tune_start: f64,
    pub tune_end: f64,
    pub compute_start: f64,
    pub compute_end: f64,
}

/// Aggregate schedule statistics.
#[derive(Debug, Clone)]
pub struct ScheduleStats {
    /// End-to-end makespan (ns).
    pub makespan_ns: f64,
    /// Busy time per optical core (ns).
    pub core_busy_ns: Vec<f64>,
    /// EPU busy time (ns).
    pub epu_busy_ns: f64,
    /// Tuning time not hidden behind other work on *any* core — the stall
    /// the decomposition removes (ns).
    pub exposed_tune_ns: f64,
    /// Mean optical-core utilization over the makespan.
    pub mean_core_utilization: f64,
}

/// Deterministic list scheduler.
#[derive(Debug, Default)]
pub struct PipelineScheduler {
    pub tasks: Vec<Task>,
}

impl PipelineScheduler {
    pub fn new() -> Self {
        Self { tasks: Vec::new() }
    }

    pub fn push(&mut self, t: Task) -> TaskId {
        self.tasks.push(t);
        self.tasks.len() - 1
    }

    /// Run list scheduling in task-submission order (tasks are submitted in
    /// a topological order by construction; the scheduler asserts it).
    ///
    /// Each core has a **compute resource** (the MR bank in the light path)
    /// and a **tuning engine** (the DAC array loading the shadow bank of
    /// the ping-pong pair). Tuning of task `t` overlaps compute of the
    /// core's previous task, but the engine itself is serial and a bank
    /// must be free: tune(t) may not start before compute of the
    /// next-to-last task on that core has finished.
    pub fn schedule(&self, num_cores: usize) -> (Vec<TaskTiming>, ScheduleStats) {
        let mut timing = vec![TaskTiming::default(); self.tasks.len()];
        let mut core_free = vec![0.0f64; num_cores];
        // compute_end of the previous and the one-before tasks per core
        // (the ping-pong bank availability horizon).
        let mut prev_end = vec![[0.0f64; 2]; num_cores];
        let mut epu_free = 0.0f64;
        let mut core_busy = vec![0.0f64; num_cores];
        let mut epu_busy = 0.0f64;
        let mut exposed_tune = 0.0f64;

        for (i, t) in self.tasks.iter().enumerate() {
            t.tune_after.for_each(|d| {
                assert!(d < i, "task {i} depends on later task {d}: not topological")
            });
            t.compute_after.for_each(|d| {
                assert!(d < i, "task {i} depends on later task {d}: not topological")
            });
            let dep_end = |deps: &Deps| -> f64 {
                let mut m = 0.0f64;
                deps.for_each(|d| m = m.max(timing[d].compute_end));
                m
            };
            match t.resource {
                Resource::Core(c) => {
                    assert!(c < num_cores, "core {c} out of range");
                    let tune_ready = dep_end(&t.tune_after);
                    // Bank for this task frees when the next-to-last task's
                    // compute ends (2-deep ping-pong); the shadow bank's DAC
                    // array is otherwise always available.
                    let bank_free = prev_end[c][0];
                    let tune_start = tune_ready.max(bank_free);
                    let tune_end = tune_start + t.tune_ns;
                    let compute_ready = dep_end(&t.compute_after);
                    let compute_start = tune_end.max(compute_ready).max(core_free[c]);
                    let compute_end = compute_start + t.compute_ns;
                    // Tuning is "exposed" when it delays compute beyond
                    // both the operand readiness and the core availability.
                    let could_start = compute_ready.max(core_free[c]);
                    exposed_tune += (tune_end - could_start).max(0.0).min(t.tune_ns);
                    core_free[c] = compute_end;
                    prev_end[c] = [prev_end[c][1], compute_end];
                    core_busy[c] += compute_end - compute_start;
                    timing[i] = TaskTiming { tune_start, tune_end, compute_start, compute_end };
                }
                Resource::Epu => {
                    let ready = dep_end(&t.compute_after);
                    let start = ready.max(epu_free);
                    let end = start + t.compute_ns;
                    epu_free = end;
                    epu_busy += t.compute_ns;
                    timing[i] = TaskTiming {
                        tune_start: start,
                        tune_end: start,
                        compute_start: start,
                        compute_end: end,
                    };
                }
            }
        }
        let makespan = timing.iter().map(|t| t.compute_end).fold(0.0, f64::max);
        let mean_util = if makespan > 0.0 {
            core_busy.iter().sum::<f64>() / (makespan * num_cores as f64)
        } else {
            0.0
        };
        (
            timing,
            ScheduleStats {
                makespan_ns: makespan,
                core_busy_ns: core_busy,
                epu_busy_ns: epu_busy,
                exposed_tune_ns: exposed_tune,
                mean_core_utilization: mean_util,
            },
        )
    }
}

/// Builder for the attention-phase schedule of a full encoder stack.
pub struct AttentionSchedule;

/// EPU softmax throughput (elements per ns) used for schedule building;
/// must match `energy::components::EpuModel` defaults.
const EPU_ELEMS_PER_NS: f64 = 8.0;

impl AttentionSchedule {
    /// Time for an `(m×k)·(k×n)` on one core, excluding tuning.
    fn mm_compute_ns(core: &OpticalCore, m: usize, k: usize, n: usize) -> f64 {
        let c = core.matmul_cost(m, k, n);
        c.cycles as f64 * core.params.cycle_ns
    }

    /// Exposed tuning latency for one MatMul: the *first* bank settle.
    /// Subsequent chunk loads stream into the shadow bank of the ping-pong
    /// pair while earlier chunks compute (m rows per chunk), so only the
    /// initial settle sits on the critical path — exactly the "one tuning
    /// step per matrix" abstraction of Fig. 5. All chunk retunes still pay
    /// energy (counted per-event in [`OpticalCore::matmul_cost`]).
    fn mm_tune_ns(core: &OpticalCore, _m: usize, _k: usize, _n: usize) -> f64 {
        core.params.tune_ns
    }

    /// Build the **decomposed** (Eq. 2, Fig. 5) schedule for `frames`
    /// consecutive inputs through `cfg.depth` encoder blocks.
    pub fn decomposed(cfg: &VitConfig, n_tokens: usize, params: CoreParams, frames: usize) -> PipelineScheduler {
        Self::build(cfg, n_tokens, params, frames, true, true)
    }

    /// Build the **direct** (naive `Q·K^T`) schedule.
    pub fn direct(cfg: &VitConfig, n_tokens: usize, params: CoreParams, frames: usize) -> PipelineScheduler {
        Self::build(cfg, n_tokens, params, frames, false, true)
    }

    /// Attention-phase-only schedules (no FFN): the `decomposition_ablation`
    /// measurement, isolating the Eq. 2 trade from the FFN critical path.
    pub fn attention_only(
        cfg: &VitConfig,
        n_tokens: usize,
        params: CoreParams,
        frames: usize,
        decomposed: bool,
    ) -> PipelineScheduler {
        Self::build(cfg, n_tokens, params, frames, decomposed, false)
    }

    fn build(
        cfg: &VitConfig,
        n_tokens: usize,
        params: CoreParams,
        frames: usize,
        decomposed: bool,
        include_ffn: bool,
    ) -> PipelineScheduler {
        assert!(params.num_cores >= 5, "the Fig. 5 flow needs 5 cores");
        let core = OpticalCore::new(params);
        let n = n_tokens;
        let d = cfg.embed_dim;
        let dk = cfg.head_dim();
        let f = cfg.ffn_dim();
        let mut s = PipelineScheduler::new();

        for frame in 0..frames {
            // "x_ready" = the task producing this block's input X.
            let mut x_ready: Vec<TaskId> = Vec::new();
            for b in 0..cfg.depth {
                let nm = |s: &'static str| TaskName {
                    frame: frame as u32,
                    block: b as u32,
                    head: u32::MAX,
                    kind: s,
                };
                let mut head_outs: Vec<TaskId> = Vec::new();
                for hh in 0..cfg.num_heads {
                    let hnm = |s: &'static str| TaskName {
                        frame: frame as u32,
                        block: b as u32,
                        head: hh as u32,
                        kind: s,
                    };
                    // C1: Q_h = X·W_Q_h   (tune: W_Q known; stream: X)
                    let q = s.push(Task {
                        name: hnm("q"),
                        resource: Resource::Core(0),
                        tune_ns: Self::mm_tune_ns(&core, n, d, dk),
                        compute_ns: Self::mm_compute_ns(&core, n, d, dk),
                        tune_after: Deps::None,
                        compute_after: Deps::from_slice(&x_ready),
                    });
                    let (scores, v) = if decomposed {
                        // C2: A1 = Q·W_K^T (tune known), C3: S = A1·X^T (tune X^T: needs X,
                        // but X is this block's input — ready with x_ready, not an
                        // intra-head intermediate).
                        let a1 = s.push(Task {
                            name: hnm("a1"),
                            resource: Resource::Core(1),
                            tune_ns: Self::mm_tune_ns(&core, n, dk, d),
                            compute_ns: Self::mm_compute_ns(&core, n, dk, d),
                            tune_after: Deps::None,
                            compute_after: Deps::One(q),
                        });
                        let sc = s.push(Task {
                            name: hnm("s"),
                            resource: Resource::Core(2),
                            tune_ns: Self::mm_tune_ns(&core, n, d, n),
                            compute_ns: Self::mm_compute_ns(&core, n, d, n),
                            tune_after: Deps::from_slice(&x_ready),
                            compute_after: Deps::One(a1),
                        });
                        // C5: V = X·W_V (tune known, stream X).
                        let v = s.push(Task {
                            name: hnm("v"),
                            resource: Resource::Core(4),
                            tune_ns: Self::mm_tune_ns(&core, n, d, dk),
                            compute_ns: Self::mm_compute_ns(&core, n, d, dk),
                            tune_after: Deps::None,
                            compute_after: Deps::from_slice(&x_ready),
                        });
                        (sc, v)
                    } else {
                        // Direct: K = X·W_K on C2, then scores tune K^T (an
                        // intermediate!) on C3.
                        let kt = s.push(Task {
                            name: hnm("k"),
                            resource: Resource::Core(1),
                            tune_ns: Self::mm_tune_ns(&core, n, d, dk),
                            compute_ns: Self::mm_compute_ns(&core, n, d, dk),
                            tune_after: Deps::None,
                            compute_after: Deps::from_slice(&x_ready),
                        });
                        // Tuning waits for K, *and* K must round-trip the
                        // buffer memory (write after ADC, read into the
                        // tuning DACs) — the intermediate-buffering cost
                        // Eq. 2 eliminates. 64 B/ns SRAM bandwidth.
                        let k_buffer_ns = (2 * n * dk) as f64 / 64.0;
                        let sc = s.push(Task {
                            name: hnm("s"),
                            resource: Resource::Core(2),
                            tune_ns: Self::mm_tune_ns(&core, n, dk, n) + k_buffer_ns,
                            compute_ns: Self::mm_compute_ns(&core, n, dk, n),
                            tune_after: Deps::One(kt), // tuning waits for K!
                            compute_after: Deps::One(q),
                        });
                        let v = s.push(Task {
                            name: hnm("v"),
                            resource: Resource::Core(4),
                            tune_ns: Self::mm_tune_ns(&core, n, d, dk),
                            compute_ns: Self::mm_compute_ns(&core, n, d, dk),
                            tune_after: Deps::None,
                            compute_after: Deps::from_slice(&x_ready),
                        });
                        (sc, v)
                    };
                    // EPU: P = softmax(S/√dk) — n² elements.
                    let p = s.push(Task {
                        name: hnm("softmax"),
                        resource: Resource::Epu,
                        tune_ns: 0.0,
                        compute_ns: (n * n) as f64 / EPU_ELEMS_PER_NS,
                        tune_after: Deps::None,
                        compute_after: Deps::One(scores),
                    });
                    // C4: O_h = P·V — tuned by the softmax result (Fig. 5).
                    let o = s.push(Task {
                        name: hnm("o"),
                        resource: Resource::Core(3),
                        tune_ns: Self::mm_tune_ns(&core, n, n, dk),
                        compute_ns: Self::mm_compute_ns(&core, n, n, dk),
                        tune_after: Deps::One(p),
                        compute_after: Deps::One(v),
                    });
                    head_outs.push(o);
                }
                // Output projection: concat heads → X·W_O. Runs on C0 (free
                // by now); streams the concatenated head outputs.
                let proj = s.push(Task {
                    name: nm("proj"),
                    resource: Resource::Core(0),
                    tune_ns: Self::mm_tune_ns(&core, n, d, d),
                    compute_ns: Self::mm_compute_ns(&core, n, d, d),
                    tune_after: Deps::None,
                    compute_after: Deps::from_slice(&head_outs),
                });
                // EPU: residual + layernorm.
                let ln1 = s.push(Task {
                    name: nm("add_ln"),
                    resource: Resource::Epu,
                    tune_ns: 0.0,
                    compute_ns: (2 * n * d) as f64 / EPU_ELEMS_PER_NS,
                    tune_after: Deps::None,
                    compute_after: Deps::One(proj),
                });
                if !include_ffn {
                    x_ready = vec![ln1];
                    continue;
                }
                // FFN: split column tiles of both linears across all cores.
                let ffn1 = Self::push_split_matmul(&mut s, &core, nm("ffn1"), n, d, f, Deps::One(ln1));
                let gelu = s.push(Task {
                    name: nm("gelu"),
                    resource: Resource::Epu,
                    tune_ns: 0.0,
                    compute_ns: (n * f) as f64 / EPU_ELEMS_PER_NS,
                    tune_after: Deps::None,
                    compute_after: Deps::from_vec(ffn1),
                });
                let ffn2 = Self::push_split_matmul(&mut s, &core, nm("ffn2"), n, f, d, Deps::One(gelu));
                let ln2 = s.push(Task {
                    name: nm("add_ln2"),
                    resource: Resource::Epu,
                    tune_ns: 0.0,
                    compute_ns: (2 * n * d) as f64 / EPU_ELEMS_PER_NS,
                    tune_after: Deps::None,
                    compute_after: Deps::from_vec(ffn2),
                });
                x_ready = vec![ln2];
            }
        }
        s
    }

    /// Split an `(m×k)·(k×n)` across all cores by column tiles; returns the
    /// per-core task ids (all must complete before dependents start).
    fn push_split_matmul(
        s: &mut PipelineScheduler,
        core: &OpticalCore,
        name: TaskName,
        m: usize,
        k: usize,
        n: usize,
        deps: Deps,
    ) -> Vec<TaskId> {
        let ncores = core.params.num_cores;
        let col_tiles = n.div_ceil(core.params.arms);
        let tiles_per_core = col_tiles.div_ceil(ncores);
        let mut ids = Vec::new();
        let mut assigned = 0usize;
        for c in 0..ncores {
            let tiles = tiles_per_core.min(col_tiles - assigned);
            if tiles == 0 {
                break;
            }
            assigned += tiles;
            let cols = tiles * core.params.arms.min(n);
            let id = s.push(Task {
                name: TaskName { head: c as u32, ..name },
                resource: Resource::Core(c),
                tune_ns: Self::mm_tune_ns(core, m, k, cols.min(n)),
                compute_ns: Self::mm_compute_ns(core, m, k, cols.min(n)),
                tune_after: Deps::None,
                compute_after: deps.clone(),
            });
            ids.push(id);
        }
        ids
    }

    /// Steady-state per-frame latency: schedule 3 consecutive frames once
    /// and difference the per-frame completion horizons of frames 2 and 3
    /// (pipeline-parallelism-aware throughput; one build instead of two —
    /// EXPERIMENTS.md §Perf).
    pub fn steady_state_frame_ns(
        cfg: &VitConfig,
        n_tokens: usize,
        params: CoreParams,
        decomposed: bool,
    ) -> f64 {
        let s = if decomposed {
            Self::decomposed(cfg, n_tokens, params, 3)
        } else {
            Self::direct(cfg, n_tokens, params, 3)
        };
        let (timing, _) = s.schedule(params.num_cores);
        let horizon = |max_frame: u32| {
            s.tasks
                .iter()
                .zip(&timing)
                .filter(|(t, _)| t.name.frame <= max_frame)
                .map(|(_, tm)| tm.compute_end)
                .fold(0.0, f64::max)
        };
        horizon(2) - horizon(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::{VitConfig, VitVariant};

    fn tiny() -> VitConfig {
        VitConfig::variant(VitVariant::Tiny, 96, 10)
    }

    #[test]
    fn schedule_is_causal() {
        let cfg = tiny();
        let s = AttentionSchedule::decomposed(&cfg, 37, CoreParams::default(), 1);
        let (timing, _) = s.schedule(5);
        for (i, t) in s.tasks.iter().enumerate() {
            for d in t.compute_after.to_vec() {
                assert!(
                    timing[d].compute_end <= timing[i].compute_start + 1e-9,
                    "task {} starts before dep {} ends",
                    s.tasks[i].name,
                    s.tasks[d].name
                );
            }
            for d in t.tune_after.to_vec() {
                assert!(timing[d].compute_end <= timing[i].tune_start + 1e-9);
            }
        }
    }

    #[test]
    fn no_compute_overlap_per_core() {
        // Tuning may overlap the previous task's compute (ping-pong banks),
        // but the light path itself is serial per core.
        let cfg = tiny();
        let s = AttentionSchedule::decomposed(&cfg, 37, CoreParams::default(), 2);
        let (timing, _) = s.schedule(5);
        let mut per_core: Vec<Vec<(f64, f64)>> = vec![Vec::new(); 5];
        for (i, t) in s.tasks.iter().enumerate() {
            if let Resource::Core(c) = t.resource {
                per_core[c].push((timing[i].compute_start, timing[i].compute_end));
            }
        }
        for ivs in &mut per_core {
            ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in ivs.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-9, "overlap {:?} {:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn tuning_overlaps_previous_compute() {
        // The ping-pong bank model must actually hide tuning: somewhere in
        // the schedule a task's tune interval overlaps an earlier task's
        // compute interval on the same core.
        let cfg = tiny();
        let s = AttentionSchedule::decomposed(&cfg, 37, CoreParams::default(), 1);
        let (timing, _) = s.schedule(5);
        let mut found = false;
        for (i, t) in s.tasks.iter().enumerate() {
            if let Resource::Core(c) = t.resource {
                for (j, u) in s.tasks.iter().enumerate().take(i) {
                    if u.resource == Resource::Core(c)
                        && timing[i].tune_start < timing[j].compute_end - 1e-9
                        && timing[i].tune_end > timing[j].compute_start + 1e-9
                    {
                        found = true;
                    }
                }
            }
        }
        assert!(found, "no tuning/compute overlap found — ping-pong not modeled");
    }

    #[test]
    fn decomposed_beats_direct_on_masked_attention() {
        // The Eq. 2 regime: RoI-masked token counts (small n) where the
        // removed K^T tuning stall + buffer round-trip outweigh the extra
        // optical MACs. Attention-phase-only (the FFN path is identical in
        // both flows and hides the difference).
        let cfg = tiny();
        let p = CoreParams::default();
        let d = AttentionSchedule::attention_only(&cfg, 13, p, 1, false).schedule(5).1;
        let dc = AttentionSchedule::attention_only(&cfg, 13, p, 1, true).schedule(5).1;
        assert!(
            dc.makespan_ns < d.makespan_ns,
            "decomposed {} >= direct {}",
            dc.makespan_ns,
            d.makespan_ns
        );
    }

    #[test]
    fn decomposition_crossover_at_large_n() {
        // The reproduction's honest finding (EXPERIMENTS.md): at large token
        // counts the decomposition's extra MACs (h·n²·d vs n²·d) outweigh
        // the tuning savings — the trade the paper leaves implicit.
        let cfg = tiny();
        let p = CoreParams::default();
        let cfg224 = crate::vit::VitConfig::variant(crate::vit::VitVariant::Tiny, 224, 10);
        let d = AttentionSchedule::attention_only(&cfg224, 197, p, 1, false).schedule(5).1;
        let dc = AttentionSchedule::attention_only(&cfg224, 197, p, 1, true).schedule(5).1;
        assert!(
            d.makespan_ns < dc.makespan_ns,
            "expected direct {} < decomposed {} at n=197",
            d.makespan_ns,
            dc.makespan_ns
        );
        let _ = cfg;
    }

    #[test]
    fn direct_has_more_exposed_tuning() {
        let cfg = tiny();
        let p = CoreParams { tune_ns: 200.0, ..CoreParams::default() };
        let d = AttentionSchedule::attention_only(&cfg, 13, p, 1, false).schedule(5).1;
        let dc = AttentionSchedule::attention_only(&cfg, 13, p, 1, true).schedule(5).1;
        assert!(d.exposed_tune_ns > dc.exposed_tune_ns, "{} <= {}", d.exposed_tune_ns, dc.exposed_tune_ns);
    }

    #[test]
    fn pipelining_amortizes() {
        // Per-frame steady-state latency must be below the single-frame
        // makespan (tuning hides behind the previous frame's compute).
        let cfg = tiny();
        let p = CoreParams::default();
        let single = AttentionSchedule::decomposed(&cfg, 37, p, 1).schedule(5).1.makespan_ns;
        let steady = AttentionSchedule::steady_state_frame_ns(&cfg, 37, p, true);
        assert!(steady <= single + 1e-6, "steady {steady} single {single}");
        assert!(steady > 0.0);
    }

    #[test]
    fn utilization_bounded() {
        let cfg = tiny();
        let s = AttentionSchedule::decomposed(&cfg, 37, CoreParams::default(), 1);
        let (_, stats) = s.schedule(5);
        assert!(stats.mean_core_utilization > 0.0 && stats.mean_core_utilization <= 1.0);
    }

    #[test]
    fn fewer_tokens_is_faster() {
        let cfg = tiny();
        let p = CoreParams::default();
        let full = AttentionSchedule::decomposed(&cfg, 37, p, 1).schedule(5).1.makespan_ns;
        let masked = AttentionSchedule::decomposed(&cfg, 13, p, 1).schedule(5).1.makespan_ns;
        assert!(masked < full * 0.6, "masked {masked} full {full}");
    }
}
