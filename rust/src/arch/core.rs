//! Optical processing core cycle/cost model (§III, Fig. 3(b) & Fig. 4).
//!
//! One core = 32 VCSEL wavelength channels × 64 waveguide arms, a BPD per
//! arm, DACs feeding the MR tuning circuits and VCSEL drivers, ADCs reading
//! the BPDs. Per cycle it performs a 32-input × 64-column chunk of a VVM;
//! a full `(m×k)·(k×n)` MatMul is swept over `m · ceil(k/32) · ceil(n/64)`
//! cycles with electronic partial-sum accumulation across k-chunks (Fig. 6).

use super::workload::{MatMulOp, Workload};

/// Dimensions and clocks of one optical core.
#[derive(Debug, Clone, Copy)]
pub struct CoreParams {
    /// WDM input channels (VCSELs) — 32 in the paper.
    pub wavelengths: usize,
    /// Waveguide arms (output columns) — 64 = d_k in the paper.
    pub arms: usize,
    /// Compute cycle time (ns): bounded by the ADC sample rate, not the
    /// optics (photodetection runs >100 GHz; the 1 GS/s ADC is the wall).
    pub cycle_ns: f64,
    /// Time to (re)tune one full 32×64 MR bank (ns). All MRs in a bank tune
    /// in parallel off their own DACs (DAC settle + ring
    /// electro-optic relaxation). Cores carry **double-buffered (ping-pong)
    /// bank pairs**: the tuning engine loads one bank while the other
    /// computes — the reading of Fig. 5's "utilizes idle periods for
    /// tuning" under which the Fig. 9 delay breakdown stays compute-bound.
    pub tune_ns: f64,
    /// Number of optical cores in the accelerator (5 in the paper).
    pub num_cores: usize,
}

impl Default for CoreParams {
    fn default() -> Self {
        CoreParams { wavelengths: 32, arms: 64, cycle_ns: 1.0, tune_ns: 250.0, num_cores: 5 }
    }
}

impl CoreParams {
    /// MRs per bank (one weight element per MR).
    pub fn mrs_per_bank(&self) -> usize {
        self.wavelengths * self.arms
    }

    /// Peak MACs per cycle per core.
    pub fn macs_per_cycle(&self) -> u64 {
        (self.wavelengths * self.arms) as u64
    }
}

/// Cost of running one MatMul on one core.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MatMulCost {
    /// Compute cycles (each = one 32×64 chunk VVM).
    pub cycles: u64,
    /// MR-bank re-tuning events (each loads 32×64 weights).
    pub tune_events: u64,
    /// VCSEL symbols emitted (input-side DAC conversions too).
    pub vcsel_symbols: u64,
    /// BPD samples == ADC conversions (one per arm per cycle).
    pub adc_conversions: u64,
    /// Weight-side DAC conversions (MR tuning values).
    pub weight_dac_conversions: u64,
    /// Electronic partial-sum additions across k-chunks.
    pub partial_sum_adds: u64,
    /// Useful (unpadded) MACs.
    pub macs: u64,
    /// Padded MAC slots (utilization denominator).
    pub mac_slots: u64,
    /// Bytes moved: stationary weights loaded once per tuning event.
    pub weight_bytes: u64,
    /// Bytes moved: streamed input chunks.
    pub input_bytes: u64,
    /// Bytes moved: result write-back.
    pub output_bytes: u64,
}

impl MatMulCost {
    pub fn add(&mut self, o: &MatMulCost) {
        self.cycles += o.cycles;
        self.tune_events += o.tune_events;
        self.vcsel_symbols += o.vcsel_symbols;
        self.adc_conversions += o.adc_conversions;
        self.weight_dac_conversions += o.weight_dac_conversions;
        self.partial_sum_adds += o.partial_sum_adds;
        self.macs += o.macs;
        self.mac_slots += o.mac_slots;
        self.weight_bytes += o.weight_bytes;
        self.input_bytes += o.input_bytes;
        self.output_bytes += o.output_bytes;
    }

    /// Fraction of MAC slots doing useful work (padding loss).
    pub fn utilization(&self) -> f64 {
        if self.mac_slots == 0 {
            0.0
        } else {
            self.macs as f64 / self.mac_slots as f64
        }
    }
}

/// The cycle/cost model of a single optical core.
#[derive(Debug, Clone, Copy)]
pub struct OpticalCore {
    pub params: CoreParams,
}

impl OpticalCore {
    pub fn new(params: CoreParams) -> Self {
        OpticalCore { params }
    }

    /// Cost of a `(m×k)·(k×n)` MatMul (single instance).
    ///
    /// Weight-stationary sweep: for each of `ceil(n/64)` column tiles and
    /// `ceil(k/32)` k-chunks, tune the bank once and stream all `m` rows
    /// through it (Fig. 6's color-coded schedule). Partial sums accumulate
    /// in the electronic unit's 64-wide register file across k-chunks — no
    /// memory round-trip (the buffering the decomposition avoids is for
    /// *intermediate matrices*, not these in-flight partials).
    pub fn matmul_cost(&self, m: usize, k: usize, n: usize) -> MatMulCost {
        let w = self.params.wavelengths;
        let a = self.params.arms;
        let k_chunks = k.div_ceil(w) as u64;
        let col_tiles = n.div_ceil(a) as u64;
        let m64 = m as u64;

        let tune_events = k_chunks * col_tiles;
        let cycles = m64 * k_chunks * col_tiles;
        let vcsel_symbols = cycles * w as u64;
        let adc_conversions = cycles * a as u64;
        let weight_dac_conversions = tune_events * self.params.mrs_per_bank() as u64;
        // Each output element accumulates k_chunks partials => k_chunks-1 adds.
        let partial_sum_adds = m64 * (n as u64) * (k_chunks - 1);
        let macs = (m * k * n) as u64;
        let mac_slots = cycles * self.params.macs_per_cycle();
        MatMulCost {
            cycles,
            tune_events,
            vcsel_symbols,
            adc_conversions,
            weight_dac_conversions,
            partial_sum_adds,
            macs,
            mac_slots,
            weight_bytes: tune_events * self.params.mrs_per_bank() as u64, // 8-bit weights
            input_bytes: m64 * k_chunks * w as u64, // 8-bit inputs, re-read per col tile? buffered in driver
            output_bytes: m64 * n as u64,           // 8-bit outputs
        }
    }

    /// Cost for a [`MatMulOp`] (multiplies by its instance count).
    pub fn op_cost(&self, op: &MatMulOp) -> MatMulCost {
        let unit = self.matmul_cost(op.m, op.k, op.n);
        let c = op.count as u64;
        MatMulCost {
            cycles: unit.cycles * c,
            tune_events: unit.tune_events * c,
            vcsel_symbols: unit.vcsel_symbols * c,
            adc_conversions: unit.adc_conversions * c,
            weight_dac_conversions: unit.weight_dac_conversions * c,
            partial_sum_adds: unit.partial_sum_adds * c,
            macs: unit.macs * c,
            mac_slots: unit.mac_slots * c,
            weight_bytes: unit.weight_bytes * c,
            input_bytes: unit.input_bytes * c,
            output_bytes: unit.output_bytes * c,
        }
    }

    /// Aggregate cost of an entire workload on one core (no parallelism).
    pub fn workload_cost(&self, w: &Workload) -> MatMulCost {
        let mut total = MatMulCost::default();
        for op in &w.matmuls {
            total.add(&self.op_cost(op));
        }
        total
    }

    /// Serial (un-pipelined) execution time of a cost on one core (ns).
    pub fn serial_time_ns(&self, c: &MatMulCost) -> f64 {
        c.tune_events as f64 * self.params.tune_ns + c.cycles as f64 * self.params.cycle_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::{VitConfig, VitVariant};

    fn core() -> OpticalCore {
        OpticalCore::new(CoreParams::default())
    }

    #[test]
    fn exact_tile_fit_has_full_utilization() {
        // (8 × 64)·(64 × 128): k = 2 chunks, n = 2 tiles, no padding.
        let c = core().matmul_cost(8, 64, 128);
        assert_eq!(c.cycles, 8 * 2 * 2);
        assert_eq!(c.tune_events, 4);
        assert!((c.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn padding_lowers_utilization() {
        let c = core().matmul_cost(5, 33, 65); // both dims just past a tile edge
        assert!(c.utilization() < 0.5, "util {}", c.utilization());
    }

    #[test]
    fn adc_conversions_per_cycle_equal_arms() {
        let c = core().matmul_cost(10, 32, 64);
        assert_eq!(c.adc_conversions, c.cycles * 64);
        assert_eq!(c.vcsel_symbols, c.cycles * 32);
    }

    #[test]
    fn partial_sum_adds_counted() {
        let c = core().matmul_cost(4, 96, 64); // 3 k-chunks
        assert_eq!(c.partial_sum_adds, 4 * 64 * 2);
    }

    #[test]
    fn weight_dacs_match_bank_loads() {
        let c = core().matmul_cost(4, 96, 64);
        assert_eq!(c.weight_dac_conversions, c.tune_events * 2048);
    }

    #[test]
    fn tiny96_cycle_count_magnitude() {
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let w = Workload::vit(&cfg, cfg.num_patches(), true);
        let c = core().workload_cost(&w);
        // ~0.2 GMACs over a 2048-MAC/cycle core with padding: ~100-200 k cycles.
        assert!((80_000..260_000).contains(&c.cycles), "cycles {}", c.cycles);
        // ADC dominates conversions.
        assert!(c.adc_conversions > c.tune_events * 100);
    }

    #[test]
    fn serial_time_includes_tuning() {
        let oc = core();
        let c = oc.matmul_cost(1, 32, 64);
        let t = oc.serial_time_ns(&c);
        let expected = oc.params.tune_ns + oc.params.cycle_ns;
        assert!((t - expected).abs() < 1e-9, "t {t}");
    }

    #[test]
    fn cost_addition_is_componentwise() {
        let oc = core();
        let a = oc.matmul_cost(8, 64, 128);
        let b = oc.matmul_cost(5, 33, 65);
        let mut s = a;
        s.add(&b);
        assert_eq!(s.cycles, a.cycles + b.cycles);
        assert_eq!(s.macs, a.macs + b.macs);
    }
}
