//! ViT compute-workload inventory.
//!
//! Enumerates every MatMul and elementwise operation of a ViT forward pass
//! as the accelerator sees it — parameterized by the *post-RoI* sequence
//! length, since masked patches are skipped before the first encoder block
//! and never touch the optics (§IV, "Region of Interest Selection").
//!
//! Two attention dataflows are modelled:
//!
//! - `direct`: `K = X·W_K`, then `S = Q·K^T` — needs a tuning step *after*
//!   K materializes, plus buffering of K.
//! - `decomposed` (Eq. 2): `S = (Q·W_K^T)·X^T` — all MR-bank operands are
//!   available at operation start, removing a tuning stall and the
//!   intermediate buffer at the cost of extra optical MACs.

use crate::vit::VitConfig;

/// Role of a MatMul in the network (drives scheduling + buffering rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatMulKind {
    /// Patch embedding projection.
    Embed,
    /// Q projection `X·W_Q`.
    QProj,
    /// K projection `X·W_K` (direct flow only).
    KProj,
    /// V projection `X·W_V`.
    VProj,
    /// Attention scores `Q·K^T` (direct flow only).
    Scores,
    /// Decomposed stage 1: `A1 = Q·W_K^T` (per head).
    DecompQWk,
    /// Decomposed stage 2: `S = A1·X^T` (per head).
    DecompAxT,
    /// `softmax(S)·V` (per head).
    AttnV,
    /// MHSA output projection.
    OutProj,
    /// FFN first linear (d -> 4d).
    Ffn1,
    /// FFN second linear (4d -> d).
    Ffn2,
    /// Classifier head.
    Head,
}

impl MatMulKind {
    /// Whether the *stationary* (MR-tuned) operand is an intermediate
    /// activation rather than a pre-known value — such MatMuls stall the
    /// pipeline until their operand materializes (the cost Eq. 2 removes).
    /// `Scores` tunes `K^T` (produced by `KProj`); `AttnV` tunes the softmax
    /// output (both flows). `DecompAxT` tunes `X^T`, which is known at
    /// operation start, so it does *not* stall.
    pub fn tunes_intermediate(&self) -> bool {
        matches!(self, MatMulKind::Scores | MatMulKind::AttnV)
    }
}

/// One matrix-matrix multiply `(m × k) · (k × n)`; the `k × n` operand is
/// the MR-tuned (stationary) side.
#[derive(Debug, Clone)]
pub struct MatMulOp {
    pub kind: MatMulKind,
    /// Human-readable site, e.g. "block3.ffn1".
    pub site: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// How many identical instances (e.g. per-head ops share dims).
    pub count: usize,
}

impl MatMulOp {
    pub fn macs(&self) -> u64 {
        (self.m as u64) * (self.k as u64) * (self.n as u64) * self.count as u64
    }
}

/// Elementwise / non-MatMul op counts (executed by the electronic unit).
#[derive(Debug, Clone, Copy, Default)]
pub struct ElementwiseOps {
    /// Softmax input elements (h · n² per block).
    pub softmax_elems: u64,
    /// GELU activations (n · 4d per block).
    pub gelu_elems: u64,
    /// LayerNorm elements (2 · n · d per block + final).
    pub layernorm_elems: u64,
    /// Residual additions (2 · n · d per block).
    pub residual_elems: u64,
}

impl ElementwiseOps {
    pub fn total(&self) -> u64 {
        self.softmax_elems + self.gelu_elems + self.layernorm_elems + self.residual_elems
    }
}

/// The full inventory for one forward pass.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub matmuls: Vec<MatMulOp>,
    pub elementwise: ElementwiseOps,
    /// Sequence length the workload was built for (post-RoI, incl. cls).
    pub seq_len: usize,
    /// Whether the decomposed (Eq. 2) attention dataflow is used.
    pub decomposed: bool,
}

impl Workload {
    /// Build the inventory for `cfg` with `kept_patches` surviving the RoI
    /// mask (use `cfg.num_patches()` for unmasked operation).
    pub fn vit(cfg: &VitConfig, kept_patches: usize, decomposed: bool) -> Self {
        assert!(kept_patches <= cfg.num_patches(), "cannot keep more patches than exist");
        let n = kept_patches + 1; // + cls token
        let d = cfg.embed_dim;
        let dk = cfg.head_dim();
        let h = cfg.num_heads;
        let f = cfg.ffn_dim();
        let mut matmuls = Vec::new();

        // Patch embedding: only kept patches are embedded (linear savings).
        matmuls.push(MatMulOp {
            kind: MatMulKind::Embed,
            site: "embed".into(),
            m: kept_patches,
            k: cfg.patch_dim(),
            n: d,
            count: 1,
        });

        for b in 0..cfg.depth {
            let site = |s: &str| format!("block{b}.{s}");
            // Q and V projections always happen.
            matmuls.push(MatMulOp { kind: MatMulKind::QProj, site: site("wq"), m: n, k: d, n: d, count: 1 });
            matmuls.push(MatMulOp { kind: MatMulKind::VProj, site: site("wv"), m: n, k: d, n: d, count: 1 });
            if decomposed {
                // Eq. 2: S = (Q·W_K^T)·X^T per head.
                matmuls.push(MatMulOp {
                    kind: MatMulKind::DecompQWk,
                    site: site("q_wkT"),
                    m: n,
                    k: dk,
                    n: d,
                    count: h,
                });
                matmuls.push(MatMulOp {
                    kind: MatMulKind::DecompAxT,
                    site: site("a1_xT"),
                    m: n,
                    k: d,
                    n: n,
                    count: h,
                });
            } else {
                matmuls.push(MatMulOp { kind: MatMulKind::KProj, site: site("wk"), m: n, k: d, n: d, count: 1 });
                matmuls.push(MatMulOp {
                    kind: MatMulKind::Scores,
                    site: site("qkT"),
                    m: n,
                    k: dk,
                    n: n,
                    count: h,
                });
            }
            matmuls.push(MatMulOp {
                kind: MatMulKind::AttnV,
                site: site("attn_v"),
                m: n,
                k: n,
                n: dk,
                count: h,
            });
            matmuls.push(MatMulOp { kind: MatMulKind::OutProj, site: site("proj"), m: n, k: d, n: d, count: 1 });
            matmuls.push(MatMulOp { kind: MatMulKind::Ffn1, site: site("ffn1"), m: n, k: d, n: f, count: 1 });
            matmuls.push(MatMulOp { kind: MatMulKind::Ffn2, site: site("ffn2"), m: n, k: f, n: d, count: 1 });
        }
        matmuls.push(MatMulOp {
            kind: MatMulKind::Head,
            site: "head".into(),
            m: 1,
            k: d,
            n: cfg.num_classes,
            count: 1,
        });

        let depth = cfg.depth as u64;
        let n64 = n as u64;
        let elementwise = ElementwiseOps {
            softmax_elems: depth * (h as u64) * n64 * n64,
            gelu_elems: depth * n64 * f as u64,
            layernorm_elems: (2 * depth + 1) * n64 * d as u64,
            residual_elems: 2 * depth * n64 * d as u64,
        };
        Workload {
            name: format!("{}@{}(n={})", cfg.embed_dim, cfg.image_size, kept_patches),
            matmuls,
            elementwise,
            seq_len: n,
            decomposed,
        }
    }

    /// Total multiply-accumulate count.
    pub fn total_macs(&self) -> u64 {
        self.matmuls.iter().map(|m| m.macs()).sum()
    }

    /// Total stationary-operand bytes (8-bit weights/operands tuned on MRs).
    pub fn stationary_bytes(&self) -> u64 {
        self.matmuls.iter().map(|m| (m.k * m.n * m.count) as u64).sum()
    }

    /// Number of MatMuls whose stationary operand is an intermediate result
    /// (pipeline stalls in the direct flow; zero in the decomposed flow
    /// except AttnV which both flows share).
    pub fn intermediate_tunings(&self) -> usize {
        self.matmuls.iter().filter(|m| m.kind.tunes_intermediate()).map(|m| m.count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::{VitConfig, VitVariant};

    fn tiny96() -> VitConfig {
        VitConfig::variant(VitVariant::Tiny, 96, 10)
    }

    #[test]
    fn tiny_96_mac_count_magnitude() {
        let cfg = tiny96();
        let w = Workload::vit(&cfg, cfg.num_patches(), true);
        let macs = w.total_macs();
        // ViT-Tiny at 96x96 (37 tokens) is ~0.2 GMACs.
        assert!((150_000_000..300_000_000).contains(&macs), "macs {macs}");
    }

    #[test]
    fn decomposed_costs_more_macs_than_direct() {
        // Eq. 2 trades extra optical MACs (h·n²·d vs n²·d for scores) for
        // the removed tuning stall — the paper's explicit trade.
        let cfg = tiny96();
        let direct = Workload::vit(&cfg, cfg.num_patches(), false);
        let decomp = Workload::vit(&cfg, cfg.num_patches(), true);
        assert!(decomp.total_macs() > direct.total_macs());
    }

    #[test]
    fn direct_flow_has_intermediate_tunings() {
        let cfg = tiny96();
        let direct = Workload::vit(&cfg, cfg.num_patches(), false);
        let decomp = Workload::vit(&cfg, cfg.num_patches(), true);
        // direct: Scores (h per block) + AttnV (h per block) tune intermediates;
        // decomposed: only AttnV does.
        assert_eq!(direct.intermediate_tunings(), 2 * cfg.num_heads * cfg.depth);
        assert_eq!(decomp.intermediate_tunings(), cfg.num_heads * cfg.depth);
    }

    #[test]
    fn masking_reduces_work_linearly_in_projections() {
        let cfg = tiny96();
        let full = Workload::vit(&cfg, 36, true);
        let half = Workload::vit(&cfg, 18, true);
        let ratio = half.total_macs() as f64 / full.total_macs() as f64;
        // Projection/FFN terms scale with n, attention with n²; with n=37
        // vs 19 the overall ratio lands slightly above 19/37 but well below 1.
        assert!(ratio > 0.40 && ratio < 0.60, "ratio {ratio}");
    }

    #[test]
    #[should_panic]
    fn too_many_kept_patches_panics() {
        let cfg = tiny96();
        Workload::vit(&cfg, 37, true);
    }

    #[test]
    fn elementwise_counts_scale_with_depth() {
        let t = Workload::vit(&tiny96(), 36, true);
        let l = Workload::vit(&VitConfig::variant(VitVariant::Large, 96, 10), 36, true);
        assert!(l.elementwise.total() > t.elementwise.total());
    }

    #[test]
    fn head_dim_matmuls_match_arm_count() {
        let cfg = tiny96();
        let w = Workload::vit(&cfg, 36, true);
        for m in &w.matmuls {
            if m.kind == MatMulKind::AttnV {
                assert_eq!(m.n, 64, "AttnV output width must equal d_k = 64 arms");
            }
        }
    }
}
