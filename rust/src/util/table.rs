//! Minimal fixed-width table formatter for bench/report output.
//!
//! The benches regenerate the paper's tables and figure series as text; this
//! gives them a consistent, diff-able rendering without external crates.

/// A simple left-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style precision for table cells.
pub fn eng(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 100.0 {
        format!("{x:.1}")
    } else if a >= 1.0 {
        format!("{x:.2}")
    } else if a >= 0.001 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

/// Format joules with an auto-scaled SI unit.
pub fn si_energy(joules: f64) -> String {
    let a = joules.abs();
    if a >= 1.0 {
        format!("{joules:.3} J")
    } else if a >= 1e-3 {
        format!("{:.3} mJ", joules * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} uJ", joules * 1e6)
    } else if a >= 1e-9 {
        format!("{:.3} nJ", joules * 1e9)
    } else {
        format!("{:.3} pJ", joules * 1e12)
    }
}

/// Format seconds with an auto-scaled SI unit.
pub fn si_time(seconds: f64) -> String {
    let a = seconds.abs();
    if a >= 1.0 {
        format!("{seconds:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} us", seconds * 1e6)
    } else {
        format!("{:.3} ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "2.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn si_units() {
        assert_eq!(si_energy(2.5e-6), "2.500 uJ");
        assert_eq!(si_energy(3.2e-3), "3.200 mJ");
        assert_eq!(si_time(1.5e-9), "1.500 ns");
        assert_eq!(si_time(0.25), "250.000 ms");
    }

    #[test]
    fn eng_scales() {
        assert_eq!(eng(0.0), "0");
        // {:.0} uses round-half-to-even.
        assert_eq!(eng(1234.5), "1234");
        assert_eq!(eng(12.345), "12.35");
    }
}
