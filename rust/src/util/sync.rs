//! Atomic primitives behind the loom seam.
//!
//! Code whose interleavings are model-checked (the lock-free
//! [`crate::coordinator::health::HealthSlot`] publication protocol)
//! imports its atomics from here instead of `std::sync::atomic`. Under a
//! normal build this re-exports `std` types with zero cost; under
//! `RUSTFLAGS="--cfg loom"` (the CI model-checking lane, see
//! `rust/tests/loom_models.rs`) the same names resolve to loom's
//! instrumented shims, so the exact production types and orderings are
//! what the model checker explores — the same seam tokio uses.
//!
//! Only types actually used by model-checked modules are re-exported;
//! add more as more protocols come under the model checker.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
