//! Deterministic xorshift64* PRNG with Gaussian sampling.
//!
//! The offline dependency set has no `rand` crate; every stochastic element
//! of the simulator (fabrication-process variation, synthetic sensor noise,
//! workload generation, property tests) draws from this generator so runs are
//! reproducible from a seed.

/// xorshift64* generator (Vigna 2014). Passes BigCrush on the high 32 bits;
/// more than adequate for Monte-Carlo device sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of the Box-Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a fixed point at 0).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed }, spare_normal: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`, using the high 53 bits.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine here: n is always tiny vs 2^64, the
        // bias is ~n/2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln(u) is finite.
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Fill a slice with uniform `[lo, hi)` f32 values.
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for x in buf.iter_mut() {
            *x = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
