//! Streaming and batch statistics used by the metrics layer and benches.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy. `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Online mean/min/max/count accumulator (Welford variance).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }

    /// Fold another accumulator into this one (Chan et al. parallel
    /// Welford merge). Merging per-worker accumulators is exactly
    /// equivalent to having pushed every sample into one accumulator.
    pub fn merge(&mut self, other: &Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.m2 += other.m2 + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += delta * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 9.0);
        assert_eq!(acc.count(), 8);
    }

    #[test]
    fn merge_matches_single_stream() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 5.0, 3.0];
        let mut whole = Accumulator::new();
        for &x in &xs {
            whole.push(x);
        }
        // Split across three "workers", merge back.
        let mut parts = [Accumulator::new(), Accumulator::new(), Accumulator::new()];
        for (i, &x) in xs.iter().enumerate() {
            parts[i % 3].push(x);
        }
        let mut merged = Accumulator::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.count(), whole.count());
        assert!((merged.mean() - whole.mean()).abs() < 1e-12);
        assert!((merged.stddev() - whole.stddev()).abs() < 1e-9);
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Accumulator::new();
        a.push(2.0);
        a.push(4.0);
        let before = (a.count(), a.mean(), a.min(), a.max());
        a.merge(&Accumulator::new());
        assert_eq!((a.count(), a.mean(), a.min(), a.max()), before);
        let mut empty = Accumulator::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 2);
        assert!((empty.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_accumulator_is_zero() {
        let acc = Accumulator::new();
        assert_eq!(acc.mean(), 0.0);
        assert_eq!(acc.min(), 0.0);
        assert_eq!(acc.count(), 0);
    }
}
