//! Tiny benchmarking harness (criterion is not available offline).
//!
//! Each `rust/benches/*.rs` binary uses [`time_fn`] for wall-clock timing of
//! hot paths and prints the paper-table reproduction via [`crate::util::table`].
//! [`CountingAlloc`] additionally lets a bench or test binary count heap
//! allocations, which is how the zero-allocation frame hot path is asserted.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

/// A counting wrapper around the system allocator. Install it in a bench or
/// integration-test binary with
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: optovit::util::bench::CountingAlloc = optovit::util::bench::CountingAlloc;
/// ```
///
/// and read the process-wide allocation counter with [`alloc_count`] /
/// [`count_allocations`]. Without the `#[global_allocator]` attribute the
/// counter stays at zero, so counts are only meaningful in binaries that
/// opt in.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // relaxed-ok: process-wide event counter on the allocator hot
        // path; exactness is only claimed for single-threaded runs.
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // relaxed-ok: same counter as `alloc`.
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // relaxed-ok: same counter as `alloc`.
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Heap allocations since process start (0 unless [`CountingAlloc`] is the
/// installed global allocator).
pub fn alloc_count() -> u64 {
    // relaxed-ok: same counter as `alloc`; callers difference two reads
    // on one thread.
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Run `f` and return `(result, allocations performed while it ran)`.
/// The count is process-wide: run on a quiet (single-threaded) process for
/// exact numbers.
pub fn count_allocations<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let before = alloc_count();
    let out = f();
    (out, alloc_count() - before)
}

/// Result of a timed run.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: u32,
    /// Mean wall-clock seconds per iteration.
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10}/iter  (min {:>10}, max {:>10}, {} iters)",
            self.name,
            super::table::si_time(self.mean_s),
            super::table::si_time(self.min_s),
            super::table::si_time(self.max_s),
            self.iters
        )
    }
}

/// Time `f` over `iters` iterations after `warmup` unrecorded runs.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn time_fn<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Timing {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        // lint-allow(clock): benchmark timing measures the real wall
        // clock by definition; it never feeds serving deadlines.
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    let min_s = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_s = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    Timing { name: name.to_string(), iters, mean_s, min_s, max_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_and_reports() {
        let t = time_fn("noop-sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
        assert!(t.summary().contains("noop-sum"));
    }

    #[test]
    fn count_allocations_is_inert_without_installation() {
        // The lib test binary does not install CountingAlloc, so the counter
        // must stay flat even across an allocating closure.
        let (v, n) = count_allocations(|| vec![1u8; 4096]);
        assert_eq!(v.len(), 4096);
        assert_eq!(n, 0);
    }
}
