//! Small self-contained utilities (the offline crate set has no `rand`,
//! `serde`, or `criterion`, so we carry our own PRNG, stats, and table
//! formatting).

pub mod bench;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
