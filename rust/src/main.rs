//! Opto-ViT leader binary: CLI over the serving pipeline and the
//! architecture-simulation reports.
//!
//! ```text
//! optovit serve   [--backend pjrt|host|sim] [--frames N] [--workers W] [--queue D]
//!                 [--batch B] [--batch-wait-us U] [--window W]
//!                 [--cameras K] [--weights w0,w1,..] [--pin]
//!                 [--precision auto|int4|int8|fp32]
//!                 [--slo-ms F] [--quota N] [--rate F]
//!                 [--autoscale] [--min-workers N] [--max-workers N]
//!                 [--faults S] [--drift-rate R]
//!                 [--cores N] [--arrival-fps F]
//!                 [--no-mask] [--seed S] [--objects K] [--artifacts DIR]
//! optovit report  [--decomposed true]        # Fig. 8/9 energy+delay grid
//! optovit roi     [--size 96|224]            # Fig. 10/11 operating points
//! optovit table4                              # SiPh accelerator comparison
//! optovit resolution [--channels 32]          # §IV MR resolution analysis
//! optovit info                                 # list compiled artifacts
//! ```
//!
//! `--backend host` and `--backend sim` serve with no HLO artifacts on
//! disk (pure-Rust reference compute); `sim` additionally reports modeled
//! photonic-core latency instead of host wall-clock.
//!
//! `--cameras K` serves K independent synthetic sensors as K sessions over
//! **one** shared server (the session-oriented serving surface): frames
//! from all cameras interleave through the shared worker pool and
//! micro-batch lanes, admission is weighted round-robin (`--weights`),
//! and the report shows each camera's session next to the aggregate.
//! `--pin` best-effort pins each worker thread to a host core.
//!
//! Per-session QoS (session surface — using any of these with one camera
//! routes the run through the server): `--slo-ms F` declares a
//! submit→emit latency SLO on every camera session (deadline-aware lane
//! flushes + `slo miss`/p99 columns), `--quota N` caps each session's
//! frames in flight, `--rate F` token-bucket-limits each session's
//! admission rate in frames/s (rejections count the distinct `q-drop`
//! column, never `dropped`).
//!
//! `--autoscale` (session surface) arms the SLO-driven elasticity
//! controller: a background `AutoScaler` ticks against the live server,
//! scaling the worker pool up under queue-depth/SLO pressure, shedding
//! the lowest-weight sessions when capped (the distinct `shed` column),
//! and draining workers back down when calm. `--min-workers`/
//! `--max-workers` bound the pool (default: never below the starting
//! `--workers`, never above 4x it); the report appends the scale-event
//! log and flags retired workers in the per-worker table.
//!
//! `--precision` picks the serving precision policy: a fixed tier
//! (`int4`, `int8`, `fp32`) for every frame, or `auto` for ROI-driven
//! per-frame tier selection (importance-heavy frames at INT8,
//! background-heavy at INT4). Passing the flag also arms the fp32
//! electronic-reference probe, so the report gains a per-tier table with
//! frame counts and top-1 agreement against the fp32 reference.
//!
//! `--faults S` (sim backend only) seeds a per-worker degraded-optics
//! schedule (MR thermal drift, stuck cells, dead VCSEL lanes) on the
//! serving clock; `--drift-rate R` sets the drift accumulation in nm/s
//! (default 1e-4). The per-worker table then reports each worker's final
//! health score, completed recalibration windows, and at-risk frames,
//! and the serve report counts `accuracy-at-risk` frames.
//!
//! `--cores N` / `--arrival-fps F` (sim backend only) arm the queueing
//! co-sim: each worker replays the five-core scheduler's task graph
//! through the discrete-event simulator at each frame's actual arrival
//! time, so modeled latency includes waiting for busy cores under load.
//! `--cores` sets the modeled optical core count (≥ 5, default 5);
//! `--arrival-fps` paces virtual arrivals at a fixed offered load
//! (frame `k` arrives at `k/F` seconds) instead of stamping them from
//! the serving clock. The report gains a `modeled queueing` line and a
//! per-worker queueing column.

use optovit::baselines;
use optovit::cli::Args;
use optovit::coordinator::autoscale::{AutoScaler, ScaleAction, ScalePolicy};
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::{serve_sharded, EngineConfig};
use optovit::coordinator::pipeline::{serve, Pipeline, PipelineConfig, ServeOptions, ServeReport};
use optovit::coordinator::server::{spawn_synthetic_sensor, Quota, Server, SessionOptions};
use optovit::coordinator::stats::StageMetrics;
use optovit::energy::AcceleratorModel;
use optovit::photonics::fpv::FpvModel;
use optovit::photonics::MrGeometry;
use optovit::quant::{PrecisionPolicy, PrecisionTier};
use optovit::coordinator::clock::Clock;
use optovit::runtime::{AnyFactory, BackendFactory, BackendKind, FaultPlan, QueueingPlan};
use optovit::util::table::{si_energy, si_time, Table};
use optovit::vit::{MgnetConfig, VitConfig, VitVariant};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("report") => cmd_report(&args),
        Some("roi") => cmd_roi(&args),
        Some("table4") => cmd_table4(),
        Some("resolution") => cmd_resolution(&args),
        Some("info") => cmd_info(&args),
        other => {
            eprintln!("unknown command {other:?}");
            eprintln!("commands: serve | report | roi | table4 | resolution | info");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    args.check_known(&[
        "frames", "seed", "objects", "workers", "queue", "batch", "batch-wait-us", "window",
        "cameras", "weights", "pin", "precision", "slo-ms", "quota", "rate", "autoscale",
        "min-workers", "max-workers", "faults", "drift-rate", "cores", "arrival-fps", "no-mask",
        "backend", "artifacts",
    ])
    .map_err(anyhow::Error::msg)?;
    let frames = args.get_u64("frames", 50).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    let objects = args.get_usize("objects", 2).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 1).map_err(anyhow::Error::msg)?.max(1);
    let queue_depth = args.get_usize("queue", 4).map_err(anyhow::Error::msg)?.max(1);
    let batch = args.get_usize("batch", 1).map_err(anyhow::Error::msg)?.max(1);
    let batch_wait = args.get_duration_us("batch-wait-us", 500).map_err(anyhow::Error::msg)?;
    let window = args.get_usize("window", 64).map_err(anyhow::Error::msg)?.max(1);
    let cameras = args.get_usize("cameras", 1).map_err(anyhow::Error::msg)?.max(1);
    let weights = args.get_usize_list("weights", &[]).map_err(anyhow::Error::msg)?;
    // Mixed-precision serving: the policy rides every camera session; an
    // explicit flag also arms the fp32 electronic-reference probe so the
    // report can score integer-tier agreement.
    let precision_explicit = args.get("precision").is_some();
    let precision: PrecisionPolicy =
        args.get_or("precision", "int8").parse().map_err(anyhow::Error::msg)?;
    // Per-session QoS knobs (applied to every camera session).
    let slo = args.get_opt_duration_ms("slo-ms").map_err(anyhow::Error::msg)?;
    let quota_inflight = args.get_usize("quota", 0).map_err(anyhow::Error::msg)?;
    let quota_rate = args.get_f64("rate", 0.0).map_err(anyhow::Error::msg)?;
    if quota_rate < 0.0 {
        anyhow::bail!("--rate: must be a non-negative frames/s figure");
    }
    let mut quota = Quota::unlimited();
    if quota_inflight > 0 {
        quota = quota.with_inflight(quota_inflight);
    }
    if quota_rate > 0.0 {
        // A one-second burst keeps the sustained rate the binding limit.
        quota = Quota::rate(quota_rate, (quota_rate.ceil() as usize).max(1))
            .with_inflight(quota.max_inflight);
    }
    let has_qos = slo.is_some() || !quota.is_unlimited();
    // Elasticity knobs (session surface: --autoscale routes through the
    // server even for one camera).
    let autoscale = args.get_bool("autoscale");
    if (args.get("min-workers").is_some() || args.get("max-workers").is_some()) && !autoscale {
        anyhow::bail!("--min-workers/--max-workers require --autoscale (the elasticity controller)");
    }
    let min_workers = args.get_usize("min-workers", 1).map_err(anyhow::Error::msg)?.max(1);
    let max_workers =
        args.get_usize("max-workers", workers * 4).map_err(anyhow::Error::msg)?;
    if autoscale {
        if max_workers < workers {
            anyhow::bail!(
                "--max-workers {max_workers} is below the starting --workers {workers}"
            );
        }
        if min_workers > workers {
            anyhow::bail!(
                "--min-workers {min_workers} is above the starting --workers {workers}"
            );
        }
    }
    let scale_policy = autoscale.then(|| ScalePolicy {
        min_workers,
        max_workers,
        ..ScalePolicy::default()
    });
    // Loud-failure discipline (same reason as check_known above): weights
    // only mean something with multiple sessions, and a longer list than
    // cameras is a miscount, not something to truncate silently.
    if !weights.is_empty() && cameras == 1 {
        anyhow::bail!("--weights requires --cameras K (one admission weight per camera)");
    }
    if weights.len() > cameras {
        anyhow::bail!("--weights lists {} weights for {cameras} camera(s)", weights.len());
    }
    let artifact_dir = args.get_or("artifacts", "artifacts").to_string();
    // `BackendKind::from_str` is the single source of truth for the
    // choice set (its error already lists the choices). Real inference is
    // the default when the pjrt substrate is compiled in; otherwise the
    // modeled photonic substrate serves without artifacts.
    let default_backend = if cfg!(feature = "pjrt") { "pjrt" } else { "sim" };
    let kind: BackendKind =
        args.get_or("backend", default_backend).parse().map_err(anyhow::Error::msg)?;
    let mut cfg = PipelineConfig::tiny_96();
    cfg.use_mask = !args.get_bool("no-mask");
    cfg.fp32_reference = precision_explicit;
    let mut factory = AnyFactory::new(kind, artifact_dir);
    // The host/sim reference models build their classifier head from the
    // factory config; keep it in lockstep with the pipeline's head width.
    factory.host.num_classes = cfg.num_classes;
    // Degraded-optics schedule: sim-only (the fault model perturbs the
    // *modeled* photonic substrate; host/pjrt have no such substrate).
    let fault_seed = args
        .get("faults")
        .map(|v| v.parse::<u64>().map_err(|e| format!("--faults: {e}")))
        .transpose()
        .map_err(anyhow::Error::msg)?;
    let drift_rate = args.get_f64("drift-rate", 1e-4).map_err(anyhow::Error::msg)?;
    if !(drift_rate >= 0.0 && drift_rate.is_finite()) {
        anyhow::bail!("--drift-rate: must be a finite non-negative nm/s figure");
    }
    if args.get("drift-rate").is_some() && fault_seed.is_none() {
        anyhow::bail!("--drift-rate requires --faults S (the fault-schedule seed)");
    }
    if let Some(seed) = fault_seed {
        if kind != BackendKind::Sim {
            anyhow::bail!("--faults requires --backend sim (the modeled photonic substrate)");
        }
        factory = factory.with_faults(FaultPlan {
            seed,
            drift_nm_per_s: drift_rate,
            clock: Clock::system(),
        });
    }
    // Queueing co-sim: sim-only (waiting is modeled against the photonic
    // scheduler's task graph; host/pjrt have no modeled substrate).
    let cores = args
        .get("cores")
        .map(|v| v.parse::<usize>().map_err(|e| format!("--cores: {e}")))
        .transpose()
        .map_err(anyhow::Error::msg)?;
    let arrival_fps = args
        .get("arrival-fps")
        .map(|v| v.parse::<f64>().map_err(|e| format!("--arrival-fps: {e}")))
        .transpose()
        .map_err(anyhow::Error::msg)?;
    if let Some(f) = arrival_fps {
        if !(f > 0.0 && f.is_finite()) {
            anyhow::bail!("--arrival-fps: must be a finite positive frames/s figure");
        }
    }
    if let Some(c) = cores {
        if c < 5 {
            anyhow::bail!("--cores: the five-core pipeline flow needs at least 5 optical cores");
        }
    }
    if cores.is_some() || arrival_fps.is_some() {
        if kind != BackendKind::Sim {
            anyhow::bail!("--cores/--arrival-fps require --backend sim (the queueing co-sim)");
        }
        factory = factory.with_queueing(QueueingPlan {
            cores: cores.unwrap_or(5),
            pace_fps: arrival_fps,
            clock: Clock::system(),
        });
    }
    let opts = ServeOptions {
        sensor_seed: seed,
        num_objects: objects,
        num_frames: frames,
        queue_depth,
        batch: BatchPolicy::batched(batch, batch_wait),
        window,
        pin_workers: args.get_bool("pin"),
        precision,
    };
    match kind {
        BackendKind::Pjrt => println!("warming up (compiling artifacts)..."),
        BackendKind::Host | BackendKind::Sim => {
            println!("warming up ({kind} backend, no artifacts needed)...")
        }
    }
    // QoS and elasticity knobs are server-side, so any of them routes the
    // run through the session-oriented server — even for one camera.
    if cameras > 1 || has_qos || autoscale {
        return cmd_serve_cameras(
            &cfg, &factory, workers, cameras, &weights, slo, quota, scale_policy, &opts,
        );
    }
    let (r, metrics) = if workers > 1 {
        serve_sharded(&cfg, &factory, workers, &opts)?
    } else {
        // `serve` returns the result stream; draining it through `finish`
        // derives the terminal report from the streamed frames.
        let mut p = Pipeline::with_backend(cfg, factory.create(0)?)?;
        let r = serve(&mut p, &opts)?.finish()?;
        let metrics = std::mem::take(&mut p.metrics);
        (r, metrics)
    };
    print_serve_report(&r, &metrics);
    Ok(())
}

/// `optovit serve --cameras K`: K synthetic sensors → K sessions over one
/// shared [`Server`] — the session-oriented serving surface, with frames
/// from every camera interleaving through the shared worker pool and
/// micro-batch lanes under weighted fair admission, each session carrying
/// the CLI's QoS options (`--slo-ms`, `--quota`, `--rate`). With
/// `--autoscale` a background [`AutoScaler`] ticks against the live
/// server, resizing the pool (within `--min-workers`/`--max-workers`)
/// and shedding lowest-weight sessions at the cap.
#[allow(clippy::too_many_arguments)]
fn cmd_serve_cameras(
    cfg: &PipelineConfig,
    factory: &AnyFactory,
    workers: usize,
    cameras: usize,
    weights: &[usize],
    slo: Option<std::time::Duration>,
    quota: Quota,
    scale_policy: Option<ScalePolicy>,
    opts: &ServeOptions,
) -> anyhow::Result<()> {
    let mut ecfg = EngineConfig::for_serving(cfg, opts, workers);
    if let Some(p) = &scale_policy {
        // The policy cap is also the pool capacity the server pre-sizes
        // its slots for.
        ecfg.max_workers = p.max_workers;
    }
    let image_size = cfg.image_size;
    let server = {
        let cfg = cfg.clone();
        let factory = factory.clone();
        Server::start(move |wid| Pipeline::with_backend(cfg.clone(), factory.create(wid)?), ecfg)?
    };
    println!(
        "serving {} frames/camera from {cameras} sessions over one {workers}-worker server...",
        opts.num_frames
    );
    let mut cams = Vec::with_capacity(cameras);
    for cam in 0..cameras {
        let weight = weights.get(cam).copied().unwrap_or(1).max(1) as u32;
        let mut sopts = SessionOptions::named(format!("camera-{cam}"))
            .with_weight(weight)
            .with_queue_depth(opts.queue_depth)
            .with_quota(quota)
            .with_precision(opts.precision);
        if let Some(slo) = slo {
            sopts = sopts.with_slo(slo);
        }
        let session = server.session(sopts)?;
        let (submitter, stream) = session.split();
        let sensor = spawn_synthetic_sensor(
            submitter,
            server.watch(),
            image_size,
            opts.num_objects,
            opts.sensor_seed + cam as u64,
            opts.num_frames,
        );
        let drain = std::thread::spawn(move || stream.finish());
        cams.push((cam, weight, sensor, drain));
    }
    let mut t = Table::new(vec![
        "camera", "weight", "frames", "int4", "int8", "fp32", "dropped", "q-drop", "shed",
        "slo miss", "at-risk", "fps", "latency", "p99", "batch", "IoU",
    ]);
    // Drain every camera with the autoscaler (if armed) ticking in a
    // scoped thread alongside; the stop flag is set before any early
    // return so the scope's implicit join cannot deadlock.
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        if let Some(policy) = scale_policy.clone() {
            let server = &server;
            let stop = &stop;
            scope.spawn(move || {
                let clock = Clock::system();
                let mut scaler = AutoScaler::new(policy, clock.clone());
                // relaxed-ok: standalone stop latch; the scope join is the
                // happens-before edge.
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let _ = scaler.tick(server);
                    clock.sleep(std::time::Duration::from_millis(200));
                }
            });
        }
        let joined = (|| -> anyhow::Result<()> {
            for (cam, weight, sensor, drain) in cams {
                sensor.join().ok();
                let report = drain
                    .join()
                    .map_err(|_| anyhow::anyhow!("camera {cam} drain thread panicked"))??;
                t.row(vec![
                    format!("camera-{cam}"),
                    weight.to_string(),
                    report.frames.to_string(),
                    report.tier_frames[0].to_string(),
                    report.tier_frames[1].to_string(),
                    report.tier_frames[2].to_string(),
                    report.dropped.to_string(),
                    report.dropped_quota.to_string(),
                    report.dropped_shed.to_string(),
                    report.slo_miss.to_string(),
                    report.accuracy_at_risk.to_string(),
                    format!("{:.1}", report.wall_fps),
                    si_time(report.mean_latency_s),
                    si_time(report.p99_latency_s),
                    format!("{:.2}", report.mean_batch),
                    format!("{:.3}", report.mean_mask_iou),
                ]);
            }
            Ok(())
        })();
        // relaxed-ok: standalone stop latch (see the ticker loop above).
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        joined
    })?;
    println!("\nper-session reports:");
    print!("{}", t.render());
    let events = server.scale_events();
    if !events.is_empty() {
        println!("\nscale events ({} live workers at close):", server.live_workers());
        for e in &events {
            let what = match &e.action {
                ScaleAction::Up => "scale-up".to_string(),
                ScaleAction::Down => "scale-down".to_string(),
                ScaleAction::ShedOn { below_weight } => format!("shed <{below_weight}"),
                ScaleAction::ShedOff => "shed-off".to_string(),
            };
            println!("  t={:>9.3}s  {:<10}  -> {} workers  ({})", e.at_s, what, e.workers, e.detail);
        }
    }
    let (agg, metrics) = server.shutdown()?;
    println!("\n== aggregate (all sessions) ==");
    print_serve_report(&agg, &metrics);
    Ok(())
}

fn print_serve_report(r: &ServeReport, metrics: &StageMetrics) {
    println!("\n== serve report ==");
    println!("backend              {}", r.backend);
    println!("workers              {}", r.workers);
    println!("frames processed     {}", r.frames);
    println!("frames dropped       {}", r.dropped);
    if r.dropped_quota > 0 {
        println!("quota rejections     {}", r.dropped_quota);
    }
    if r.dropped_shed > 0 {
        println!("shed rejections      {} (autoscaler admission shedding)", r.dropped_shed);
    }
    if r.slo_miss > 0 || r.p99_latency_s > 0.0 {
        println!("SLO misses           {}", r.slo_miss);
        println!("p99 session latency  {}", si_time(r.p99_latency_s));
    }
    if r.accuracy_at_risk > 0 {
        println!("accuracy-at-risk     {} frames (served on degraded optics)", r.accuracy_at_risk);
    }
    println!("wall throughput      {:.1} fps", r.wall_fps);
    println!(
        "mean latency         {}{}",
        si_time(r.mean_latency_s),
        if r.backend == "sim" { "  (modeled photonic-core)" } else { "" }
    );
    if r.modeled_queueing_s > 0.0 {
        println!(
            "modeled queueing     {} total (waiting for busy cores, co-sim)",
            si_time(r.modeled_queueing_s)
        );
    }
    println!("mean modeled energy  {}/frame", si_energy(r.mean_energy_j));
    println!("modeled efficiency   {:.1} KFPS/W", r.modeled_kfps_per_watt);
    println!("mean micro-batch     {:.2} frames/dispatch", r.mean_batch);
    println!("mean kept patches    {:.1} / 36", r.mean_kept_patches);
    println!("mask IoU vs GT       {:.3}", r.mean_mask_iou);
    println!("top-1 vs synth label {:.3}", r.top1_accuracy);
    // Shown whenever the run served anything off the default int8 tier
    // or scored frames against the fp32 electronic reference.
    let tiered = r.tier_frames[0] > 0
        || r.tier_frames[2] > 0
        || r.tier_ref_frames.iter().sum::<u64>() > 0;
    if tiered {
        println!("\nper-tier breakdown:");
        let mut t = Table::new(vec!["tier", "frames", "fp32-checked", "agreement"]);
        for tier in PrecisionTier::ALL {
            let i = tier.index();
            if r.tier_frames[i] == 0 {
                continue;
            }
            t.row(vec![
                tier.to_string(),
                r.tier_frames[i].to_string(),
                r.tier_ref_frames[i].to_string(),
                r.tier_agreement(tier).map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
            ]);
        }
        print!("{}", t.render());
    }
    if r.workers > 1 {
        println!("\nper-worker utilization:");
        let mut t = Table::new(vec![
            "worker", "core", "frames", "busy", "queueing", "utilization", "health", "recals",
            "at-risk", "queue", "state",
        ]);
        for w in &r.per_worker {
            t.row(vec![
                w.worker.to_string(),
                w.core.map(|c| c.to_string()).unwrap_or_else(|| "-".to_string()),
                w.frames.to_string(),
                si_time(w.busy_s),
                si_time(w.queueing_s),
                format!("{:.2}", w.utilization),
                format!("{:.2}", w.health),
                w.recals.to_string(),
                w.at_risk_frames.to_string(),
                w.queue_depth.to_string(),
                if w.retired { "retired" } else { "live" }.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    println!("\nper-stage latency:");
    let mut t = Table::new(vec!["stage", "mean", "max", "count"]);
    for (s, mean, max, n) in metrics.stage_rows() {
        t.row(vec![s, si_time(mean), si_time(max), n.to_string()]);
    }
    print!("{}", t.render());
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let decomposed = args.get_or("decomposed", "true") == "true";
    let m = AcceleratorModel::default();
    let mut t = Table::new(vec![
        "model", "res", "energy", "E:ADC%", "E:tune%", "delay", "D:optical%",
    ]);
    for v in VitVariant::ALL {
        for res in [224usize, 96] {
            let cfg = VitConfig::variant(v, res, 1000);
            let r = m.frame_report(&format!("{v}-{res}"), &cfg, cfg.num_patches(), decomposed);
            let adc = r.energy.adc_j / r.energy.total_j() * 100.0;
            let tune = r.energy.tuning_j / r.energy.total_j() * 100.0;
            let opt = r.delay.optical_s / r.delay.total_s() * 100.0;
            t.row(vec![
                v.name().to_string(),
                res.to_string(),
                si_energy(r.energy.total_j()),
                format!("{adc:.1}"),
                format!("{tune:.1}"),
                si_time(r.delay.total_s()),
                format!("{opt:.1}"),
            ]);
        }
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_roi(args: &Args) -> anyhow::Result<()> {
    let size = args.get_usize("size", 224).map_err(anyhow::Error::msg)?;
    let m = AcceleratorModel::default();
    let cfg = VitConfig::variant(VitVariant::Base, size, 1000);
    let mg = MgnetConfig::classification(size);
    let full = m.frame_report("full", &cfg, cfg.num_patches(), true);
    let mut t = Table::new(vec!["operating point", "kept", "energy", "latency", "saving%"]);
    t.row(vec![
        "baseline (no MGNet)".to_string(),
        cfg.num_patches().to_string(),
        si_energy(full.energy.total_j()),
        si_time(full.delay.total_s()),
        "0.0".to_string(),
    ]);
    for frac in [0.75, 0.5, 0.33, 0.25] {
        let kept = ((cfg.num_patches() as f64) * frac).round() as usize;
        let r = m.masked_report("masked", &cfg, &mg, kept);
        let sav = (1.0 - r.energy.total_j() / full.energy.total_j()) * 100.0;
        t.row(vec![
            format!("MGNet keep {:.0}%", frac * 100.0),
            kept.to_string(),
            si_energy(r.energy.total_j()),
            si_time(r.delay.total_s()),
            format!("{sav:.1}"),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_table4() -> anyhow::Result<()> {
    let mut t = Table::new(vec!["design", "node(nm)", "KFPS/W", "improv. of Opto-ViT"]);
    for r in baselines::table_iv() {
        let imp = if r.name == "Opto-ViT" {
            "ref".to_string()
        } else {
            format!("{:+.1}%", r.improvement_pct)
        };
        t.row(vec![r.name, r.node, format!("{:.2}", r.kfps_per_watt), imp]);
    }
    for p in baselines::reference_platforms() {
        t.row(vec![
            p.name.to_string(),
            "-".to_string(),
            format!("{:.2}", p.kfps_per_watt),
            format!("{:+.0}x", baselines::optovit_kfps_per_watt() / p.kfps_per_watt),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_resolution(args: &Args) -> anyhow::Result<()> {
    let channels = args.get_usize("channels", 32).map_err(anyhow::Error::msg)?;
    let fpv = FpvModel::default();
    let qs: Vec<f64> = (1..=20).map(|k| k as f64 * 1000.0).collect();
    let rows = fpv.q_sweep(MrGeometry::default(), channels, &qs);
    let mut t = Table::new(vec!["Q", "crosstalk bits", "FPV bits", "effective bits"]);
    for r in rows {
        t.row(vec![
            format!("{:.0}", r.q_factor),
            format!("{:.2}", r.crosstalk_bits),
            format!("{:.2}", r.fpv_bits),
            format!("{:.2}", r.effective_bits),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let artifact_dir = args.get_or("artifacts", "artifacts").to_string();
    // Listing artifacts is a directory scan — no PJRT client needed, so
    // `info` works whether or not the `pjrt` feature is compiled in.
    let mut names = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&artifact_dir) {
        for e in rd.flatten() {
            if let Some(name) = e.path().file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
    }
    names.sort();
    if names.is_empty() {
        println!("no artifacts in '{artifact_dir}' — run `make artifacts`");
        println!("(serving without artifacts: `optovit serve --backend host|sim`)");
    } else {
        println!("artifacts in '{artifact_dir}':");
        for n in names {
            println!("  {n}");
        }
    }
    Ok(())
}
