//! 8-bit symmetric uniform quantization (§IV "Accuracy Analysis").
//!
//! Mirrors `python/compile/quant.py`: symmetric uniform quantization with a
//! dynamically chosen scale (max-abs calibration), matching the precision
//! limits of the photonic weight banks and the 8-bit ADC/DAC interfaces.
//! The rust side needs it to quantize sensor frames before they enter the
//! HLO graph and to sanity-check artifact numerics.

/// Symmetric int8 quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Scale such that `real = scale * int`.
    pub scale: f32,
    /// Number of integer bits (8 in the paper).
    pub bits: u32,
}

impl QuantParams {
    /// Max-abs calibration over a tensor: `scale = max|x| / (2^(b-1) - 1)`.
    pub fn calibrate(xs: &[f32], bits: u32) -> Self {
        assert!(bits >= 2 && bits <= 16);
        let max_abs = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let scale = if max_abs > 0.0 { max_abs / qmax } else { 1.0 };
        QuantParams { scale, bits }
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    pub fn qmin(&self) -> i32 {
        -self.qmax()
    }

    /// Quantize one value to the integer grid.
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale).round() as i32;
        q.clamp(self.qmin(), self.qmax())
    }

    /// Dequantize an integer back to real.
    pub fn dequantize(&self, q: i32) -> f32 {
        q as f32 * self.scale
    }

    /// Fake-quantize (quantize-dequantize): what QAT simulates in training
    /// and what the serving path applies to activations.
    pub fn fake_quantize(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Fake-quantize a whole slice in place.
    pub fn fake_quantize_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.fake_quantize(*x);
        }
    }

    /// Worst-case absolute rounding error: half an LSB.
    pub fn max_abs_error(&self) -> f32 {
        self.scale / 2.0
    }
}

/// Quantize a tensor with its own max-abs calibration; returns (ints, params).
pub fn quantize_tensor(xs: &[f32], bits: u32) -> (Vec<i8>, QuantParams) {
    let p = QuantParams::calibrate(xs, bits);
    assert!(bits <= 8, "i8 storage holds at most 8 bits");
    (xs.iter().map(|&x| p.quantize(x) as i8).collect(), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_by_half_lsb() {
        let mut rng = Rng::new(77);
        let mut xs = vec![0.0f32; 4096];
        rng.fill_uniform_f32(&mut xs, -3.0, 3.0);
        let p = QuantParams::calibrate(&xs, 8);
        for &x in &xs {
            let err = (p.fake_quantize(x) - x).abs();
            assert!(err <= p.max_abs_error() + 1e-6, "err {err} > {}", p.max_abs_error());
        }
    }

    #[test]
    fn idempotent() {
        let xs = [0.5f32, -1.25, 2.0, 0.0];
        let p = QuantParams::calibrate(&xs, 8);
        for &x in &xs {
            let once = p.fake_quantize(x);
            assert_eq!(p.fake_quantize(once), once);
        }
    }

    #[test]
    fn symmetric_range() {
        let p = QuantParams::calibrate(&[1.0, -1.0], 8);
        assert_eq!(p.qmax(), 127);
        assert_eq!(p.qmin(), -127);
        assert_eq!(p.quantize(1.0), 127);
        assert_eq!(p.quantize(-1.0), -127);
    }

    #[test]
    fn clamps_outliers() {
        let p = QuantParams { scale: 0.01, bits: 8 };
        assert_eq!(p.quantize(100.0), 127);
        assert_eq!(p.quantize(-100.0), -127);
    }

    #[test]
    fn zero_tensor_safe() {
        let (q, p) = quantize_tensor(&[0.0; 16], 8);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(p.scale, 1.0);
    }

    #[test]
    fn lower_bits_mean_larger_error() {
        let mut rng = Rng::new(5);
        let mut xs = vec![0.0f32; 1024];
        rng.fill_uniform_f32(&mut xs, -1.0, 1.0);
        let e8 = QuantParams::calibrate(&xs, 8).max_abs_error();
        let e4 = QuantParams::calibrate(&xs, 4).max_abs_error();
        assert!(e4 > e8 * 8.0);
    }
}
