//! Symmetric uniform quantization (§IV "Accuracy Analysis") and the
//! serving precision tiers built on it.
//!
//! Mirrors `python/compile/quant.py`: symmetric uniform quantization with a
//! dynamically chosen scale (max-abs calibration), matching the precision
//! limits of the photonic weight banks and the ADC/DAC interfaces. The rust
//! side needs it to quantize sensor frames before they enter the HLO graph
//! and to sanity-check artifact numerics.
//!
//! Beyond the paper's uniform 8-bit scheme, serving supports token-aware
//! **mixed precision** (TVA-style): every frame executes at a
//! [`PrecisionTier`] — INT8 (the paper's QAT operating point), INT4 (half
//! the DAC/ADC bits and VCSEL symbol energy for background-heavy frames),
//! or FP32 (the electronic host reference used to *measure* the accuracy
//! cost of the integer tiers, never a photonic operating point). Tenants
//! pick a [`PrecisionPolicy`]: a fixed tier, or `Auto`, where the router
//! derives the tier per frame from the MGNet ROI mask (high-importance
//! frames → INT8, background-heavy frames → INT4).

use std::fmt;
use std::str::FromStr;

/// `Auto` precision routing: a frame whose ROI mask keeps at least this
/// fraction of its patches is deemed importance-heavy and runs at INT8;
/// below it the frame is background-heavy and drops to INT4.
pub const AUTO_ROI_THRESHOLD: f64 = 0.5;

/// An execution precision tier on the serving path.
///
/// `index()` is the canonical per-tier array slot used by the
/// `ServeReport` tier counters (`[int4, int8, fp32]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PrecisionTier {
    /// 4-bit symmetric quantization: half the converter bits of INT8.
    Int4,
    /// 8-bit symmetric quantization — the paper's QAT operating point.
    Int8,
    /// Full-precision host reference (no fake-quantization). Models the
    /// *electronic* fallback, not a photonic tier: 32 bits of converter
    /// traffic make it strictly the most expensive tier, and serving uses
    /// it only to score integer-tier output agreement.
    Fp32,
}

impl PrecisionTier {
    /// Every tier, in `index()` order.
    pub const ALL: [PrecisionTier; 3] = [PrecisionTier::Int4, PrecisionTier::Int8, PrecisionTier::Fp32];

    /// Canonical array slot for per-tier counters: int4 = 0, int8 = 1,
    /// fp32 = 2.
    pub fn index(self) -> usize {
        match self {
            PrecisionTier::Int4 => 0,
            PrecisionTier::Int8 => 1,
            PrecisionTier::Fp32 => 2,
        }
    }

    /// Integer bits of the tier's fake-quantization grid. 32 is the
    /// "unquantized" sentinel: the host reference skips fake-quantization
    /// entirely (no 32-bit integer grid is ever materialized).
    pub fn bits(self) -> u32 {
        match self {
            PrecisionTier::Int4 => 4,
            PrecisionTier::Int8 => 8,
            PrecisionTier::Fp32 => 32,
        }
    }

    /// Converter-traffic scale relative to the 8-bit baseline the energy
    /// model's component figures are calibrated at: DAC/ADC conversions,
    /// VCSEL symbol energy, and MR weight-streaming bytes all scale with
    /// the bit width (`bits / 8`).
    pub fn converter_scale(self) -> f64 {
        self.bits() as f64 / 8.0
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PrecisionTier::Int4 => "int4",
            PrecisionTier::Int8 => "int8",
            PrecisionTier::Fp32 => "fp32",
        }
    }
}

impl fmt::Display for PrecisionTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for PrecisionTier {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "int4" => Ok(PrecisionTier::Int4),
            "int8" => Ok(PrecisionTier::Int8),
            "fp32" => Ok(PrecisionTier::Fp32),
            other => Err(format!("unknown precision tier '{other}' (expected int4|int8|fp32)")),
        }
    }
}

/// A tenant's precision policy: one fixed tier for every frame, or
/// ROI-driven per-frame tier selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionPolicy {
    /// Every frame executes at this tier.
    Fixed(PrecisionTier),
    /// The router picks the tier per frame from the MGNet ROI mask:
    /// kept-patch fraction ≥ [`AUTO_ROI_THRESHOLD`] → INT8, else INT4.
    /// Unmasked pipelines (every patch kept) resolve to INT8.
    Auto,
}

impl Default for PrecisionPolicy {
    /// INT8 everywhere — bit-identical to the pre-tier serving path.
    fn default() -> Self {
        PrecisionPolicy::Fixed(PrecisionTier::Int8)
    }
}

impl PrecisionPolicy {
    /// The fixed tier, if the policy is not ROI-driven.
    pub fn fixed_tier(self) -> Option<PrecisionTier> {
        match self {
            PrecisionPolicy::Fixed(t) => Some(t),
            PrecisionPolicy::Auto => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PrecisionPolicy::Auto => "auto",
            PrecisionPolicy::Fixed(t) => t.as_str(),
        }
    }
}

impl fmt::Display for PrecisionPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for PrecisionPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(PrecisionPolicy::Auto),
            other => other
                .parse::<PrecisionTier>()
                .map(PrecisionPolicy::Fixed)
                .map_err(|_| format!("unknown precision policy '{other}' (expected auto|int4|int8|fp32)")),
        }
    }
}

/// Symmetric integer quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Scale such that `real = scale * int`.
    pub scale: f32,
    /// Number of integer bits (8 in the paper).
    pub bits: u32,
}

impl QuantParams {
    /// Max-abs calibration over a tensor: `scale = max|x| / (2^(b-1) - 1)`.
    ///
    /// Every input must be finite: a NaN or infinity would otherwise be
    /// silently *laundered* — `f32::max` skips NaN, so calibration would
    /// proceed from the remaining values, and `quantize(NaN)`'s saturating
    /// cast would turn the poisoned value into a clean `0`. Debug builds
    /// assert; release builds fall back to the documented clamp behaviour
    /// (non-finite values are ignored for calibration, NaN quantizes to 0,
    /// ±∞ saturates to the grid edge). Callers that cannot rule out
    /// non-finite inputs (e.g. raw sensor data) should use
    /// [`QuantParams::try_calibrate`] and handle the failure.
    pub fn calibrate(xs: &[f32], bits: u32) -> Self {
        debug_assert!(
            xs.iter().all(|x| x.is_finite()),
            "calibrate: non-finite input (use try_calibrate for untrusted data)"
        );
        Self::calibrate_clamped(xs, bits)
    }

    /// Max-abs calibration that *reports* non-finite input instead of
    /// asserting: `None` if any value is NaN or ±∞.
    pub fn try_calibrate(xs: &[f32], bits: u32) -> Option<Self> {
        if xs.iter().any(|x| !x.is_finite()) {
            return None;
        }
        Some(Self::calibrate_clamped(xs, bits))
    }

    /// The shared calibration body; skips non-finite values by
    /// construction (`f32::max` ignores NaN, and ±∞ is filtered).
    fn calibrate_clamped(xs: &[f32], bits: u32) -> Self {
        assert!(bits >= 2 && bits <= 16);
        let max_abs = xs
            .iter()
            .filter(|x| x.is_finite())
            .fold(0.0f32, |m, &x| m.max(x.abs()));
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let scale = if max_abs > 0.0 { max_abs / qmax } else { 1.0 };
        QuantParams { scale, bits }
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    pub fn qmin(&self) -> i32 {
        -self.qmax()
    }

    /// Quantize one value to the integer grid. NaN maps to 0 and ±∞
    /// saturates to the grid edge (the `as i32` cast is saturating) —
    /// acceptable only after calibration vouched for the tensor, which is
    /// why [`QuantParams::calibrate`] rejects non-finite input.
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale).round() as i32;
        q.clamp(self.qmin(), self.qmax())
    }

    /// Dequantize an integer back to real.
    pub fn dequantize(&self, q: i32) -> f32 {
        q as f32 * self.scale
    }

    /// Fake-quantize (quantize-dequantize): what QAT simulates in training
    /// and what the serving path applies to activations.
    pub fn fake_quantize(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Fake-quantize a whole slice in place.
    pub fn fake_quantize_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.fake_quantize(*x);
        }
    }

    /// Worst-case absolute rounding error: half an LSB.
    pub fn max_abs_error(&self) -> f32 {
        self.scale / 2.0
    }
}

/// Quantize a tensor with its own max-abs calibration; returns (ints, params).
pub fn quantize_tensor(xs: &[f32], bits: u32) -> (Vec<i8>, QuantParams) {
    // Validate storage width *before* calibrating: calibration accepts up
    // to 16 bits, so checking afterwards would do the work and then panic.
    assert!(bits <= 8, "i8 storage holds at most 8 bits");
    let p = QuantParams::calibrate(xs, bits);
    (xs.iter().map(|&x| p.quantize(x) as i8).collect(), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_by_half_lsb() {
        let mut rng = Rng::new(77);
        let mut xs = vec![0.0f32; 4096];
        rng.fill_uniform_f32(&mut xs, -3.0, 3.0);
        let p = QuantParams::calibrate(&xs, 8);
        for &x in &xs {
            let err = (p.fake_quantize(x) - x).abs();
            assert!(err <= p.max_abs_error() + 1e-6, "err {err} > {}", p.max_abs_error());
        }
    }

    #[test]
    fn idempotent() {
        let xs = [0.5f32, -1.25, 2.0, 0.0];
        let p = QuantParams::calibrate(&xs, 8);
        for &x in &xs {
            let once = p.fake_quantize(x);
            assert_eq!(p.fake_quantize(once), once);
        }
    }

    #[test]
    fn symmetric_range() {
        let p = QuantParams::calibrate(&[1.0, -1.0], 8);
        assert_eq!(p.qmax(), 127);
        assert_eq!(p.qmin(), -127);
        assert_eq!(p.quantize(1.0), 127);
        assert_eq!(p.quantize(-1.0), -127);
    }

    #[test]
    fn clamps_outliers() {
        let p = QuantParams { scale: 0.01, bits: 8 };
        assert_eq!(p.quantize(100.0), 127);
        assert_eq!(p.quantize(-100.0), -127);
    }

    #[test]
    fn zero_tensor_safe() {
        let (q, p) = quantize_tensor(&[0.0; 16], 8);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(p.scale, 1.0);
    }

    #[test]
    fn lower_bits_mean_larger_error() {
        let mut rng = Rng::new(5);
        let mut xs = vec![0.0f32; 1024];
        rng.fill_uniform_f32(&mut xs, -1.0, 1.0);
        let e8 = QuantParams::calibrate(&xs, 8).max_abs_error();
        let e4 = QuantParams::calibrate(&xs, 4).max_abs_error();
        assert!(e4 > e8 * 8.0);
    }

    // ---- NaN/Inf regressions (the silent-laundering bugfix) ----

    #[test]
    fn try_calibrate_reports_non_finite_input() {
        assert_eq!(QuantParams::try_calibrate(&[0.5, f32::NAN, 1.0], 8), None);
        assert_eq!(QuantParams::try_calibrate(&[f32::INFINITY], 8), None);
        assert_eq!(QuantParams::try_calibrate(&[f32::NEG_INFINITY, 0.0], 8), None);
        // Finite tensors calibrate identically through both entry points.
        let xs = [0.5f32, -1.25, 2.0];
        assert_eq!(QuantParams::try_calibrate(&xs, 8), Some(QuantParams::calibrate(&xs, 8)));
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite input")]
    fn calibrate_asserts_on_nan_in_debug() {
        let _ = QuantParams::calibrate(&[1.0, f32::NAN], 8);
    }

    #[test]
    fn release_clamp_behaviour_is_documented_not_laundered() {
        // The release-mode fallback path (calibrate_clamped) ignores
        // non-finite values for scale selection, quantizes NaN to 0, and
        // saturates ±∞ — the *documented* clamp, exercised directly so the
        // behaviour is pinned in both build profiles.
        let p = QuantParams::calibrate_clamped(&[0.5, f32::NAN, f32::INFINITY, -2.0], 8);
        let clean = QuantParams::calibrate(&[0.5, -2.0], 8);
        assert_eq!(p, clean, "non-finite values must not move the scale");
        assert_eq!(p.quantize(f32::NAN), 0);
        assert_eq!(p.quantize(f32::INFINITY), p.qmax());
        assert_eq!(p.quantize(f32::NEG_INFINITY), p.qmin());
    }

    #[test]
    #[should_panic(expected = "i8 storage")]
    fn quantize_tensor_rejects_wide_bits_before_calibrating() {
        // The old ordering calibrated first and asserted after; 9 bits
        // must be rejected up front (calibrate accepts up to 16, so this
        // panic is the *storage* check, not calibration's).
        let _ = quantize_tensor(&[1.0, 2.0], 9);
    }

    // ---- Precision tiers ----

    #[test]
    fn tier_indices_bits_and_scales_are_canonical() {
        for (i, t) in PrecisionTier::ALL.iter().enumerate() {
            assert_eq!(t.index(), i);
        }
        assert_eq!(PrecisionTier::Int4.bits(), 4);
        assert_eq!(PrecisionTier::Int8.bits(), 8);
        assert_eq!(PrecisionTier::Fp32.bits(), 32);
        assert_eq!(PrecisionTier::Int4.converter_scale(), 0.5);
        assert_eq!(PrecisionTier::Int8.converter_scale(), 1.0);
        assert_eq!(PrecisionTier::Fp32.converter_scale(), 4.0);
    }

    #[test]
    fn tier_and_policy_round_trip_their_names() {
        for t in PrecisionTier::ALL {
            assert_eq!(t.as_str().parse::<PrecisionTier>(), Ok(t));
            assert_eq!(t.to_string(), t.as_str());
        }
        assert_eq!("auto".parse::<PrecisionPolicy>(), Ok(PrecisionPolicy::Auto));
        assert_eq!(
            "int4".parse::<PrecisionPolicy>(),
            Ok(PrecisionPolicy::Fixed(PrecisionTier::Int4))
        );
        assert!("int7".parse::<PrecisionPolicy>().is_err());
        assert_eq!(PrecisionPolicy::default(), PrecisionPolicy::Fixed(PrecisionTier::Int8));
        assert_eq!(PrecisionPolicy::Auto.fixed_tier(), None);
        assert_eq!(
            PrecisionPolicy::default().fixed_tier(),
            Some(PrecisionTier::Int8)
        );
    }
}
