//! The frame-serving pipeline: MGNet → RoI mask → bucket routing → backbone.
//!
//! The pipeline is generic over the execution substrate: any
//! [`crate::runtime::Backend`] (PJRT over compiled HLO, the pure-Rust
//! host reference, or the analytic photonic simulator) plugs in without
//! the request path knowing which one it drives. No PJRT symbol appears in
//! this module — artifact names are the only contract.
//!
//! The steady-state hot path is **allocation-free up to each backend
//! call**: every per-frame buffer (patchify output, score/mask staging,
//! kept-index list, zero-padded bucket tensors) lives in a reusable
//! [`FrameScratch`], and backends accept borrowed [`TensorRef`] views, so
//! no frame ever clones its patch tensor. `rust/tests/alloc_hot_path.rs`
//! asserts the staging stages with a counting allocator, and
//! `rust/tests/host_backend.rs` bounds the full frame over
//! [`crate::runtime::HostBackend`].

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{recv_frame, BucketRouter, FrameQueue};
use super::stats::{StageMetrics, WorkerStats};
use crate::energy::AcceleratorModel;
use crate::roi::PatchMask;
use crate::runtime::{Backend, TensorRef};
use crate::sensor::Frame;
use crate::vit::{MgnetConfig, VitConfig, VitVariant};

/// Configuration of one serving pipeline instance.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub variant: VitVariant,
    pub image_size: usize,
    pub num_classes: usize,
    /// Kept-patch buckets the backbone artifacts exist at. Must be strictly
    /// ascending and end at the full patch count — enforced by
    /// [`PipelineConfig::validate`] at pipeline construction.
    pub buckets: Vec<usize>,
    /// MGNet sigmoid threshold `t_reg`.
    pub region_threshold: f32,
    /// Disable to run the unmasked baseline (all patches).
    pub use_mask: bool,
}

impl PipelineConfig {
    /// Default Tiny@96 pipeline matching `python/compile/aot.py` exports.
    pub fn tiny_96() -> Self {
        PipelineConfig {
            variant: VitVariant::Tiny,
            image_size: 96,
            num_classes: 10,
            buckets: vec![9, 18, 27, 36],
            region_threshold: 0.5,
            use_mask: true,
        }
    }

    pub fn vit_config(&self) -> VitConfig {
        VitConfig::variant(self.variant, self.image_size, self.num_classes)
    }

    pub fn mgnet_config(&self) -> MgnetConfig {
        MgnetConfig::classification(self.image_size)
    }

    /// Artifact name for the MGNet stage.
    pub fn mgnet_artifact(&self) -> String {
        format!("mgnet_{}", self.image_size)
    }

    /// Artifact name for the backbone at a bucket size.
    pub fn backbone_artifact(&self, bucket: usize) -> String {
        format!(
            "vit_{}_{}_n{}",
            self.variant.name().to_lowercase(),
            self.image_size,
            bucket
        )
    }

    /// Check the bucket ladder at construction time (a bad ladder would
    /// otherwise surface frames later as a routing panic or a missing
    /// artifact deep in a worker thread): buckets must be non-empty,
    /// strictly ascending, and end at the full patch count.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.buckets.is_empty(),
            "pipeline config has no buckets — at least the full patch count is required"
        );
        anyhow::ensure!(
            self.buckets.windows(2).all(|w| w[0] < w[1]),
            "buckets {:?} must be strictly ascending",
            self.buckets
        );
        let full = self.vit_config().num_patches();
        anyhow::ensure!(
            self.buckets.last() == Some(&full),
            "largest bucket {:?} must equal the full patch count {} so every mask has a home",
            self.buckets.last(),
            full
        );
        Ok(())
    }
}

/// Per-frame output.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub frame_index: u64,
    pub logits: Vec<f32>,
    pub mask: PatchMask,
    /// Bucket the frame was routed to.
    pub bucket: usize,
    /// Modeled accelerator energy for this frame (J).
    pub modeled_energy_j: f64,
    /// Latency attributed to this frame (s): modeled accelerator latency
    /// when the backend simulates timing (`sim`), host wall-clock
    /// otherwise.
    pub latency_s: f64,
}

impl FrameResult {
    /// Argmax over the logits. `total_cmp` gives NaN a defined order, so a
    /// NaN logit can never panic the serving loop.
    pub fn predicted_class(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Reusable per-frame working memory. All buffers are sized once (at
/// pipeline construction) for the largest bucket, so steady-state frames
/// perform zero heap allocation before each backend call.
#[derive(Debug)]
pub struct FrameScratch {
    /// Patchified frame, `(num_patches, patch_dim)` row-major.
    patches: Vec<f32>,
    /// Per-patch MGNet scores (pre-sigmoid logits; 1.0 in no-mask runs).
    scores: Vec<f32>,
    /// Thresholded keep mask.
    mask: PatchMask,
    /// Kept-patch indices, row-major order.
    kept: Vec<usize>,
    /// Zero-padded `(bucket, patch_dim)` backbone input (largest-bucket
    /// capacity; per-frame prefixes are used).
    bucket_patches: Vec<f32>,
    /// Original grid position of each bucket slot.
    pos_idx: Vec<f32>,
    /// Validity mask over bucket slots (1.0 = real patch, 0.0 = padding).
    valid: Vec<f32>,
}

impl FrameScratch {
    pub fn new(num_patches: usize, patch_dim: usize, max_bucket: usize) -> Self {
        FrameScratch {
            patches: Vec::with_capacity(num_patches * patch_dim),
            scores: Vec::with_capacity(num_patches),
            mask: PatchMask { side: 0, keep: Vec::with_capacity(num_patches) },
            kept: Vec::with_capacity(num_patches),
            bucket_patches: vec![0.0; max_bucket * patch_dim],
            pos_idx: vec![0.0; max_bucket],
            valid: vec![0.0; max_bucket],
        }
    }

    /// Scratch sized for one pipeline configuration.
    pub fn for_config(cfg: &PipelineConfig) -> Self {
        let vit = cfg.vit_config();
        let max_bucket =
            cfg.buckets.iter().copied().max().unwrap_or_else(|| vit.num_patches());
        Self::new(vit.num_patches(), vit.patch_dim(), max_bucket)
    }

    /// Stage 1: patchify the frame into the scratch patch buffer.
    pub fn stage_patchify(&mut self, frame: &Frame, patch_px: usize) {
        frame.patchify_into(patch_px, &mut self.patches);
    }

    /// The patchified frame (valid after [`FrameScratch::stage_patchify`]).
    pub fn patches(&self) -> &[f32] {
        &self.patches
    }

    /// Stage 2: adopt MGNet scores and threshold them into the keep mask.
    pub fn stage_mask(&mut self, side: usize, scores: &[f32], t_reg: f32) {
        self.scores.clear();
        self.scores.extend_from_slice(scores);
        self.mask.fill_from_scores(side, &self.scores, t_reg);
    }

    /// Stage 2, no-mask baseline: keep everything with uniform scores.
    pub fn stage_mask_full(&mut self, side: usize) {
        self.scores.clear();
        self.scores.resize(side * side, 1.0);
        self.mask.fill_full(side);
    }

    pub fn mask(&self) -> &PatchMask {
        &self.mask
    }

    /// Stage 3: route the kept count to a bucket and stage kept patches
    /// into the zero-padded bucket buffers. Returns the bucket size;
    /// afterwards `bucket_patches`/`pos_idx`/`valid` views hold the
    /// backbone inputs. `total_cmp` is used throughout so NaN scores sort
    /// deterministically instead of panicking.
    pub fn stage_route(&mut self, router: &BucketRouter, patch_dim: usize) -> usize {
        self.mask.kept_indices_into(&mut self.kept);
        if self.kept.is_empty() {
            // Always process at least the highest-score patch.
            let best = self
                .scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            self.kept.push(best);
        }
        let bucket = router.route(self.kept.len());
        if self.kept.len() > bucket {
            let scores = &self.scores;
            self.kept.sort_unstable_by(|&a, &b| scores[b].total_cmp(&scores[a]));
            self.kept.truncate(bucket);
            self.kept.sort_unstable();
        }
        let staged = &mut self.bucket_patches[..bucket * patch_dim];
        staged.fill(0.0);
        self.pos_idx[..bucket].fill(0.0);
        self.valid[..bucket].fill(0.0);
        for (slot, &pidx) in self.kept.iter().enumerate() {
            staged[slot * patch_dim..(slot + 1) * patch_dim]
                .copy_from_slice(&self.patches[pidx * patch_dim..(pidx + 1) * patch_dim]);
            self.pos_idx[slot] = pidx as f32;
            self.valid[slot] = 1.0;
        }
        bucket
    }

    /// Kept-patch indices (valid after [`FrameScratch::stage_route`]).
    pub fn kept(&self) -> &[usize] {
        &self.kept
    }

    /// Staged `(bucket, patch_dim)` backbone input.
    pub fn bucket_patches(&self, bucket: usize, patch_dim: usize) -> &[f32] {
        &self.bucket_patches[..bucket * patch_dim]
    }

    /// Staged position indices for the bucket slots.
    pub fn pos_idx(&self, bucket: usize) -> &[f32] {
        &self.pos_idx[..bucket]
    }

    /// Staged validity mask for the bucket slots.
    pub fn valid(&self, bucket: usize) -> &[f32] {
        &self.valid[..bucket]
    }
}

/// The pipeline, generic over its execution [`Backend`]. Backends are not
/// required to be `Send`, so a pipeline is constructed and driven on one
/// thread; sharded serving constructs one `Pipeline` per worker thread
/// (see [`crate::coordinator::engine`]).
pub struct Pipeline<B: Backend> {
    cfg: PipelineConfig,
    backend: B,
    router: BucketRouter,
    model: AcceleratorModel,
    scratch: FrameScratch,
    /// Cached (`Copy`) configs so the hot path never rebuilds them.
    vit_cfg: VitConfig,
    mgnet_cfg: MgnetConfig,
    /// Artifact names, formatted once at construction: the hot path must
    /// not `format!` per frame.
    mgnet_name: String,
    backbone_names: Vec<(usize, String)>,
    pub metrics: StageMetrics,
}

impl<B: Backend> Pipeline<B> {
    /// Build a pipeline over an already-constructed backend. Validates the
    /// bucket ladder (see [`PipelineConfig::validate`]).
    pub fn with_backend(cfg: PipelineConfig, backend: B) -> Result<Self> {
        cfg.validate()?;
        let router = BucketRouter::new(cfg.buckets.clone());
        let vit_cfg = cfg.vit_config();
        let backbone_names: Vec<(usize, String)> =
            router.buckets().iter().map(|&b| (b, cfg.backbone_artifact(b))).collect();
        let scratch = FrameScratch::for_config(&cfg);
        Ok(Pipeline {
            backend,
            router,
            model: AcceleratorModel::default(),
            scratch,
            vit_cfg,
            mgnet_cfg: cfg.mgnet_config(),
            mgnet_name: cfg.mgnet_artifact(),
            backbone_names,
            metrics: StageMetrics::new(),
            cfg,
        })
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// The execution substrate this pipeline drives.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Stable backend identifier, carried into [`ServeReport`].
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Pre-load all artifacts (avoids compile jitter on the first frames —
    /// PJRT compilation and host module materialization both happen here,
    /// never on the steady-state path).
    pub fn warmup(&mut self) -> Result<()> {
        if self.cfg.use_mask {
            self.backend.load(&self.mgnet_name)?;
        }
        for (_, name) in &self.backbone_names {
            self.backend.load(name)?;
        }
        Ok(())
    }

    /// Process one frame end-to-end. Steady-state frames perform zero heap
    /// allocation before each backend call: all staging goes through the
    /// reusable [`FrameScratch`] and inputs are passed as borrowed
    /// [`TensorRef`] views.
    pub fn process_frame(&mut self, frame: &Frame) -> Result<FrameResult> {
        let t_start = Instant::now();
        let patch_px = self.vit_cfg.patch_size;
        let side = frame.size / patch_px;
        let n_full = side * side;
        let patch_dim = self.vit_cfg.patch_dim();

        // 1. Patchify (the sensor→accelerator interface) into scratch.
        let t0 = Instant::now();
        self.scratch.stage_patchify(frame, patch_px);
        self.metrics.record_stage("patchify", t0.elapsed().as_secs_f64());

        // 2. MGNet scores → binary mask (Eq. 3 + sigmoid threshold).
        if self.cfg.use_mask {
            let t0 = Instant::now();
            let dims = [n_full as i64, patch_dim as i64];
            let scores = self
                .backend
                .execute1(&self.mgnet_name, &[TensorRef::new(&self.scratch.patches, &dims)])
                .context("MGNet stage")?;
            self.metrics.record_stage("mgnet", t0.elapsed().as_secs_f64());
            self.scratch.stage_mask(side, &scores, self.cfg.region_threshold);
        } else {
            self.scratch.stage_mask_full(side);
        }

        // 3. Route to a bucket; select top-score patches if over-full,
        //    otherwise pad with zeroed invalid slots.
        let t0 = Instant::now();
        let bucket = self.scratch.stage_route(&self.router, patch_dim);
        let kept_count = self.scratch.kept.len();
        self.metrics.record_stage("route", t0.elapsed().as_secs_f64());

        // 4. Backbone on the pruned sequence.
        let t0 = Instant::now();
        let artifact = self
            .backbone_names
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, n)| n.as_str())
            .expect("router buckets all have precomputed artifact names");
        let bdims = [bucket as i64, patch_dim as i64];
        let vdims = [bucket as i64];
        let logits = self
            .backend
            .execute1(
                artifact,
                &[
                    TensorRef::new(&self.scratch.bucket_patches[..bucket * patch_dim], &bdims),
                    TensorRef::new(&self.scratch.pos_idx[..bucket], &vdims),
                    TensorRef::new(&self.scratch.valid[..bucket], &vdims),
                ],
            )
            .context("backbone stage")?;
        self.metrics.record_stage("backbone", t0.elapsed().as_secs_f64());

        // 5. Modeled accelerator energy at this kept count (charged for
        //    every backend — the host is a stand-in for the photonic core).
        let energy_j = if self.cfg.use_mask {
            self.model.masked_energy(&self.vit_cfg, &self.mgnet_cfg, kept_count).total_j()
        } else {
            self.model.frame_energy(&self.vit_cfg, self.vit_cfg.num_patches(), true).total_j()
        };
        // "total" is always host wall-clock (it feeds busy-time and
        // utilization accounting); a simulating backend additionally
        // charges its modeled frame latency under "modeled", which then
        // becomes the reported per-frame latency.
        let wall_s = t_start.elapsed().as_secs_f64();
        self.metrics.record_stage("total", wall_s);
        let modeled = self.backend.modeled_frame_latency_s(kept_count, self.cfg.use_mask);
        if let Some(m) = modeled {
            self.metrics.record_stage("modeled", m);
        }
        self.metrics.record_frame(energy_j, kept_count);

        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask: self.scratch.mask.clone(),
            bucket,
            modeled_energy_j: energy_j,
            latency_s: modeled.unwrap_or(wall_s),
        })
    }
}

/// Summary of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Which execution backend served the run (`"pjrt"`/`"host"`/`"sim"`).
    pub backend: String,
    pub frames: u64,
    /// Frames the sensor actually failed to enqueue (`try_push`
    /// rejections) — not frames merely in flight when the run stopped.
    pub dropped: u64,
    pub wall_fps: f64,
    /// Mean per-frame latency: modeled accelerator latency under the `sim`
    /// backend, host wall-clock otherwise.
    pub mean_latency_s: f64,
    pub mean_energy_j: f64,
    pub modeled_kfps_per_watt: f64,
    pub mean_kept_patches: f64,
    /// Mean IoU of the MGNet mask vs. the sensor ground truth.
    pub mean_mask_iou: f64,
    /// Top-1 agreement with the synthetic class labels (meaningful only
    /// when the backbone weights are trained).
    pub top1_accuracy: f64,
    /// Worker pipelines that served the run (1 for the single-threaded
    /// [`serve`] path).
    pub workers: usize,
    /// Per-worker utilization breakdown.
    pub per_worker: Vec<WorkerStats>,
}

/// Drive a pipeline from a live sensor thread for `num_frames` frames.
/// The sensor produces frames as fast as the queue accepts them; a full
/// queue drops frames (real near-sensor backpressure).
pub fn serve<B: Backend>(
    pipeline: &mut Pipeline<B>,
    sensor_seed: u64,
    num_objects: usize,
    num_frames: u64,
    queue_depth: usize,
) -> Result<ServeReport> {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    let size = pipeline.cfg.image_size;
    // Warm up before the sensor exists: compile time can neither inflate
    // the rejection count nor leak a sensor thread on warmup failure.
    pipeline.warmup()?;

    let (queue, rx) = FrameQueue::bounded(queue_depth);
    // Count actual enqueue rejections in the sensor thread: frames still
    // sitting in the queue at stop time were never dropped.
    let rejected = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    // Consumer is already warm, so the sensor starts producing at once.
    let go = Arc::new(AtomicBool::new(true));
    let (rejected_t, stop_t, go_t) = (rejected.clone(), stop.clone(), go.clone());
    let sensor = std::thread::spawn(move || {
        super::batcher::sensor_loop(
            queue,
            size,
            num_objects,
            sensor_seed,
            &go_t,
            &stop_t,
            &rejected_t,
        )
    });

    pipeline.metrics.start_run();
    let patch_px = pipeline.vit_cfg.patch_size;
    let mut iou_sum = 0.0f64;
    let mut correct = 0u64;
    let mut done = 0u64;
    let mut serve_err = None;
    while done < num_frames {
        let Some(frame) = recv_frame(&rx, Duration::from_secs(5)) else {
            break;
        };
        let gt = frame.gt_mask(patch_px);
        let label = frame.label;
        match pipeline.process_frame(&frame) {
            Ok(r) => {
                iou_sum += r.mask.iou(&gt);
                correct += (r.predicted_class() == label) as u64;
                done += 1;
            }
            Err(e) => {
                // Stop the sensor before propagating, or it spins forever.
                serve_err = Some(e);
                break;
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    // Drain so the sensor thread unblocks, then join.
    while rx.try_recv().is_ok() {}
    sensor.join().ok();
    if let Some(e) = serve_err {
        return Err(e);
    }

    let m = &pipeline.metrics;
    let busy_s = m.stage_sum_s("total");
    let elapsed_s = m.run_elapsed_s();
    Ok(ServeReport {
        backend: pipeline.backend_name().to_string(),
        frames: done,
        dropped: rejected.load(Ordering::Relaxed),
        wall_fps: m.wall_fps(),
        mean_latency_s: m.frame_latency_mean_s(),
        mean_energy_j: m.mean_energy_j(),
        modeled_kfps_per_watt: m.modeled_kfps_per_watt(),
        mean_kept_patches: m.mean_kept_patches(),
        mean_mask_iou: if done > 0 { iou_sum / done as f64 } else { 0.0 },
        top1_accuracy: if done > 0 { correct as f64 / done as f64 } else { 0.0 },
        workers: 1,
        per_worker: vec![WorkerStats {
            worker: 0,
            frames: done,
            busy_s,
            utilization: if elapsed_s > 0.0 { (busy_s / elapsed_s).min(1.0) } else { 0.0 },
        }],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{HostBackend, HostConfig};
    use crate::sensor::VideoSource;

    fn host() -> HostBackend {
        HostBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() })
    }

    #[test]
    fn config_artifact_names() {
        let c = PipelineConfig::tiny_96();
        assert_eq!(c.mgnet_artifact(), "mgnet_96");
        assert_eq!(c.backbone_artifact(36), "vit_tiny_96_n36");
    }

    #[test]
    fn validate_rejects_empty_buckets() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("no buckets"), "{err}");
        assert!(Pipeline::with_backend(c, host()).is_err());
    }

    #[test]
    fn validate_rejects_unsorted_buckets() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![18, 9, 36];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("ascending"), "{err}");
        // Duplicates are a ladder bug too, not a silent dedup.
        c.buckets = vec![9, 9, 36];
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_missing_full_bucket() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![9, 18]; // missing 36
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("full patch count"), "{err}");
        assert!(Pipeline::with_backend(c, host()).is_err());
    }

    #[test]
    fn validate_accepts_the_default_ladder() {
        assert!(PipelineConfig::tiny_96().validate().is_ok());
    }

    #[test]
    fn pipeline_reports_its_backend() {
        let p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        assert_eq!(p.backend_name(), "host");
        assert!(!p.backend().needs_artifacts());
    }

    #[test]
    fn frame_result_argmax() {
        let r = FrameResult {
            frame_index: 0,
            logits: vec![0.1, 0.9, 0.3],
            mask: PatchMask::full(6),
            bucket: 36,
            modeled_energy_j: 1e-5,
            latency_s: 0.01,
        };
        assert_eq!(r.predicted_class(), 1);
    }

    #[test]
    fn frame_result_argmax_survives_nan() {
        let r = FrameResult {
            frame_index: 0,
            logits: vec![f32::NAN, 0.9, 0.3],
            mask: PatchMask::full(6),
            bucket: 36,
            modeled_energy_j: 1e-5,
            latency_s: 0.01,
        };
        // Must not panic; any in-range index is acceptable.
        assert!(r.predicted_class() < 3);
    }

    #[test]
    fn scratch_patchify_matches_frame_patchify() {
        let mut src = VideoSource::new(96, 2, 42);
        let frame = src.next_frame();
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        assert_eq!(scratch.patches(), frame.patchify(16).as_slice());
    }

    #[test]
    fn scratch_route_stages_kept_patches() {
        let mut src = VideoSource::new(96, 1, 13);
        let frame = src.next_frame();
        let router = BucketRouter::even(36, 4);
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        // Score patches from ground truth: kept patches get +2, rest -2.
        let gt = frame.gt_mask(16);
        let scores: Vec<f32> = gt.keep.iter().map(|&k| if k { 2.0 } else { -2.0 }).collect();
        scratch.stage_mask(6, &scores, 0.5);
        let bucket = scratch.stage_route(&router, 768);
        assert_eq!(scratch.mask(), &gt);
        assert_eq!(scratch.kept(), gt.kept_indices().as_slice());
        assert_eq!(bucket, router.route(gt.kept()));
        // Each staged slot holds the right patch; padding slots are zero.
        let patches = frame.patchify(16);
        let staged = scratch.bucket_patches(bucket, 768);
        for (slot, &pidx) in scratch.kept().iter().enumerate() {
            let want = &patches[pidx * 768..(pidx + 1) * 768];
            assert_eq!(&staged[slot * 768..(slot + 1) * 768], want);
            assert_eq!(scratch.pos_idx(bucket)[slot], pidx as f32);
            assert_eq!(scratch.valid(bucket)[slot], 1.0);
        }
        for slot in scratch.kept().len()..bucket {
            assert!(staged[slot * 768..(slot + 1) * 768].iter().all(|&x| x == 0.0));
            assert_eq!(scratch.valid(bucket)[slot], 0.0);
        }
    }

    #[test]
    fn scratch_route_empty_mask_keeps_best_patch() {
        let mut src = VideoSource::new(96, 1, 7);
        let frame = src.next_frame();
        let router = BucketRouter::even(36, 4);
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        let mut scores = vec![-5.0f32; 36];
        scores[17] = -1.0; // still below threshold, but the best
        scratch.stage_mask(6, &scores, 0.5);
        assert_eq!(scratch.mask().kept(), 0);
        let bucket = scratch.stage_route(&router, 768);
        assert_eq!(scratch.kept(), &[17]);
        assert_eq!(bucket, 9);
    }

    #[test]
    fn scratch_route_truncates_to_clamped_bucket() {
        // Router whose largest bucket is below the full patch count: an
        // over-full mask must keep the top-score patches, in grid order.
        let mut src = VideoSource::new(96, 2, 21);
        let frame = src.next_frame();
        let router = BucketRouter::new(vec![9, 18]);
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        let scores: Vec<f32> = (0..36).map(|i| i as f32).collect();
        scratch.stage_mask(6, &scores, 0.5); // sigmoid(i) > 0.5 for i >= 1
        assert!(scratch.mask().kept() > 18);
        let bucket = scratch.stage_route(&router, 768);
        assert_eq!(bucket, 18);
        // Top-18 scores are patches 18..36, re-sorted into grid order.
        let expect: Vec<usize> = (18..36).collect();
        assert_eq!(scratch.kept(), expect.as_slice());
    }
}
