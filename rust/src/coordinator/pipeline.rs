//! The frame-serving pipeline: MGNet → RoI mask → bucket routing → backbone.

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{recv_frame, BucketRouter, FrameQueue};
use super::stats::StageMetrics;
use crate::energy::AcceleratorModel;
use crate::roi::PatchMask;
use crate::runtime::{Runtime, Tensor};
use crate::sensor::{Frame, VideoSource};
use crate::vit::{MgnetConfig, VitConfig, VitVariant};

/// Configuration of one serving pipeline instance.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub variant: VitVariant,
    pub image_size: usize,
    pub num_classes: usize,
    /// Kept-patch buckets the backbone was AOT-compiled at (ascending;
    /// must include the full patch count).
    pub buckets: Vec<usize>,
    /// MGNet sigmoid threshold `t_reg`.
    pub region_threshold: f32,
    /// Disable to run the unmasked baseline (all patches).
    pub use_mask: bool,
}

impl PipelineConfig {
    /// Default Tiny@96 pipeline matching `python/compile/aot.py` exports.
    pub fn tiny_96() -> Self {
        PipelineConfig {
            variant: VitVariant::Tiny,
            image_size: 96,
            num_classes: 10,
            buckets: vec![9, 18, 27, 36],
            region_threshold: 0.5,
            use_mask: true,
        }
    }

    pub fn vit_config(&self) -> VitConfig {
        VitConfig::variant(self.variant, self.image_size, self.num_classes)
    }

    pub fn mgnet_config(&self) -> MgnetConfig {
        MgnetConfig::classification(self.image_size)
    }

    /// Artifact name for the MGNet stage.
    pub fn mgnet_artifact(&self) -> String {
        format!("mgnet_{}", self.image_size)
    }

    /// Artifact name for the backbone at a bucket size.
    pub fn backbone_artifact(&self, bucket: usize) -> String {
        format!(
            "vit_{}_{}_n{}",
            self.variant.name().to_lowercase(),
            self.image_size,
            bucket
        )
    }
}

/// Per-frame output.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub frame_index: u64,
    pub logits: Vec<f32>,
    pub mask: PatchMask,
    /// Bucket the frame was routed to.
    pub bucket: usize,
    /// Modeled accelerator energy for this frame (J).
    pub modeled_energy_j: f64,
    /// Host wall-clock latency (s) for the full pipeline.
    pub latency_s: f64,
}

impl FrameResult {
    pub fn predicted_class(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// The pipeline; owns the (non-`Send`) PJRT runtime, so it is constructed
/// and driven on one thread.
pub struct Pipeline {
    cfg: PipelineConfig,
    runtime: Runtime,
    router: BucketRouter,
    model: AcceleratorModel,
    pub metrics: StageMetrics,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig, artifact_dir: &str) -> Result<Self> {
        let router = BucketRouter::new(cfg.buckets.clone());
        let full = cfg.vit_config().num_patches();
        anyhow::ensure!(
            router.buckets().last() == Some(&full),
            "largest bucket {:?} must equal the full patch count {}",
            router.buckets().last(),
            full
        );
        Ok(Pipeline {
            cfg,
            runtime: Runtime::new(artifact_dir)?,
            router,
            model: AcceleratorModel::default(),
            metrics: StageMetrics::new(),
        })
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Pre-compile all artifacts (avoids compile jitter on the first frames).
    pub fn warmup(&mut self) -> Result<()> {
        if self.cfg.use_mask {
            let name = self.cfg.mgnet_artifact();
            self.runtime.load(&name)?;
        }
        for &b in self.router.buckets().to_vec().iter() {
            let name = self.cfg.backbone_artifact(b);
            self.runtime.load(&name)?;
        }
        Ok(())
    }

    /// Process one frame end-to-end.
    pub fn process_frame(&mut self, frame: &Frame) -> Result<FrameResult> {
        let t_start = Instant::now();
        let vit_cfg = self.cfg.vit_config();
        let patch_px = vit_cfg.patch_size;
        let side = frame.size / patch_px;
        let n_full = side * side;
        let patch_dim = vit_cfg.patch_dim();

        // 1. Patchify (the sensor→accelerator interface).
        let t0 = Instant::now();
        let patches = frame.patchify(patch_px);
        self.metrics.record_stage("patchify", t0.elapsed().as_secs_f64());

        // 2. MGNet scores → binary mask (Eq. 3 + sigmoid threshold).
        let (mask, scores) = if self.cfg.use_mask {
            let t0 = Instant::now();
            let scores = self
                .runtime
                .execute1(
                    &self.cfg.mgnet_artifact(),
                    &[Tensor::new(patches.clone(), vec![n_full as i64, patch_dim as i64])],
                )
                .context("MGNet stage")?;
            self.metrics.record_stage("mgnet", t0.elapsed().as_secs_f64());
            let mask = PatchMask::from_scores(side, &scores, self.cfg.region_threshold);
            (mask, scores)
        } else {
            (PatchMask::full(side), vec![1.0f32; n_full])
        };

        // 3. Route to a bucket; select top-score patches if over-full,
        //    otherwise pad with zeroed invalid slots.
        let t0 = Instant::now();
        let mut kept = mask.kept_indices();
        if kept.is_empty() {
            // Always process at least the highest-score patch.
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            kept.push(best);
        }
        let bucket = self.router.route(kept.len());
        if kept.len() > bucket {
            kept.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            kept.truncate(bucket);
            kept.sort_unstable();
        }
        let mut bucket_patches = vec![0.0f32; bucket * patch_dim];
        let mut pos_idx = vec![0.0f32; bucket];
        let mut valid = vec![0.0f32; bucket];
        for (slot, &pidx) in kept.iter().enumerate() {
            bucket_patches[slot * patch_dim..(slot + 1) * patch_dim]
                .copy_from_slice(&patches[pidx * patch_dim..(pidx + 1) * patch_dim]);
            pos_idx[slot] = pidx as f32;
            valid[slot] = 1.0;
        }
        self.metrics.record_stage("route", t0.elapsed().as_secs_f64());

        // 4. Backbone on the pruned sequence.
        let t0 = Instant::now();
        let logits = self
            .runtime
            .execute1(
                &self.cfg.backbone_artifact(bucket),
                &[
                    Tensor::new(bucket_patches, vec![bucket as i64, patch_dim as i64]),
                    Tensor::new(pos_idx, vec![bucket as i64]),
                    Tensor::new(valid, vec![bucket as i64]),
                ],
            )
            .context("backbone stage")?;
        self.metrics.record_stage("backbone", t0.elapsed().as_secs_f64());

        // 5. Modeled accelerator energy at this kept count.
        let energy_j = if self.cfg.use_mask {
            self.model.masked_energy(&vit_cfg, &self.cfg.mgnet_config(), kept.len()).total_j()
        } else {
            self.model.frame_energy(&vit_cfg, vit_cfg.num_patches(), true).total_j()
        };
        let latency = t_start.elapsed().as_secs_f64();
        self.metrics.record_stage("total", latency);
        self.metrics.record_frame(energy_j, kept.len());

        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: energy_j,
            latency_s: latency,
        })
    }
}

/// Summary of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub frames: u64,
    pub dropped: u64,
    pub wall_fps: f64,
    pub mean_latency_s: f64,
    pub mean_energy_j: f64,
    pub modeled_kfps_per_watt: f64,
    pub mean_kept_patches: f64,
    /// Mean IoU of the MGNet mask vs. the sensor ground truth.
    pub mean_mask_iou: f64,
    /// Top-1 agreement with the synthetic class labels (meaningful only
    /// when the backbone artifact embeds trained weights).
    pub top1_accuracy: f64,
}

/// Drive a pipeline from a live sensor thread for `num_frames` frames.
/// The sensor produces frames as fast as the queue accepts them; a full
/// queue drops frames (real near-sensor backpressure).
pub fn serve(
    pipeline: &mut Pipeline,
    sensor_seed: u64,
    num_objects: usize,
    num_frames: u64,
    queue_depth: usize,
) -> Result<ServeReport> {
    let size = pipeline.cfg.image_size;
    let (queue, rx) = FrameQueue::bounded(queue_depth);
    let produced = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let produced_t = produced.clone();
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop_t = stop.clone();
    let sensor = std::thread::spawn(move || {
        let mut src = VideoSource::new(size, num_objects, sensor_seed);
        while !stop_t.load(std::sync::atomic::Ordering::Relaxed) {
            let f = src.next_frame();
            produced_t.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // try_push drops on full queue; yield briefly to let the
            // consumer drain.
            if !queue.try_push(f) {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    });

    pipeline.warmup()?;
    pipeline.metrics.start_run();
    let patch_px = pipeline.cfg.vit_config().patch_size;
    let mut iou_sum = 0.0f64;
    let mut correct = 0u64;
    let mut done = 0u64;
    while done < num_frames {
        let Some(frame) = recv_frame(&rx, Duration::from_secs(5)) else {
            break;
        };
        let gt = frame.gt_mask(patch_px);
        let label = frame.label;
        let r = pipeline.process_frame(&frame)?;
        iou_sum += r.mask.iou(&gt);
        correct += (r.predicted_class() == label) as u64;
        done += 1;
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    // Drain so the sensor thread unblocks, then join.
    while rx.try_recv().is_ok() {}
    sensor.join().ok();

    let m = &pipeline.metrics;
    Ok(ServeReport {
        frames: done,
        dropped: produced.load(std::sync::atomic::Ordering::Relaxed).saturating_sub(done),
        wall_fps: m.wall_fps(),
        mean_latency_s: m.stage_mean_s("total"),
        mean_energy_j: m.mean_energy_j(),
        modeled_kfps_per_watt: m.modeled_kfps_per_watt(),
        mean_kept_patches: m.mean_kept_patches(),
        mean_mask_iou: if done > 0 { iou_sum / done as f64 } else { 0.0 },
        top1_accuracy: if done > 0 { correct as f64 / done as f64 } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_artifact_names() {
        let c = PipelineConfig::tiny_96();
        assert_eq!(c.mgnet_artifact(), "mgnet_96");
        assert_eq!(c.backbone_artifact(36), "vit_tiny_96_n36");
    }

    #[test]
    fn pipeline_requires_full_bucket() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![9, 18]; // missing 36
        assert!(Pipeline::new(c, "/tmp").is_err());
    }

    #[test]
    fn frame_result_argmax() {
        let r = FrameResult {
            frame_index: 0,
            logits: vec![0.1, 0.9, 0.3],
            mask: PatchMask::full(6),
            bucket: 36,
            modeled_energy_j: 1e-5,
            latency_s: 0.01,
        };
        assert_eq!(r.predicted_class(), 1);
    }
}
