//! The frame-serving pipeline: MGNet → RoI mask → bucket routing →
//! bucket-major micro-batches → backbone.
//!
//! The pipeline is generic over the execution substrate: any
//! [`crate::runtime::Backend`] (PJRT over compiled HLO, the pure-Rust
//! host reference, or the analytic photonic simulator) plugs in without
//! the request path knowing which one it drives. No PJRT symbol appears in
//! this module — artifact names are the only contract.
//!
//! The execution API is **batch-first** and split-phase:
//!
//! - [`Pipeline::route_frame`] runs the front half (patchify → MGNet →
//!   mask → route) and returns a [`RoutedFrame`] staged for its bucket;
//! - [`Pipeline::complete_batch`] drives one
//!   [`crate::runtime::Backend::execute_batch`] call over a single-bucket
//!   group of routed frames, amortizing dispatch (and, on the modeled
//!   accelerator, weight-bank programming) across the batch;
//! - [`Pipeline::process_frame`] is the degenerate one-frame case, kept as
//!   its own allocation-free fast path, and [`Pipeline::process_batch`]
//!   composes the two halves bucket-major for callers holding a frame
//!   slice.
//!
//! Serving is **streaming**: [`serve`] returns a [`FrameStream`] — an
//! iterator of in-order [`FrameResult`]s backed by a
//! [`super::batcher::MicroBatcher`] and a bounded reassembly window — and
//! the terminal [`ServeReport`] is derived from the drained stream via
//! [`FrameStream::finish`].
//!
//! The steady-state one-frame hot path is **allocation-free up to each
//! backend call**: every per-frame buffer (patchify output, score/mask
//! staging, kept-index list, zero-padded bucket tensors) lives in a
//! reusable [`FrameScratch`], and backends accept borrowed [`TensorRef`]
//! views, so no frame ever clones its patch tensor.
//! `rust/tests/alloc_hot_path.rs` asserts the staging stages with a
//! counting allocator, and `rust/tests/host_backend.rs` bounds the full
//! frame over [`crate::runtime::HostBackend`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::batcher::{recv_frame, BatchPolicy, BucketRouter, FrameQueue, MicroBatcher};
use super::clock::Clock;
use super::stats::{StageMetrics, WorkerStats};
use crate::energy::AcceleratorModel;
use crate::quant::{PrecisionPolicy, PrecisionTier, AUTO_ROI_THRESHOLD};
use crate::roi::PatchMask;
use crate::runtime::{Backend, TensorRef};
use crate::sensor::Frame;
use crate::vit::{MgnetConfig, VitConfig, VitVariant};

/// Configuration of one serving pipeline instance.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub variant: VitVariant,
    pub image_size: usize,
    pub num_classes: usize,
    /// Kept-patch buckets the backbone artifacts exist at. Must be strictly
    /// ascending and end at the full patch count — enforced by
    /// [`PipelineConfig::validate`] at pipeline construction.
    pub buckets: Vec<usize>,
    /// MGNet sigmoid threshold `t_reg`.
    pub region_threshold: f32,
    /// Disable to run the unmasked baseline (all patches).
    pub use_mask: bool,
    /// Score integer-tier output agreement against an fp32 electronic
    /// reference: every non-fp32 frame additionally runs the backbone at
    /// [`PrecisionTier::Fp32`] and records whether the argmax matched.
    /// The probe is a measurement instrument — its modeled energy and
    /// latency are never charged to the frame. Off by default (it doubles
    /// backbone compute).
    pub fp32_reference: bool,
}

impl PipelineConfig {
    /// Default Tiny@96 pipeline matching `python/compile/aot.py` exports.
    pub fn tiny_96() -> Self {
        PipelineConfig {
            variant: VitVariant::Tiny,
            image_size: 96,
            num_classes: 10,
            buckets: vec![9, 18, 27, 36],
            region_threshold: 0.5,
            use_mask: true,
            fp32_reference: false,
        }
    }

    pub fn vit_config(&self) -> VitConfig {
        VitConfig::variant(self.variant, self.image_size, self.num_classes)
    }

    pub fn mgnet_config(&self) -> MgnetConfig {
        MgnetConfig::classification(self.image_size)
    }

    /// Artifact name for the MGNet stage.
    pub fn mgnet_artifact(&self) -> String {
        format!("mgnet_{}", self.image_size)
    }

    /// Artifact name for the backbone at a bucket size.
    pub fn backbone_artifact(&self, bucket: usize) -> String {
        format!(
            "vit_{}_{}_n{}",
            self.variant.name().to_lowercase(),
            self.image_size,
            bucket
        )
    }

    /// Check the bucket ladder at construction time (a bad ladder would
    /// otherwise surface frames later as a routing panic or a missing
    /// artifact deep in a worker thread): buckets must be non-empty,
    /// strictly ascending, and end at the full patch count.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.buckets.is_empty(),
            "pipeline config has no buckets — at least the full patch count is required"
        );
        anyhow::ensure!(
            // lint-allow(panic): `windows(2)` yields exactly-2 slices.
            self.buckets.windows(2).all(|w| w[0] < w[1]),
            "buckets {:?} must be strictly ascending",
            self.buckets
        );
        let full = self.vit_config().num_patches();
        anyhow::ensure!(
            self.buckets.last() == Some(&full),
            "largest bucket {:?} must equal the full patch count {} so every mask has a home",
            self.buckets.last(),
            full
        );
        Ok(())
    }
}

/// Per-frame output.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub frame_index: u64,
    pub logits: Vec<f32>,
    pub mask: PatchMask,
    /// Bucket the frame was routed to.
    pub bucket: usize,
    /// Modeled accelerator energy for this frame (J).
    pub modeled_energy_j: f64,
    /// Latency attributed to this frame (s): modeled accelerator latency
    /// when the backend simulates timing (`sim`), host wall-clock
    /// otherwise — including any time the frame waited in a micro-batch
    /// lane on the batched path.
    pub latency_s: f64,
    /// Modeled queueing share of `latency_s` (s): waiting time charged by
    /// the discrete-event co-sim (see [`crate::cosim`]) when a queueing
    /// plan is armed on the `sim` backend; exactly 0.0 otherwise.
    pub modeled_queueing_s: f64,
    /// Frames that shared this frame's backbone dispatch (1 on the
    /// per-frame path). Lets per-session accounting report the mean
    /// micro-batch size without access to the worker's [`StageMetrics`].
    pub batch_size: usize,
    /// Precision tier the backbone actually executed at (resolved from the
    /// frame's [`PrecisionPolicy`] — `Auto` resolves against the staged
    /// ROI mask at route time).
    pub tier: PrecisionTier,
    /// Whether this frame's argmax agreed with the fp32 electronic
    /// reference. `Some` only when the pipeline's
    /// [`PipelineConfig::fp32_reference`] probe is on and the frame itself
    /// ran at an integer tier; `None` otherwise.
    pub fp32_agreement: Option<bool>,
}

/// Argmax over a logit slice. `total_cmp` gives NaN a defined order, so a
/// NaN logit can never panic the serving loop; an empty slice maps to
/// class 0.
fn argmax(logits: &[f32]) -> usize {
    logits.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

impl FrameResult {
    /// Argmax over the logits (NaN-safe — see [`argmax`]).
    pub fn predicted_class(&self) -> usize {
        argmax(&self.logits)
    }
}

/// A frame that has cleared the front half of the pipeline (patchify →
/// MGNet → mask → route) and is staged for a batched backbone call: the
/// unit the bucket-major [`MicroBatcher`] accumulates and
/// [`Pipeline::complete_batch`] consumes.
///
/// Owns its staged bucket tensors (copied out of the pipeline's
/// [`FrameScratch`], which the next routed frame will overwrite), so any
/// number of routed frames can wait in lanes while the pipeline keeps
/// routing.
#[derive(Debug)]
pub struct RoutedFrame {
    pub frame_index: u64,
    /// Synthetic class label carried along for accuracy scoring.
    pub label: usize,
    /// Bucket the frame was routed to (its micro-batch lane).
    pub bucket: usize,
    /// Kept patches after masking (≥ 1).
    pub kept_count: usize,
    /// Precision tier resolved at route time. Micro-batch lanes are
    /// bucket×tier-major: a 4-bit frame must never ride an 8-bit group's
    /// weight programming, so [`Pipeline::complete_batch`] rejects
    /// mixed-tier groups outright.
    pub tier: PrecisionTier,
    /// The thresholded keep mask (moved into the final [`FrameResult`]).
    pub mask: PatchMask,
    /// Staged `(bucket, patch_dim)` backbone input.
    patches: Vec<f32>,
    /// Original grid position of each bucket slot.
    pos_idx: Vec<f32>,
    /// Validity mask over bucket slots.
    valid: Vec<f32>,
    /// Host wall-clock spent in the front half (seconds).
    front_s: f64,
    /// When the front half finished — the start of the frame's lane wait,
    /// so reported latency can include time spent queued for a batch.
    staged_at: Instant,
}

/// Reusable per-frame working memory. All buffers are sized once (at
/// pipeline construction) for the largest bucket, so steady-state frames
/// perform zero heap allocation before each backend call.
#[derive(Debug)]
pub struct FrameScratch {
    /// Patchified frame, `(num_patches, patch_dim)` row-major.
    patches: Vec<f32>,
    /// Per-patch MGNet scores (pre-sigmoid logits; 1.0 in no-mask runs).
    scores: Vec<f32>,
    /// Thresholded keep mask.
    mask: PatchMask,
    /// Kept-patch indices, row-major order.
    kept: Vec<usize>,
    /// Zero-padded `(bucket, patch_dim)` backbone input (largest-bucket
    /// capacity; per-frame prefixes are used).
    bucket_patches: Vec<f32>,
    /// Original grid position of each bucket slot.
    pos_idx: Vec<f32>,
    /// Validity mask over bucket slots (1.0 = real patch, 0.0 = padding).
    valid: Vec<f32>,
}

impl FrameScratch {
    pub fn new(num_patches: usize, patch_dim: usize, max_bucket: usize) -> Self {
        FrameScratch {
            patches: Vec::with_capacity(num_patches * patch_dim),
            scores: Vec::with_capacity(num_patches),
            mask: PatchMask { side: 0, keep: Vec::with_capacity(num_patches) },
            kept: Vec::with_capacity(num_patches),
            bucket_patches: vec![0.0; max_bucket * patch_dim],
            pos_idx: vec![0.0; max_bucket],
            valid: vec![0.0; max_bucket],
        }
    }

    /// Scratch sized for one pipeline configuration.
    pub fn for_config(cfg: &PipelineConfig) -> Self {
        let vit = cfg.vit_config();
        let max_bucket =
            cfg.buckets.iter().copied().max().unwrap_or_else(|| vit.num_patches());
        Self::new(vit.num_patches(), vit.patch_dim(), max_bucket)
    }

    /// Stage 1: patchify the frame into the scratch patch buffer.
    pub fn stage_patchify(&mut self, frame: &Frame, patch_px: usize) {
        frame.patchify_into(patch_px, &mut self.patches);
    }

    /// The patchified frame (valid after [`FrameScratch::stage_patchify`]).
    pub fn patches(&self) -> &[f32] {
        &self.patches
    }

    /// Stage 2: adopt MGNet scores and threshold them into the keep mask.
    pub fn stage_mask(&mut self, side: usize, scores: &[f32], t_reg: f32) {
        self.scores.clear();
        self.scores.extend_from_slice(scores);
        self.mask.fill_from_scores(side, &self.scores, t_reg);
    }

    /// Stage 2, no-mask baseline: keep everything with uniform scores.
    pub fn stage_mask_full(&mut self, side: usize) {
        self.scores.clear();
        self.scores.resize(side * side, 1.0);
        self.mask.fill_full(side);
    }

    pub fn mask(&self) -> &PatchMask {
        &self.mask
    }

    /// Stage 3: route the kept count to a bucket and stage kept patches
    /// into the zero-padded bucket buffers. Returns the bucket size;
    /// afterwards `bucket_patches`/`pos_idx`/`valid` views hold the
    /// backbone inputs. `total_cmp` is used throughout so NaN scores sort
    /// deterministically instead of panicking.
    // lint-allow(panic, fn): hot-path staging over buffers sized at
    // construction for the largest bucket; `route()` never returns a
    // bucket above `self.kept` capacity and kept indices come from the
    // mask over the same frame, so every index is in bounds by
    // construction. `.get()` here would hide real corruption and cost a
    // branch per patch on the per-frame path.
    pub fn stage_route(&mut self, router: &BucketRouter, patch_dim: usize) -> usize {
        self.mask.kept_indices_into(&mut self.kept);
        if self.kept.is_empty() {
            // Always process at least the highest-score patch.
            let best = self
                .scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            self.kept.push(best);
        }
        let bucket = router.route(self.kept.len());
        if self.kept.len() > bucket {
            let scores = &self.scores;
            self.kept.sort_unstable_by(|&a, &b| scores[b].total_cmp(&scores[a]));
            self.kept.truncate(bucket);
            self.kept.sort_unstable();
        }
        let staged = &mut self.bucket_patches[..bucket * patch_dim];
        staged.fill(0.0);
        self.pos_idx[..bucket].fill(0.0);
        self.valid[..bucket].fill(0.0);
        for (slot, &pidx) in self.kept.iter().enumerate() {
            staged[slot * patch_dim..(slot + 1) * patch_dim]
                .copy_from_slice(&self.patches[pidx * patch_dim..(pidx + 1) * patch_dim]);
            self.pos_idx[slot] = pidx as f32;
            self.valid[slot] = 1.0;
        }
        bucket
    }

    /// Kept-patch indices (valid after [`FrameScratch::stage_route`]).
    pub fn kept(&self) -> &[usize] {
        &self.kept
    }

    /// Staged `(bucket, patch_dim)` backbone input.
    // lint-allow(panic, fn): `bucket` is the value `stage_route` returned
    // for this scratch; the buffer was sized for the largest bucket at
    // construction.
    pub fn bucket_patches(&self, bucket: usize, patch_dim: usize) -> &[f32] {
        &self.bucket_patches[..bucket * patch_dim]
    }

    /// Staged position indices for the bucket slots.
    // lint-allow(panic, fn): same bounds invariant as `bucket_patches`.
    pub fn pos_idx(&self, bucket: usize) -> &[f32] {
        &self.pos_idx[..bucket]
    }

    /// Staged validity mask for the bucket slots.
    // lint-allow(panic, fn): same bounds invariant as `bucket_patches`.
    pub fn valid(&self, bucket: usize) -> &[f32] {
        &self.valid[..bucket]
    }
}

/// The pipeline, generic over its execution [`Backend`]. Backends are not
/// required to be `Send`, so a pipeline is constructed and driven on one
/// thread; sharded serving constructs one `Pipeline` per worker thread
/// (see [`crate::coordinator::engine`]).
pub struct Pipeline<B: Backend> {
    cfg: PipelineConfig,
    backend: B,
    router: BucketRouter,
    model: AcceleratorModel,
    scratch: FrameScratch,
    /// Cached (`Copy`) configs so the hot path never rebuilds them.
    vit_cfg: VitConfig,
    mgnet_cfg: MgnetConfig,
    /// Artifact names, formatted once at construction: the hot path must
    /// not `format!` per frame.
    mgnet_name: String,
    backbone_names: Vec<(usize, String)>,
    /// Time source for every stage timestamp and lane deadline
    /// ([`Clock::system`] in production; a manual clock in deterministic
    /// tests). Reading it is a branch around `Instant::now()` — no
    /// allocation, no dyn dispatch, so the frame hot path stays within
    /// its allocation budget.
    clock: Clock,
    pub metrics: StageMetrics,
}

impl<B: Backend> Pipeline<B> {
    /// Build a pipeline over an already-constructed backend, timed by the
    /// production [`Clock::system`]. Validates the bucket ladder (see
    /// [`PipelineConfig::validate`]).
    pub fn with_backend(cfg: PipelineConfig, backend: B) -> Result<Self> {
        Self::with_backend_and_clock(cfg, backend, Clock::system())
    }

    /// [`Pipeline::with_backend`] on an explicit [`Clock`] — the seam that
    /// makes stage timing and lane deadlines deterministic under a manual
    /// clock.
    pub fn with_backend_and_clock(cfg: PipelineConfig, backend: B, clock: Clock) -> Result<Self> {
        cfg.validate()?;
        let router = BucketRouter::new(cfg.buckets.clone());
        let vit_cfg = cfg.vit_config();
        let backbone_names: Vec<(usize, String)> =
            router.buckets().iter().map(|&b| (b, cfg.backbone_artifact(b))).collect();
        let scratch = FrameScratch::for_config(&cfg);
        Ok(Pipeline {
            backend,
            router,
            model: AcceleratorModel::default(),
            scratch,
            vit_cfg,
            mgnet_cfg: cfg.mgnet_config(),
            mgnet_name: cfg.mgnet_artifact(),
            backbone_names,
            clock,
            metrics: StageMetrics::new(),
            cfg,
        })
    }

    /// The clock this pipeline stamps stage timings with.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// The execution substrate this pipeline drives.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Stable backend identifier, carried into [`ServeReport`].
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend's optical-hardware condition (`None` on substrates
    /// without a fault model) — the signal the health-aware server routes
    /// on.
    pub fn backend_health(&mut self) -> Option<crate::runtime::BackendHealth> {
        self.backend.health()
    }

    /// Recalibrate the backend's modeled optics (see
    /// [`crate::runtime::Backend::recalibrate`]).
    pub fn recalibrate_backend(&mut self) -> Option<crate::runtime::RecalCost> {
        self.backend.recalibrate()
    }

    /// Pre-load all artifacts (avoids compile jitter on the first frames —
    /// PJRT compilation and host module materialization both happen here,
    /// never on the steady-state path).
    pub fn warmup(&mut self) -> Result<()> {
        if self.cfg.use_mask {
            self.backend.load(&self.mgnet_name)?;
        }
        for (_, name) in &self.backbone_names {
            self.backend.load(name)?;
        }
        Ok(())
    }

    /// The front half shared by [`Pipeline::process_frame`] and
    /// [`Pipeline::route_frame`]: patchify → MGNet → mask → route, all
    /// staged in the reusable [`FrameScratch`]. Returns the routed bucket;
    /// the staged tensors live in `self.scratch` until the next frame.
    fn stage_front(&mut self, frame: &Frame) -> Result<usize> {
        let patch_px = self.vit_cfg.patch_size;
        let side = frame.size / patch_px;
        let n_full = side * side;
        let patch_dim = self.vit_cfg.patch_dim();

        // 1. Patchify (the sensor→accelerator interface) into scratch.
        let t0 = self.clock.now();
        self.scratch.stage_patchify(frame, patch_px);
        self.metrics.record_stage("patchify", self.clock.seconds_since(t0));

        // 2. MGNet scores → binary mask (Eq. 3 + sigmoid threshold).
        if self.cfg.use_mask {
            let t0 = self.clock.now();
            let dims = [n_full as i64, patch_dim as i64];
            let scores = self
                .backend
                .execute1(&self.mgnet_name, &[TensorRef::new(&self.scratch.patches, &dims)])
                .context("MGNet stage")?;
            self.metrics.record_stage("mgnet", self.clock.seconds_since(t0));
            self.scratch.stage_mask(side, &scores, self.cfg.region_threshold);
        } else {
            self.scratch.stage_mask_full(side);
        }

        // 3. Route to a bucket; select top-score patches if over-full,
        //    otherwise pad with zeroed invalid slots.
        let t0 = self.clock.now();
        let bucket = self.scratch.stage_route(&self.router, patch_dim);
        self.metrics.record_stage("route", self.clock.seconds_since(t0));
        Ok(bucket)
    }

    /// Resolve a frame's precision policy to a concrete execution tier.
    /// `Fixed` is taken as-is. `Auto` derives the tier from the ROI mask
    /// staged by [`Pipeline::stage_front`] for this very frame: a frame
    /// keeping at least [`AUTO_ROI_THRESHOLD`] of its patches is
    /// importance-heavy and runs at INT8; below that it is
    /// background-heavy and drops to INT4. Unmasked baselines carry no
    /// ROI signal, so `Auto` degrades to the INT8 operating point there.
    fn resolve_tier(&self, policy: PrecisionPolicy) -> PrecisionTier {
        match policy {
            PrecisionPolicy::Fixed(tier) => tier,
            PrecisionPolicy::Auto => {
                if !self.cfg.use_mask {
                    return PrecisionTier::Int8;
                }
                let kept_frac =
                    self.scratch.kept.len() as f64 / self.vit_cfg.num_patches() as f64;
                if kept_frac >= AUTO_ROI_THRESHOLD {
                    PrecisionTier::Int8
                } else {
                    PrecisionTier::Int4
                }
            }
        }
    }

    /// Degraded optics cost extra modeled energy (drift compensation and
    /// re-tune retries): up to `+FAULT_ENERGY_PENALTY` at health 0.
    /// Exactly 1.0 on substrates without a fault model.
    fn energy_factor(&mut self) -> f64 {
        match self.backend.health() {
            Some(h) => 1.0 + crate::runtime::sim::FAULT_ENERGY_PENALTY * (1.0 - h.health),
            None => 1.0,
        }
    }

    /// Modeled accelerator energy for one frame (J), charged for every
    /// backend — the host is a stand-in for the photonic core. A frame
    /// riding a bucket-major batch behind its group's first frame reuses
    /// the programmed **backbone** MR weight banks, so followers are
    /// discounted by the backbone's weight-programming share
    /// ([`AcceleratorModel::weight_program_energy_j`]): modeled
    /// energy/frame *drops* as batch size grows. The MGNet share is never
    /// discounted — MGNet executes per frame at route time, interleaved
    /// with other buckets' batches, so its banks are reprogrammed anyway
    /// (and it always runs at INT8, whatever the backbone tier).
    /// Degraded optics inflate the figure by [`Pipeline::energy_factor`].
    fn modeled_energy_j(
        &mut self,
        kept_count: usize,
        first_in_batch: bool,
        tier: PrecisionTier,
    ) -> f64 {
        let (full, backbone_kept) = if self.cfg.use_mask {
            (
                self.model
                    .masked_energy_tiered(&self.vit_cfg, &self.mgnet_cfg, kept_count, tier)
                    .total_j(),
                kept_count,
            )
        } else {
            let n = self.vit_cfg.num_patches();
            (self.model.frame_energy_tiered(&self.vit_cfg, n, true, tier).total_j(), n)
        };
        let ideal = if first_in_batch {
            full
        } else {
            let saved =
                self.model.weight_program_energy_j_tiered(&self.vit_cfg, backbone_kept, true, tier);
            (full - saved).max(0.0)
        };
        ideal * self.energy_factor()
    }

    /// Record a simulating backend's modeled per-stage latency (MGNet and
    /// backbone separately, plus the `"modeled"` total that becomes the
    /// reported frame latency). Returns the modeled stages, or `None` on
    /// measuring backends.
    ///
    /// When the backend's queueing co-sim is armed (see [`crate::cosim`]),
    /// each call here also feeds **one arrival event** into it and charges
    /// the resulting waiting time as the `"modeled_queueing"` stage —
    /// `modeled_stages_s` itself reports pure load-independent *service*
    /// stages (which is what makes them cacheable), so queueing is added
    /// exactly once per frame, at completion time.
    fn record_modeled(
        &mut self,
        kept_count: usize,
        first_in_batch: bool,
        tier: PrecisionTier,
    ) -> Option<crate::runtime::ModeledStages> {
        let mut stages = self.backend.modeled_stages_s_tiered(
            kept_count,
            self.cfg.use_mask,
            first_in_batch,
            tier,
        )?;
        stages.queueing_s = self.backend.modeled_queueing_s(kept_count, self.cfg.use_mask);
        if self.cfg.use_mask {
            self.metrics.record_stage("modeled_mgnet", stages.mgnet_s);
        }
        self.metrics.record_stage("modeled_backbone", stages.backbone_s);
        self.metrics.record_stage("modeled_queueing", stages.queueing_s);
        self.metrics.record_stage("modeled", stages.total_s());
        Some(stages)
    }

    /// Process one frame end-to-end — the degenerate batch of one.
    /// Steady-state frames perform zero heap allocation before each
    /// backend call: all staging goes through the reusable [`FrameScratch`]
    /// and inputs are passed as borrowed [`TensorRef`] views.
    pub fn process_frame(&mut self, frame: &Frame) -> Result<FrameResult> {
        let t_start = self.clock.now();
        let patch_dim = self.vit_cfg.patch_dim();
        let bucket = self.stage_front(frame)?;
        let kept_count = self.scratch.kept.len();
        let tier = self.resolve_tier(frame.precision);

        // Backbone on the pruned sequence.
        let t0 = self.clock.now();
        let artifact = self
            .backbone_names
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, n)| n.as_str())
            .ok_or_else(|| anyhow!("bucket {bucket} has no artifact in the ladder"))?;
        let bdims = [bucket as i64, patch_dim as i64];
        let vdims = [bucket as i64];
        // lint-allow(panic): staged-view slices use the bucket returned by
        // `stage_route` for this very frame (see `FrameScratch` bounds
        // invariant).
        let holders = [
            TensorRef::new(&self.scratch.bucket_patches[..bucket * patch_dim], &bdims),
            TensorRef::new(&self.scratch.pos_idx[..bucket], &vdims),
            TensorRef::new(&self.scratch.valid[..bucket], &vdims),
        ];
        let logits = if tier == PrecisionTier::Int8 {
            // The INT8 operating point stays on `execute1` — the exact
            // pre-tier hot path, allocation profile included.
            self.backend.execute1(artifact, &holders).context("backbone stage")?
        } else {
            let one: [&[TensorRef<'_>]; 1] = [&holders];
            let mut outs = self
                .backend
                .execute_batch_tiered(artifact, &one, tier)
                .context("backbone stage")?;
            let mut out = outs
                .pop()
                .ok_or_else(|| anyhow!("backend returned no result sets for a batch of 1"))?;
            ensure!(
                out.len() == 1,
                "artifact '{artifact}' returned {} outputs, expected 1",
                out.len()
            );
            out.pop().ok_or_else(|| anyhow!("backend returned an empty output set"))?
        };
        self.metrics.record_stage("backbone", self.clock.seconds_since(t0));
        // Snapshot frame wall time before the optional probe below, so
        // agreement accounting never inflates reported latency.
        let wall_s = self.clock.seconds_since(t_start);

        // Optional fp32 electronic-reference probe for output-agreement
        // accounting. Its modeled energy/latency are never charged — the
        // probe is a measurement instrument, not a served inference.
        let fp32_agreement = if self.cfg.fp32_reference && tier != PrecisionTier::Fp32 {
            let one: [&[TensorRef<'_>]; 1] = [&holders];
            let probe = self
                .backend
                .execute_batch_tiered(artifact, &one, PrecisionTier::Fp32)
                .context("fp32 agreement reference")?;
            probe
                .into_iter()
                .next()
                .and_then(|mut out| out.pop())
                .map(|ref_logits| argmax(&ref_logits) == argmax(&logits))
        } else {
            None
        };

        let energy_j = self.modeled_energy_j(kept_count, true, tier);
        // "total" is always host wall-clock (it feeds busy-time and
        // utilization accounting); a simulating backend additionally
        // charges its modeled frame latency under "modeled", which then
        // becomes the reported per-frame latency.
        self.metrics.record_stage("total", wall_s);
        let modeled = self.record_modeled(kept_count, true, tier);
        self.metrics.record_frame(energy_j, kept_count);
        self.metrics.record_batch_size(1);

        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask: self.scratch.mask.clone(),
            bucket,
            modeled_energy_j: energy_j,
            latency_s: modeled.map(|s| s.total_s()).unwrap_or(wall_s),
            modeled_queueing_s: modeled.map_or(0.0, |s| s.queueing_s),
            batch_size: 1,
            tier,
            fp32_agreement,
        })
    }

    /// Run the front half of the pipeline and stage the frame for a
    /// bucket-major micro-batch. The returned [`RoutedFrame`] owns copies
    /// of its staged bucket tensors, so it can wait in a
    /// [`MicroBatcher`] lane while later frames overwrite the scratch.
    // lint-allow(panic, fn): the only indexing is the staged-view slices
    // under the `stage_route` bounds invariant (see `FrameScratch`).
    pub fn route_frame(&mut self, frame: &Frame) -> Result<RoutedFrame> {
        let t_start = self.clock.now();
        let patch_dim = self.vit_cfg.patch_dim();
        let bucket = self.stage_front(frame)?;
        Ok(RoutedFrame {
            frame_index: frame.index,
            label: frame.label,
            bucket,
            kept_count: self.scratch.kept.len(),
            tier: self.resolve_tier(frame.precision),
            mask: self.scratch.mask.clone(),
            patches: self.scratch.bucket_patches[..bucket * patch_dim].to_vec(),
            pos_idx: self.scratch.pos_idx[..bucket].to_vec(),
            valid: self.scratch.valid[..bucket].to_vec(),
            front_s: self.clock.seconds_since(t_start),
            staged_at: self.clock.now(),
        })
    }

    /// Complete a single-bucket, single-tier group of routed frames with
    /// **one** [`Backend::execute_batch_tiered`] call, returning results
    /// in group order.
    ///
    /// The group's first frame pays the full modeled cost; followers
    /// amortize the weight-programming share (energy here, latency via
    /// the backend's batch-aware model), so modeled energy/frame drops as
    /// dispatch amortizes. That amortization is exactly why the group must
    /// be tier-pure: a 4-bit frame riding an 8-bit group would reuse
    /// weight banks programmed at the wrong grid. The measured
    /// `"backbone"` wall time is split evenly across the batch.
    pub fn complete_batch(&mut self, batch: Vec<RoutedFrame>) -> Result<Vec<FrameResult>> {
        ensure!(!batch.is_empty(), "complete_batch needs at least one routed frame");
        // lint-allow(panic): non-emptiness ensured on the line above.
        let (bucket, tier) = (batch[0].bucket, batch[0].tier);
        ensure!(
            batch.iter().all(|rf| rf.bucket == bucket),
            "complete_batch requires a single-bucket (bucket-major) group"
        );
        ensure!(
            batch.iter().all(|rf| rf.tier == tier),
            "complete_batch requires a single-tier group — a {tier} frame must not \
             ride another tier's weight programming"
        );
        let n = batch.len();
        let patch_dim = self.vit_cfg.patch_dim();
        let artifact = self
            .backbone_names
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, name)| name.as_str())
            .ok_or_else(|| anyhow!("bucket {bucket} has no artifact in the ladder"))?;
        let bdims = [bucket as i64, patch_dim as i64];
        let vdims = [bucket as i64];

        let t0 = self.clock.now();
        let holders: Vec<[TensorRef<'_>; 3]> = batch
            .iter()
            .map(|rf| {
                [
                    TensorRef::new(&rf.patches, &bdims),
                    TensorRef::new(&rf.pos_idx, &vdims),
                    TensorRef::new(&rf.valid, &vdims),
                ]
            })
            .collect();
        // lint-allow(panic): full-range `&h[..]` reslice cannot be out of
        // bounds.
        let inputs: Vec<&[TensorRef<'_>]> = holders.iter().map(|h| &h[..]).collect();
        let outs = self
            .backend
            .execute_batch_tiered(artifact, &inputs, tier)
            .context("batched backbone stage")?;
        ensure!(
            outs.len() == n,
            "backend returned {} result sets for a batch of {n}",
            outs.len()
        );
        // The measured share and completion stamp are taken before the
        // optional probe below, so agreement accounting never inflates
        // reported wall latency.
        let backbone_share = self.clock.seconds_since(t0) / n as f64;
        let completed_at = self.clock.now();

        // Optional fp32 electronic-reference probe (see
        // [`PipelineConfig::fp32_reference`]): one extra batched call whose
        // modeled energy/latency are never charged to the frames.
        let ref_outs = if self.cfg.fp32_reference && tier != PrecisionTier::Fp32 {
            Some(
                self.backend
                    .execute_batch_tiered(artifact, &inputs, PrecisionTier::Fp32)
                    .context("fp32 agreement reference")?,
            )
        } else {
            None
        };
        drop(inputs);
        drop(holders);

        let mut results = Vec::with_capacity(n);
        for (i, (rf, mut out)) in batch.into_iter().zip(outs).enumerate() {
            ensure!(
                out.len() == 1,
                "artifact '{}' returned {} outputs, expected 1",
                self.cfg.backbone_artifact(bucket),
                out.len()
            );
            let logits =
                out.pop().ok_or_else(|| anyhow!("backend returned an empty output set"))?;
            let fp32_agreement = ref_outs
                .as_ref()
                .and_then(|r| r.get(i))
                .and_then(|out| out.first())
                .map(|ref_logits| argmax(ref_logits) == argmax(&logits));
            let first = i == 0;
            self.metrics.record_stage("backbone", backbone_share);
            let energy_j = self.modeled_energy_j(rf.kept_count, first, tier);
            // "total" stays compute-only (front half + this frame's share
            // of the batched call) — it feeds busy-time/utilization.
            // "latency" is what the frame actually experienced: front half
            // plus everything since it was staged, **including its lane
            // wait** — so a `--batch`/`--batch-wait-us` sweep reports the
            // real latency cost of batching, not just its throughput win.
            self.metrics.record_stage("total", rf.front_s + backbone_share);
            let latency_wall_s =
                rf.front_s + completed_at.saturating_duration_since(rf.staged_at).as_secs_f64();
            self.metrics.record_stage("latency", latency_wall_s);
            let modeled = self.record_modeled(rf.kept_count, first, tier);
            self.metrics.record_frame(energy_j, rf.kept_count);
            self.metrics.record_batch_size(n);
            results.push(FrameResult {
                frame_index: rf.frame_index,
                logits,
                mask: rf.mask,
                bucket,
                modeled_energy_j: energy_j,
                latency_s: modeled.map(|s| s.total_s()).unwrap_or(latency_wall_s),
                modeled_queueing_s: modeled.map_or(0.0, |s| s.queueing_s),
                batch_size: n,
                tier,
                fp32_agreement,
            });
        }
        Ok(results)
    }

    /// Process a slice of frames bucket×tier-major: route every frame,
    /// group by (bucket, tier) — bucket in ladder order, tier in
    /// [`PrecisionTier::index`] order — complete each group with one
    /// batched backend call, and return results in **input order**. A
    /// slice of one falls through to the allocation-free
    /// [`Pipeline::process_frame`].
    pub fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        if frames.len() <= 1 {
            return frames.iter().map(|f| self.process_frame(f)).collect();
        }
        let mut routed: Vec<Option<RoutedFrame>> = Vec::with_capacity(frames.len());
        for f in frames {
            routed.push(Some(self.route_frame(f)?));
        }
        let mut results: Vec<Option<FrameResult>> = (0..frames.len()).map(|_| None).collect();
        let ladder: Vec<usize> = self.router.buckets().to_vec();
        for bucket in ladder {
            for tier in PrecisionTier::ALL {
                let idxs: Vec<usize> = routed
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| {
                        r.as_ref().is_some_and(|rf| rf.bucket == bucket && rf.tier == tier)
                    })
                    .map(|(i, _)| i)
                    .collect();
                if idxs.is_empty() {
                    continue;
                }
                let mut group: Vec<RoutedFrame> = Vec::with_capacity(idxs.len());
                for &i in &idxs {
                    group.push(
                        // lint-allow(panic): `idxs` was collected from
                        // `enumerate()` over `routed` above.
                        routed[i].take().ok_or_else(|| {
                            anyhow!("frame {i} was claimed by two bucket groups")
                        })?,
                    );
                }
                let group_results = self.complete_batch(group)?;
                for (i, r) in idxs.into_iter().zip(group_results) {
                    // lint-allow(panic): same `enumerate()`-derived indices.
                    results[i] = Some(r);
                }
            }
        }
        results
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.ok_or_else(|| anyhow!("frame {i} was routed to a bucket outside the ladder"))
            })
            .collect()
    }
}

/// Summary of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Which execution backend served the run (`"pjrt"`/`"host"`/`"sim"`).
    pub backend: String,
    pub frames: u64,
    /// Frames the sensor actually failed to enqueue (`try_push`
    /// backpressure rejections) — not frames merely in flight when the
    /// run stopped, and not pushes against a hung-up consumer.
    pub dropped: u64,
    /// Submissions rejected by the session's **admission quota**
    /// (`coordinator::server::Quota`: max in-flight and/or token-bucket
    /// rate) — a policy decision, kept strictly distinct from `dropped`,
    /// which counts queue-full backpressure. Always 0 on paths without
    /// session quotas (the in-thread `serve` and the batch-job wrappers).
    pub dropped_quota: u64,
    /// Submissions rejected by the autoscaler's **overload shedding**
    /// (`coordinator::autoscale`): when scale-up is capped at
    /// `max_workers` and the pool stays overloaded, sessions below the
    /// shed weight threshold are refused admission until load recedes.
    /// Kept strictly distinct from `dropped` (queue backpressure) and
    /// `dropped_quota` (per-session policy); the terminal aggregate is
    /// exactly the per-session sum. Always 0 without an autoscaler.
    pub dropped_shed: u64,
    /// Frames whose **submit→emit** latency exceeded the session's
    /// declared SLO (`SessionOptions::slo`). 0 when no SLO was declared.
    /// Counted at emission against the serving clock, so a manual-clock
    /// test can assert it exactly.
    pub slo_miss: u64,
    /// Frames served by a worker whose backend reported **accuracy-at-risk**
    /// hardware health at completion time (degraded optics below
    /// `photonics::AT_RISK_HEALTH`). Per session in session reports; the
    /// terminal aggregate is exactly the per-session sum. Always 0 on
    /// substrates without a fault model.
    pub accuracy_at_risk: u64,
    /// Frames served at each precision tier, indexed by
    /// [`PrecisionTier::index`] (`[int4, int8, fp32]`). Sums to `frames`;
    /// per session in session reports, and the terminal aggregate is
    /// exactly the per-session sum.
    pub tier_frames: [u64; 3],
    /// Frames that additionally ran the fp32 electronic-reference
    /// agreement probe, per tier — all zero unless the pipeline's
    /// `fp32_reference` output-agreement accounting is on. The terminal
    /// aggregate is exactly the per-session sum.
    pub tier_ref_frames: [u64; 3],
    /// Probed frames whose tier-quantized argmax agreed with the fp32
    /// reference, per tier (`tier_agree[i] <= tier_ref_frames[i]`). The
    /// terminal aggregate is exactly the per-session sum.
    pub tier_agree: [u64; 3],
    /// p99 of submit→emit latency (seconds) across the report's sessions,
    /// from a log-scale histogram (`LatencyHistogram`, ~15% bucket
    /// resolution, quantiles reported as bucket lower bounds — never
    /// exaggerated). Note this is *end-to-end* session latency (queueing
    /// + lane wait + compute), unlike `mean_latency_s`, which is the
    /// per-frame compute/modeled latency; 0.0 on paths without session
    /// accounting.
    pub p99_latency_s: f64,
    pub wall_fps: f64,
    /// Mean per-frame latency: modeled accelerator latency under the `sim`
    /// backend, host wall-clock otherwise (lane wait included on the
    /// batched path — see `StageMetrics::frame_latency_mean_s`).
    pub mean_latency_s: f64,
    /// **Total** modeled queueing time (s) summed over the report's
    /// frames: the waiting share charged by the discrete-event co-sim when
    /// a queueing plan is armed on the `sim` backend (`--cores` /
    /// `--arrival-fps`); 0.0 otherwise. A sum rather than a mean so the
    /// server-wide aggregate is exactly the sum of the per-session
    /// figures.
    pub modeled_queueing_s: f64,
    pub mean_energy_j: f64,
    pub modeled_kfps_per_watt: f64,
    pub mean_kept_patches: f64,
    /// Mean micro-batch size frames were executed in (1.0 when batching
    /// is off).
    pub mean_batch: f64,
    /// Mean IoU of the MGNet mask vs. the sensor ground truth.
    pub mean_mask_iou: f64,
    /// Top-1 agreement with the synthetic class labels (meaningful only
    /// when the backbone weights are trained).
    pub top1_accuracy: f64,
    /// Worker pipelines that served the run (1 for the single-threaded
    /// [`serve`] path).
    pub workers: usize,
    /// Per-worker utilization breakdown.
    pub per_worker: Vec<WorkerStats>,
}

impl ServeReport {
    /// Fraction of fp32-probed frames at `tier` whose argmax agreed with
    /// the electronic reference, or `None` when the tier ran no probes.
    pub fn tier_agreement(&self, tier: PrecisionTier) -> Option<f64> {
        // lint-allow(panic): `PrecisionTier::index()` < 3 by construction —
        // the counter arrays are sized to the tier set.
        let i = tier.index();
        if self.tier_ref_frames[i] == 0 {
            None
        } else {
            Some(self.tier_agree[i] as f64 / self.tier_ref_frames[i] as f64)
        }
    }
}

/// Knobs of a serving run — shared by the streaming [`serve`] and the
/// sharded `serve_sharded`.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Sensor RNG seed.
    pub sensor_seed: u64,
    /// Moving objects in the synthetic scene.
    pub num_objects: usize,
    /// Frames to serve before the stream ends.
    pub num_frames: u64,
    /// Bounded sensor-queue depth (backpressure point).
    pub queue_depth: usize,
    /// Bucket-major micro-batching policy (default: per-frame).
    pub batch: BatchPolicy,
    /// Reassembly window: max results buffered out of order before the
    /// oldest lane is force-flushed so the head of the stream can emit.
    /// Bounds stream memory on unbounded runs.
    pub window: usize,
    /// Best-effort worker-thread core pinning
    /// (`coordinator::affinity::pin_current_thread`). Honored by the
    /// sharded `serve_sharded` path; the in-thread [`serve`] path has no
    /// worker threads to pin and ignores it.
    pub pin_workers: bool,
    /// Precision policy stamped onto every frame the stream serves: one
    /// fixed tier, or ROI-driven [`PrecisionPolicy::Auto`].
    pub precision: PrecisionPolicy,
}

impl ServeOptions {
    /// Defaults matching the pre-streaming `serve` behaviour: seed 42,
    /// 2 objects, queue depth 4, per-frame batching.
    pub fn frames(num_frames: u64) -> Self {
        ServeOptions {
            sensor_seed: 42,
            num_objects: 2,
            num_frames,
            queue_depth: 4,
            batch: BatchPolicy::per_frame(),
            window: 64,
            pin_workers: false,
            precision: PrecisionPolicy::default(),
        }
    }
}

/// A routed frame waiting in a stream lane, tagged with its emission
/// sequence number and its front-half quality scores.
struct StreamItem {
    seq: u64,
    iou: f64,
    rf: RoutedFrame,
}

/// A completed frame waiting for in-order emission.
struct PendingResult {
    result: FrameResult,
    iou: f64,
    correct: bool,
}

/// How long the stream waits on an idle sensor queue before concluding
/// the producer is gone (matches the pre-streaming `serve` timeout).
const SENSOR_IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// The streaming serve surface: an `Iterator` of in-order
/// [`FrameResult`]s over a live sensor thread.
///
/// Internally the stream routes each arriving frame (front half on the
/// pipeline), parks it in a bucket-major [`MicroBatcher`] lane, and
/// completes flushed lanes with one batched backend call each. Because
/// lanes flush independently, results complete out of arrival order; a
/// reassembly buffer re-orders them and is **bounded** by
/// [`ServeOptions::window`] — when the buffer plus the lanes reach the
/// window, the longest-waiting lane is force-flushed, so an unbounded run
/// can never accumulate unbounded state.
///
/// Dropping the stream stops and joins the sensor thread. After the
/// stream is drained (or to drain-and-summarize in one call), derive the
/// run summary with [`FrameStream::finish`] / [`FrameStream::report`].
pub struct FrameStream<'p, B: Backend> {
    pipeline: &'p mut Pipeline<B>,
    rx: Receiver<Frame>,
    sensor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    rejected: Arc<AtomicU64>,
    batcher: MicroBatcher<StreamItem>,
    window: usize,
    /// Frames still wanted from the sensor (shrinks if the sensor dies).
    target: u64,
    /// Frames routed into lanes so far (also the next sequence number).
    routed: u64,
    /// Frames handed to the caller so far.
    emitted: u64,
    next_emit: u64,
    pending: BTreeMap<u64, PendingResult>,
    iou_sum: f64,
    correct: u64,
    /// Per-tier frame counters, indexed by [`PrecisionTier::index`],
    /// accumulated at emission (like `iou_sum`/`correct`).
    tier_frames: [u64; 3],
    tier_ref_frames: [u64; 3],
    tier_agree: [u64; 3],
    /// Precision policy stamped onto every sensor frame before routing.
    precision: PrecisionPolicy,
    failed: bool,
    patch_px: usize,
}

impl<'p, B: Backend> FrameStream<'p, B> {
    fn new(pipeline: &'p mut Pipeline<B>, opts: &ServeOptions) -> Result<Self> {
        let size = pipeline.cfg.image_size;
        // Warm up before the sensor exists: compile time can neither
        // inflate the rejection count nor leak a sensor thread on warmup
        // failure.
        pipeline.warmup()?;

        let (queue, rx) = FrameQueue::bounded(opts.queue_depth.max(1));
        // Count actual enqueue rejections in the sensor thread: frames
        // still sitting in the queue at stop time were never dropped.
        let rejected = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        // Consumer is already warm, so the sensor starts producing at once.
        let go = Arc::new(AtomicBool::new(true));
        let (rejected_t, stop_t, go_t) = (rejected.clone(), stop.clone(), go.clone());
        let (num_objects, sensor_seed) = (opts.num_objects, opts.sensor_seed);
        let sensor_clock = pipeline.clock.clone();
        let sensor = std::thread::spawn(move || {
            super::batcher::sensor_loop(
                queue,
                size,
                num_objects,
                sensor_seed,
                &sensor_clock,
                &go_t,
                &stop_t,
                &rejected_t,
            )
        });

        let t_run = pipeline.clock.now();
        pipeline.metrics.start_run_at(t_run);
        let patch_px = pipeline.vit_cfg.patch_size;
        let batcher = MicroBatcher::new(pipeline.router.buckets(), opts.batch);
        Ok(FrameStream {
            pipeline,
            rx,
            sensor: Some(sensor),
            stop,
            rejected,
            batcher,
            window: opts.window.max(1),
            target: opts.num_frames,
            routed: 0,
            emitted: 0,
            next_emit: 0,
            pending: BTreeMap::new(),
            iou_sum: 0.0,
            correct: 0,
            tier_frames: [0; 3],
            tier_ref_frames: [0; 3],
            tier_agree: [0; 3],
            precision: opts.precision,
            failed: false,
            patch_px,
        })
    }

    /// Stop the sensor thread and join it (idempotent).
    fn shutdown(&mut self) {
        // relaxed-ok: standalone stop latch; the join below is the
        // happens-before edge for everything the sensor wrote.
        self.stop.store(true, Ordering::Relaxed);
        // Drain leftovers so the producer side quiesces, then join.
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.sensor.take() {
            h.join().ok();
        }
    }

    /// Complete one flushed lane group and park its results for in-order
    /// emission.
    fn complete(&mut self, group: Vec<StreamItem>) -> Result<()> {
        let mut meta = Vec::with_capacity(group.len());
        let mut rfs = Vec::with_capacity(group.len());
        for item in group {
            meta.push((item.seq, item.iou, item.rf.label));
            rfs.push(item.rf);
        }
        let results = self.pipeline.complete_batch(rfs)?;
        for ((seq, iou, label), result) in meta.into_iter().zip(results) {
            let correct = result.predicted_class() == label;
            self.pending.insert(seq, PendingResult { result, iou, correct });
        }
        Ok(())
    }

    /// One step of forward progress: flush a matured lane, enforce the
    /// reassembly window, drain lanes at end of input, or route the next
    /// sensor frame.
    fn advance(&mut self) -> Result<()> {
        let now = self.pipeline.clock.now();
        // 1. Deadline flushes come first: a lane past `max_wait` must not
        //    wait behind new arrivals.
        if let Some((_bucket, group)) = self.batcher.poll(now) {
            return self.complete(group);
        }
        // 2. Bounded reassembly window: when buffered results + laned
        //    frames reach the window, force the longest-waiting lane out
        //    so the head of the stream can make progress.
        if self.pending.len() + self.batcher.pending() >= self.window {
            if let Some((_bucket, group)) = self.batcher.flush_oldest() {
                return self.complete(group);
            }
        }
        // 3. End of input: drain remaining lanes.
        if self.routed >= self.target {
            if let Some((_bucket, group)) = self.batcher.flush_oldest() {
                return self.complete(group);
            }
            // Every routed frame is laned, pending, or emitted, and the
            // caller only reaches here wanting more — so an empty batcher
            // here means results were lost. Fail loudly rather than spin.
            anyhow::bail!(
                "frame stream stalled: {} of {} frames emitted with no work in flight",
                self.emitted,
                self.target
            );
        }
        // 4. Route the next frame, waiting no longer than the earliest
        //    lane deadline.
        let timeout = self
            .batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(now).max(Duration::from_micros(50)))
            .unwrap_or(SENSOR_IDLE_TIMEOUT)
            .min(SENSOR_IDLE_TIMEOUT);
        match recv_frame(&self.rx, timeout) {
            Some(mut frame) => {
                // The synthetic sensor stamps the default policy; the
                // stream's tenant-level policy overrides it here, before
                // routing resolves `Auto` against the frame's ROI mask.
                frame.precision = self.precision;
                let gt = frame.gt_mask(self.patch_px);
                // Degenerate per-frame policy (the default): keep the
                // allocation-free `process_frame` fast path — every push
                // would flush a singleton lane anyway, and `RoutedFrame`
                // would copy the staged bucket tensors for nothing.
                if self.batcher.policy().max_batch <= 1 {
                    let result = self.pipeline.process_frame(&frame)?;
                    let iou = result.mask.iou(&gt);
                    let correct = result.predicted_class() == frame.label;
                    self.pending.insert(self.routed, PendingResult { result, iou, correct });
                    self.routed += 1;
                    if self.routed >= self.target {
                        // relaxed-ok: standalone stop latch (see shutdown).
                        self.stop.store(true, Ordering::Relaxed);
                    }
                    return Ok(());
                }
                let rf = self.pipeline.route_frame(&frame)?;
                let iou = rf.mask.iou(&gt);
                let (bucket, tier) = (rf.bucket, rf.tier);
                let item = StreamItem { seq: self.routed, iou, rf };
                self.routed += 1;
                if self.routed >= self.target {
                    // The sensor has nothing left to contribute; stop it
                    // now so tail rejections don't pile up while the last
                    // lanes drain.
                    // relaxed-ok: standalone stop latch (see shutdown).
                    self.stop.store(true, Ordering::Relaxed);
                }
                if let Some((_bucket, group)) =
                    self.batcher.push_tiered(bucket, tier, item, self.pipeline.clock.now())
                {
                    return self.complete(group);
                }
                Ok(())
            }
            None => {
                // Timeout. With lanes pending this is just the deadline
                // bounding the wait; with an idle batcher after a full
                // quiet period, the producer is gone — end the stream at
                // what we have (the pre-streaming `serve` did the same).
                if self.batcher.is_empty() && timeout >= SENSOR_IDLE_TIMEOUT {
                    self.target = self.routed;
                }
                Ok(())
            }
        }
    }

    fn next_result(&mut self) -> Option<Result<FrameResult>> {
        loop {
            if let Some(p) = self.pending.remove(&self.next_emit) {
                self.next_emit += 1;
                self.emitted += 1;
                self.iou_sum += p.iou;
                self.correct += p.correct as u64;
                // lint-allow(panic): `PrecisionTier::index()` < 3 by
                // construction — the counter arrays are sized to the tier
                // set.
                let ti = p.result.tier.index();
                self.tier_frames[ti] += 1;
                if let Some(agree) = p.result.fp32_agreement {
                    self.tier_ref_frames[ti] += 1;
                    self.tier_agree[ti] += agree as u64;
                }
                return Some(Ok(p.result));
            }
            if self.failed {
                return None;
            }
            if self.emitted >= self.target {
                self.shutdown();
                return None;
            }
            if let Err(e) = self.advance() {
                self.failed = true;
                self.shutdown();
                return Some(Err(e));
            }
        }
    }

    /// Results buffered out of order right now (always `< window` plus
    /// the group that completed last).
    pub fn buffered(&self) -> usize {
        self.pending.len()
    }

    /// Snapshot of the run summary so far — after the stream is drained
    /// this is the full [`ServeReport`] the pre-streaming `serve`
    /// returned.
    pub fn report(&self) -> ServeReport {
        let m = &self.pipeline.metrics;
        let now = self.pipeline.clock.now();
        let busy_s = m.stage_sum_s("total");
        let elapsed_s = m.run_elapsed_s_at(now);
        let done = self.emitted;
        ServeReport {
            backend: self.pipeline.backend_name().to_string(),
            frames: done,
            // relaxed-ok: monotonic counter snapshot for reporting; the
            // final authoritative read happens after the sensor join.
            dropped: self.rejected.load(Ordering::Relaxed),
            // The in-thread path has no sessions, hence no quota, SLO, or
            // health-routing accounting (see the field docs).
            dropped_quota: 0,
            dropped_shed: 0,
            slo_miss: 0,
            accuracy_at_risk: 0,
            tier_frames: self.tier_frames,
            tier_ref_frames: self.tier_ref_frames,
            tier_agree: self.tier_agree,
            p99_latency_s: 0.0,
            wall_fps: m.wall_fps_at(now),
            mean_latency_s: m.frame_latency_mean_s(),
            modeled_queueing_s: m.stage_sum_s("modeled_queueing"),
            mean_energy_j: m.mean_energy_j(),
            modeled_kfps_per_watt: m.modeled_kfps_per_watt(),
            mean_kept_patches: m.mean_kept_patches(),
            mean_batch: m.mean_batch(),
            mean_mask_iou: if done > 0 { self.iou_sum / done as f64 } else { 0.0 },
            top1_accuracy: if done > 0 { self.correct as f64 / done as f64 } else { 0.0 },
            workers: 1,
            per_worker: vec![WorkerStats {
                worker: 0,
                frames: done,
                busy_s,
                queueing_s: m.stage_mean_s("modeled_queueing"),
                utilization: if elapsed_s > 0.0 { (busy_s / elapsed_s).min(1.0) } else { 0.0 },
                core: None,
                health: 1.0,
                recals: 0,
                at_risk_frames: 0,
                queue_depth: 0,
                retired: false,
            }],
        }
    }

    /// Drain the rest of the stream (propagating any serving error) and
    /// derive the terminal [`ServeReport`] from it.
    pub fn finish(mut self) -> Result<ServeReport> {
        while let Some(r) = self.next_result() {
            r?;
        }
        Ok(self.report())
    }
}

impl<B: Backend> Iterator for FrameStream<'_, B> {
    type Item = Result<FrameResult>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_result()
    }
}

impl<B: Backend> Drop for FrameStream<'_, B> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Drive a pipeline from a live sensor thread and return the result
/// **stream**: an iterator of in-order [`FrameResult`]s with a bounded
/// reassembly window (see [`FrameStream`]). The sensor produces frames as
/// fast as the queue accepts them; a full queue drops frames (real
/// near-sensor backpressure). Derive the terminal summary with
/// [`FrameStream::finish`]:
///
/// ```ignore
/// let report = serve(&mut pipeline, &ServeOptions::frames(100))?.finish()?;
/// ```
///
/// **Wrapper status.** `serve` is the *in-thread degenerate case* of the
/// session-oriented serving surface ([`crate::coordinator::server::Server`]):
/// one synthetic-sensor tenant, one pipeline, no worker threads — the same
/// MicroBatcher lanes and bounded-window reassembly, driven inline because
/// the caller owns the (non-`Send`) backend. Multi-worker and multi-tenant
/// serving go through `Server` (of which `serve_sharded` is the one-session
/// wrapper); both surfaces produce the same [`ServeReport`] shape.
pub fn serve<'p, B: Backend>(
    pipeline: &'p mut Pipeline<B>,
    opts: &ServeOptions,
) -> Result<FrameStream<'p, B>> {
    FrameStream::new(pipeline, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{HostBackend, HostConfig};
    use crate::sensor::VideoSource;

    fn host() -> HostBackend {
        HostBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() })
    }

    #[test]
    fn config_artifact_names() {
        let c = PipelineConfig::tiny_96();
        assert_eq!(c.mgnet_artifact(), "mgnet_96");
        assert_eq!(c.backbone_artifact(36), "vit_tiny_96_n36");
    }

    #[test]
    fn validate_rejects_empty_buckets() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("no buckets"), "{err}");
        assert!(Pipeline::with_backend(c, host()).is_err());
    }

    #[test]
    fn validate_rejects_unsorted_buckets() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![18, 9, 36];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("ascending"), "{err}");
        // Duplicates are a ladder bug too, not a silent dedup.
        c.buckets = vec![9, 9, 36];
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_missing_full_bucket() {
        let mut c = PipelineConfig::tiny_96();
        c.buckets = vec![9, 18]; // missing 36
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("full patch count"), "{err}");
        assert!(Pipeline::with_backend(c, host()).is_err());
    }

    #[test]
    fn validate_accepts_the_default_ladder() {
        assert!(PipelineConfig::tiny_96().validate().is_ok());
    }

    #[test]
    fn pipeline_reports_its_backend() {
        let p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        assert_eq!(p.backend_name(), "host");
        assert!(!p.backend().needs_artifacts());
    }

    #[test]
    fn frame_result_argmax() {
        let r = FrameResult {
            frame_index: 0,
            logits: vec![0.1, 0.9, 0.3],
            mask: PatchMask::full(6),
            bucket: 36,
            modeled_energy_j: 1e-5,
            latency_s: 0.01,
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: PrecisionTier::Int8,
            fp32_agreement: None,
        };
        assert_eq!(r.predicted_class(), 1);
    }

    #[test]
    fn frame_result_argmax_survives_nan() {
        let r = FrameResult {
            frame_index: 0,
            logits: vec![f32::NAN, 0.9, 0.3],
            mask: PatchMask::full(6),
            bucket: 36,
            modeled_energy_j: 1e-5,
            latency_s: 0.01,
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: PrecisionTier::Int8,
            fp32_agreement: None,
        };
        // Must not panic; any in-range index is acceptable.
        assert!(r.predicted_class() < 3);
    }

    #[test]
    fn route_then_complete_matches_process_frame() {
        let mut src = VideoSource::new(96, 2, 42);
        let frame = src.next_frame();
        let mut direct_p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let mut split_p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let direct = direct_p.process_frame(&frame).unwrap();
        let rf = split_p.route_frame(&frame).unwrap();
        assert_eq!(rf.bucket, direct.bucket);
        assert_eq!(rf.frame_index, direct.frame_index);
        let batched = split_p.complete_batch(vec![rf]).unwrap();
        assert_eq!(batched.len(), 1);
        assert_eq!(batched[0].logits, direct.logits, "split-phase must match the fast path");
        assert_eq!(batched[0].mask, direct.mask);
        assert_eq!(batched[0].modeled_energy_j, direct.modeled_energy_j);
    }

    #[test]
    fn same_bucket_batch_amortizes_energy() {
        let mut src = VideoSource::new(96, 2, 42);
        let frame = src.next_frame();
        let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let a = p.route_frame(&frame).unwrap();
        let b = p.route_frame(&frame).unwrap();
        let rs = p.complete_batch(vec![a, b]).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].logits, rs[1].logits, "same frame must give identical logits");
        assert!(
            rs[1].modeled_energy_j < rs[0].modeled_energy_j,
            "the follower frame must amortize weight-programming energy \
             ({} !< {})",
            rs[1].modeled_energy_j,
            rs[0].modeled_energy_j
        );
        assert!(rs[1].modeled_energy_j > 0.0);
        assert!((p.metrics.mean_batch() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn follower_energy_discount_is_strict_but_bounded() {
        let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        for kept in [1usize, 12, 36] {
            let first = p.modeled_energy_j(kept, true, PrecisionTier::Int8);
            let follow = p.modeled_energy_j(kept, false, PrecisionTier::Int8);
            assert!(follow > 0.0, "kept {kept}: follower energy must stay positive");
            assert!(follow < first, "kept {kept}: follower must model less energy");
        }
        let mut cfg = PipelineConfig::tiny_96();
        cfg.use_mask = false;
        let mut pf = Pipeline::with_backend(cfg, host()).unwrap();
        let first = pf.modeled_energy_j(36, true, PrecisionTier::Int8);
        let follow = pf.modeled_energy_j(36, false, PrecisionTier::Int8);
        assert!(follow > 0.0 && follow < first, "unmasked runs amortize too");
    }

    #[test]
    fn complete_batch_rejects_mixed_and_empty_groups() {
        let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        assert!(p.complete_batch(Vec::new()).is_err(), "empty group");
        let dummy = |bucket: usize| RoutedFrame {
            frame_index: 0,
            label: 0,
            bucket,
            kept_count: 1,
            tier: PrecisionTier::Int8,
            mask: PatchMask::full(6),
            patches: vec![0.0; bucket * 768],
            pos_idx: vec![0.0; bucket],
            valid: vec![0.0; bucket],
            front_s: 0.0,
            staged_at: Instant::now(),
        };
        let err = p.complete_batch(vec![dummy(9), dummy(18)]).unwrap_err();
        assert!(err.to_string().contains("single-bucket"), "{err}");
    }

    #[test]
    fn process_batch_preserves_input_order() {
        let mut src = VideoSource::new(96, 2, 21);
        let frames: Vec<_> = (0..5).map(|_| src.next_frame()).collect();
        let mut batch_p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let mut seq_p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let batched = batch_p.process_batch(&frames).unwrap();
        assert_eq!(batched.len(), frames.len());
        for (frame, r) in frames.iter().zip(&batched) {
            assert_eq!(r.frame_index, frame.index, "results must come back in input order");
            let direct = seq_p.process_frame(frame).unwrap();
            assert_eq!(r.logits, direct.logits, "bucket-major grouping must not change numerics");
            assert_eq!(r.bucket, direct.bucket);
        }
    }

    #[test]
    fn scratch_patchify_matches_frame_patchify() {
        let mut src = VideoSource::new(96, 2, 42);
        let frame = src.next_frame();
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        assert_eq!(scratch.patches(), frame.patchify(16).as_slice());
    }

    #[test]
    fn scratch_route_stages_kept_patches() {
        let mut src = VideoSource::new(96, 1, 13);
        let frame = src.next_frame();
        let router = BucketRouter::even(36, 4);
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        // Score patches from ground truth: kept patches get +2, rest -2.
        let gt = frame.gt_mask(16);
        let scores: Vec<f32> = gt.keep.iter().map(|&k| if k { 2.0 } else { -2.0 }).collect();
        scratch.stage_mask(6, &scores, 0.5);
        let bucket = scratch.stage_route(&router, 768);
        assert_eq!(scratch.mask(), &gt);
        assert_eq!(scratch.kept(), gt.kept_indices().as_slice());
        assert_eq!(bucket, router.route(gt.kept()));
        // Each staged slot holds the right patch; padding slots are zero.
        let patches = frame.patchify(16);
        let staged = scratch.bucket_patches(bucket, 768);
        for (slot, &pidx) in scratch.kept().iter().enumerate() {
            let want = &patches[pidx * 768..(pidx + 1) * 768];
            assert_eq!(&staged[slot * 768..(slot + 1) * 768], want);
            assert_eq!(scratch.pos_idx(bucket)[slot], pidx as f32);
            assert_eq!(scratch.valid(bucket)[slot], 1.0);
        }
        for slot in scratch.kept().len()..bucket {
            assert!(staged[slot * 768..(slot + 1) * 768].iter().all(|&x| x == 0.0));
            assert_eq!(scratch.valid(bucket)[slot], 0.0);
        }
    }

    #[test]
    fn scratch_route_empty_mask_keeps_best_patch() {
        let mut src = VideoSource::new(96, 1, 7);
        let frame = src.next_frame();
        let router = BucketRouter::even(36, 4);
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        let mut scores = vec![-5.0f32; 36];
        scores[17] = -1.0; // still below threshold, but the best
        scratch.stage_mask(6, &scores, 0.5);
        assert_eq!(scratch.mask().kept(), 0);
        let bucket = scratch.stage_route(&router, 768);
        assert_eq!(scratch.kept(), &[17]);
        assert_eq!(bucket, 9);
    }

    #[test]
    fn auto_policy_resolves_tier_from_roi_density() {
        let auto_frame = || {
            let mut src = VideoSource::new(96, 2, 42);
            let mut f = src.next_frame();
            f.precision = PrecisionPolicy::Auto;
            f
        };
        // t_reg = 0.0: sigmoid scores always clear the threshold → every
        // patch kept → importance-heavy → INT8.
        let mut cfg = PipelineConfig::tiny_96();
        cfg.region_threshold = 0.0;
        let mut p = Pipeline::with_backend(cfg, host()).unwrap();
        let r = p.process_frame(&auto_frame()).unwrap();
        assert_eq!(r.tier, PrecisionTier::Int8);
        assert_eq!(r.fp32_agreement, None, "the agreement probe is off by default");
        // t_reg = 1.0: sigmoid never reaches it → empty mask → best-patch
        // fallback keeps 1/36 → background-heavy → INT4.
        let mut cfg = PipelineConfig::tiny_96();
        cfg.region_threshold = 1.0;
        let mut p = Pipeline::with_backend(cfg, host()).unwrap();
        let r = p.process_frame(&auto_frame()).unwrap();
        assert_eq!(r.tier, PrecisionTier::Int4);
        // Unmasked baselines carry no ROI signal: Auto degrades to INT8.
        let mut cfg = PipelineConfig::tiny_96();
        cfg.use_mask = false;
        let mut p = Pipeline::with_backend(cfg, host()).unwrap();
        let r = p.process_frame(&auto_frame()).unwrap();
        assert_eq!(r.tier, PrecisionTier::Int8);
    }

    #[test]
    fn fixed_tiers_order_modeled_energy() {
        let mut energy = Vec::new();
        for tier in PrecisionTier::ALL {
            let mut src = VideoSource::new(96, 2, 42);
            let mut frame = src.next_frame();
            frame.precision = PrecisionPolicy::Fixed(tier);
            let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
            let r = p.process_frame(&frame).unwrap();
            assert_eq!(r.tier, tier);
            energy.push(r.modeled_energy_j);
        }
        assert!(energy[0] < energy[1], "int4 must model less energy than int8");
        assert!(energy[1] < energy[2], "the fp32 reference is the most expensive tier");
    }

    #[test]
    fn fp32_reference_probe_scores_agreement_without_energy_charge() {
        let frame_at = |tier| {
            let mut src = VideoSource::new(96, 2, 42);
            let mut f = src.next_frame();
            f.precision = PrecisionPolicy::Fixed(tier);
            f
        };
        let mut cfg = PipelineConfig::tiny_96();
        cfg.fp32_reference = true;
        let mut probed = Pipeline::with_backend(cfg, host()).unwrap();
        let mut plain = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let frame = frame_at(PrecisionTier::Int4);
        let r = probed.process_frame(&frame).unwrap();
        assert!(r.fp32_agreement.is_some(), "probe must score agreement on the per-frame path");
        let r_plain = plain.process_frame(&frame).unwrap();
        assert_eq!(r_plain.fp32_agreement, None);
        assert_eq!(
            r.modeled_energy_j, r_plain.modeled_energy_j,
            "the fp32 probe is a measurement instrument — its energy is never charged"
        );
        assert_eq!(r.logits, r_plain.logits);
        // The batched path carries the probe too.
        let a = probed.route_frame(&frame).unwrap();
        let b = probed.route_frame(&frame).unwrap();
        let rs = probed.complete_batch(vec![a, b]).unwrap();
        assert!(rs.iter().all(|r| r.fp32_agreement.is_some()));
        // An fp32-tier frame needs no probe against itself.
        let r = probed.process_frame(&frame_at(PrecisionTier::Fp32)).unwrap();
        assert_eq!(r.fp32_agreement, None);
    }

    #[test]
    fn complete_batch_rejects_mixed_tier_groups() {
        let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let mut src = VideoSource::new(96, 2, 42);
        let mut frame = src.next_frame();
        frame.precision = PrecisionPolicy::Fixed(PrecisionTier::Int8);
        let a = p.route_frame(&frame).unwrap();
        frame.precision = PrecisionPolicy::Fixed(PrecisionTier::Int4);
        let b = p.route_frame(&frame).unwrap();
        assert_eq!(a.bucket, b.bucket, "same frame, same bucket — only the tier differs");
        let err = p.complete_batch(vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("single-tier"), "{err}");
    }

    #[test]
    fn process_batch_groups_by_bucket_and_tier() {
        let mut src = VideoSource::new(96, 2, 21);
        let mut frames: Vec<_> = (0..4).map(|_| src.next_frame()).collect();
        frames[1].precision = PrecisionPolicy::Fixed(PrecisionTier::Int4);
        frames[3].precision = PrecisionPolicy::Fixed(PrecisionTier::Int4);
        let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), host()).unwrap();
        let rs = p.process_batch(&frames).unwrap();
        assert_eq!(rs.len(), frames.len());
        for (f, r) in frames.iter().zip(&rs) {
            assert_eq!(r.frame_index, f.index, "results must come back in input order");
            let want = match f.precision {
                PrecisionPolicy::Fixed(t) => t,
                PrecisionPolicy::Auto => unreachable!("test uses fixed policies only"),
            };
            assert_eq!(r.tier, want);
            // Groups are tier-pure, so a frame's reported batch size counts
            // exactly its same-(bucket, tier) peers.
            let peers = frames
                .iter()
                .zip(&rs)
                .filter(|(pf, pr)| pr.bucket == r.bucket && pf.precision == f.precision)
                .count();
            assert_eq!(r.batch_size, peers);
        }
    }

    #[test]
    fn scratch_route_truncates_to_clamped_bucket() {
        // Router whose largest bucket is below the full patch count: an
        // over-full mask must keep the top-score patches, in grid order.
        let mut src = VideoSource::new(96, 2, 21);
        let frame = src.next_frame();
        let router = BucketRouter::new(vec![9, 18]);
        let mut scratch = FrameScratch::new(36, 768, 36);
        scratch.stage_patchify(&frame, 16);
        let scores: Vec<f32> = (0..36).map(|i| i as f32).collect();
        scratch.stage_mask(6, &scores, 0.5); // sigmoid(i) > 0.5 for i >= 1
        assert!(scratch.mask().kept() > 18);
        let bucket = scratch.stage_route(&router, 768);
        assert_eq!(bucket, 18);
        // Top-18 scores are patches 18..36, re-sorted into grid order.
        let expect: Vec<usize> = (18..36).collect();
        assert_eq!(scratch.kept(), expect.as_slice());
    }
}
