//! The serving stack's time seam: a pluggable [`Clock`] with a
//! production [`Clock::system`] variant and a step-controlled
//! [`ManualClock`] for deterministic tests.
//!
//! Every time-dependent serving behaviour — micro-batch lane deadlines,
//! SLO deadlines and miss accounting, admission token buckets, stall and
//! warmup timeouts — reads time through a `Clock` instead of calling
//! `Instant::now()` directly, and every wait goes through a clock-aware
//! [`Event`] instead of `thread::sleep` polling. Under the system clock
//! this is zero-cost (an enum match around `Instant::now()`, no
//! allocation, no dyn dispatch — the frame hot path stays within its
//! allocation budget); under a manual clock, time moves **only** when the
//! test calls [`ManualClock::advance`], which makes deadline flushes,
//! SLO misses, and rate quotas provable with exact expectations
//! (`rust/tests/qos.rs`) instead of wall-clock luck.
//!
//! Design notes:
//!
//! - Manual time is anchored at a real `Instant` captured at clock
//!   creation (`now() = anchor + offset`), so manual timestamps
//!   interoperate with every `Instant`-typed field in the stack — no
//!   parallel time type to thread through.
//! - [`Event`] is a generation-counted condvar: readers snapshot
//!   [`Event::generation`], re-check their predicate, then wait; any
//!   [`Event::notify`] (or, under a manual clock, any `advance`) wakes
//!   them. Waits take **absolute** deadlines ([`Event::wait_until`]) so a
//!   clock step between computing a deadline and entering the wait can
//!   never stretch the wait past it.
//! - Events created from a manual clock share the clock's condvar, so a
//!   single `advance` wakes every deadline-waiting thread in the server
//!   at once — exactly the "step the world" semantics a deterministic
//!   test wants.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Cap applied to wait timeouts before adding them to an `Instant`, so a
/// caller-provided "practically forever" duration can never overflow
/// `Instant` arithmetic.
const MAX_WAIT: Duration = Duration::from_secs(60 * 60 * 24 * 365);

/// Defensive real-time re-check period for manual-clock waits: waiters are
/// woken by `advance`/`notify` broadcasts, but re-check their predicate on
/// this cadence anyway so a test bug degrades to a slow loop, not a hang
/// with no stack worth reading.
const MANUAL_RECHECK: Duration = Duration::from_millis(50);

/// Shared state of a manual timeline.
struct ManualInner {
    /// Real instant the manual timeline is anchored at; manual `now()` is
    /// `anchor + offset`, so manual times interoperate with `Instant`.
    anchor: Instant,
    /// Time elapsed on the manual timeline (advanced explicitly).
    offset: Mutex<Duration>,
    /// Broadcast on every [`ManualClock::advance`] **and** every
    /// [`Event::notify`] of an event created from this clock.
    cv: Condvar,
}

fn recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A monotonic time source the serving stack reads instead of calling
/// `Instant::now()` directly. Cloning is cheap (unit or `Arc` bump); the
/// system variant adds no allocation and no dyn dispatch to any path.
#[derive(Clone)]
pub struct Clock {
    inner: ClockInner,
}

#[derive(Clone)]
enum ClockInner {
    /// Production clock: `Instant::now()` / `thread::sleep`.
    System,
    /// Test clock: time is frozen until [`ManualClock::advance`] moves it.
    Manual(Arc<ManualInner>),
}

impl fmt::Debug for Clock {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.inner {
            ClockInner::System => write!(f, "SystemClock"),
            ClockInner::Manual(m) => {
                write!(f, "ManualClock(+{:?})", *recover(&m.offset))
            }
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::system()
    }
}

impl Clock {
    /// The production wall clock.
    pub fn system() -> Clock {
        Clock { inner: ClockInner::System }
    }

    /// A frozen, step-controlled timeline: returns the clock (thread it
    /// through the serving config) and the [`ManualClock`] handle the test
    /// advances it with.
    pub fn manual() -> (Clock, ManualClock) {
        let inner = Arc::new(ManualInner {
            anchor: Instant::now(),
            offset: Mutex::new(Duration::ZERO),
            cv: Condvar::new(),
        });
        (Clock { inner: ClockInner::Manual(inner.clone()) }, ManualClock { inner })
    }

    /// Whether this is a step-controlled test clock.
    pub fn is_manual(&self) -> bool {
        matches!(self.inner, ClockInner::Manual(_))
    }

    /// Current time on this clock's timeline (monotonic).
    pub fn now(&self) -> Instant {
        match &self.inner {
            ClockInner::System => Instant::now(),
            ClockInner::Manual(m) => m.anchor + *recover(&m.offset),
        }
    }

    /// Seconds elapsed on this clock since `earlier` (0 if `earlier` is in
    /// the future — manual clocks never run backwards, but callers may
    /// race an advance).
    pub fn seconds_since(&self, earlier: Instant) -> f64 {
        self.now().saturating_duration_since(earlier).as_secs_f64()
    }

    /// Sleep `d` on this clock's timeline: a real `thread::sleep` under
    /// the system clock; under a manual clock, block until `advance` has
    /// moved `now()` past the target.
    pub fn sleep(&self, d: Duration) {
        match &self.inner {
            ClockInner::System => std::thread::sleep(d),
            ClockInner::Manual(m) => {
                let deadline = self.now() + d.min(MAX_WAIT);
                let mut off = recover(&m.offset);
                while m.anchor + *off < deadline {
                    let (g, _timeout) = m
                        .cv
                        .wait_timeout(off, MANUAL_RECHECK)
                        .unwrap_or_else(PoisonError::into_inner);
                    off = g;
                }
            }
        }
    }

    /// A wait/notify cell bound to this clock's timeline (see [`Event`]).
    pub fn event(&self) -> Event {
        let kind = match &self.inner {
            ClockInner::System => {
                EventKind::System { lock: Mutex::new(()), cv: Condvar::new() }
            }
            ClockInner::Manual(m) => EventKind::Manual(m.clone()),
        };
        Event { gen: AtomicU64::new(0), kind }
    }
}

/// Step controller for a [`Clock::manual`] timeline. Cloneable; advancing
/// wakes every thread blocked in a clock [`Event`] wait or `sleep`.
#[derive(Clone)]
pub struct ManualClock {
    inner: Arc<ManualInner>,
}

impl ManualClock {
    /// The `Clock` view of this timeline (same as the one returned by
    /// [`Clock::manual`]).
    pub fn clock(&self) -> Clock {
        Clock { inner: ClockInner::Manual(self.inner.clone()) }
    }

    /// Current manual time.
    pub fn now(&self) -> Instant {
        self.inner.anchor + *recover(&self.inner.offset)
    }

    /// Move the timeline forward by `d` in **one atomic jump** (waiters
    /// never observe intermediate times) and wake every clock waiter.
    pub fn advance(&self, d: Duration) {
        {
            let mut off = recover(&self.inner.offset);
            *off = off.saturating_add(d);
        }
        self.inner.cv.notify_all();
    }

    /// Total time advanced so far.
    pub fn elapsed(&self) -> Duration {
        *recover(&self.inner.offset)
    }
}

enum EventKind {
    System { lock: Mutex<()>, cv: Condvar },
    /// Shares the manual clock's mutex/condvar so `advance` wakes waiters.
    Manual(Arc<ManualInner>),
}

/// A generation-counted wait/notify cell on a [`Clock`] timeline — the
/// primitive that replaced the serving stack's `thread::sleep` polling
/// loops.
///
/// Race-free usage pattern (the generation snapshot must come **before**
/// the predicate re-check, so a notify between check and wait returns
/// immediately instead of being missed):
///
/// ```ignore
/// loop {
///     let gen = event.generation();
///     if predicate() { break; }
///     event.wait_until(gen, deadline);
/// }
/// ```
pub struct Event {
    gen: AtomicU64,
    kind: EventKind,
}

impl fmt::Debug for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match &self.kind {
            EventKind::System { .. } => "system",
            EventKind::Manual(_) => "manual",
        };
        write!(f, "Event({kind}, gen {})", self.generation())
    }
}

impl Event {
    /// Snapshot the notify generation (take it *before* re-checking the
    /// predicate you are about to wait on).
    pub fn generation(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    /// Wake every waiter. The generation bump happens under the wait lock,
    /// so a notify can never slip between a waiter's generation snapshot
    /// and its wait.
    pub fn notify(&self) {
        match &self.kind {
            EventKind::System { lock, cv } => {
                let _g = recover(lock);
                self.gen.fetch_add(1, Ordering::Release);
                cv.notify_all();
            }
            EventKind::Manual(m) => {
                let _g = recover(&m.offset);
                self.gen.fetch_add(1, Ordering::Release);
                m.cv.notify_all();
            }
        }
    }

    /// Block until the generation moves past `gen`, or the clock reaches
    /// the **absolute** `deadline` — whichever comes first. Returns the
    /// current generation. Under a manual clock the deadline is manual
    /// time: the wait ends only on a notify or an `advance` past it.
    pub fn wait_until(&self, gen: u64, deadline: Instant) -> u64 {
        match &self.kind {
            EventKind::System { lock, cv } => {
                let mut g = recover(lock);
                while self.generation() == gen {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (g2, _t) = cv
                        .wait_timeout(g, deadline - now)
                        .unwrap_or_else(PoisonError::into_inner);
                    g = g2;
                }
            }
            EventKind::Manual(m) => {
                let mut off = recover(&m.offset);
                while self.generation() == gen && m.anchor + *off < deadline {
                    let (o2, _t) = m
                        .cv
                        .wait_timeout(off, MANUAL_RECHECK)
                        .unwrap_or_else(PoisonError::into_inner);
                    off = o2;
                }
            }
        }
        self.generation()
    }

    /// [`Event::wait_until`] with a relative timeout measured on the
    /// event's own clock. Prefer `wait_until` when the deadline was
    /// computed earlier — a clock step in between must not stretch the
    /// wait.
    pub fn wait_for(&self, gen: u64, timeout: Duration) -> u64 {
        let now = match &self.kind {
            EventKind::System { .. } => Instant::now(),
            EventKind::Manual(m) => m.anchor + *recover(&m.offset),
        };
        self.wait_until(gen, now + timeout.min(MAX_WAIT))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn system_clock_is_monotonic_and_cheap() {
        let c = Clock::system();
        assert!(!c.is_manual());
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert_eq!(c.seconds_since(b + Duration::from_secs(5)), 0.0, "future => 0");
    }

    #[test]
    fn manual_clock_moves_only_on_advance() {
        let (clock, manual) = Clock::manual();
        assert!(clock.is_manual());
        let t0 = clock.now();
        assert_eq!(clock.now(), t0, "frozen until advanced");
        manual.advance(Duration::from_millis(10));
        assert_eq!(clock.now(), t0 + Duration::from_millis(10));
        assert_eq!(manual.elapsed(), Duration::from_millis(10));
        assert!((clock.seconds_since(t0) - 0.010).abs() < 1e-12);
    }

    #[test]
    fn manual_sleep_wakes_only_after_sufficient_advance() {
        let (clock, manual) = Clock::manual();
        let (tx, rx) = mpsc::channel();
        let c2 = clock.clone();
        let h = std::thread::spawn(move || {
            c2.sleep(Duration::from_millis(5));
            tx.send(c2.now()).unwrap();
        });
        // The sleeper's target is relative to whenever it entered the
        // sleep (which races this thread), so step until it reports in —
        // each advance is atomic and a sleeper can never wake early, so
        // waking proves an advance moved time past its target.
        let woke_at = loop {
            match rx.try_recv() {
                Ok(t) => break t,
                Err(_) => manual.advance(Duration::from_millis(5)),
            }
        };
        // A 5 ms sleep can only end once at least 5 ms of manual time
        // passed after it began.
        assert!(manual.elapsed() >= Duration::from_millis(5));
        assert!(woke_at <= clock.now());
        h.join().unwrap();
    }

    #[test]
    fn event_notify_wakes_waiter_and_bumps_generation() {
        let clock = Clock::system();
        let ev = Arc::new(clock.event());
        let g0 = ev.generation();
        let ev2 = ev.clone();
        let h = std::thread::spawn(move || ev2.wait_for(g0, Duration::from_secs(30)));
        ev.notify();
        let g1 = h.join().unwrap();
        assert!(g1 > g0, "wait must observe the notify generation");
    }

    #[test]
    fn system_event_wait_until_expires() {
        let clock = Clock::system();
        let ev = clock.event();
        let gen = ev.generation();
        let deadline = Instant::now() + Duration::from_millis(5);
        let after = ev.wait_until(gen, deadline);
        assert_eq!(after, gen, "no notify: the deadline ended the wait");
        assert!(Instant::now() >= deadline);
    }

    #[test]
    fn manual_event_wait_until_ends_on_advance_past_deadline() {
        let (clock, manual) = Clock::manual();
        let ev = Arc::new(clock.event());
        let deadline = clock.now() + Duration::from_millis(10);
        let gen = ev.generation();
        let ev2 = ev.clone();
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            ev2.wait_until(gen, deadline);
            tx.send(()).unwrap();
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(20)).is_err(),
            "manual waits must not expire on wall-clock time"
        );
        manual.advance(Duration::from_millis(10));
        rx.recv().expect("advance to the deadline must end the wait");
        h.join().unwrap();
    }

    #[test]
    fn manual_event_notify_wakes_without_time_passing() {
        let (clock, manual) = Clock::manual();
        let ev = Arc::new(clock.event());
        let gen = ev.generation();
        let far = clock.now() + Duration::from_secs(3600);
        let ev2 = ev.clone();
        let h = std::thread::spawn(move || ev2.wait_until(gen, far));
        ev.notify();
        assert!(h.join().unwrap() > gen);
        assert_eq!(manual.elapsed(), Duration::ZERO, "no time passed");
    }

    #[test]
    fn generation_snapshot_before_notify_returns_immediately() {
        // A notify between the snapshot and the wait must not be missed.
        let clock = Clock::system();
        let ev = clock.event();
        let gen = ev.generation();
        ev.notify();
        let t0 = Instant::now();
        ev.wait_for(gen, Duration::from_secs(30));
        assert!(t0.elapsed() < Duration::from_secs(5), "stale generation returns at once");
    }
}
