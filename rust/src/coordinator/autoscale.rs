//! SLO-driven fleet elasticity: the closed-loop controller over
//! [`Server::scale_up`] / [`Server::scale_down`] / [`Server::set_shed`].
//!
//! The serving paper-shape is a fixed photonic accelerator pool sized for
//! the worst case; this module sizes it for the *observed* case instead.
//! An [`AutoScaler`] is ticked periodically (it is **not** a thread — the
//! caller owns the cadence, which is what keeps the control loop
//! deterministic under a manual clock: `rust/tests/storm.rs` drives it
//! tick-by-tick between `ManualClock::advance` calls, the CLI ticks it
//! from the main serving loop, and `coordinator::loadgen` ticks it once
//! per simulated interval). Each tick reads one [`ServerStats`] snapshot
//! and distills three signals:
//!
//! - **queue depth** — mean in-flight frames per live worker (the
//!   per-worker `WorkerHealthStats::queue_depth` gauge),
//! - **SLO miss rate** — misses per emitted frame *since the last tick*
//!   (delta, not lifetime, so old pain cannot pin the pool high),
//! - **p99 trend** — whether the aggregate submit→emit p99 rose since
//!   the last tick (a scale-down veto, not a scale-up trigger).
//!
//! The decision ladder, with hysteresis between the up and down bands so
//! the pool never flaps:
//!
//! 1. Overloaded (`depth >= up_queue_depth` **or**
//!    `miss rate > up_miss_rate`) and below the policy/pool cap →
//!    [`Server::scale_up`], rate-limited by `up_cooldown`.
//! 2. Overloaded **at** the cap for `shed_after` consecutive ticks →
//!    admission shedding: reject the lowest weight class first
//!    ([`Server::set_shed`] with the second-lowest distinct session
//!    weight), escalating one class per further `shed_after` ticks but
//!    never shedding the highest class.
//! 3. Calm (`depth <= down_queue_depth`, no new misses, p99 not rising)
//!    → first lift shedding, then — after `down_cooldown` since the last
//!    resize — [`Server::scale_down`] toward `min_workers`. The server
//!    itself refuses to drain a lone serving worker.
//!
//! Every acted-on decision is recorded by the server in its
//! [`ScaleEvent`] log ([`ServerStats::scale_events`]), stamped on the
//! serving clock.

use std::time::{Duration, Instant};

use super::clock::Clock;
use super::server::{ScaleError, ServeError, Server};
use super::stats::WorkerMode;

/// Hysteresis bands, cooldowns, and bounds for one [`AutoScaler`].
///
/// The defaults are deliberately conservative: scale up on ~2 queued
/// frames per worker or >5% fresh SLO misses, scale down only once the
/// pool is nearly idle, and wait `shed_after` consecutive capped ticks
/// before turning tenants away.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalePolicy {
    /// Never scale below this many live workers (clamped to `>= 1`; the
    /// server additionally never drains a lone serving worker).
    pub min_workers: usize,
    /// Never scale above this many live workers. `0` means "no policy
    /// bound" — the pool capacity ([`super::engine::EngineConfig::
    /// pool_capacity`]) still applies either way.
    pub max_workers: usize,
    /// Scale up when mean queued frames per live worker reaches this.
    pub up_queue_depth: f64,
    /// Scale up when the since-last-tick SLO miss rate exceeds this.
    pub up_miss_rate: f64,
    /// Scale down (or lift shedding) only when mean queue depth is at or
    /// below this. Keep well under `up_queue_depth`: the gap is the
    /// hysteresis that prevents flapping.
    pub down_queue_depth: f64,
    /// Minimum spacing between two scale-ups (the first is immediate).
    pub up_cooldown: Duration,
    /// Minimum spacing between a scale-down and the previous resize in
    /// either direction (longer than `up_cooldown`: growing is urgent,
    /// shrinking is housekeeping).
    pub down_cooldown: Duration,
    /// Consecutive overloaded-at-cap ticks before shedding starts (and
    /// between shedding escalations).
    pub shed_after: u32,
}

impl Default for ScalePolicy {
    fn default() -> Self {
        ScalePolicy {
            min_workers: 1,
            max_workers: 0,
            up_queue_depth: 2.0,
            up_miss_rate: 0.05,
            down_queue_depth: 0.25,
            up_cooldown: Duration::from_secs(2),
            down_cooldown: Duration::from_secs(10),
            shed_after: 2,
        }
    }
}

/// What a scale/shed decision did ([`ScaleEvent::action`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleAction {
    /// One worker spawned into the pool.
    Up,
    /// One worker flagged `Retiring` (drains, then exits).
    Down,
    /// Admission shedding (re)armed: sessions with `weight <
    /// below_weight` are turned away.
    ShedOn { below_weight: u32 },
    /// Admission shedding lifted.
    ShedOff,
}

/// One recorded scale/shed decision, stamped on the serving clock
/// (seconds since [`Server::start`]). The full log is
/// [`ServerStats::scale_events`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleEvent {
    /// Seconds since the server started, on the serving clock.
    pub at_s: f64,
    pub action: ScaleAction,
    /// Live workers *after* the action (for `Down`: the size the pool is
    /// draining toward).
    pub workers: usize,
    /// Human-readable cause, e.g. `"worker 3 spawned into slot 1"`.
    pub detail: String,
}

/// The controller state: last-resize timestamps for the cooldowns and
/// last-tick counters for the delta signals. See the module docs for the
/// decision ladder.
pub struct AutoScaler {
    policy: ScalePolicy,
    clock: Clock,
    last_up: Option<Instant>,
    last_down: Option<Instant>,
    last_frames: u64,
    last_misses: u64,
    last_p99: f64,
    overloaded_ticks: u32,
}

impl AutoScaler {
    /// A controller for servers on `clock` (pass the serving clock —
    /// cooldowns must live on the same timeline as the traffic).
    pub fn new(policy: ScalePolicy, clock: Clock) -> Self {
        AutoScaler {
            policy: ScalePolicy { min_workers: policy.min_workers.max(1), ..policy },
            clock,
            last_up: None,
            last_down: None,
            last_frames: 0,
            last_misses: 0,
            last_p99: 0.0,
            overloaded_ticks: 0,
        }
    }

    /// The policy in force.
    pub fn policy(&self) -> &ScalePolicy {
        &self.policy
    }

    /// One control iteration: snapshot the server, apply the decision
    /// ladder, return the action taken (`None` = deliberately held
    /// still). Call it on a steady cadence; the cooldowns assume ticks
    /// arrive at least as often as they are long.
    pub fn tick(
        &mut self,
        server: &Server,
    ) -> std::result::Result<Option<ScaleAction>, ServeError> {
        let stats = server.stats()?;
        let now = self.clock.now();

        let live = stats.live_workers.max(1);
        let queued: u64 = stats
            .worker_health
            .iter()
            .filter(|w| w.mode != WorkerMode::Retired)
            .map(|w| w.queue_depth)
            .sum();
        let mean_depth = queued as f64 / live as f64;
        let (d_frames, d_misses) = (
            stats.aggregate.frames.saturating_sub(self.last_frames),
            stats.aggregate.slo_miss.saturating_sub(self.last_misses),
        );
        let miss_rate = if d_frames > 0 {
            d_misses as f64 / d_frames as f64
        } else if d_misses > 0 {
            // Misses with zero emissions (everything late and still in
            // flight) is the worst signal, not a divide-by-zero blind
            // spot.
            1.0
        } else {
            0.0
        };
        let p99_rising = stats.aggregate.p99_latency_s > self.last_p99 + 1e-9;
        self.last_frames = stats.aggregate.frames;
        self.last_misses = stats.aggregate.slo_miss;
        self.last_p99 = stats.aggregate.p99_latency_s;

        let overloaded =
            mean_depth >= self.policy.up_queue_depth || miss_rate > self.policy.up_miss_rate;
        if overloaded {
            let under_policy_cap =
                self.policy.max_workers == 0 || live < self.policy.max_workers;
            if under_policy_cap {
                let cooled = self
                    .last_up
                    .map(|t| now.saturating_duration_since(t) >= self.policy.up_cooldown)
                    .unwrap_or(true);
                if !cooled {
                    return Ok(None);
                }
                match server.scale_up() {
                    Ok(_) => {
                        self.last_up = Some(now);
                        self.overloaded_ticks = 0;
                        return Ok(Some(ScaleAction::Up));
                    }
                    // Pool capacity bound: fall through to the shedding
                    // ladder exactly as a policy cap would.
                    Err(ScaleError::AtCapacity) => {}
                    Err(_) => return Ok(None),
                }
            }
            self.overloaded_ticks += 1;
            if self.overloaded_ticks >= self.policy.shed_after {
                let weights: Vec<u32> = stats.sessions.iter().map(|s| s.weight).collect();
                if let Some(below) = next_shed_threshold(&weights, server.shed_below()) {
                    if server.set_shed(below) {
                        // Escalate one weight class per `shed_after`
                        // further overloaded ticks, not per tick.
                        self.overloaded_ticks = 0;
                        return Ok(Some(ScaleAction::ShedOn { below_weight: below }));
                    }
                }
            }
            return Ok(None);
        }

        self.overloaded_ticks = 0;
        let calm = mean_depth <= self.policy.down_queue_depth;
        if !calm {
            // Between the bands: hysteresis — hold the pool still.
            return Ok(None);
        }
        if server.shed_below() > 0 {
            // Re-admit everyone before giving capacity back.
            if server.clear_shed() {
                return Ok(Some(ScaleAction::ShedOff));
            }
            return Ok(None);
        }
        if live <= self.policy.min_workers || d_misses > 0 || p99_rising {
            return Ok(None);
        }
        let last_resize = match (self.last_up, self.last_down) {
            (Some(u), Some(d)) => Some(u.max(d)),
            (a, b) => a.or(b),
        };
        let cooled = last_resize
            .map(|t| now.saturating_duration_since(t) >= self.policy.down_cooldown)
            .unwrap_or(true);
        if !cooled {
            return Ok(None);
        }
        match server.scale_down() {
            Ok(_) => {
                self.last_down = Some(now);
                Ok(Some(ScaleAction::Down))
            }
            // AtFloor (lone serving worker) and Closed are quiet holds.
            Err(_) => Ok(None),
        }
    }
}

/// The next shedding threshold, one weight class above `current`:
/// distinct session weights sorted ascending, candidates are all but
/// the lowest (shedding *below* weight `w` rejects every class under
/// `w`), and the highest class is never shed — with a single distinct
/// weight there is nothing to differentiate, so no shedding at all.
fn next_shed_threshold(session_weights: &[u32], current: u32) -> Option<u32> {
    let mut weights = session_weights.to_vec();
    weights.sort_unstable();
    weights.dedup();
    if weights.len() < 2 {
        return None;
    }
    // lint-allow(panic): length >= 2 checked above.
    weights[1..].iter().copied().find(|&w| w > current)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_ladder_walks_distinct_weights_and_spares_the_top() {
        let weights = [1, 1, 2, 4];
        // First escalation sheds the lowest class only.
        assert_eq!(next_shed_threshold(&weights, 0), Some(2));
        // Then the next class up...
        assert_eq!(next_shed_threshold(&weights, 2), Some(4));
        // ...but never past the highest: weight-4 tenants always admit.
        assert_eq!(next_shed_threshold(&weights, 4), None);
    }

    #[test]
    fn shed_ladder_needs_two_weight_classes() {
        assert_eq!(next_shed_threshold(&[3, 3, 3], 0), None);
        assert_eq!(next_shed_threshold(&[], 0), None);
    }

    #[test]
    fn default_policy_has_hysteresis_and_floors() {
        let p = ScalePolicy::default();
        assert!(p.down_queue_depth < p.up_queue_depth, "bands must not overlap");
        assert!(p.down_cooldown > p.up_cooldown, "shrinking is housekeeping");
        assert_eq!(AutoScaler::new(ScalePolicy { min_workers: 0, ..p }, Clock::system())
            .policy()
            .min_workers, 1, "min_workers clamps to >= 1");
    }
}
