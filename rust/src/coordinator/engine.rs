//! Batch-job entry points over the sharded serving machinery — now thin
//! **one-session wrappers** over the session-oriented
//! [`super::server::Server`].
//!
//! Historically this module owned the dispatcher → N workers → reassembler
//! threads itself, for exactly one frame source. That machinery moved into
//! [`super::server`], where any number of tenant [`super::server::Session`]s
//! share it; what remains here is the batch-job surface built on top:
//!
//! - [`run`] starts a `Server`, opens **one** session fed by the synthetic
//!   sensor, streams every in-order [`FrameResult`] into the caller's
//!   sink, and shuts the server down into the terminal [`ServeReport`] +
//!   merged [`StageMetrics`] — observably the same contract as the
//!   pre-session engine (in-order emission, `dropped` = real sensor
//!   rejections, worker failures fail the run, a bounded reassembly
//!   window backpressures dispatch).
//! - [`serve_sharded`] / [`serve_sharded_with`] wrap [`run`] for
//!   [`Pipeline`] workers built through a [`BackendFactory`] (one backend
//!   constructed *inside* each worker thread, so non-`Send` substrates
//!   like PJRT shard cleanly).
//!
//! The per-worker micro-batching, least-loaded dispatch, bounded
//! reassembly, and failure semantics all live in `server.rs` now; the
//! [`FrameWorker`] trait and [`EngineConfig`] stay here as the pool's
//! construction contract.

use anyhow::{anyhow, Result};

use super::batcher::BatchPolicy;
use super::clock::Clock;
use super::pipeline::{FrameResult, Pipeline, PipelineConfig, ServeOptions, ServeReport};
use super::server::{spawn_synthetic_sensor, ServeError, Server, SessionOptions};
use super::stats::StageMetrics;
use crate::quant::PrecisionPolicy;
use crate::runtime::{Backend, BackendFactory};
use crate::sensor::Frame;

/// A per-thread frame processor the engine can drive. [`Pipeline`] (over
/// any backend) is the production implementation; tests plug in mock
/// workers.
///
/// Implementations are constructed *inside* their worker thread (see
/// [`run`]'s `factory`), so they do not need to be `Send` — exactly the
/// constraint non-`Send` backends like PJRT impose.
pub trait FrameWorker {
    /// One-time per-worker preparation (e.g. artifact compilation).
    fn warmup(&mut self) -> Result<()> {
        Ok(())
    }

    /// Process one frame end-to-end.
    fn process(&mut self, frame: &Frame) -> Result<FrameResult>;

    /// Process a micro-batch collected by the worker loop, returning one
    /// result per frame in input order. The default loops
    /// [`FrameWorker::process`]; [`Pipeline`] overrides it with
    /// bucket-major batched execution so dispatch overhead amortizes
    /// inside each worker.
    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        frames.iter().map(|f| self.process(f)).collect()
    }

    /// Hand the worker's accumulated metrics to the engine at shutdown.
    fn take_metrics(&mut self) -> StageMetrics;

    /// Identifier of the execution substrate, carried into
    /// [`ServeReport::backend`].
    fn backend_name(&self) -> &'static str {
        "custom"
    }

    /// Current optical-hardware condition of the worker's substrate.
    /// `None` (the default) means no fault model: the server treats the
    /// worker as permanently healthy. [`Pipeline`] forwards its backend's
    /// [`crate::runtime::Backend::health`].
    fn health(&mut self) -> Option<crate::runtime::BackendHealth> {
        None
    }

    /// Recalibrate degraded hardware (reset to pristine), returning the
    /// modeled cost the server charges while the worker is drained.
    /// `None` (the default) means nothing to recalibrate.
    fn recalibrate(&mut self) -> Option<crate::runtime::RecalCost> {
        None
    }
}

impl<B: Backend> FrameWorker for Pipeline<B> {
    fn warmup(&mut self) -> Result<()> {
        Pipeline::warmup(self)
    }

    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        self.process_frame(frame)
    }

    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        Pipeline::process_batch(self, frames)
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }

    fn backend_name(&self) -> &'static str {
        Pipeline::backend_name(self)
    }

    fn health(&mut self) -> Option<crate::runtime::BackendHealth> {
        self.backend_health()
    }

    fn recalibrate(&mut self) -> Option<crate::runtime::RecalCost> {
        self.recalibrate_backend()
    }
}

/// Engine topology + workload parameters (also the [`Server`]'s pool
/// configuration — the sensor fields are used only by the one-session
/// batch-job wrappers).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (each with its own pipeline); clamped to >= 1.
    /// This is the pool's *initial* size; see
    /// [`EngineConfig::max_workers`] for elastic headroom.
    pub workers: usize,
    /// Upper bound on the live worker pool for elastic scaling
    /// ([`super::autoscale::AutoScaler`] / [`Server::scale_up`]). `0`
    /// (the default) means the pool is fixed at `workers` — exactly the
    /// pre-elastic behavior. When set, the server reserves slots so
    /// `Server::scale_up` can spawn additional workers (through the same
    /// per-thread factory) up to this many.
    pub max_workers: usize,
    /// Bounded queue depth per worker.
    pub queue_depth: usize,
    /// Bounded sensor→dispatcher queue depth (the wrapper session's
    /// submission queue).
    pub sensor_queue_depth: usize,
    /// Patch side in pixels (for ground-truth mask scoring).
    pub patch_px: usize,
    /// Sensor frame side in pixels.
    pub image_size: usize,
    /// Moving objects in the synthetic scene.
    pub num_objects: usize,
    /// Sensor RNG seed.
    pub sensor_seed: u64,
    /// How long the reassembler waits for all workers to warm up
    /// (artifact compilation can take minutes).
    pub warmup_timeout_s: f64,
    /// Steady-state stall timeout: dispatched-but-unemitted frames with no
    /// progress for this long fail the server instead of hanging it. An
    /// *idle* server (nothing in flight) never trips it.
    pub stall_timeout_s: f64,
    /// Per-worker micro-batching: each worker collects up to
    /// `batch.max_batch` frames from its queue (waiting at most
    /// `batch.max_wait` after the first) and processes them with one
    /// [`FrameWorker::process_batch`] call. Frames from *all* sessions
    /// ride the same groups (cross-session bucket-major amortization).
    pub batch: BatchPolicy,
    /// Bounded reassembly window (per session): the dispatcher stops
    /// admitting a session's frames while `dispatched - consumed` would
    /// exceed this many, so reassembly memory and undrained results stay
    /// bounded per tenant. `0` derives a default from the topology
    /// (`workers * (queue_depth + max_batch) * 2 + 16` — roomy enough
    /// that healthy runs never feel it).
    pub reassembly_window: usize,
    /// Best-effort core pinning for worker threads via
    /// [`super::affinity::pin_current_thread`] (Linux `sched_setaffinity`;
    /// a no-op elsewhere). The pinned core is recorded per worker in
    /// [`super::stats::WorkerStats::core`].
    pub pin_workers: bool,
    /// Time source for every serving deadline, wait, and timestamp in the
    /// server built from this config: micro-batch lane deadlines, SLO
    /// deadlines and miss accounting, quota token refills, warmup/stall
    /// timeouts. [`Clock::system`] (the default) in production; a
    /// [`super::clock::ManualClock`] makes all of the above exactly
    /// assertable in tests (`rust/tests/qos.rs`).
    pub clock: Clock,
    /// How the dispatcher reacts to worker hardware degradation
    /// ([`FrameWorker::health`]): health-aware routing and recalibration
    /// scheduling. The default is aware; set
    /// [`HealthPolicy::aware`] `= false` for the health-blind control
    /// behavior (exactly the pre-fault dispatcher).
    pub health: HealthPolicy,
    /// Precision policy stamped on the one-session wrapper's frames
    /// ([`ServeOptions::precision`] / `--precision`). Multi-tenant callers
    /// set this per session via
    /// [`SessionOptions::with_precision`] instead.
    pub precision: PrecisionPolicy,
}

/// Dispatcher policy for degraded workers (see `coordinator::server`):
/// route critical traffic away from accuracy-at-risk workers, and pull a
/// worker out of rotation for recalibration when its health sinks below
/// [`HealthPolicy::recal_below`].
#[derive(Debug, Clone, Copy)]
pub struct HealthPolicy {
    /// Master switch. `false` reproduces the health-blind dispatcher
    /// bit-for-bit: no routing bias, no recal windows (health and at-risk
    /// frames are still *recorded*).
    pub aware: bool,
    /// Health threshold below which a worker is drained and recalibrated
    /// (only while at least one other worker is serving).
    pub recal_below: f64,
    /// Sessions with admission weight at or above this are *critical*:
    /// like SLO sessions, their frames avoid accuracy-at-risk workers
    /// whenever a healthy worker is alive.
    pub critical_weight: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy { aware: true, recal_below: 0.6, critical_weight: 3 }
    }
}

impl EngineConfig {
    /// Defaults matching `PipelineConfig::tiny_96` serving.
    pub fn new(workers: usize, patch_px: usize, image_size: usize) -> Self {
        let workers = workers.max(1);
        EngineConfig {
            workers,
            max_workers: 0,
            queue_depth: 4,
            sensor_queue_depth: 4 * workers,
            patch_px,
            image_size,
            num_objects: 2,
            sensor_seed: 42,
            warmup_timeout_s: 600.0,
            stall_timeout_s: 60.0,
            batch: BatchPolicy::per_frame(),
            reassembly_window: 0,
            pin_workers: false,
            clock: Clock::system(),
            health: HealthPolicy::default(),
            precision: PrecisionPolicy::default(),
        }
    }

    /// Derive the pool configuration for serving a [`PipelineConfig`]
    /// under [`ServeOptions`] — the single mapping shared by
    /// [`serve_sharded_with`], `optovit serve --cameras`, and the
    /// examples, so a new serving knob cannot be forgotten at one of the
    /// call sites.
    pub fn for_serving(pipe_cfg: &PipelineConfig, opts: &ServeOptions, workers: usize) -> Self {
        let vit = pipe_cfg.vit_config();
        let mut cfg = EngineConfig::new(workers, vit.patch_size, pipe_cfg.image_size);
        cfg.queue_depth = opts.queue_depth.max(1);
        cfg.sensor_queue_depth = opts.queue_depth.max(1) * cfg.workers;
        cfg.num_objects = opts.num_objects;
        cfg.sensor_seed = opts.sensor_seed;
        cfg.batch = opts.batch;
        cfg.pin_workers = opts.pin_workers;
        cfg.precision = opts.precision;
        // One window knob across both serving paths: `--window` bounds the
        // single-pipeline stream and the per-session reassembler alike.
        cfg.reassembly_window = opts.window.max(1);
        cfg
    }

    /// The pool's slot capacity: `max(workers, max_workers)` workers can
    /// ever be live at once (`max_workers == 0` fixes the pool at
    /// `workers`). The server sizes its per-slot state to this.
    pub fn pool_capacity(&self) -> usize {
        self.workers.max(1).max(self.max_workers)
    }

    /// The effective bounded reassembly window (see
    /// [`EngineConfig::reassembly_window`]).
    pub fn effective_window(&self) -> usize {
        if self.reassembly_window > 0 {
            self.reassembly_window
        } else {
            let workers = self.workers.max(1);
            workers * (self.queue_depth.max(1) + self.batch.max_batch.max(1)) * 2 + 16
        }
    }
}

/// Run a sharded serving job: `num_frames` frames from the synthetic
/// sensor, sharded across `cfg.workers` workers built by `factory` (one
/// call per worker thread, so non-`Send` pipelines are fine). `sink`
/// receives every [`FrameResult`] strictly in dispatch order.
///
/// This is the **one-session wrapper** over [`Server`]: it starts the
/// server, opens a single session fed by a synthetic-sensor thread
/// (counting real enqueue rejections as `dropped`), drains the session's
/// in-order stream into `sink`, and shuts the server down into the
/// combined [`ServeReport`] plus the merged cross-worker
/// [`StageMetrics`].
pub fn run<W, F>(
    factory: F,
    cfg: &EngineConfig,
    num_frames: u64,
    mut sink: impl FnMut(&FrameResult),
) -> Result<(ServeReport, StageMetrics)>
where
    W: FrameWorker + 'static,
    F: Fn(usize) -> Result<W> + Send + Sync + 'static,
{
    let server = Server::start(factory, cfg.clone())?;
    let session = server.session(
        SessionOptions::named("sensor")
            .with_queue_depth(cfg.sensor_queue_depth.max(1))
            .with_window(cfg.effective_window())
            .with_precision(cfg.precision),
    )?;
    let (submitter, mut stream) = session.split();
    let sensor = spawn_synthetic_sensor(
        submitter,
        server.watch(),
        cfg.image_size,
        cfg.num_objects,
        cfg.sensor_seed,
        num_frames,
    );
    let mut stream_err: Option<ServeError> = None;
    for item in &mut stream {
        match item {
            Ok(r) => sink(&r),
            Err(e) => {
                stream_err = Some(e);
                break;
            }
        }
    }
    sensor.join().ok();
    drop(stream);
    match server.shutdown() {
        Ok(pair) => match stream_err {
            // The stream only errs when the server failed, in which case
            // shutdown reports it — this arm is a defensive fallback.
            Some(e) => Err(anyhow!("sharded serve failed: {e}")),
            None => Ok(pair),
        },
        Err(e) => Err(e),
    }
}

/// Serve [`ServeOptions::num_frames`] frames through `workers` parallel
/// [`Pipeline`]s, streaming every in-order [`FrameResult`] into `sink` as
/// it is reassembled — the sharded counterpart of the single-pipeline
/// [`super::pipeline::FrameStream`]. Each worker thread builds its own
/// backend through `factory` (so non-`Send` substrates shard cleanly), its
/// own pipeline around it, and micro-batches its queue under
/// [`ServeOptions::batch`].
///
/// **Wrapper status**: a documented one-session wrapper over
/// [`super::server::Server`] (via [`run`]) — open a `Server` directly to
/// share the same worker pool between multiple cameras/tenants.
pub fn serve_sharded_with<F>(
    pipe_cfg: &PipelineConfig,
    factory: &F,
    workers: usize,
    opts: &ServeOptions,
    sink: impl FnMut(&FrameResult),
) -> Result<(ServeReport, StageMetrics)>
where
    F: BackendFactory + Clone + Send + 'static,
    F::Backend: 'static,
{
    let cfg = EngineConfig::for_serving(pipe_cfg, opts, workers);
    let pipe_cfg = pipe_cfg.clone();
    let factory = factory.clone();
    // Worker pipelines stamp their stage timings on the server's clock,
    // so one seam governs every timestamp in the run.
    let clock = cfg.clock.clone();
    run(
        move |wid| {
            Pipeline::with_backend_and_clock(pipe_cfg.clone(), factory.create(wid)?, clock.clone())
        },
        &cfg,
        opts.num_frames,
        sink,
    )
}

/// [`serve_sharded_with`] without a result sink: drain the stream
/// internally and return only the terminal report + merged metrics.
/// Like `serve_sharded_with`, a documented one-session wrapper over the
/// session-oriented [`super::server::Server`].
pub fn serve_sharded<F>(
    pipe_cfg: &PipelineConfig,
    factory: &F,
    workers: usize,
    opts: &ServeOptions,
) -> Result<(ServeReport, StageMetrics)>
where
    F: BackendFactory + Clone + Send + 'static,
    F::Backend: 'static,
{
    serve_sharded_with(pipe_cfg, factory, workers, opts, |_r| {})
}
