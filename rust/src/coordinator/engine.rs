//! Sharded multi-worker serving engine.
//!
//! The single-pipeline [`super::pipeline::serve`] loop is capped at one
//! host core because execution backends are not required to be `Send`
//! (the PJRT client is `Rc`-backed). This engine scales the host side the
//! way production photonic-transformer servers exploit parallel
//! dynamically-operated cores: a dispatcher thread shards frames across N
//! worker threads, **each of which constructs its own pipeline + backend**
//! (one [`crate::runtime::Backend`] instance per thread, built by a
//! [`BackendFactory`]), and a reassembler emits results strictly in
//! dispatch order.
//!
//! ```text
//!                       ┌─▶ worker 0 (own Pipeline/Backend) ─┐
//! sensor ─▶ dispatcher ─┼─▶ worker 1 (own Pipeline/Backend) ─┼─▶ reassembler
//!           (load-aware │        …                           │  (in-order,
//!            round-robin)└─▶ worker N-1 ─────────────────────┘   merged metrics)
//! ```
//!
//! Scheduling is round-robin biased by queue depth: each frame goes to the
//! alive worker with the fewest in-flight frames (ties broken in rotation
//! order), falling back to a blocking hand-off only when every bounded
//! worker queue is full. A worker that panics or returns an error fails the
//! whole run promptly — the dispatcher detects the closed queue, the
//! reassembler sees the failure message, and no thread is left hanging.
//!
//! Each worker **micro-batches** its queue under
//! [`EngineConfig::batch`]: it collects up to `max_batch` frames (waiting
//! at most `max_wait` after the first) and drives them through one
//! [`FrameWorker::process_batch`] call — for [`Pipeline`] workers that is
//! a bucket-major `Backend::execute_batch`, so PJRT dispatch overhead
//! amortizes inside every worker. The reassembler's out-of-order buffer is
//! **bounded** ([`EngineConfig::reassembly_window`]), so unbounded
//! streaming runs cannot accumulate unbounded memory; in-order results
//! stream into the caller's sink as they reassemble
//! ([`serve_sharded_with`]).

use std::collections::BTreeMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{recv_frame, sensor_loop, BatchPolicy, FrameQueue};
use super::pipeline::{FrameResult, Pipeline, PipelineConfig, ServeOptions, ServeReport};
use super::stats::{StageMetrics, WorkerStats};
use crate::runtime::{Backend, BackendFactory};
use crate::sensor::Frame;

/// A per-thread frame processor the engine can drive. [`Pipeline`] (over
/// any backend) is the production implementation; tests plug in mock
/// workers.
///
/// Implementations are constructed *inside* their worker thread (see
/// [`run`]'s `factory`), so they do not need to be `Send` — exactly the
/// constraint non-`Send` backends like PJRT impose.
pub trait FrameWorker {
    /// One-time per-worker preparation (e.g. artifact compilation).
    fn warmup(&mut self) -> Result<()> {
        Ok(())
    }

    /// Process one frame end-to-end.
    fn process(&mut self, frame: &Frame) -> Result<FrameResult>;

    /// Process a micro-batch collected by the worker loop, returning one
    /// result per frame in input order. The default loops
    /// [`FrameWorker::process`]; [`Pipeline`] overrides it with
    /// bucket-major batched execution so dispatch overhead amortizes
    /// inside each worker.
    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        frames.iter().map(|f| self.process(f)).collect()
    }

    /// Hand the worker's accumulated metrics to the engine at shutdown.
    fn take_metrics(&mut self) -> StageMetrics;

    /// Identifier of the execution substrate, carried into
    /// [`ServeReport::backend`].
    fn backend_name(&self) -> &'static str {
        "custom"
    }
}

impl<B: Backend> FrameWorker for Pipeline<B> {
    fn warmup(&mut self) -> Result<()> {
        Pipeline::warmup(self)
    }

    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        self.process_frame(frame)
    }

    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        Pipeline::process_batch(self, frames)
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }

    fn backend_name(&self) -> &'static str {
        Pipeline::backend_name(self)
    }
}

/// Engine topology + workload parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (each with its own pipeline); clamped to >= 1.
    pub workers: usize,
    /// Bounded queue depth per worker.
    pub queue_depth: usize,
    /// Bounded sensor→dispatcher queue depth.
    pub sensor_queue_depth: usize,
    /// Patch side in pixels (for ground-truth mask scoring).
    pub patch_px: usize,
    /// Sensor frame side in pixels.
    pub image_size: usize,
    /// Moving objects in the synthetic scene.
    pub num_objects: usize,
    /// Sensor RNG seed.
    pub sensor_seed: u64,
    /// How long the reassembler waits for all workers to warm up
    /// (artifact compilation can take minutes).
    pub warmup_timeout_s: f64,
    /// Steady-state stall timeout: no worker progress for this long fails
    /// the run instead of hanging it.
    pub stall_timeout_s: f64,
    /// Per-worker micro-batching: each worker collects up to
    /// `batch.max_batch` frames from its queue (waiting at most
    /// `batch.max_wait` after the first) and processes them with one
    /// [`FrameWorker::process_batch`] call.
    pub batch: BatchPolicy,
    /// Bounded reassembly window: the dispatcher stalls (backpressure,
    /// propagating to the dropping sensor queue) while
    /// `dispatched - emitted` would exceed this many frames, so the
    /// reassembler's out-of-order buffer is bounded even on unbounded
    /// runs with one pathologically slow worker. `0` derives a default
    /// from the topology (`workers * (queue_depth + max_batch) * 2 + 16`
    /// — roomy enough that healthy runs never feel it).
    pub reassembly_window: usize,
}

impl EngineConfig {
    /// Defaults matching `PipelineConfig::tiny_96` serving.
    pub fn new(workers: usize, patch_px: usize, image_size: usize) -> Self {
        let workers = workers.max(1);
        EngineConfig {
            workers,
            queue_depth: 4,
            sensor_queue_depth: 4 * workers,
            patch_px,
            image_size,
            num_objects: 2,
            sensor_seed: 42,
            warmup_timeout_s: 600.0,
            stall_timeout_s: 60.0,
            batch: BatchPolicy::per_frame(),
            reassembly_window: 0,
        }
    }

    /// The effective bounded reassembly window (see
    /// [`EngineConfig::reassembly_window`]).
    pub fn effective_window(&self) -> usize {
        if self.reassembly_window > 0 {
            self.reassembly_window
        } else {
            let workers = self.workers.max(1);
            workers * (self.queue_depth.max(1) + self.batch.max_batch.max(1)) * 2 + 16
        }
    }
}

/// What a worker thread hands back on clean exit (metrics + utilization +
/// backend identity), or the failure message that must abort the run.
type WorkerOutcome = std::result::Result<(StageMetrics, WorkerStats, &'static str), String>;

/// Messages from workers / dispatcher to the reassembler.
enum Msg {
    /// Worker finished warmup and is accepting frames.
    Ready,
    /// One processed frame, tagged with its dense dispatch sequence number.
    Result { seq: u64, result: FrameResult, iou: f64, correct: bool },
    /// Worker drained its queue and exited cleanly.
    Done { stats: WorkerStats, metrics: StageMetrics, backend: &'static str },
    /// Worker failed (error or panic): the run must fail, not hang.
    Failed { error: String },
    /// Dispatcher finished; exactly `dispatched` results are expected.
    DispatchDone { dispatched: u64 },
}

/// Run a sharded serving session: `num_frames` frames from the synthetic
/// sensor, sharded across `cfg.workers` workers built by `factory` (one
/// call per worker thread, so non-`Send` pipelines are fine). `sink`
/// receives every [`FrameResult`] strictly in dispatch order.
///
/// Returns the combined [`ServeReport`] plus the merged cross-worker
/// [`StageMetrics`] for per-stage reporting.
pub fn run<W, F>(
    factory: F,
    cfg: &EngineConfig,
    num_frames: u64,
    mut sink: impl FnMut(&FrameResult),
) -> Result<(ServeReport, StageMetrics)>
where
    W: FrameWorker,
    F: Fn(usize) -> Result<W> + Sync,
{
    let n_workers = cfg.workers.max(1);
    let factory = &factory;

    // Sensor → dispatcher queue; `dropped` counts actual try_push
    // rejections, not frames in flight at stop time.
    let (sensor_q, sensor_rx) = FrameQueue::bounded(cfg.sensor_queue_depth.max(1));
    let rejected = AtomicU64::new(0);
    // go: all workers warmed up, start producing/dispatching.
    // stop: sensor shutdown. abort: dispatcher shutdown (failure path).
    let go = AtomicBool::new(false);
    let stop = AtomicBool::new(false);
    let abort = AtomicBool::new(false);
    let inflight: Vec<AtomicU64> = (0..n_workers).map(|_| AtomicU64::new(0)).collect();

    let (res_tx, res_rx) = mpsc::channel::<Msg>();
    let mut worker_txs = Vec::with_capacity(n_workers);
    let mut worker_rxs = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let (tx, rx) = mpsc::sync_channel::<(u64, Frame)>(cfg.queue_depth.max(1));
        worker_txs.push(tx);
        worker_rxs.push(rx);
    }

    // Emitted-result counter shared with the dispatcher: the reassembly
    // window is enforced as dispatch backpressure (`dispatched - emitted`
    // bounded), never as a failure of a healthy-but-skewed run.
    let emitted_ctr = AtomicU64::new(0);
    let (rejected_r, go_r, stop_r, abort_r) = (&rejected, &go, &stop, &abort);
    let emitted_r = &emitted_ctr;
    let inflight_r = &inflight;
    let patch_px = cfg.patch_px;
    let (image_size, num_objects, sensor_seed) = (cfg.image_size, cfg.num_objects, cfg.sensor_seed);
    let warmup_timeout = Duration::from_secs_f64(cfg.warmup_timeout_s.max(0.1));
    let stall_timeout = Duration::from_secs_f64(cfg.stall_timeout_s.max(0.1));
    let batch_policy = cfg.batch;
    let reassembly_window = cfg.effective_window();

    let outcome = std::thread::scope(|s| {
        // --- sensor thread: produce frames as fast as the queue accepts,
        //     idle until all workers are warm (`go`) ---
        s.spawn(move || {
            sensor_loop(sensor_q, image_size, num_objects, sensor_seed, go_r, stop_r, rejected_r)
        });

        // --- worker threads: own pipeline each, drain own bounded queue,
        //     micro-batching up to `batch.max_batch` frames per
        //     process_batch call ---
        for (wid, rx) in worker_rxs.into_iter().enumerate() {
            let res_tx = res_tx.clone();
            s.spawn(move || {
                let body = AssertUnwindSafe(|| -> WorkerOutcome {
                    let mut w = factory(wid)
                        .map_err(|e| format!("worker {wid}: construction failed: {e:#}"))?;
                    w.warmup().map_err(|e| format!("worker {wid}: warmup failed: {e:#}"))?;
                    res_tx.send(Msg::Ready).ok();
                    // Utilization window opens at the first frame, not at
                    // warmup completion: a fast-warming worker must not be
                    // charged its peers' compile time as idle.
                    let mut t_first: Option<Instant> = None;
                    let mut busy = Duration::ZERO;
                    let mut frames = 0u64;
                    let max_batch = batch_policy.max_batch.max(1);
                    let mut seqs: Vec<u64> = Vec::with_capacity(max_batch);
                    let mut group: Vec<Frame> = Vec::with_capacity(max_batch);
                    let mut closed = false;
                    while !closed {
                        // Block for the first frame of the group...
                        seqs.clear();
                        group.clear();
                        match rx.recv() {
                            Ok((seq, frame)) => {
                                seqs.push(seq);
                                group.push(frame);
                            }
                            Err(_) => break,
                        }
                        t_first.get_or_insert_with(Instant::now);
                        // ...then top it up until max_batch or the
                        // deadline, whichever comes first.
                        if max_batch > 1 {
                            let deadline = Instant::now() + batch_policy.max_wait;
                            while group.len() < max_batch {
                                let remaining =
                                    deadline.saturating_duration_since(Instant::now());
                                if remaining.is_zero() {
                                    break;
                                }
                                match rx.recv_timeout(remaining) {
                                    Ok((seq, frame)) => {
                                        seqs.push(seq);
                                        group.push(frame);
                                    }
                                    Err(RecvTimeoutError::Timeout) => break,
                                    Err(RecvTimeoutError::Disconnected) => {
                                        closed = true;
                                        break;
                                    }
                                }
                            }
                        }
                        // Ground truth before processing (frames are
                        // consumed by reference, results by value).
                        let gts: Vec<_> = group.iter().map(|f| f.gt_mask(patch_px)).collect();
                        let labels: Vec<usize> = group.iter().map(|f| f.label).collect();
                        let t0 = Instant::now();
                        let out = w.process_batch(&group);
                        busy += t0.elapsed();
                        inflight_r[wid].fetch_sub(group.len() as u64, Ordering::Relaxed);
                        let rs = out.map_err(|e| {
                            format!(
                                "worker {wid}: batch of {} (first frame {}) failed: {e:#}",
                                group.len(),
                                group.first().map(|f| f.index).unwrap_or(0)
                            )
                        })?;
                        if rs.len() != group.len() {
                            return Err(format!(
                                "worker {wid}: process_batch returned {} results for {} frames",
                                rs.len(),
                                group.len()
                            ));
                        }
                        frames += rs.len() as u64;
                        for ((&seq, r), (gt, &label)) in
                            seqs.iter().zip(rs).zip(gts.iter().zip(&labels))
                        {
                            let iou = r.mask.iou(gt);
                            let correct = r.predicted_class() == label;
                            res_tx.send(Msg::Result { seq, result: r, iou, correct }).ok();
                        }
                    }
                    let active_s = t_first.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                    let busy_s = busy.as_secs_f64();
                    let backend = w.backend_name();
                    Ok((
                        w.take_metrics(),
                        WorkerStats {
                            worker: wid,
                            frames,
                            busy_s,
                            utilization: if active_s > 0.0 {
                                (busy_s / active_s).min(1.0)
                            } else {
                                0.0
                            },
                        },
                        backend,
                    ))
                });
                match std::panic::catch_unwind(body) {
                    Ok(Ok((metrics, stats, backend))) => {
                        res_tx.send(Msg::Done { stats, metrics, backend }).ok();
                    }
                    Ok(Err(error)) => {
                        res_tx.send(Msg::Failed { error }).ok();
                    }
                    Err(_) => {
                        res_tx
                            .send(Msg::Failed { error: format!("worker {wid} panicked") })
                            .ok();
                    }
                }
            });
        }

        // --- dispatcher thread: load-aware round-robin sharding ---
        let dispatch_tx = res_tx.clone();
        s.spawn(move || {
            while !go_r.load(Ordering::Relaxed) && !abort_r.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_micros(500));
            }
            let mut dispatched = 0u64;
            let mut rr = 0usize;
            let mut alive = vec![true; n_workers];
            // Reused across frames: the dispatcher itself stays off the
            // per-frame heap, like the pipeline hot path it feeds.
            let mut candidates: Vec<usize> = Vec::with_capacity(n_workers);
            'dispatch: while dispatched < num_frames && !abort_r.load(Ordering::Relaxed) {
                // Bounded reassembly window: hold new dispatches while the
                // gap to the emission front is at the window. Backpressure
                // propagates to the sensor queue (the dropping point), and
                // the reassembler's buffer stays bounded no matter how
                // skewed the workers run.
                while dispatched.saturating_sub(emitted_r.load(Ordering::Relaxed))
                    >= reassembly_window as u64
                    && !abort_r.load(Ordering::Relaxed)
                {
                    std::thread::sleep(Duration::from_micros(200));
                }
                if abort_r.load(Ordering::Relaxed) {
                    break;
                }
                let Some(frame) = recv_frame(&sensor_rx, Duration::from_secs(5)) else {
                    break;
                };
                let mut undelivered = frame;
                'place: loop {
                    candidates.clear();
                    candidates.extend((0..n_workers).filter(|&w| alive[w]));
                    if candidates.is_empty() {
                        dispatch_tx
                            .send(Msg::Failed { error: "all workers died".to_string() })
                            .ok();
                        break 'dispatch;
                    }
                    // Least-loaded first; ties broken in rotation order so
                    // equally-idle workers get frames round-robin.
                    let rot = rr % n_workers;
                    candidates.sort_unstable_by_key(|&w| {
                        (inflight_r[w].load(Ordering::Relaxed), (w + n_workers - rot) % n_workers)
                    });
                    let mut f = undelivered;
                    for &w in &candidates {
                        match worker_txs[w].try_send((dispatched, f)) {
                            Ok(()) => {
                                inflight_r[w].fetch_add(1, Ordering::Relaxed);
                                dispatched += 1;
                                rr += 1;
                                break 'place;
                            }
                            Err(TrySendError::Full((_, fr))) => f = fr,
                            Err(TrySendError::Disconnected((_, fr))) => {
                                alive[w] = false;
                                f = fr;
                            }
                        }
                    }
                    // Every alive queue is full: block on the least-loaded
                    // alive worker (backpressure, not drop — the sensor
                    // queue provides the dropping).
                    let Some(&w) = candidates.iter().find(|&&w| alive[w]) else {
                        undelivered = f;
                        continue 'place;
                    };
                    match worker_txs[w].send((dispatched, f)) {
                        Ok(()) => {
                            inflight_r[w].fetch_add(1, Ordering::Relaxed);
                            dispatched += 1;
                            rr += 1;
                            break 'place;
                        }
                        Err(mpsc::SendError((_, fr))) => {
                            alive[w] = false;
                            undelivered = fr;
                        }
                    }
                }
            }
            dispatch_tx.send(Msg::DispatchDone { dispatched }).ok();
            stop_r.store(true, Ordering::Relaxed);
            // Drain leftovers so the sensor never blocks, then close the
            // worker queues so they drain and exit.
            while sensor_rx.try_recv().is_ok() {}
            drop(worker_txs);
        });
        drop(res_tx);

        // --- reassembler (this thread): strict in-order emission ---
        let mut pending: BTreeMap<u64, (FrameResult, f64, bool)> = BTreeMap::new();
        let mut next_emit = 0u64;
        let mut emitted = 0u64;
        let mut iou_sum = 0.0f64;
        let mut correct = 0u64;
        let mut ready = 0usize;
        let mut done_workers = 0usize;
        let mut expected: Option<u64> = None;
        let mut merged = StageMetrics::new();
        let mut per_worker: Vec<WorkerStats> = Vec::new();
        let mut backend_name: &'static str = "custom";
        let mut t0: Option<Instant> = None;
        let mut failure: Option<String> = None;

        loop {
            if let Some(exp) = expected {
                if emitted >= exp && done_workers == n_workers {
                    break;
                }
            }
            let timeout = if go.load(Ordering::Relaxed) { stall_timeout } else { warmup_timeout };
            match res_rx.recv_timeout(timeout) {
                Ok(Msg::Ready) => {
                    ready += 1;
                    if ready == n_workers {
                        t0 = Some(Instant::now());
                        go.store(true, Ordering::Relaxed);
                    }
                }
                Ok(Msg::Result { seq, result, iou, correct: ok }) => {
                    pending.insert(seq, (result, iou, ok));
                    while let Some((r, i, c)) = pending.remove(&next_emit) {
                        iou_sum += i;
                        correct += c as u64;
                        sink(&r);
                        emitted += 1;
                        next_emit += 1;
                    }
                    emitted_ctr.store(emitted, Ordering::Relaxed);
                    // Backstop: the dispatcher never lets more than
                    // `reassembly_window` frames sit between dispatch and
                    // emission, so a larger buffer means the engine lost a
                    // result — fail fast instead of buffering forever.
                    if pending.len() > reassembly_window {
                        failure = Some(format!(
                            "reassembly window overflow: {} results buffered out of order \
                             (window {reassembly_window}, next expected seq {next_emit}) — \
                             a result was lost",
                            pending.len()
                        ));
                        break;
                    }
                }
                Ok(Msg::Done { stats, metrics, backend }) => {
                    merged.merge(&metrics);
                    per_worker.push(stats);
                    backend_name = backend;
                    done_workers += 1;
                }
                Ok(Msg::Failed { error }) => {
                    failure = Some(error);
                    break;
                }
                Ok(Msg::DispatchDone { dispatched }) => {
                    expected = Some(dispatched);
                }
                Err(RecvTimeoutError::Timeout) => {
                    failure = Some(format!(
                        "engine stalled: no progress for {:.1}s ({} of {:?} frames emitted)",
                        timeout.as_secs_f64(),
                        emitted,
                        expected
                    ));
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    if expected.is_some_and(|e| emitted >= e) && done_workers == n_workers {
                        break;
                    }
                    failure = Some("engine threads exited before completing the run".to_string());
                    break;
                }
            }
        }
        let wall_s = t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        // Unstick every thread (no-ops on the happy path), then let the
        // scope join them.
        abort.store(true, Ordering::Relaxed);
        stop.store(true, Ordering::Relaxed);
        go.store(true, Ordering::Relaxed);
        per_worker.sort_by_key(|w| w.worker);
        (failure, emitted, iou_sum, correct, merged, per_worker, backend_name, wall_s)
    });

    let (failure, emitted, iou_sum, correct, merged, per_worker, backend_name, wall_s) = outcome;
    if let Some(error) = failure {
        return Err(anyhow!("sharded serve failed: {error}"));
    }
    let report = ServeReport {
        backend: backend_name.to_string(),
        frames: emitted,
        dropped: rejected.load(Ordering::Relaxed),
        wall_fps: if wall_s > 0.0 { emitted as f64 / wall_s } else { 0.0 },
        mean_latency_s: merged.frame_latency_mean_s(),
        mean_energy_j: merged.mean_energy_j(),
        modeled_kfps_per_watt: merged.modeled_kfps_per_watt(),
        mean_kept_patches: merged.mean_kept_patches(),
        mean_batch: merged.mean_batch(),
        mean_mask_iou: if emitted > 0 { iou_sum / emitted as f64 } else { 0.0 },
        top1_accuracy: if emitted > 0 { correct as f64 / emitted as f64 } else { 0.0 },
        workers: n_workers,
        per_worker,
    };
    Ok((report, merged))
}

/// Serve [`ServeOptions::num_frames`] frames through `workers` parallel
/// [`Pipeline`]s, streaming every in-order [`FrameResult`] into `sink` as
/// it is reassembled — the sharded counterpart of the single-pipeline
/// [`super::pipeline::FrameStream`]. Each worker thread builds its own
/// backend through `factory` (so non-`Send` substrates shard cleanly), its
/// own pipeline around it, and micro-batches its queue under
/// [`ServeOptions::batch`]; the reassembler's out-of-order buffer is
/// bounded (see [`EngineConfig::reassembly_window`]).
pub fn serve_sharded_with<F: BackendFactory>(
    pipe_cfg: &PipelineConfig,
    factory: &F,
    workers: usize,
    opts: &ServeOptions,
    sink: impl FnMut(&FrameResult),
) -> Result<(ServeReport, StageMetrics)> {
    let vit = pipe_cfg.vit_config();
    let mut cfg = EngineConfig::new(workers, vit.patch_size, pipe_cfg.image_size);
    cfg.queue_depth = opts.queue_depth.max(1);
    cfg.sensor_queue_depth = opts.queue_depth.max(1) * cfg.workers;
    cfg.num_objects = opts.num_objects;
    cfg.sensor_seed = opts.sensor_seed;
    cfg.batch = opts.batch;
    // One window knob across both serving paths: `--window` bounds the
    // single-pipeline stream and the engine reassembler alike.
    cfg.reassembly_window = opts.window.max(1);
    run(
        |wid| Pipeline::with_backend(pipe_cfg.clone(), factory.create(wid)?),
        &cfg,
        opts.num_frames,
        sink,
    )
}

/// [`serve_sharded_with`] without a result sink: drain the stream
/// internally and return only the terminal report + merged metrics.
pub fn serve_sharded<F: BackendFactory>(
    pipe_cfg: &PipelineConfig,
    factory: &F,
    workers: usize,
    opts: &ServeOptions,
) -> Result<(ServeReport, StageMetrics)> {
    serve_sharded_with(pipe_cfg, factory, workers, opts, |_r| {})
}
