//! Session-oriented serving: a long-lived [`Server`] that owns the
//! dispatcher → N workers → reassembler machinery once, shared by any
//! number of independent client [`Session`]s (one per camera / tenant).
//!
//! The batch-job entry points (`engine::run`, `serve_sharded`) consume a
//! single frame source start-to-finish, so two cameras could never share
//! the worker pool, the micro-batcher, or the reassembler. The paper's
//! near-sensor deployment is the opposite shape: **one accelerator,
//! continuous traffic from many sensors**. This module is that shape:
//!
//! ```text
//! session "cam-0" ──┐ (bounded queue, weight w0)
//! session "cam-1" ──┤            ┌─▶ worker 0 (Pipeline + Backend,
//! session "cam-2" ──┼▶ admission │     bucket-major micro-batch) ─┐
//!        …          │  (weighted ├─▶ worker 1 …                   ├─▶ per-session
//!                   │   round-   │        …                       │   reassembly →
//!                   └─  robin)   └─▶ worker N-1 ──────────────────┘   in-order
//!                                                                     SessionStreams
//! ```
//!
//! Invariants the API guarantees:
//!
//! - **Per-session FIFO.** A session's results stream back strictly in its
//!   own submission order, regardless of which workers served them or how
//!   sessions interleaved (per-session sequence numbers + a per-session
//!   reassembly buffer bounded by the session window).
//! - **Cross-session amortization.** All sessions share the workers'
//!   per-bucket micro-batch lanes: same-bucket frames from *different*
//!   cameras complete in one `Backend::execute_batch` call, so a fleet of
//!   similar sensors batches better than any of them alone (gated by
//!   `rust/tests/sessions.rs`).
//! - **Fair admission.** The dispatcher dequeues sessions weighted
//!   round-robin (up to [`SessionOptions::weight`] frames per turn), so a
//!   hot camera saturating its queue cannot starve an idle-ish one.
//! - **Isolated backpressure.** Each session has a bounded submission
//!   queue ([`Session::submit`] blocks, [`Session::try_submit`] rejects)
//!   and a per-session dispatch window: a tenant that stops draining its
//!   stream stalls only its own admission, never its neighbours'.
//! - **Graceful teardown.** Closing a session drains what it already
//!   submitted; *dropping* one mid-flight (queue + results in flight)
//!   cancels it without panicking the server — queued frames are
//!   discarded, in-flight results fall on the floor, every other session
//!   keeps streaming. Poisoned locks and hung-up channels surface as
//!   [`ServeError`], never as a panic.
//! - **Failure is loud.** A worker error/panic fails the server: every
//!   stream ends with one [`ServeError::Failed`], and
//!   [`Server::shutdown`] returns the failure.
//! - **Per-session QoS.** A session may declare a latency SLO
//!   ([`SessionOptions::slo`]): its frames carry `accepted_at + slo`
//!   deadlines, the dispatcher's **earliest-deadline-first pre-pass**
//!   admits the most imminent peeked deadline ahead of the plain
//!   round-robin order (within the session's weighted share, so
//!   fairness is untouched), and a worker **flushes its micro-batch
//!   group early** when the earliest such deadline arrives instead of
//!   waiting out `BatchPolicy::max_wait` (deadline-aware flush); every
//!   emission is
//!   scored against the SLO and recorded in the session's
//!   `ServeReport::slo_miss` and submit→emit `p99_latency_s`. A session
//!   may also carry an admission [`Quota`] (max in-flight + token-bucket
//!   rate): quota-rejected `try_submit`s count the **distinct**
//!   `ServeReport::dropped_quota` (never `dropped`, which stays pure
//!   backpressure), while blocking `submit` waits for the quota to admit.
//! - **Degraded-optics awareness.** Each worker publishes its backend's
//!   optical health score (drift, stuck cells, dead lanes → estimated
//!   accuracy-at-risk; see `crate::photonics::DegradationState`) into a
//!   lock-free per-worker [`HealthSlot`] read by the dispatcher. Under
//!   [`super::engine::HealthPolicy`] (`aware`, the default) placement
//!   routes **critical** frames (SLO sessions, weight >=
//!   `critical_weight`) away from at-risk workers, the worker rotation
//!   anchor is health-weighted ([`HealthWeightedWrr`] — a degraded worker
//!   still gets >= 1 turn per cycle, so it is never starved), and a
//!   worker whose health falls below `recal_below` is **drained**
//!   (receives no new frames), pays its backend's modeled recalibration
//!   window (`FrameWorker::recalibrate`), and rejoins healthy. Frames
//!   served while the worker was at risk count the session's
//!   `ServeReport::accuracy_at_risk` (the aggregate is exactly the
//!   per-session sum). With `aware = false` routing is health-blind —
//!   the control arm of `rust/tests/faults.rs`.
//! - **Deterministic time.** Every deadline, wait, and timestamp reads
//!   the server's [`super::clock::Clock`] ([`EngineConfig::clock`]), and
//!   every wait is a clock-aware [`super::clock::Event`] (no
//!   `thread::sleep` polling anywhere in this module). Under a manual
//!   clock the QoS semantics above are provable with exact expectations —
//!   the `rust/tests/qos.rs` gate.
//! - **Elastic pool.** The worker pool is no longer fixed at startup:
//!   [`Server::scale_up`] spawns one more worker through the factory
//!   retained from [`Server::start`] (up to
//!   [`EngineConfig::pool_capacity`]), and [`Server::scale_down`] retires
//!   the highest-slot serving worker through the recalibration drain
//!   machinery (`Serving → Retiring → Retired`: no new placements, queue
//!   drains, clean exit with final stats flagged `retired` so totals stay
//!   monotone). A lone serving worker is never drained. When scale-up is
//!   capped, [`Server::set_shed`] turns away the lowest-weight tenants
//!   ([`PushOutcome::Shed`], counted in the distinct
//!   `ServeReport::dropped_shed`). Every scale/shed decision lands in the
//!   [`ScaleEvent`](super::autoscale::ScaleEvent) log on
//!   [`ServerStats::scale_events`]. The closed-loop controller driving
//!   these knobs is `coordinator::autoscale`; `rust/tests/storm.rs` gates
//!   the semantics under a manual clock.
//!
//! `serve_sharded(_with)` and `engine::run` are thin one-session wrappers
//! over this module (a synthetic-sensor tenant feeding one session), which
//! is what keeps their pre-session observable semantics.

use std::collections::BTreeMap;
use std::fmt;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::autoscale::{ScaleAction, ScaleEvent};
use super::batcher::PushOutcome;
use super::clock::{Clock, Event};
use super::engine::{EngineConfig, FrameWorker};
use super::pipeline::{FrameResult, ServeReport};
use super::stats::{LatencyHistogram, StageMetrics, WorkerHealthStats, WorkerMode, WorkerStats};
use crate::quant::PrecisionPolicy;
use crate::sensor::{Frame, VideoSource};

// Wait caps for the event-driven loops. Every admission-relevant
// transition (submit, consume, close, cancel, worker pop, failure, …)
// notifies the server's activity [`Event`], so these are *backstops*
// against a lost wakeup on the system clock — not poll intervals. Under a
// manual clock they never expire on their own (time only moves on
// `advance`), which is exactly what makes waits deterministic.
/// Dispatcher post-sweep idle wait.
const DISPATCH_IDLE_WAIT: Duration = Duration::from_millis(20);
/// Dispatcher warmup-hold re-check.
const WARMUP_POLL: Duration = Duration::from_millis(100);
/// Worker wait for its queue's first frame.
const WORKER_IDLE_WAIT: Duration = Duration::from_millis(100);
/// Dispatcher wait while every alive worker queue is full.
const PLACE_WAIT: Duration = Duration::from_millis(2);
/// Blocking-submit re-check while an in-flight quota is saturated.
const QUOTA_RECHECK: Duration = Duration::from_millis(100);

/// How serving machinery failures surface to session holders — never as a
/// panic (see the module invariants).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The server or this session no longer accepts the operation
    /// (closed, shut down, or the session was canceled).
    Closed,
    /// The serving machinery failed (worker error or panic, lost thread);
    /// the message is the first recorded failure.
    Failed(String),
    /// A lock guarding the named shared state was poisoned by a panicking
    /// thread.
    Poisoned(&'static str),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Closed => write!(f, "serving session closed"),
            ServeError::Failed(msg) => write!(f, "serving failed: {msg}"),
            ServeError::Poisoned(what) => write!(f, "serving state poisoned: {what}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Lock for a public API path: poisoning surfaces as
/// [`ServeError::Poisoned`] instead of a panic.
fn guard<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> std::result::Result<MutexGuard<'a, T>, ServeError> {
    m.lock().map_err(|_| ServeError::Poisoned(what))
}

/// Lock for internal accounting: the guarded data is plain counters, so a
/// poisoned lock is recovered rather than propagated (the panic that
/// poisoned it is reported through the worker failure path).
fn recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Per-session admission quota: a cap on frames in flight plus an
/// optional token-bucket rate limit. Quota rejections are a *policy*
/// outcome, counted in the distinct `ServeReport::dropped_quota` — never
/// in `dropped`, which stays pure queue-full backpressure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quota {
    /// Max frames submitted but not yet taken off the session's stream
    /// (`0` = unlimited). Bounds one tenant's footprint across queue +
    /// workers + reassembly regardless of how fast it submits.
    pub max_inflight: usize,
    /// Sustained admission rate in frames/second (`0.0` = unlimited),
    /// enforced by a token bucket on the serving clock.
    pub rate_fps: f64,
    /// Token-bucket burst capacity (effective only with `rate_fps > 0`;
    /// clamped to >= 1). The bucket starts full, so a session may burst
    /// this many frames before the rate binds.
    pub burst: usize,
}

impl Quota {
    /// No quota (the default): admission bounded only by the submission
    /// queue and the dispatch window.
    pub fn unlimited() -> Self {
        Quota { max_inflight: 0, rate_fps: 0.0, burst: 0 }
    }

    /// In-flight cap only.
    pub fn inflight(max: usize) -> Self {
        Quota { max_inflight: max, ..Quota::unlimited() }
    }

    /// Token-bucket rate only.
    pub fn rate(fps: f64, burst: usize) -> Self {
        Quota { max_inflight: 0, rate_fps: fps.max(0.0), burst: burst.max(1) }
    }

    /// Combine an in-flight cap with this quota's rate.
    pub fn with_inflight(mut self, max: usize) -> Self {
        self.max_inflight = max;
        self
    }

    /// Whether this quota never binds (the [`Quota::unlimited`] default).
    pub fn is_unlimited(&self) -> bool {
        self.max_inflight == 0 && self.rate_fps <= 0.0
    }
}

impl Default for Quota {
    fn default() -> Self {
        Quota::unlimited()
    }
}

/// Which quota denied an admission, and (for the rate bucket) when to
/// retry.
enum QuotaDenied {
    InFlight,
    Rate { retry_at: Instant },
}

/// Token-bucket state for [`Quota::rate_fps`], refilled lazily on the
/// serving clock.
#[derive(Debug)]
struct TokenBucket {
    tokens: f64,
    last_refill: Instant,
}

/// Knobs of one serving session.
#[derive(Debug, Clone)]
pub struct SessionOptions {
    /// Diagnostic label carried into [`SessionStats`].
    pub name: String,
    /// Bounded submission-queue depth ([`Session::submit`] blocks /
    /// [`Session::try_submit`] rejects when full).
    pub queue_depth: usize,
    /// Fair-admission weight: frames the dispatcher may take from this
    /// session per round-robin turn (>= 1). Weight 2 gets ~2x the
    /// admission share of weight 1 under contention.
    pub weight: u32,
    /// Per-session dispatch window: max frames between dispatch and the
    /// consumer's stream (bounds per-session reassembly memory and
    /// undrained results). `0` derives a default from the server topology
    /// ([`EngineConfig::effective_window`]).
    pub window: usize,
    /// Latency SLO on **submit→emit** time. Frames from this session
    /// carry `accepted_at + slo` deadlines: a worker flushes its
    /// micro-batch group early when the earliest such deadline arrives
    /// (overriding `BatchPolicy::max_wait`), and emissions later than the
    /// SLO count the session's `ServeReport::slo_miss`.
    pub slo: Option<Duration>,
    /// Admission quota (see [`Quota`]). `try_submit` rejections under it
    /// return [`PushOutcome::Quota`] and count `dropped_quota`; blocking
    /// `submit` waits for the quota to admit.
    pub quota: Quota,
    /// Serving precision policy ([`PrecisionPolicy`]): a fixed
    /// [`crate::quant::PrecisionTier`] for every frame, or `Auto` to pick
    /// the tier per frame from MGNet ROI density. Stamped onto each
    /// submitted frame; the worker pipeline resolves and serves it.
    pub precision: PrecisionPolicy,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            name: String::new(),
            queue_depth: 8,
            weight: 1,
            window: 0,
            slo: None,
            quota: Quota::unlimited(),
            precision: PrecisionPolicy::default(),
        }
    }
}

impl SessionOptions {
    /// Defaults with a diagnostic name.
    pub fn named(name: impl Into<String>) -> Self {
        SessionOptions { name: name.into(), ..SessionOptions::default() }
    }

    pub fn with_weight(mut self, weight: u32) -> Self {
        self.weight = weight.max(1);
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Declare a submit→emit latency SLO (see [`SessionOptions::slo`]).
    pub fn with_slo(mut self, slo: Duration) -> Self {
        self.slo = Some(slo);
        self
    }

    /// Attach an admission quota (see [`Quota`]).
    pub fn with_quota(mut self, quota: Quota) -> Self {
        self.quota = quota;
        self
    }

    /// Declare a serving precision policy (see
    /// [`SessionOptions::precision`]).
    pub fn with_precision(mut self, precision: PrecisionPolicy) -> Self {
        self.precision = precision;
        self
    }
}

/// Per-session running totals, accumulated by the reassembler at emission
/// time and snapshotted into per-session [`ServeReport`]s.
#[derive(Debug, Default, Clone)]
struct SessionAccum {
    frames: u64,
    iou_sum: f64,
    correct: u64,
    energy_sum: f64,
    latency_sum: f64,
    /// Total modeled queueing time (s) across emitted frames — the co-sim
    /// waiting share of `latency_sum`. Kept as a sum so the server-wide
    /// aggregate is exactly the per-session sum.
    queueing_sum: f64,
    kept_sum: f64,
    batch_sum: f64,
    /// Emissions later than the session's SLO (0 without an SLO).
    slo_miss: u64,
    /// Frames served by a worker whose backend reported accuracy-at-risk
    /// at completion time (0 without a fault model).
    accuracy_at_risk: u64,
    /// Frames served per precision tier, indexed by
    /// [`crate::quant::PrecisionTier::index`] (`[int4, int8, fp32]`).
    /// Kept as exact counts so the server-wide aggregate is precisely
    /// the per-session element-wise sum.
    tier_frames: [u64; 3],
    /// Frames per tier that also ran the fp32 electronic reference probe.
    tier_ref_frames: [u64; 3],
    /// Of the probed frames per tier, how many agreed with the fp32
    /// reference top-1 class.
    tier_agree: [u64; 3],
    /// Submit→emit latency distribution (p99 in the report).
    session_latency: LatencyHistogram,
    first_emit: Option<Instant>,
    last_emit: Option<Instant>,
    /// Every frame the session submitted before closing was emitted.
    complete: bool,
}

/// Shared per-session state (counters + accumulated report inputs).
#[derive(Debug)]
struct SessionShared {
    id: u64,
    name: String,
    weight: u32,
    window: usize,
    /// Latency SLO on submit→emit time ([`SessionOptions::slo`]).
    slo: Option<Duration>,
    /// Admission quota ([`SessionOptions::quota`]).
    quota: Quota,
    /// Serving precision policy ([`SessionOptions::precision`]): stamped
    /// onto every frame at submission so routing stays session-scoped.
    precision: PrecisionPolicy,
    /// Frames accepted into the submission queue.
    submitted: AtomicU64,
    /// Frames handed to workers (dispatcher mirror).
    dispatched: AtomicU64,
    /// Results the consumer has taken off the stream — the dispatch
    /// window compares against this, which is what isolates a
    /// non-draining tenant's backpressure to its own session.
    consumed: AtomicU64,
    /// `try_submit` rejections (the session's `ServeReport::dropped`).
    rejected: AtomicU64,
    /// Quota rejections (the session's `ServeReport::dropped_quota` —
    /// policy, kept distinct from backpressure `rejected`).
    rejected_quota: AtomicU64,
    /// Overload-shedding rejections (the session's
    /// `ServeReport::dropped_shed` — the autoscaler's fleet-level valve,
    /// kept distinct from both backpressure and per-session quota).
    rejected_shed: AtomicU64,
    /// Token-bucket state for [`Quota::rate_fps`].
    bucket: Mutex<TokenBucket>,
    /// The stream side was dropped: discard this session's frames.
    canceled: AtomicBool,
    accum: Mutex<SessionAccum>,
}

impl SessionAccum {
    /// Build a [`ServeReport`] from one consistent snapshot of the totals.
    fn to_report(
        &self,
        dropped: u64,
        dropped_quota: u64,
        dropped_shed: u64,
        backend: &str,
        workers: usize,
    ) -> ServeReport {
        let frames = self.frames;
        let div = |sum: f64| if frames > 0 { sum / frames as f64 } else { 0.0 };
        let span = match (self.first_emit, self.last_emit) {
            (Some(first), Some(last)) if last > first => (last - first).as_secs_f64(),
            _ => 0.0,
        };
        let mean_energy = div(self.energy_sum);
        ServeReport {
            backend: backend.to_string(),
            frames,
            dropped,
            dropped_quota,
            dropped_shed,
            slo_miss: self.slo_miss,
            accuracy_at_risk: self.accuracy_at_risk,
            tier_frames: self.tier_frames,
            tier_ref_frames: self.tier_ref_frames,
            tier_agree: self.tier_agree,
            p99_latency_s: self.session_latency.quantile(0.99),
            wall_fps: if span > 0.0 { frames as f64 / span } else { 0.0 },
            mean_latency_s: div(self.latency_sum),
            modeled_queueing_s: self.queueing_sum,
            mean_energy_j: mean_energy,
            modeled_kfps_per_watt: super::stats::kfps_per_watt(mean_energy),
            mean_kept_patches: div(self.kept_sum),
            mean_batch: div(self.batch_sum),
            mean_mask_iou: div(self.iou_sum),
            top1_accuracy: if frames > 0 { self.correct as f64 / frames as f64 } else { 0.0 },
            workers,
            per_worker: Vec::new(),
        }
    }
}

impl SessionShared {
    /// One consistent snapshot of the session's accumulated totals.
    fn snapshot(&self) -> SessionAccum {
        recover(&self.accum).clone()
    }

    fn report(&self, backend: &str, workers: usize) -> ServeReport {
        self.snapshot().to_report(
            self.rejected.load(Ordering::Relaxed), // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            self.rejected_quota.load(Ordering::Relaxed), // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            self.rejected_shed.load(Ordering::Relaxed), // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            backend,
            workers,
        )
    }

    /// Take one admission slot under the session quota. On success a rate
    /// token (if any) has been consumed — call
    /// [`SessionShared::refund_token`] if the subsequent enqueue fails, so
    /// a frame that never entered the system does not burn budget.
    fn admit_quota(&self, clock: &Clock) -> std::result::Result<(), QuotaDenied> {
        if self.quota.is_unlimited() {
            return Ok(());
        }
        if self.quota.max_inflight > 0 {
            let inflight = self
                .submitted
                .load(Ordering::Relaxed) // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                .saturating_sub(self.consumed.load(Ordering::Relaxed)); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            if inflight >= self.quota.max_inflight as u64 {
                return Err(QuotaDenied::InFlight);
            }
        }
        if self.quota.rate_fps > 0.0 {
            let burst = self.quota.burst.max(1) as f64;
            let mut b = recover(&self.bucket);
            let now = clock.now();
            let dt = now.saturating_duration_since(b.last_refill).as_secs_f64();
            b.last_refill = now;
            b.tokens = (b.tokens + dt * self.quota.rate_fps).min(burst);
            if b.tokens < 1.0 {
                let wait_s = (1.0 - b.tokens) / self.quota.rate_fps;
                return Err(QuotaDenied::Rate { retry_at: now + Duration::from_secs_f64(wait_s) });
            }
            b.tokens -= 1.0;
        }
        Ok(())
    }

    /// Return the rate token consumed by a successful
    /// [`SessionShared::admit_quota`] whose enqueue then failed.
    fn refund_token(&self) {
        if self.quota.rate_fps > 0.0 {
            let mut b = recover(&self.bucket);
            b.tokens = (b.tokens + 1.0).min(self.quota.burst.max(1) as f64);
        }
    }
}

/// A frame in the session submission queue, stamped with its admission
/// time (the clock origin of SLO deadlines and submit→emit latency).
type Submitted = (Frame, Instant);

/// A dispatched frame: session + per-session sequence number, the
/// admission timestamp, and — for SLO sessions — the completion deadline
/// (`accepted_at + slo`) the worker's deadline-aware flush honors.
struct Job {
    session: u64,
    seq: u64,
    accepted_at: Instant,
    /// `Some` only for SLO sessions: the micro-batch group holding this
    /// frame flushes no later than this instant.
    deadline: Option<Instant>,
    /// Accuracy-critical under the server's `HealthPolicy` (SLO session
    /// or weight >= `critical_weight`): placement steers this frame away
    /// from accuracy-at-risk workers.
    critical: bool,
    frame: Frame,
}

/// What a worker thread hands back on clean exit (metrics + utilization +
/// backend identity), or the failure message that must fail the server.
type WorkerOutcome = std::result::Result<(StageMetrics, WorkerStats, &'static str), String>;

/// The terminal server outcome [`Server::shutdown`] reads back: aggregate
/// report + merged metrics, or the first recorded failure.
type FinalOutcome = std::result::Result<(ServeReport, StageMetrics), String>;

/// Messages from the dispatcher / workers to the reassembler.
enum Msg {
    /// Worker finished warmup and is accepting frames.
    Ready { backend: &'static str },
    /// One processed frame (`accepted_at` = submission-queue admission
    /// time, so the reassembler can score submit→emit latency and SLO
    /// misses on the serving clock).
    Result {
        session: u64,
        seq: u64,
        accepted_at: Instant,
        result: FrameResult,
        iou: f64,
        correct: bool,
        /// The serving worker's backend reported accuracy-at-risk when
        /// this frame completed (counts `ServeReport::accuracy_at_risk`).
        at_risk: bool,
    },
    /// No more frames will be dispatched for this session; exactly
    /// `dispatched` results are expected.
    SessionDone { session: u64, dispatched: u64 },
    /// Worker exited cleanly with its metrics (boxed: the metrics bundle
    /// dwarfs every other variant, and this is a once-per-worker message).
    WorkerDone { stats: WorkerStats, metrics: Box<StageMetrics>, backend: &'static str },
    /// The server must fail (worker error/panic, dead pool).
    /// `worker_exit` is true when the sender is a worker thread that will
    /// send no `WorkerDone` — it still counts toward pool shutdown.
    Failure { error: String, worker_exit: bool },
    /// The dispatcher exited (graceful or abort).
    DispatcherExited,
}

/// Dispatcher-side session state.
struct DispatchEntry {
    shared: Arc<SessionShared>,
    rx: Receiver<Submitted>,
    /// Head-of-queue frame pulled off `rx` by [`DispatchEntry::peek`] (the
    /// EDF pre-pass inspects deadlines without admitting) and not yet
    /// dispatched. Always consumed before `rx` by
    /// [`DispatchEntry::try_next`]; must be discarded when the session's
    /// queue is drained on cancel.
    peeked: Option<Submitted>,
    dispatched: u64,
    done_sent: bool,
}

impl DispatchEntry {
    /// Look at the session's head-of-queue frame without admitting it.
    fn peek(&mut self) -> Option<&Submitted> {
        if self.peeked.is_none() {
            self.peeked = self.rx.try_recv().ok();
        }
        self.peeked.as_ref()
    }

    /// Take the session's next queued frame — the peeked one first, so
    /// peeking never reorders or loses a frame.
    fn try_next(&mut self) -> std::result::Result<Submitted, mpsc::TryRecvError> {
        match self.peeked.take() {
            Some(s) => Ok(s),
            None => self.rx.try_recv(),
        }
    }
}

/// Reassembler-side session state. Pending tuples carry the frame's
/// at-risk flag and admission timestamp so in-order emission can count
/// `accuracy_at_risk` and score submit→emit latency / SLO misses.
struct ReasmState {
    shared: Arc<SessionShared>,
    out: Option<SyncSender<FrameResult>>,
    pending: BTreeMap<u64, (FrameResult, f64, bool, bool, Instant)>,
    next_emit: u64,
    emitted: u64,
    expected: Option<u64>,
}

/// Hand-off point where [`Server::session`] publishes new sessions to the
/// dispatcher and reassembler threads.
#[derive(Default)]
struct Registry {
    new_dispatch: Vec<DispatchEntry>,
    new_reasm: Vec<ReasmState>,
}

// The per-worker hardware-health cell lives in `super::health` (extracted
// so its lock-free publication protocol sits behind the loom seam and is
// model-checked in `rust/tests/loom_models.rs`); re-exported here because
// it is part of the server's architecture.
pub use super::health::HealthSlot;

/// Why a scale operation was refused. Refusals are normal controller
/// feedback — the autoscaler reacts to them (e.g. turns on shedding when
/// [`ScaleError::AtCapacity`]) — not server failures, and they are never
/// recorded as [`ScaleEvent`]s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleError {
    /// Every slot up to [`EngineConfig::pool_capacity`] holds a live
    /// worker — the autoscaler's cue to start shedding.
    AtCapacity,
    /// Scaling down would leave no serving worker: a lone worker is never
    /// drained (availability over elasticity).
    AtFloor,
    /// The server is closing/failed, or the dispatcher already exited —
    /// the pool no longer changes size.
    Closed,
    /// A lock guarding pool state was poisoned by a panicking thread.
    Poisoned(&'static str),
}

impl fmt::Display for ScaleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScaleError::AtCapacity => write!(f, "worker pool at capacity"),
            ScaleError::AtFloor => write!(f, "a lone serving worker is never drained"),
            ScaleError::Closed => write!(f, "server closing; pool size is frozen"),
            ScaleError::Poisoned(what) => write!(f, "pool state poisoned: {what}"),
        }
    }
}

impl std::error::Error for ScaleError {}

/// Dynamic worker-pool occupancy, guarded by one mutex so scale
/// decisions, spawner hand-off, and worker exits stay mutually
/// consistent. Slot index = `ServerCore::inflight` / `health` index; the
/// vectors are sized to [`EngineConfig::pool_capacity`] once at start so
/// scale-up never reallocates shared state.
struct PoolState {
    /// Per-slot occupant: `Some(wid)` while a (possibly retiring) worker
    /// thread owns the slot; `None` once it exited.
    slots: Vec<Option<usize>>,
    /// Per-slot logical pin-core claim (`Some` only under
    /// `EngineConfig::pin_workers`); released with the slot on exit.
    claims: Vec<Option<usize>>,
    /// Worker queues spawned by [`Server::scale_up`] and not yet adopted
    /// by the dispatcher: `(slot, sender)`.
    pending: Vec<(usize, SyncSender<Job>)>,
    /// Workers ever spawned — the unique-wid source and the
    /// reassembler's exit expectation (`worker_exits` catches up to it).
    spawned: usize,
    /// The dispatcher exited and dropped every queue: no more spawns.
    closed: bool,
}

impl PoolState {
    /// Workers currently holding a slot (serving, draining,
    /// recalibrating, or retiring — their thread is still running).
    fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn lowest_free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }
}

/// Lowest logical core index not claimed by a live worker — the pin
/// target for a newly spawned worker under `EngineConfig::pin_workers`.
/// A retired worker's claim returns to the free set, so scale cycles
/// reuse low cores instead of marching rightward (or blindly re-pinning
/// from core 0 over a live worker).
fn lowest_free_core(claims: &[Option<usize>]) -> usize {
    (0usize..=claims.len()).find(|c| !claims.contains(&Some(*c))).unwrap_or(0)
}

/// State shared by the server handle, its threads, and session handles.
struct ServerCore {
    cfg: EngineConfig,
    /// The serving clock (mirrors `cfg.clock`; every thread reads it).
    clock: Clock,
    /// The one wait/notify cell every event-driven loop blocks on:
    /// submissions, consumptions, worker-queue pops, session lifecycle,
    /// readiness, and failure all notify it. One cell keeps the wakeup
    /// graph trivially complete (no transition can miss a waiter) at the
    /// cost of some spurious wakeups — the right trade at worker-count
    /// scale.
    activity: Event,
    n_workers: usize,
    /// Slot capacity of the elastic pool ([`EngineConfig::pool_capacity`]);
    /// `inflight` and `health` are sized to it once, so the dispatcher and
    /// scale-up never reallocate shared vectors.
    capacity: usize,
    default_window: usize,
    ready: AtomicBool,
    closing: AtomicBool,
    abort: AtomicBool,
    failed: AtomicBool,
    failure: Mutex<Option<String>>,
    backend: Mutex<&'static str>,
    t_ready: Mutex<Option<Instant>>,
    inflight: Vec<AtomicU64>,
    /// Per-worker health cells (same indexing as `inflight`).
    health: Vec<HealthSlot>,
    total_dispatched: AtomicU64,
    next_session: AtomicU64,
    registry: Mutex<Registry>,
    sessions: Mutex<Vec<Arc<SessionShared>>>,
    outcome: Mutex<Option<FinalOutcome>>,
    /// Dynamic pool occupancy + spawner hand-off (see [`PoolState`]).
    pool: Mutex<PoolState>,
    /// Scale/shed decision log, exposed via [`ServerStats::scale_events`].
    scale_events: Mutex<Vec<ScaleEvent>>,
    /// Admission-shedding threshold: `try_submit` from sessions with
    /// `weight <` this returns [`PushOutcome::Shed`] (`0` = off). Set by
    /// the autoscaler when scale-up is capped, lowest weights first.
    shed_below: AtomicU32,
    /// Final health rows of retired workers (mode `Retired`), kept so
    /// [`ServerStats`] totals stay monotone across a scale-down.
    retired_health: Mutex<Vec<WorkerHealthStats>>,
    /// Serving-clock origin of [`ScaleEvent::at_s`].
    t_start: Instant,
}

impl ServerCore {
    fn failure_msg(&self) -> Option<String> {
        if !self.failed.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            return None;
        }
        recover(&self.failure).clone()
    }

    fn fail(&self, error: &str) {
        let mut f = recover(&self.failure);
        if f.is_none() {
            *f = Some(error.to_string());
        }
        drop(f);
        self.failed.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        self.abort.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        // Every blocked loop must observe the failure promptly.
        self.activity.notify();
    }
}

/// A cheap, `Send + Clone` view of the server's liveness flags — what a
/// producer thread needs to pace itself against warmup and failure
/// without holding the server handle.
#[derive(Clone)]
pub struct ServerWatch {
    core: Arc<ServerCore>,
}

impl ServerWatch {
    /// All workers warmed up; dispatch is live.
    pub fn ready(&self) -> bool {
        self.core.ready.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
    }

    /// The server failed (see [`ServerWatch::failure`]).
    pub fn failed(&self) -> bool {
        self.core.failed.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
    }

    /// Graceful shutdown has begun; new submissions are rejected.
    pub fn closing(&self) -> bool {
        self.core.closing.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
    }

    /// The first recorded failure, if any.
    pub fn failure(&self) -> Option<String> {
        self.core.failure_msg()
    }
}

/// Submission half of a [`Session`] (`Send`: feed it from a sensor
/// thread). Dropping it closes the session's input — already-submitted
/// frames still drain through the stream.
pub struct SessionSubmitter {
    tx: Option<SyncSender<Submitted>>,
    shared: Arc<SessionShared>,
    core: Arc<ServerCore>,
}

impl SessionSubmitter {
    /// Blocking submission under backpressure: waits while the session
    /// queue is full **or the session's admission [`Quota`] is
    /// exhausted** (an in-flight slot frees when the consumer drains; a
    /// rate token refills with the serving clock), errs if the
    /// session/server is closed or failed. Blocking callers never count
    /// `dropped_quota` — that counter is the non-blocking
    /// [`SessionSubmitter::try_submit`] rejection record.
    ///
    /// `submitted` is incremented **before** the send: a graceful
    /// shutdown finalizes a session only once `dispatched` has caught up
    /// with `submitted`, so a frame this method accepted can never be
    /// silently discarded by a racing shutdown sweep.
    pub fn submit(&self, mut frame: Frame) -> std::result::Result<(), ServeError> {
        let Some(tx) = &self.tx else { return Err(ServeError::Closed) };
        // Session policy overrides whatever the sensor stamped: precision
        // is a per-tenant serving contract, not a per-frame caller knob.
        frame.precision = self.shared.precision;
        loop {
            // Generation before the predicate checks: a state change
            // between check and wait ends the wait immediately.
            let gen = self.core.activity.generation();
            if let Some(msg) = self.core.failure_msg() {
                return Err(ServeError::Failed(msg));
            }
            if self.core.closing.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                || self.shared.canceled.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            {
                return Err(ServeError::Closed);
            }
            let shed = self.core.shed_below.load(Ordering::Relaxed); // relaxed-ok: shed latch; submitters re-check on the activity event
            if shed > 0 && self.shared.weight < shed {
                // Fleet overload shedding: block until the autoscaler
                // clears it (`clear_shed` notifies). Blocking callers
                // never count `dropped_shed` — that is the non-blocking
                // `try_submit` rejection record.
                self.core.activity.wait_for(gen, QUOTA_RECHECK);
                continue;
            }
            match self.shared.admit_quota(&self.core.clock) {
                Ok(()) => break,
                Err(QuotaDenied::InFlight) => {
                    self.core.activity.wait_for(gen, QUOTA_RECHECK);
                }
                Err(QuotaDenied::Rate { retry_at }) => {
                    self.core.activity.wait_until(gen, retry_at);
                }
            }
        }
        self.shared.submitted.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
        match tx.send((frame, self.core.clock.now())) {
            Ok(()) => {
                self.core.activity.notify();
                Ok(())
            }
            Err(_) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                self.shared.refund_token();
                match self.core.failure_msg() {
                    Some(msg) => Err(ServeError::Failed(msg)),
                    None => Err(ServeError::Closed),
                }
            }
        }
    }

    /// Non-blocking submission; [`PushOutcome::Full`] counts as a
    /// rejection in the session's `ServeReport::dropped` (the sensor
    /// backpressure contract of the batch-job API), while
    /// [`PushOutcome::Quota`] — an admission-[`Quota`] rejection — counts
    /// the **distinct** `ServeReport::dropped_quota`, so policy drops can
    /// never masquerade as backpressure. Under autoscaler overload
    /// shedding ([`Server::set_shed`]), a below-threshold session gets
    /// [`PushOutcome::Shed`] — counted in the third distinct counter,
    /// `ServeReport::dropped_shed` — checked before the quota, so the
    /// fleet-level valve never burns per-session budget.
    pub fn try_submit(&self, mut frame: Frame) -> PushOutcome {
        frame.precision = self.shared.precision;
        if self.core.closing.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            || self.core.failed.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            || self.shared.canceled.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        {
            return PushOutcome::Closed;
        }
        let Some(tx) = &self.tx else { return PushOutcome::Closed };
        let shed = self.core.shed_below.load(Ordering::Relaxed); // relaxed-ok: shed latch; submitters re-check on the activity event
        if shed > 0 && self.shared.weight < shed {
            self.shared.rejected_shed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            return PushOutcome::Shed;
        }
        if self.shared.admit_quota(&self.core.clock).is_err() {
            self.shared.rejected_quota.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            return PushOutcome::Quota;
        }
        // Pre-increment for the same shutdown-race reason as `submit`.
        self.shared.submitted.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
        match tx.try_send((frame, self.core.clock.now())) {
            Ok(()) => {
                self.core.activity.notify();
                PushOutcome::Queued
            }
            Err(TrySendError::Full(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                self.shared.refund_token();
                self.shared.rejected.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                PushOutcome::Full
            }
            Err(TrySendError::Disconnected(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                self.shared.refund_token();
                PushOutcome::Closed
            }
        }
    }

    /// Close the session's input (idempotent): no more submissions; the
    /// stream ends once everything already submitted has been emitted.
    pub fn close(&mut self) {
        self.tx = None;
        // The dispatcher finalizes the session on the hung-up queue.
        self.core.activity.notify();
    }
}

impl Drop for SessionSubmitter {
    fn drop(&mut self) {
        // Dropping the sender closes the session's input; wake the
        // dispatcher so it observes the hang-up without a timeout.
        self.core.activity.notify();
    }
}

/// Consumption half of a [`Session`]: an iterator of this session's
/// [`FrameResult`]s, strictly in submission order. Dropping it without
/// draining **cancels** the session (queued frames are discarded) — the
/// graceful mid-flight teardown path.
pub struct SessionStream {
    rx: Receiver<FrameResult>,
    shared: Arc<SessionShared>,
    core: Arc<ServerCore>,
    gave_error: bool,
    finished: bool,
}

impl SessionStream {
    fn next_result(&mut self) -> Option<std::result::Result<FrameResult, ServeError>> {
        if self.finished {
            return None;
        }
        loop {
            match self.rx.recv_timeout(Duration::from_millis(100)) {
                Ok(r) => {
                    self.shared.consumed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                    // A drain opens the dispatch window (and any in-flight
                    // quota): wake the dispatcher and blocked submitters.
                    self.core.activity.notify();
                    return Some(Ok(r));
                }
                // Quiet channel: keep waiting unless the server failed
                // (buffered results always drain first, so an empty
                // channel on a failed server is the end of the stream —
                // and a session that raced server teardown can never
                // block its consumer forever).
                Err(RecvTimeoutError::Timeout) => {
                    if !self.core.failed.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                        continue;
                    }
                    return self.end_of_stream();
                }
                Err(RecvTimeoutError::Disconnected) => return self.end_of_stream(),
            }
        }
    }

    /// The stream is over: surface the server failure exactly once, or end
    /// cleanly for complete / canceled / shutdown-raced sessions.
    fn end_of_stream(&mut self) -> Option<std::result::Result<FrameResult, ServeError>> {
        self.finished = true;
        if self.gave_error || recover(&self.shared.accum).complete {
            return None;
        }
        self.gave_error = true;
        self.core.failure_msg().map(|msg| Err(ServeError::Failed(msg)))
    }

    /// Non-blocking pull: `Some(Ok)` for a result already buffered,
    /// `Some(Err)` to surface a server failure (exactly once), `None`
    /// when the stream is quiet *or* over — check [`ServeReport`]
    /// completion to tell them apart. Lets a single driver thread drain
    /// hundreds of sessions between clock advances (the load-generator
    /// harness in `coordinator::loadgen`) without parking on any one.
    pub fn try_next(&mut self) -> Option<std::result::Result<FrameResult, ServeError>> {
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.shared.consumed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                self.core.activity.notify();
                Some(Ok(r))
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => self.end_of_stream(),
        }
    }

    /// Snapshot of this session's running [`ServeReport`].
    pub fn report(&self) -> ServeReport {
        self.shared.report(*recover(&self.core.backend), self.core.n_workers)
    }

    /// Drain the rest of the stream (propagating a server failure) and
    /// return the session's terminal [`ServeReport`].
    pub fn finish(mut self) -> std::result::Result<ServeReport, ServeError> {
        while let Some(item) = self.next_result() {
            item?;
        }
        Ok(self.report())
    }
}

impl Iterator for SessionStream {
    type Item = std::result::Result<FrameResult, ServeError>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_result()
    }
}

impl Drop for SessionStream {
    fn drop(&mut self) {
        // An undrained stream marks the session canceled so the dispatcher
        // discards its remaining frames instead of serving a consumer that
        // is gone. A drained/complete session keeps its clean record.
        if !self.finished && !recover(&self.shared.accum).complete {
            self.shared.canceled.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        }
        // Wake the dispatcher to sweep the canceled session promptly.
        self.core.activity.notify();
    }
}

/// One tenant's handle on a running [`Server`]: submit frames under
/// backpressure, iterate in-order results, snapshot the per-session
/// report. Split it ([`Session::split`]) to feed and drain from different
/// threads.
pub struct Session {
    submitter: SessionSubmitter,
    stream: SessionStream,
}

impl Session {
    /// Session id (unique per server).
    pub fn id(&self) -> u64 {
        self.submitter.shared.id
    }

    /// See [`SessionSubmitter::submit`].
    pub fn submit(&self, frame: Frame) -> std::result::Result<(), ServeError> {
        self.submitter.submit(frame)
    }

    /// See [`SessionSubmitter::try_submit`].
    pub fn try_submit(&self, frame: Frame) -> PushOutcome {
        self.submitter.try_submit(frame)
    }

    /// Close the input side (idempotent); the stream drains what was
    /// already submitted.
    pub fn close(&mut self) {
        self.submitter.close();
    }

    /// Snapshot of this session's running [`ServeReport`].
    pub fn report(&self) -> ServeReport {
        self.stream.report()
    }

    /// See [`SessionStream::try_next`] (non-blocking pull).
    pub fn try_next(&mut self) -> Option<std::result::Result<FrameResult, ServeError>> {
        self.stream.try_next()
    }

    /// Split into the `Send` submission half and the stream half, so a
    /// sensor thread can feed while another thread drains.
    pub fn split(self) -> (SessionSubmitter, SessionStream) {
        (self.submitter, self.stream)
    }

    /// Close, drain every remaining result, and return the session's
    /// terminal [`ServeReport`] (the one-call equivalent of
    /// `FrameStream::finish`).
    pub fn finish(mut self) -> std::result::Result<ServeReport, ServeError> {
        self.submitter.close();
        self.stream.finish()
    }
}

impl Iterator for Session {
    type Item = std::result::Result<FrameResult, ServeError>;

    fn next(&mut self) -> Option<Self::Item> {
        self.stream.next_result()
    }
}

/// Per-session row of [`ServerStats`].
#[derive(Debug, Clone)]
pub struct SessionStats {
    pub id: u64,
    pub name: String,
    pub weight: u32,
    /// Every submitted frame was emitted (session closed and drained).
    pub complete: bool,
    /// The session was canceled mid-flight (stream dropped).
    pub canceled: bool,
    /// Frames accepted into the submission queue so far.
    pub submitted: u64,
    /// Frames dispatched but not yet taken off the stream.
    pub inflight: u64,
    pub report: ServeReport,
}

/// Server-wide snapshot: the aggregate over all sessions plus one
/// [`SessionStats`] row per session (open or finished).
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub backend: String,
    /// Workers configured at start ([`EngineConfig::workers`]) — the
    /// elastic pool's starting size, not its current one.
    pub workers: usize,
    /// Workers currently holding a pool slot (serving, draining,
    /// recalibrating, or retiring; retired workers have released theirs).
    pub live_workers: usize,
    /// Admission-shedding threshold in force (`0` = off): sessions with
    /// `weight <` this are being turned away ([`PushOutcome::Shed`]).
    pub shed_below: u32,
    /// Aggregate report across every session (per-frame means weighted by
    /// frames; `wall_fps` over the server's post-warmup lifetime).
    pub aggregate: ServeReport,
    pub sessions: Vec<SessionStats>,
    /// Live per-worker hardware-health snapshot (health score, serving
    /// mode, queue depth, recal counts/energy) — all 1.0/`Serving`/zero
    /// for backends without a fault model. Retired workers keep their
    /// final row (mode `Retired`, queue depth 0) so totals stay monotone
    /// across a scale-down.
    pub worker_health: Vec<WorkerHealthStats>,
    /// Every scale/shed decision so far, in order ([`ScaleEvent`]).
    pub scale_events: Vec<ScaleEvent>,
}

/// Type-erased worker spawner retained by the [`Server`] so
/// [`Server::scale_up`] can add workers after `start` without knowing the
/// concrete `FrameWorker`/factory types: `(wid, slot, pin_core)` → (job
/// queue sender for the dispatcher to adopt, worker thread handle).
type Spawner =
    dyn Fn(usize, usize, Option<usize>) -> (SyncSender<Job>, JoinHandle<()>) + Send + Sync;

/// A long-lived serving instance: the dispatcher, worker pool, and
/// reassembler are started **once**; independent [`Session`]s come and go
/// on top (see the module docs for the invariants). `serve_sharded` is the
/// one-session batch-job wrapper over this type. The worker pool is
/// elastic: [`Server::scale_up`] / [`Server::scale_down`] resize it at
/// runtime (typically driven by `coordinator::autoscale`).
pub struct Server {
    core: Arc<ServerCore>,
    handles: Vec<JoinHandle<()>>,
    /// Spawns one more worker thread through the retained factory (see
    /// [`Spawner`]).
    spawner: Arc<Spawner>,
    /// Handles of workers spawned by [`Server::scale_up`], joined on
    /// shutdown/drop alongside the initial `handles`.
    scaled: Mutex<Vec<JoinHandle<()>>>,
}

impl Server {
    /// Start the serving machinery: N worker threads (each constructing
    /// its own, possibly non-`Send`, [`FrameWorker`] via `factory`), the
    /// fair-admission dispatcher, and the per-session reassembler. Workers
    /// warm up immediately; sessions may be opened (and fed) before warmup
    /// finishes — dispatch begins once every initial worker is ready.
    ///
    /// The factory is retained (type-erased) so [`Server::scale_up`] can
    /// grow the pool later, up to [`EngineConfig::pool_capacity`].
    pub fn start<W, F>(factory: F, cfg: EngineConfig) -> Result<Server>
    where
        W: FrameWorker + 'static,
        F: Fn(usize) -> Result<W> + Send + Sync + 'static,
    {
        let n_workers = cfg.workers.max(1);
        let capacity = cfg.pool_capacity();
        let default_window = cfg.effective_window();
        let clock = cfg.clock.clone();
        let activity = clock.event();
        let t_start = clock.now();
        let core = Arc::new(ServerCore {
            clock,
            activity,
            n_workers,
            capacity,
            default_window,
            ready: AtomicBool::new(false),
            closing: AtomicBool::new(false),
            abort: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            failure: Mutex::new(None),
            backend: Mutex::new("custom"),
            t_ready: Mutex::new(None),
            inflight: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            health: (0..capacity).map(|_| HealthSlot::new()).collect(),
            total_dispatched: AtomicU64::new(0),
            next_session: AtomicU64::new(0),
            registry: Mutex::new(Registry::default()),
            sessions: Mutex::new(Vec::new()),
            outcome: Mutex::new(None),
            pool: Mutex::new(PoolState {
                slots: vec![None; capacity],
                claims: vec![None; capacity],
                pending: Vec::new(),
                spawned: 0,
                closed: false,
            }),
            scale_events: Mutex::new(Vec::new()),
            shed_below: AtomicU32::new(0),
            retired_health: Mutex::new(Vec::new()),
            t_start,
            cfg,
        });
        let factory = Arc::new(factory);
        let (res_tx, res_rx) = mpsc::channel::<Msg>();

        // Type-erase the factory into a spawner closure so scale_up can
        // add workers without the `W`/`F` generics. It holds a `res_tx`
        // clone for late-spawned workers; the reassembler exits on its
        // counted conditions, never on channel disconnect, so the
        // long-lived clone is harmless.
        let spawner: Arc<Spawner> = {
            let (core, factory, res_tx) = (core.clone(), factory.clone(), res_tx.clone());
            Arc::new(move |wid, slot, pin_core| {
                let (tx, rx) = mpsc::sync_channel::<Job>(core.cfg.queue_depth.max(1));
                let (core_w, factory_w, res_tx_w) =
                    (core.clone(), factory.clone(), res_tx.clone());
                let handle = std::thread::spawn(move || {
                    worker_loop(wid, slot, pin_core, &*factory_w, &core_w, rx, res_tx_w)
                });
                (tx, handle)
            })
        };

        let mut handles = Vec::with_capacity(n_workers + 2);
        // The dispatcher owns one sender slot per pool slot; unspawned
        // slots hold `None` until scale-up fills them.
        let mut worker_txs: Vec<Option<SyncSender<Job>>> = (0..capacity).map(|_| None).collect();
        {
            let mut pool = recover(&core.pool);
            for wid in 0..n_workers {
                let pin_core = core.cfg.pin_workers.then(|| lowest_free_core(&pool.claims));
                pool.slots[wid] = Some(wid); // lint-allow(panic): slot ids are allocated below pool capacity, the arrays' fixed length
                pool.claims[wid] = pin_core; // lint-allow(panic): slot ids are allocated below pool capacity, the arrays' fixed length
                pool.spawned += 1;
                let (tx, handle) = spawner(wid, wid, pin_core);
                worker_txs[wid] = Some(tx); // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                handles.push(handle);
            }
        }
        let (core_d, res_tx_d) = (core.clone(), res_tx.clone());
        handles.push(std::thread::spawn(move || dispatcher_loop(&core_d, worker_txs, res_tx_d)));
        let core_r = core.clone();
        handles.push(std::thread::spawn(move || reassembler_loop(&core_r, res_rx)));

        Ok(Server { core, handles, spawner, scaled: Mutex::new(Vec::new()) })
    }

    /// Grow the live pool by one worker, spawned gracefully through the
    /// factory retained from [`Server::start`] (it warms up in-thread and
    /// joins placement; frames may queue on it while it warms). The new
    /// worker takes the lowest free slot, and — under
    /// `EngineConfig::pin_workers` — the lowest core not claimed by a
    /// live worker. Refused with [`ScaleError::AtCapacity`] once every
    /// slot up to [`EngineConfig::pool_capacity`] is occupied (the
    /// autoscaler's cue to shed) and with [`ScaleError::Closed`] on a
    /// closing server. Records a [`ScaleEvent`]; returns the live count
    /// including the new worker.
    pub fn scale_up(&self) -> std::result::Result<usize, ScaleError> {
        if self.core.closing.load(Ordering::Relaxed) || self.core.failed.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        {
            return Err(ScaleError::Closed);
        }
        let (wid, slot, live) = {
            let mut pool =
                self.core.pool.lock().map_err(|_| ScaleError::Poisoned("worker pool"))?;
            if pool.closed {
                return Err(ScaleError::Closed);
            }
            let Some(slot) = pool.lowest_free_slot() else {
                return Err(ScaleError::AtCapacity);
            };
            let wid = pool.spawned;
            pool.spawned += 1;
            let pin_core = self.core.cfg.pin_workers.then(|| lowest_free_core(&pool.claims));
            pool.slots[slot] = Some(wid); // lint-allow(panic): slot ids are allocated below pool capacity, the arrays' fixed length
            pool.claims[slot] = pin_core; // lint-allow(panic): slot ids are allocated below pool capacity, the arrays' fixed length
            // Re-arm the slot's health cell for its fresh occupant (the
            // previous occupant's final row lives in `retired_health`).
            self.core.health[slot].reset(); // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
            let (tx, handle) = (self.spawner)(wid, slot, pin_core);
            pool.pending.push((slot, tx));
            recover(&self.scaled).push(handle);
            (wid, slot, pool.live())
        };
        self.record_scale(
            ScaleAction::Up,
            live,
            format!("worker {wid} spawned into slot {slot}"),
        );
        // The dispatcher adopts the pending queue on its next sweep.
        self.core.activity.notify();
        Ok(live)
    }

    /// Shrink the live pool by one: flag the highest-slot **serving**
    /// worker `Retiring` and let the drain machinery finish the job — the
    /// dispatcher stops placing on it, waits for its queue to drain
    /// (`inflight == 0`), then closes the queue; the worker exits cleanly
    /// with its final stats flagged `retired` and its slot (and pin-core
    /// claim) returns to the free set. Never drains a lone serving worker
    /// ([`ScaleError::AtFloor`] — draining/recalibrating peers don't
    /// count). Records a [`ScaleEvent`]; returns the live count the pool
    /// is shrinking toward.
    pub fn scale_down(&self) -> std::result::Result<usize, ScaleError> {
        if self.core.closing.load(Ordering::Relaxed) || self.core.failed.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        {
            return Err(ScaleError::Closed);
        }
        let (victim, target) = {
            let pool = self.core.pool.lock().map_err(|_| ScaleError::Poisoned("worker pool"))?;
            if pool.closed {
                return Err(ScaleError::Closed);
            }
            let mut serving = pool
                .slots
                .iter()
                .enumerate()
                .filter(|(slot, occ)| {
                    occ.is_some() && self.core.health[*slot].mode() == WorkerMode::Serving // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                })
                .map(|(slot, _)| slot);
            let (first, last) = (serving.next(), serving.last());
            let victim = match (first, last) {
                // A lone serving worker is never drained.
                (_, None) | (None, _) => return Err(ScaleError::AtFloor),
                (Some(_), Some(highest)) => highest,
            };
            self.core.health[victim].set_mode(WorkerMode::Retiring); // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
            (victim, pool.live() - 1)
        };
        self.record_scale(ScaleAction::Down, target, format!("slot {victim} retiring"));
        // Wake the dispatcher so an already-drained victim retires now.
        self.core.activity.notify();
        Ok(target)
    }

    /// Enable admission shedding: `try_submit` from sessions with
    /// `weight < below_weight` returns [`PushOutcome::Shed`] (counted in
    /// the distinct `ServeReport::dropped_shed`) until
    /// [`Server::clear_shed`]. The autoscaler's overload valve when
    /// scale-up is capped — lowest-weight tenants are rejected first.
    /// `below_weight == 0` clears. Records a [`ScaleEvent`] when the
    /// threshold actually changes; returns whether it did.
    pub fn set_shed(&self, below_weight: u32) -> bool {
        if below_weight == 0 {
            return self.clear_shed();
        }
        let prev = self.core.shed_below.swap(below_weight, Ordering::Relaxed); // relaxed-ok: shed latch; submitters re-check on the activity event
        if prev == below_weight {
            return false;
        }
        let live = recover(&self.core.pool).live();
        self.record_scale(
            ScaleAction::ShedOn { below_weight },
            live,
            format!("shedding tenants below weight {below_weight}"),
        );
        self.core.activity.notify();
        true
    }

    /// Disable admission shedding (blocked submitters re-admit). Records
    /// a [`ScaleEvent`] if shedding was on; returns whether it was.
    pub fn clear_shed(&self) -> bool {
        let prev = self.core.shed_below.swap(0, Ordering::Relaxed); // relaxed-ok: shed latch; submitters re-check on the activity event
        if prev == 0 {
            return false;
        }
        let live = recover(&self.core.pool).live();
        self.record_scale(ScaleAction::ShedOff, live, "shedding cleared".to_string());
        self.core.activity.notify();
        true
    }

    /// Admission-shedding threshold in force (`0` = off).
    pub fn shed_below(&self) -> u32 {
        self.core.shed_below.load(Ordering::Relaxed) // relaxed-ok: shed latch; submitters re-check on the activity event
    }

    /// Workers currently holding a pool slot (their thread is running:
    /// serving, draining, recalibrating, or retiring).
    pub fn live_workers(&self) -> usize {
        recover(&self.core.pool).live()
    }

    /// Snapshot of the scale/shed decision log, in decision order.
    pub fn scale_events(&self) -> Vec<ScaleEvent> {
        recover(&self.core.scale_events).clone()
    }

    fn record_scale(&self, action: ScaleAction, workers: usize, detail: String) {
        let at_s = self.core.clock.seconds_since(self.core.t_start);
        recover(&self.core.scale_events).push(ScaleEvent { at_s, action, workers, detail });
    }

    /// Open an independent serving session. Frames from all sessions share
    /// the worker pool and per-bucket micro-batch lanes; this session's
    /// results stream back in its own submission order.
    pub fn session(&self, opts: SessionOptions) -> std::result::Result<Session, ServeError> {
        if let Some(msg) = self.core.failure_msg() {
            return Err(ServeError::Failed(msg));
        }
        if self.core.closing.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            return Err(ServeError::Closed);
        }
        let id = self.core.next_session.fetch_add(1, Ordering::Relaxed); // relaxed-ok: unique-id allocator; atomicity suffices
        let requested = if opts.window > 0 { opts.window } else { self.core.default_window };
        let window = requested.max(1);
        let (tx, rx) = mpsc::sync_channel::<Submitted>(opts.queue_depth.max(1));
        // Stream capacity == window: the dispatcher never lets more than
        // `window` frames sit between dispatch and the consumer, so the
        // reassembler's non-blocking forwards cannot overflow it.
        let (out_tx, out_rx) = mpsc::sync_channel::<FrameResult>(window);
        let shared = Arc::new(SessionShared {
            id,
            name: if opts.name.is_empty() { format!("session-{id}") } else { opts.name },
            weight: opts.weight.max(1),
            window,
            slo: opts.slo,
            quota: opts.quota,
            precision: opts.precision,
            submitted: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            consumed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            rejected_shed: AtomicU64::new(0),
            // The rate bucket starts full: a session may burst up to
            // `quota.burst` frames before the sustained rate binds.
            bucket: Mutex::new(TokenBucket {
                tokens: opts.quota.burst.max(1) as f64,
                last_refill: self.core.clock.now(),
            }),
            canceled: AtomicBool::new(false),
            accum: Mutex::new(SessionAccum::default()),
        });
        {
            let mut reg = guard(&self.core.registry, "session registry")?;
            reg.new_dispatch.push(DispatchEntry {
                shared: shared.clone(),
                rx,
                peeked: None,
                dispatched: 0,
                done_sent: false,
            });
            reg.new_reasm.push(ReasmState {
                shared: shared.clone(),
                out: Some(out_tx),
                pending: BTreeMap::new(),
                next_emit: 0,
                emitted: 0,
                expected: None,
            });
        }
        guard(&self.core.sessions, "session list")?.push(shared.clone());
        // Wake the dispatcher/reassembler to adopt the new session.
        self.core.activity.notify();
        Ok(Session {
            submitter: SessionSubmitter {
                tx: Some(tx),
                shared: shared.clone(),
                core: self.core.clone(),
            },
            stream: SessionStream {
                rx: out_rx,
                shared,
                core: self.core.clone(),
                gave_error: false,
                finished: false,
            },
        })
    }

    /// A `Send + Clone` liveness view for producer threads.
    pub fn watch(&self) -> ServerWatch {
        ServerWatch { core: self.core.clone() }
    }

    /// The serving clock — the timeline every deadline, wait, and
    /// [`ScaleEvent`] timestamp lives on (hand it to an
    /// [`super::autoscale::AutoScaler`] so cooldowns move with the
    /// traffic).
    pub fn clock(&self) -> Clock {
        self.core.clock.clone()
    }

    /// All workers warmed up; dispatch is live.
    pub fn ready(&self) -> bool {
        self.core.ready.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
    }

    /// Block until every worker is warm (or the server fails / `timeout`
    /// elapses on the serving clock). Event-driven: readiness and failure
    /// both notify, so there is no polling latency — and under a manual
    /// clock the timeout only expires if the test advances past it.
    pub fn wait_ready(&self, timeout: Duration) -> std::result::Result<(), ServeError> {
        let deadline = self.core.clock.now() + timeout;
        loop {
            let gen = self.core.activity.generation();
            if let Some(msg) = self.core.failure_msg() {
                return Err(ServeError::Failed(msg));
            }
            if self.ready() {
                return Ok(());
            }
            if self.core.clock.now() >= deadline {
                return Err(ServeError::Failed("workers not ready within timeout".into()));
            }
            self.core.activity.wait_until(gen, deadline);
        }
    }

    /// Server-wide snapshot: per-session [`ServeReport`]s plus the
    /// aggregate across all of them.
    pub fn stats(&self) -> std::result::Result<ServerStats, ServeError> {
        let backend = (*guard(&self.core.backend, "backend name")?).to_string();
        let sessions: Vec<Arc<SessionShared>> =
            guard(&self.core.sessions, "session list")?.clone();
        let mut rows = Vec::with_capacity(sessions.len());
        let mut agg = SessionAccum::default();
        let mut dropped = 0u64;
        let mut dropped_quota = 0u64;
        let mut dropped_shed = 0u64;
        for s in &sessions {
            // One snapshot per session: the row report and the aggregate
            // must agree even while the reassembler keeps accumulating.
            let a = s.snapshot();
            let s_dropped = s.rejected.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            let s_dropped_quota = s.rejected_quota.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            let s_dropped_shed = s.rejected_shed.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            agg.frames += a.frames;
            agg.iou_sum += a.iou_sum;
            agg.correct += a.correct;
            agg.energy_sum += a.energy_sum;
            agg.latency_sum += a.latency_sum;
            agg.queueing_sum += a.queueing_sum;
            agg.kept_sum += a.kept_sum;
            agg.batch_sum += a.batch_sum;
            // QoS accounting composes: the aggregate's SLO misses are by
            // construction the per-session sum, and latency histograms
            // merge exactly (bucket-wise addition).
            agg.slo_miss += a.slo_miss;
            agg.accuracy_at_risk += a.accuracy_at_risk;
            for t in 0..3 {
                agg.tier_frames[t] += a.tier_frames[t]; // lint-allow(panic): fixed-length tier arrays, index < 3
                agg.tier_ref_frames[t] += a.tier_ref_frames[t]; // lint-allow(panic): fixed-length tier arrays, index < 3
                agg.tier_agree[t] += a.tier_agree[t]; // lint-allow(panic): fixed-length tier arrays, index < 3
            }
            agg.session_latency.merge(&a.session_latency);
            dropped += s_dropped;
            dropped_quota += s_dropped_quota;
            dropped_shed += s_dropped_shed;
            rows.push(SessionStats {
                id: s.id,
                name: s.name.clone(),
                weight: s.weight,
                complete: a.complete,
                canceled: s.canceled.load(Ordering::Relaxed), // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                submitted: s.submitted.load(Ordering::Relaxed), // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                inflight: s
                    .dispatched
                    .load(Ordering::Relaxed) // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                    .saturating_sub(s.consumed.load(Ordering::Relaxed)), // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
                report: a.to_report(
                    s_dropped,
                    s_dropped_quota,
                    s_dropped_shed,
                    &backend,
                    self.core.n_workers,
                ),
            });
        }
        // The aggregate's wall clock spans the server's post-warmup
        // lifetime, not any one session's emission span.
        let t_ready = *recover(&self.core.t_ready);
        let wall_s =
            t_ready.map(|t| self.core.clock.seconds_since(t)).unwrap_or(0.0);
        agg.first_emit = t_ready;
        agg.last_emit = t_ready.map(|t| t + Duration::from_secs_f64(wall_s));
        let aggregate =
            agg.to_report(dropped, dropped_quota, dropped_shed, &backend, self.core.n_workers);
        // Live rows come from occupied pool slots (queue-depth gauge =
        // that slot's inflight count); retired workers keep their final
        // archived row so totals stay monotone across scale-down.
        let (live_workers, mut worker_health) = {
            let pool = guard(&self.core.pool, "worker pool")?;
            let live_rows: Vec<WorkerHealthStats> = pool
                .slots
                .iter()
                .enumerate()
                .filter_map(|(slot, occ)| {
                    // A slot whose occupant already flipped to `Retired`
                    // (but hasn't freed the slot yet) is reported by its
                    // archived row, not here — never both.
                    occ.filter(|_| self.core.health[slot].mode() != WorkerMode::Retired).map( // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                        |wid| {
                            self.core.health[slot] // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                                .snapshot(wid, self.core.inflight[slot].load(Ordering::Relaxed)) // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays; relaxed-ok: load gauge; staleness only costs placement quality
                        },
                    )
                })
                .collect();
            (pool.live(), live_rows)
        };
        worker_health
            .extend(guard(&self.core.retired_health, "retired worker stats")?.iter().cloned());
        worker_health.sort_by_key(|w| w.worker);
        Ok(ServerStats {
            backend,
            workers: self.core.n_workers,
            live_workers,
            shed_below: self.core.shed_below.load(Ordering::Relaxed), // relaxed-ok: shed latch; submitters re-check on the activity event
            aggregate,
            sessions: rows,
            worker_health,
            scale_events: recover(&self.core.scale_events).clone(),
        })
    }

    /// Graceful shutdown: stop admitting, drain every frame already
    /// submitted, join all threads, and return the server-wide aggregate
    /// [`ServeReport`] plus the merged cross-worker [`StageMetrics`] —
    /// exactly what the batch-job `run` returned. Fails with the first
    /// recorded worker failure, if any.
    ///
    /// Shutdown is **cooperative**: draining a session's backlog needs its
    /// consumer to keep taking results (the per-session window stalls
    /// dispatch otherwise), so finish or drop every [`SessionStream`]
    /// before — or concurrently with — calling this. Dropping the `Server`
    /// without `shutdown` aborts instead of draining.
    pub fn shutdown(mut self) -> Result<(ServeReport, StageMetrics)> {
        self.core.closing.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        self.core.activity.notify();
        for h in self.handles.drain(..) {
            h.join().ok();
        }
        // Scaled-up workers exit once the dispatcher (joined above) drops
        // their queues; join them after so shutdown never hangs on one.
        for h in recover(&self.scaled).drain(..) {
            h.join().ok();
        }
        match recover(&self.core.outcome).take() {
            Some(Ok(pair)) => Ok(pair),
            Some(Err(error)) => Err(anyhow!("serving failed: {error}")),
            None => Err(anyhow!("serving failed: server exited without an outcome")),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.handles.is_empty() {
            return; // shut down already
        }
        // Dropped without shutdown: abort promptly rather than drain.
        self.core.closing.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        self.core.abort.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        self.core.activity.notify();
        for h in self.handles.drain(..) {
            h.join().ok();
        }
        for h in recover(&self.scaled).drain(..) {
            h.join().ok();
        }
    }
}

/// Feed a session from a synthetic sensor until `num_frames` frames were
/// **accepted**, then close it. Mirrors the batch-job sensor contract:
/// idles until the server is warm (so warmup never inflates rejections),
/// tries each produced frame once, and counts a full queue as a dropped
/// frame (recorded in the session's `ServeReport::dropped`; a quota
/// rejection counts `dropped_quota` instead). Returns the accepted count.
///
/// Event-driven: readiness, queue drains, and quota refills all notify
/// the server's activity event, so the sensor blocks instead of
/// sleep-polling (the waits' timeouts are lost-wakeup backstops only).
pub fn spawn_synthetic_sensor(
    submitter: SessionSubmitter,
    watch: ServerWatch,
    image_size: usize,
    num_objects: usize,
    seed: u64,
    num_frames: u64,
) -> JoinHandle<u64> {
    std::thread::spawn(move || {
        let mut src = VideoSource::new(image_size, num_objects, seed);
        let mut accepted = 0u64;
        while accepted < num_frames {
            let gen = watch.core.activity.generation();
            if watch.failed() || watch.closing() {
                break;
            }
            if !watch.ready() {
                watch.core.activity.wait_for(gen, Duration::from_millis(5));
                continue;
            }
            match submitter.try_submit(src.next_frame()) {
                PushOutcome::Queued => accepted += 1,
                // Real backpressure: the frame is dropped (counted by
                // try_submit); wait for the pool to drain a slot.
                PushOutcome::Full => {
                    watch.core.activity.wait_for(gen, Duration::from_micros(200));
                }
                // Quota policy drop (counted as dropped_quota); wait for
                // a token refill / in-flight drain.
                PushOutcome::Quota => {
                    watch.core.activity.wait_for(gen, Duration::from_millis(1));
                }
                // Overload shed (counted as dropped_shed); wait for the
                // autoscaler to lift the threshold.
                PushOutcome::Shed => {
                    watch.core.activity.wait_for(gen, Duration::from_millis(1));
                }
                PushOutcome::Closed => break,
            }
        }
        accepted
        // `submitter` drops here, closing the session's input.
    })
}

// --- dispatcher ---------------------------------------------------------

/// Weighted round-robin admission state — extracted from the dispatcher
/// loop so the fairness invariant is property-testable without threads
/// (`rust/tests/property.rs`): each sweep grants session `i` at most
/// `weights[i]` admissions and starts from a rotating offset, so over any
/// run of sweeps against backlogged sessions, session `i`'s admitted
/// share tracks `w_i / Σw` within one round — a hot tenant cannot starve
/// a small one.
#[derive(Debug, Default)]
pub struct WrrAdmission {
    turn: usize,
}

impl WrrAdmission {
    pub fn new() -> Self {
        WrrAdmission { turn: 0 }
    }

    /// Sweeps completed so far (also the rotation offset of the next
    /// sweep — the dispatcher reuses it to rotate worker tie-breaking).
    pub fn turns(&self) -> usize {
        self.turn
    }

    /// One admission sweep over `weights.len()` sessions: starting at the
    /// rotating offset, call `admit(i)` up to `weights[i]` (min 1) times
    /// per session, ending that session's turn the first time it returns
    /// `false` (empty queue, window bound, canceled, fatal). Returns the
    /// number of granted admissions and advances the rotation.
    pub fn sweep(&mut self, weights: &[u32], mut admit: impl FnMut(usize) -> bool) -> u64 {
        let n = weights.len();
        let mut granted = 0u64;
        for k in 0..n {
            let i = (self.turn + k) % n;
            for _ in 0..weights[i].max(1) { // lint-allow(panic): index from iterating this collection
                if admit(i) {
                    granted += 1;
                } else {
                    break;
                }
            }
        }
        self.turn = self.turn.wrapping_add(1);
        granted
    }
}

/// Health-weighted worker rotation — the placement-side extension of
/// [`WrrAdmission`], extracted so its no-starvation invariant is
/// property-testable without threads (`rust/tests/property.rs`): each
/// cycle the cursor holds worker `w` for [`HealthWeightedWrr::credits`]
/// `(health[w])` consecutive turns (1–4), so a pristine worker anchors
/// ~4x as often as a floored one, but **every** worker — however
/// degraded — still gets at least one turn per cycle. The dispatcher
/// feeds the picks to [`place_job`] as the rotation anchor for its
/// least-loaded tie-break (health biases placement; the load criterion
/// still dominates).
#[derive(Debug, Default)]
pub struct HealthWeightedWrr {
    cursor: usize,
    credit: u32,
}

impl HealthWeightedWrr {
    pub fn new() -> Self {
        HealthWeightedWrr { cursor: 0, credit: 0 }
    }

    /// Turns per cycle a worker earns from its health score in `[0, 1]`:
    /// `ceil(4 * health)` clamped to `>= 1`. The floor is the
    /// no-starvation guarantee — a degraded worker keeps draining work
    /// (it still produces usable frames, just flagged at-risk).
    pub fn credits(health: f64) -> u32 {
        (health.clamp(0.0, 1.0) * 4.0).ceil().max(1.0) as u32
    }

    /// Pick the next rotation anchor. Allocation-free; O(1) per call.
    pub fn next(&mut self, healths: &[f64]) -> usize {
        if healths.is_empty() {
            return 0;
        }
        self.cursor %= healths.len();
        if self.credit == 0 {
            self.credit = Self::credits(healths[self.cursor]); // lint-allow(panic): cursor reduced mod len above
        }
        self.credit -= 1;
        let pick = self.cursor;
        if self.credit == 0 {
            self.cursor = (self.cursor + 1) % healths.len();
        }
        pick
    }
}

enum Placed {
    Worker,
    AllDead,
    Aborted,
}

/// Place one job on the least-loaded alive worker (ties broken in
/// rotation order). While every alive queue is full, wait on the activity
/// event (each worker pop notifies it) instead of sleep-polling — stays
/// abort-responsive, unlike a blocking send.
///
/// Under a health-aware policy, placement is additionally degradation-
/// aware: draining/recalibrating workers are ineligible (with an
/// availability fallback — if **no** serving worker is alive, any alive
/// worker beats stalling the pool), and a critical job sorts at-risk
/// workers last, ahead of the load criterion. Retiring/retired slots are
/// never placed on, health-aware or not — retirement means the queue is
/// closing for good, so there is no availability fallback onto them.
// lint-allow(panic, fn): every worker index here is drawn from
// `0..worker_txs.len()` and the parallel `alive`/`health`/`inflight`
// arrays all have pool-capacity length fixed at construction.
fn place_job(
    mut job: Job,
    worker_txs: &[Option<SyncSender<Job>>],
    alive: &mut [bool],
    core: &ServerCore,
    candidates: &mut Vec<usize>,
    rr: usize,
) -> Placed {
    let n = worker_txs.len();
    let aware = core.cfg.health.aware;
    let critical = job.critical;
    loop {
        // Generation before the placement attempt: a pop during the
        // attempt ends the post-attempt wait immediately.
        let gen = core.activity.generation();
        if core.abort.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            return Placed::Aborted;
        }
        candidates.clear();
        candidates.extend((0..n).filter(|&w| {
            alive[w]
                && worker_txs[w].is_some()
                && match core.health[w].mode() {
                    WorkerMode::Retiring | WorkerMode::Retired => false,
                    WorkerMode::Serving => true,
                    WorkerMode::Draining | WorkerMode::Recalibrating => !aware,
                }
        }));
        if candidates.is_empty() {
            // Availability over routing purity: with every serving worker
            // gone (all draining/recalibrating at once), any alive worker
            // is better than a stalled pool.
            candidates.extend((0..n).filter(|&w| {
                alive[w]
                    && worker_txs[w].is_some()
                    && !matches!(
                        core.health[w].mode(),
                        WorkerMode::Retiring | WorkerMode::Retired
                    )
            }));
        }
        if candidates.is_empty() {
            return Placed::AllDead;
        }
        let rot = rr % n;
        candidates.sort_unstable_by_key(|&w| {
            (
                aware && critical && core.health[w].at_risk(),
                core.inflight[w].load(Ordering::Relaxed), // relaxed-ok: load gauge; staleness only costs placement quality
                (w + n - rot) % n,
            )
        });
        let mut j = job;
        for &w in candidates.iter() {
            let Some(tx) = worker_txs[w].as_ref() else { continue };
            match tx.try_send(j) {
                Ok(()) => {
                    core.inflight[w].fetch_add(1, Ordering::Relaxed); // relaxed-ok: load gauge; staleness only costs placement quality
                    // Wake the worker blocked waiting for its queue.
                    core.activity.notify();
                    return Placed::Worker;
                }
                Err(TrySendError::Full(back)) => j = back,
                Err(TrySendError::Disconnected(back)) => {
                    alive[w] = false;
                    j = back;
                }
            }
        }
        job = j;
        core.activity.wait_for(gen, PLACE_WAIT);
    }
}

/// Send the session's terminal dispatch count to the reassembler once.
fn finalize_entry(entry: &mut DispatchEntry, res_tx: &mpsc::Sender<Msg>) {
    if !entry.done_sent {
        entry.done_sent = true;
        res_tx
            .send(Msg::SessionDone { session: entry.shared.id, dispatched: entry.dispatched })
            .ok();
    }
}

/// Weighted round-robin admission over all open sessions
/// ([`WrrAdmission`]), least-loaded sharding over the worker pool.
/// Each sweep runs an earliest-deadline-first pre-pass over the SLO
/// sessions' peeked head-of-queue frames: the most imminent completion
/// deadline is admitted first, within that session's ordinary weighted
/// share, before the round-robin serves everyone else.
/// Event-driven: an idle dispatcher blocks on the activity event, woken
/// by submissions, consumptions, session lifecycle, and shutdown.
///
/// The dispatcher also owns the elastic-pool handoffs: each sweep it
/// adopts queues for freshly scaled-up workers from the pool's pending
/// list, and closes the queue of any `Retiring` worker that has fully
/// drained (`inflight == 0`) so it exits cleanly.
fn dispatcher_loop(
    core: &ServerCore,
    mut worker_txs: Vec<Option<SyncSender<Job>>>,
    res_tx: mpsc::Sender<Msg>,
) {
    // Hold dispatch until every worker is warm (or the server is going
    // away) — warmup must not skew fairness toward the first session.
    loop {
        let gen = core.activity.generation();
        if core.ready.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            || core.abort.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            || core.closing.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        {
            break;
        }
        core.activity.wait_for(gen, WARMUP_POLL);
    }
    let n_workers = worker_txs.len();
    let mut entries: Vec<DispatchEntry> = Vec::new();
    let mut alive: Vec<bool> = worker_txs.iter().map(|t| t.is_some()).collect();
    let mut candidates: Vec<usize> = Vec::with_capacity(n_workers);
    let mut weights: Vec<u32> = Vec::new();
    let mut wrr = WrrAdmission::new();
    let mut hwrr = HealthWeightedWrr::new();
    let mut healths: Vec<f64> = Vec::with_capacity(n_workers);
    // EDF pre-pass scratch: `(deadline, session index)` of each SLO
    // session's head-of-queue frame, and the sessions already served
    // ahead of the round-robin this sweep.
    let mut edf: Vec<(Instant, usize)> = Vec::new();
    let mut edf_served: Vec<bool> = Vec::new();
    let policy = core.cfg.health;
    loop {
        // Activity generation *before* the sweep: any state change during
        // it (submit, consume, close, …) ends the post-sweep wait
        // immediately instead of being missed.
        let sweep_gen = core.activity.generation();
        if core.abort.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            break;
        }
        {
            let mut reg = recover(&core.registry);
            entries.extend(reg.new_dispatch.drain(..));
        }
        // Adopt queues for workers spawned by `scale_up` since the last
        // sweep, then retire any `Retiring` worker that has drained:
        // dropping its sender disconnects its queue, and the worker's
        // clean-exit path archives its final stats and frees the slot.
        {
            let mut pool = recover(&core.pool);
            for (slot, tx) in pool.pending.drain(..) {
                alive[slot] = true; // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                worker_txs[slot] = Some(tx); // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
            }
        }
        for w in 0..n_workers {
            if worker_txs[w].is_some() // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                && core.health[w].mode() == WorkerMode::Retiring // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                && core.inflight[w].load(Ordering::Relaxed) == 0 // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays; relaxed-ok: load gauge; staleness only costs placement quality
            {
                worker_txs[w] = None; // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                alive[w] = false; // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                core.activity.notify();
            }
        }
        let closing = core.closing.load(Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        // Health sweep before admission: flag any serving worker whose
        // published health fell below the recal threshold for draining —
        // but always keep at least one worker serving (availability over
        // recalibration; the laggard recals once a peer rejoins).
        if policy.aware {
            let mut spare = core
                .health
                .iter()
                .enumerate()
                .filter(|&(w, s)| alive[w] && s.mode() == WorkerMode::Serving) // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                .count()
                .saturating_sub(1);
            for (w, slot) in core.health.iter().enumerate() {
                if spare == 0 {
                    break;
                }
                if alive[w] // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
                    && slot.mode() == WorkerMode::Serving
                    && slot.health_value() < policy.recal_below
                {
                    slot.set_mode(WorkerMode::Draining);
                    spare -= 1;
                    // The worker's idle path owns the drain → recal →
                    // rejoin transitions; wake it.
                    core.activity.notify();
                }
            }
        }
        let mut progressed = false;
        // `Some` ends the run after this sweep; `Some(true)` reports the
        // dead pool first.
        let mut fatal: Option<bool> = None;
        weights.clear();
        weights.extend(entries.iter().map(|e| e.shared.weight));
        // Health-aware runs anchor worker tie-breaking with the
        // health-weighted rotation (healthy workers anchor more turns per
        // cycle, degraded ones never zero); blind runs keep the plain
        // sweep-count rotation.
        let rot = if policy.aware {
            healths.clear();
            healths.extend(core.health.iter().map(|s| s.health_value()));
            hwrr.next(&healths)
        } else {
            wrr.turns()
        };
        // EDF pre-pass: peek every SLO session's head-of-queue frame and
        // order those sessions by completion deadline, so a frame about
        // to blow its SLO is admitted before tenants whose deadlines are
        // slack (or absent). The pre-pass only *reorders* this sweep —
        // each session still gets its plain weighted share and nothing
        // more, so long-run fairness is untouched.
        edf.clear();
        edf_served.clear();
        edf_served.resize(entries.len(), false);
        for (i, entry) in entries.iter_mut().enumerate() {
            if entry.done_sent || entry.shared.canceled.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                continue;
            }
            if let Some(slo) = entry.shared.slo {
                if let Some(s) = entry.peek() {
                    edf.push((s.1 + slo, i));
                }
            }
        }
        edf.sort_unstable();
        let mut admit = |i: usize| -> bool {
            if fatal.is_some() || core.abort.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                return false;
            }
            let entry = &mut entries[i]; // lint-allow(panic): index from iterating this collection
            if entry.done_sent {
                return false;
            }
            if entry.shared.canceled.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                // Mid-flight teardown: discard whatever the dead session
                // still has queued and finalize it at its dispatch count.
                entry.peeked = None;
                while entry.rx.try_recv().is_ok() {}
                finalize_entry(entry, &res_tx);
                progressed = true;
                return false;
            }
            // Per-session dispatch window: a tenant that stops draining
            // its stream stalls only its own admission.
            let consumed = entry.shared.consumed.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
            if entry.dispatched.saturating_sub(consumed) >= entry.shared.window as u64 {
                return false;
            }
            match entry.try_next() {
                Ok((frame, accepted_at)) => {
                    // SLO sessions stamp each job with its completion
                    // deadline; the worker's deadline-aware flush honors
                    // the earliest one in its group.
                    let deadline = entry.shared.slo.map(|slo| accepted_at + slo);
                    // SLO and high-weight tenants are accuracy-critical:
                    // placement keeps them off at-risk workers.
                    let critical = entry.shared.slo.is_some()
                        || entry.shared.weight >= policy.critical_weight;
                    let job = Job {
                        session: entry.shared.id,
                        seq: entry.dispatched,
                        accepted_at,
                        deadline,
                        critical,
                        frame,
                    };
                    match place_job(job, &worker_txs, &mut alive, core, &mut candidates, rot) {
                        Placed::Worker => {
                            let entry = &mut entries[i]; // lint-allow(panic): index from iterating this collection
                            entry.dispatched += 1;
                            entry.shared.dispatched.store(entry.dispatched, Ordering::Relaxed); // relaxed-ok: single-writer progress counter; terminal reads follow the channel
                            core.total_dispatched.fetch_add(1, Ordering::Relaxed); // relaxed-ok: single-writer progress counter; terminal reads follow the channel
                            progressed = true;
                            true
                        }
                        Placed::AllDead => {
                            fatal = Some(true);
                            false
                        }
                        Placed::Aborted => {
                            fatal = Some(false);
                            false
                        }
                    }
                }
                // Empty queue: during graceful shutdown that is the end
                // of the session's input — but only once every frame a
                // submit() already accepted has landed (`dispatched`
                // caught up with `submitted`), so a racing submitter can
                // never lose an accepted frame.
                Err(mpsc::TryRecvError::Empty) => {
                    if closing
                        && entry.dispatched >= entry.shared.submitted.load(Ordering::Relaxed) // relaxed-ok: single-writer progress counter; terminal reads follow the channel
                    {
                        finalize_entry(entry, &res_tx);
                    }
                    false
                }
                // Input side hung up (close or drop): everything buffered
                // was drained above, so the count is final.
                Err(mpsc::TryRecvError::Disconnected) => {
                    finalize_entry(entry, &res_tx);
                    false
                }
            }
        };
        // Deadline order first (bounded by each session's weighted
        // share), then the plain weighted round-robin over everyone the
        // pre-pass did not touch.
        for &(_, i) in &edf {
            edf_served[i] = true; // lint-allow(panic): index from iterating this collection
            for _ in 0..weights[i].max(1) { // lint-allow(panic): index from iterating this collection
                if !admit(i) {
                    break;
                }
            }
        }
        wrr.sweep(&weights, |i| {
            if edf_served[i] { // lint-allow(panic): index from iterating this collection
                return false;
            }
            admit(i)
        });
        match fatal {
            Some(true) => {
                res_tx
                    .send(Msg::Failure {
                        error: "all workers died".to_string(),
                        worker_exit: false,
                    })
                    .ok();
                break;
            }
            Some(false) => break,
            None => {}
        }
        entries.retain(|e| !e.done_sent);
        if entries.is_empty() && closing && recover(&core.registry).new_dispatch.is_empty() {
            break;
        }
        if !progressed {
            core.activity.wait_for(sweep_gen, DISPATCH_IDLE_WAIT);
        }
    }
    // Unblock any submitter stuck on a full queue (dropping the receivers
    // fails their sends gracefully), then close the worker queues so the
    // pool drains and exits — and wake every event waiter so workers
    // observe the hang-up without a timeout.
    drop(entries);
    drop(worker_txs);
    // Close the pool under its lock: any queue a racing `scale_up`
    // already parked in `pending` is dropped here (its worker exits on
    // the disconnect), and `closed` makes later scale calls refuse.
    {
        let mut pool = recover(&core.pool);
        pool.pending.clear();
        pool.closed = true;
    }
    core.activity.notify();
    res_tx.send(Msg::DispatcherExited).ok();
}

// --- worker -------------------------------------------------------------

/// The batch-group flush deadline: first-frame arrival + `max_wait`,
/// tightened by the earliest SLO deadline in the group — the
/// **deadline-aware flush** that keeps a latency-bound frame from waiting
/// out the full batching window behind an SLO-less policy. This is the
/// queue-grouping form of the maturity rule whose lane-based counterpart
/// is `MicroBatcher::push_with_deadline` — keep the two aligned.
fn tighten(deadline: Instant, job_deadline: Option<Instant>) -> Instant {
    match job_deadline {
        Some(d) => deadline.min(d),
        None => deadline,
    }
}

/// Publish the worker's current backend health into its [`HealthSlot`].
/// Called on every worker wake, so under a manual clock each `advance`
/// refreshes the published score. A *changed* score notifies the activity
/// event so the dispatcher re-sweeps against it promptly; the `updates`
/// tick always advances (tests synchronize on it).
fn publish_health<W: FrameWorker>(slot: &HealthSlot, core: &ServerCore, w: &mut W) {
    match w.health() {
        Some(h) => {
            // Release/Acquire publication protocol lives in
            // `HealthSlot::publish` (loom-checked).
            if slot.publish(h.health, h.at_risk) {
                core.activity.notify();
            }
        }
        // No health signal: still prove liveness for tests waiting on
        // the updates tick.
        None => slot.tick(),
    }
}

/// Advance this worker's recalibration state machine one step. The
/// dispatcher flags `Serving → Draining`; the worker owns the rest:
/// once drained (`inflight == 0`, so its queue is empty too), it pays the
/// backend's modeled recalibration cost and holds `Recalibrating` until
/// `recal_due` passes on the serving clock, then rejoins `Serving` (the
/// recalibrated backend republishes full health on the next wake).
/// Workers without a recalibration hook rejoin immediately — there is
/// nothing to pay, and holding them drained would idle capacity.
fn drive_recal<W: FrameWorker>(
    slot_idx: usize,
    slot: &HealthSlot,
    core: &ServerCore,
    w: &mut W,
    clock: &Clock,
    recal_due: &mut Option<Instant>,
) {
    match slot.mode() {
        WorkerMode::Serving => {}
        // Retirement is owned by the scale-down path: the dispatcher
        // closes the drained worker's queue, and the worker's clean-exit
        // path archives its final stats. Nothing to drive here.
        WorkerMode::Retiring | WorkerMode::Retired => {}
        WorkerMode::Draining => {
            if core.inflight[slot_idx].load(Ordering::Relaxed) == 0 { // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays; relaxed-ok: load gauge; staleness only costs placement quality
                match w.recalibrate() {
                    Some(cost) => {
                        slot.add_recal_energy(cost.energy_j);
                        *recal_due = Some(clock.now() + Duration::from_secs_f64(cost.time_s));
                        slot.set_mode(WorkerMode::Recalibrating);
                    }
                    None => slot.set_mode(WorkerMode::Serving),
                }
                core.activity.notify();
            }
        }
        WorkerMode::Recalibrating => {
            // A lost `recal_due` (only possible across a panic-recovered
            // iteration) degrades to an immediate rejoin.
            if recal_due.map(|due| clock.now() >= due).unwrap_or(true) {
                *recal_due = None;
                slot.complete_recal();
                slot.set_mode(WorkerMode::Serving);
                core.activity.notify();
            }
        }
    }
}

/// One worker thread: construct the (possibly non-`Send`) frame worker
/// in-thread, warm it up, then micro-batch the queue until it closes.
/// All waits are event-driven on the serving clock: the dispatcher
/// notifies per placement, and group top-up waits until the group's
/// (possibly SLO-tightened) deadline — under a manual clock a group
/// flushes exactly when the test advances past that deadline.
fn worker_loop<W, F>(
    wid: usize,
    slot_idx: usize,
    pin_core: Option<usize>,
    factory: &F,
    core: &ServerCore,
    rx: Receiver<Job>,
    res_tx: mpsc::Sender<Msg>,
) where
    W: FrameWorker,
    F: Fn(usize) -> Result<W>,
{
    let clock = core.clock.clone();
    let patch_px = core.cfg.patch_px;
    let batch_policy = core.cfg.batch;
    let body = AssertUnwindSafe(|| -> WorkerOutcome {
        // The pin target is pool-allocated (lowest core not claimed by a
        // live worker) so a retired worker's core is reused by the next
        // spawn rather than drifting upward.
        let pinned_core = pin_core.and_then(super::affinity::pin_current_thread);
        let mut w =
            factory(wid).map_err(|e| format!("worker {wid}: construction failed: {e:#}"))?;
        w.warmup().map_err(|e| format!("worker {wid}: warmup failed: {e:#}"))?;
        res_tx.send(Msg::Ready { backend: w.backend_name() }).ok();
        // Utilization window opens at the first frame, not at warmup
        // completion: a fast-warming worker must not be charged its
        // peers' compile time as idle.
        let mut t_first: Option<Instant> = None;
        let mut busy = Duration::ZERO;
        let mut frames = 0u64;
        let max_batch = batch_policy.max_batch.max(1);
        let mut tags: Vec<(u64, u64, Instant)> = Vec::with_capacity(max_batch);
        let mut group: Vec<Frame> = Vec::with_capacity(max_batch);
        let slot = &core.health[slot_idx]; // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays
        let mut recal_due: Option<Instant> = None;
        let mut closed = false;
        while !closed {
            tags.clear();
            group.clear();
            // Block for the first frame of the group (the dispatcher
            // notifies the activity event after every placement). Every
            // wake also republishes backend health and steps the
            // recalibration state machine — which is what lets a drained
            // worker recalibrate and rejoin while its queue stays empty.
            let first = loop {
                let gen = core.activity.generation();
                publish_health(slot, core, &mut w);
                drive_recal(slot_idx, slot, core, &mut w, &clock, &mut recal_due);
                match rx.try_recv() {
                    Ok(job) => break Some(job),
                    Err(mpsc::TryRecvError::Empty) => {
                        core.activity.wait_for(gen, WORKER_IDLE_WAIT);
                    }
                    Err(mpsc::TryRecvError::Disconnected) => break None,
                }
            };
            let Some(job) = first else { break };
            // A pop freed a queue slot: wake the dispatcher's placement.
            core.activity.notify();
            t_first.get_or_insert_with(|| clock.now());
            let mut group_deadline =
                tighten(clock.now() + batch_policy.max_wait, job.deadline);
            tags.push((job.session, job.seq, job.accepted_at));
            group.push(job.frame);
            // ...then top it up until max_batch or the group deadline,
            // whichever comes first. Frames from *any* session ride the
            // same group — cross-session bucket-major amortization — and
            // each joining SLO frame can only tighten the deadline.
            if max_batch > 1 {
                while group.len() < max_batch && !closed {
                    if clock.now() >= group_deadline {
                        break;
                    }
                    let gen = core.activity.generation();
                    match rx.try_recv() {
                        Ok(job) => {
                            core.activity.notify();
                            group_deadline = tighten(group_deadline, job.deadline);
                            tags.push((job.session, job.seq, job.accepted_at));
                            group.push(job.frame);
                        }
                        Err(mpsc::TryRecvError::Empty) => {
                            core.activity.wait_until(gen, group_deadline);
                        }
                        Err(mpsc::TryRecvError::Disconnected) => {
                            closed = true;
                        }
                    }
                }
            }
            // Ground truth before processing (frames are consumed by
            // reference, results by value).
            let gts: Vec<_> = group.iter().map(|f| f.gt_mask(patch_px)).collect();
            let labels: Vec<usize> = group.iter().map(|f| f.label).collect();
            let t0 = clock.now();
            let out = w.process_batch(&group);
            busy += clock.now().saturating_duration_since(t0);
            core.inflight[slot_idx].fetch_sub(group.len() as u64, Ordering::Relaxed); // lint-allow(panic): worker id drawn from these fixed pool-capacity arrays; relaxed-ok: load gauge; staleness only costs placement quality
            // The pool has headroom again: wake blocked placement.
            core.activity.notify();
            let rs = out.map_err(|e| {
                format!(
                    "worker {wid}: batch of {} (first frame {}) failed: {e:#}",
                    group.len(),
                    group.first().map(|f| f.index).unwrap_or(0)
                )
            })?;
            if rs.len() != group.len() {
                return Err(format!(
                    "worker {wid}: process_batch returned {} results for {} frames",
                    rs.len(),
                    group.len()
                ));
            }
            frames += rs.len() as u64;
            // Score the whole group against the backend's *post-batch*
            // health: degradation accrued while serving these frames is
            // exactly what put their accuracy at risk.
            publish_health(slot, core, &mut w);
            let at_risk = slot.at_risk();
            slot.record_frames(rs.len() as u64, at_risk);
            for ((&(session, seq, accepted_at), r), (gt, &label)) in
                tags.iter().zip(rs).zip(gts.iter().zip(&labels))
            {
                let iou = r.mask.iou(gt);
                let correct = r.predicted_class() == label;
                res_tx
                    .send(Msg::Result {
                        session,
                        seq,
                        accepted_at,
                        result: r,
                        iou,
                        correct,
                        at_risk,
                    })
                    .ok();
            }
        }
        let active_s = t_first.map(|t| clock.seconds_since(t)).unwrap_or(0.0);
        let busy_s = busy.as_secs_f64();
        let backend = w.backend_name();
        let metrics = w.take_metrics();
        let queueing_s = metrics.stage_mean_s("modeled_queueing");
        // A queue closed while Retiring means scale-down drained this
        // worker out of the pool: flag its final rows `retired` and
        // archive the health row so `Server::stats` totals stay monotone
        // after the live slot is reused.
        let retired = matches!(slot.mode(), WorkerMode::Retiring | WorkerMode::Retired);
        if retired {
            slot.set_mode(WorkerMode::Retired);
            recover(&core.retired_health).push(slot.snapshot(wid, 0));
        }
        Ok((
            metrics,
            WorkerStats {
                worker: wid,
                frames,
                busy_s,
                queueing_s,
                utilization: if active_s > 0.0 { (busy_s / active_s).min(1.0) } else { 0.0 },
                core: pinned_core,
                health: slot.health_value(),
                recals: slot.recals(),
                at_risk_frames: slot.at_risk_frames(),
                queue_depth: 0,
                retired,
            },
            backend,
        ))
    });
    let outcome = std::panic::catch_unwind(body);
    // Release the pool slot (and its pin-core claim) whatever the exit
    // path — the next scale_up may reuse both.
    {
        let mut pool = recover(&core.pool);
        pool.slots[slot_idx] = None; // lint-allow(panic): slot ids are allocated below pool capacity, the arrays' fixed length
        pool.claims[slot_idx] = None; // lint-allow(panic): slot ids are allocated below pool capacity, the arrays' fixed length
    }
    core.activity.notify();
    match outcome {
        Ok(Ok((metrics, stats, backend))) => {
            res_tx.send(Msg::WorkerDone { stats, metrics: Box::new(metrics), backend }).ok();
        }
        Ok(Err(error)) => {
            res_tx.send(Msg::Failure { error, worker_exit: true }).ok();
        }
        Err(_) => {
            res_tx
                .send(Msg::Failure { error: format!("worker {wid} panicked"), worker_exit: true })
                .ok();
        }
    }
}

// --- reassembler --------------------------------------------------------

/// Server-wide totals the reassembler keeps for the terminal aggregate.
#[derive(Default)]
struct Aggregate {
    emitted: u64,
    iou_sum: f64,
    correct: u64,
}

/// Emit one completed frame to its session: update the session accum
/// (including submit→emit latency and SLO-miss scoring on the serving
/// clock) and the server aggregate, then forward to the stream
/// (non-blocking; a gone consumer cancels the session instead of
/// stalling its neighbours).
fn emit(
    state: &mut ReasmState,
    result: FrameResult,
    iou: f64,
    correct: bool,
    at_risk: bool,
    accepted_at: Instant,
    clock: &Clock,
    agg: &mut Aggregate,
) {
    let now = clock.now();
    let session_latency = now.saturating_duration_since(accepted_at);
    {
        let mut a = recover(&state.shared.accum);
        a.frames += 1;
        a.iou_sum += iou;
        a.correct += correct as u64;
        a.accuracy_at_risk += at_risk as u64;
        let ti = result.tier.index();
        a.tier_frames[ti] += 1; // lint-allow(panic): PrecisionTier::index is < 3 by construction
        if let Some(agree) = result.fp32_agreement {
            a.tier_ref_frames[ti] += 1; // lint-allow(panic): PrecisionTier::index is < 3 by construction
            a.tier_agree[ti] += agree as u64; // lint-allow(panic): PrecisionTier::index is < 3 by construction
        }
        a.energy_sum += result.modeled_energy_j;
        a.latency_sum += result.latency_s;
        a.queueing_sum += result.modeled_queueing_s;
        a.kept_sum += result.mask.kept().max(1) as f64;
        a.batch_sum += result.batch_size as f64;
        a.session_latency.record(session_latency.as_secs_f64());
        // Strictly-greater: a frame emitted exactly at its deadline made
        // the SLO (which is also what makes a deadline-aware flush under
        // a frozen manual clock record zero misses — exactly assertable).
        if state.shared.slo.is_some_and(|slo| session_latency > slo) {
            a.slo_miss += 1;
        }
        a.first_emit.get_or_insert(now);
        a.last_emit = Some(now);
    }
    agg.emitted += 1;
    agg.iou_sum += iou;
    agg.correct += correct as u64;
    state.emitted += 1;
    if let Some(tx) = &state.out {
        // The per-session dispatch window guarantees capacity; a Full or
        // Disconnected send means the consumer is gone — cancel the
        // session rather than block every other tenant.
        if tx.try_send(result).is_err() {
            state.out = None;
            state.shared.canceled.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        }
    }
}

/// Mark a session complete (all dispatched frames emitted) and end its
/// stream. A canceled session is finalized for accounting but never
/// marked `complete` — its queued frames were discarded, so "every
/// submitted frame was emitted" would be a lie.
fn try_finalize_session(state: &mut ReasmState) -> bool {
    if state.expected.is_some_and(|e| state.emitted >= e) {
        if !state.shared.canceled.load(Ordering::Relaxed) { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
            recover(&state.shared.accum).complete = true;
        }
        state.out = None; // dropping the sender ends the stream cleanly
        true
    } else {
        false
    }
}

/// Adopt sessions published since the last sweep. Called at the top of
/// every reassembler iteration **and** whenever a message names a session
/// the map doesn't know yet: a fresh session's first result can arrive in
/// the same iteration it was registered, and must not be mistaken for a
/// canceled session's leftover.
fn adopt_new_sessions(core: &ServerCore, states: &mut BTreeMap<u64, ReasmState>) {
    let mut reg = recover(&core.registry);
    for st in reg.new_reasm.drain(..) {
        states.insert(st.shared.id, st);
    }
}

/// Record the server's first failure and end every session stream; the
/// consumers read the message back through [`ServeError::Failed`].
fn fail_server(
    core: &ServerCore,
    msg: String,
    failure: &mut Option<String>,
    states: &mut BTreeMap<u64, ReasmState>,
) {
    if failure.is_none() {
        *failure = Some(msg.clone());
    }
    core.fail(&msg);
    for st in states.values_mut() {
        st.out = None;
    }
}

/// Strict per-session in-order reassembly, server failure detection, and
/// the terminal aggregate. Timestamps (warmup/stall timeouts, emission
/// times, SLO scoring) live on the serving clock; the message-receive
/// tick stays a real channel timeout so session adoption never stalls.
fn reassembler_loop(core: &ServerCore, res_rx: Receiver<Msg>) {
    let clock = core.clock.clone();
    let warmup_timeout = Duration::from_secs_f64(core.cfg.warmup_timeout_s.max(0.1));
    let stall_timeout = Duration::from_secs_f64(core.cfg.stall_timeout_s.max(0.1));
    let tick = Duration::from_millis(100).min(stall_timeout);
    let n_workers = core.n_workers;

    let mut states: BTreeMap<u64, ReasmState> = BTreeMap::new();
    let mut agg = Aggregate::default();
    let mut merged = StageMetrics::new();
    let mut per_worker: Vec<WorkerStats> = Vec::new();
    let mut backend_name: &'static str = "custom";
    let mut ready_count = 0usize;
    let mut worker_exits = 0usize;
    let mut dispatcher_exited = false;
    let mut failure: Option<String> = None;
    let t_start = clock.now();
    let mut t_ready: Option<Instant> = None;
    let mut last_progress = clock.now();

    loop {
        adopt_new_sessions(core, &mut states);
        match res_rx.recv_timeout(tick) {
            Ok(Msg::Ready { backend }) => {
                last_progress = clock.now();
                backend_name = backend;
                *recover(&core.backend) = backend;
                ready_count += 1;
                // Scaled-up workers send `Ready` too: only the initial
                // pool gates dispatch, and readiness latches once.
                if !core.ready.load(Ordering::Relaxed) && ready_count >= n_workers { // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                    let now = clock.now();
                    t_ready = Some(now);
                    *recover(&core.t_ready) = Some(now);
                    core.ready.store(true, Ordering::Relaxed); // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                    // Wake wait_ready callers, the dispatcher's warmup
                    // hold, and idling sensors.
                    core.activity.notify();
                }
            }
            Ok(Msg::Result { session, seq, accepted_at, result, iou, correct, at_risk }) => {
                last_progress = clock.now();
                let mut overflow: Option<String> = None;
                let mut finalized = false;
                if !states.contains_key(&session) {
                    // The session may have registered after this
                    // iteration's sweep — adopt before concluding it is a
                    // canceled session's leftover.
                    adopt_new_sessions(core, &mut states);
                }
                // A canceled-and-removed session can still have results in
                // flight; they fall on the floor by design.
                if let Some(state) = states.get_mut(&session) {
                    state.pending.insert(seq, (result, iou, correct, at_risk, accepted_at));
                    while let Some((r, i, c, ar, at)) = state.pending.remove(&state.next_emit) {
                        state.next_emit += 1;
                        emit(state, r, i, c, ar, at, &clock, &mut agg);
                    }
                    // Backstop: the dispatcher never lets more than
                    // `window` frames sit between dispatch and the stream,
                    // so a larger out-of-order buffer means a result was
                    // lost — fail fast instead of buffering forever.
                    if state.pending.len() > state.shared.window {
                        overflow = Some(format!(
                            "session {session}: reassembly window overflow: {} results \
                             buffered out of order (window {}, next expected seq {}) — \
                             a result was lost",
                            state.pending.len(),
                            state.shared.window,
                            state.next_emit
                        ));
                    } else {
                        finalized = try_finalize_session(state);
                    }
                }
                if let Some(msg) = overflow {
                    fail_server(core, msg, &mut failure, &mut states);
                } else if finalized {
                    states.remove(&session);
                }
            }
            Ok(Msg::SessionDone { session, dispatched }) => {
                // Serving clock, like every other arm — a raw
                // `Instant::now()` here once silently disarmed the stall
                // detector under a manual clock (caught by the clock-seam
                // lint rule).
                last_progress = clock.now();
                if !states.contains_key(&session) {
                    adopt_new_sessions(core, &mut states);
                }
                let finalized = match states.get_mut(&session) {
                    Some(state) => {
                        state.expected = Some(dispatched);
                        try_finalize_session(state)
                    }
                    None => false,
                };
                if finalized {
                    states.remove(&session);
                }
            }
            Ok(Msg::WorkerDone { stats, metrics, backend }) => {
                merged.merge(&metrics);
                per_worker.push(stats);
                backend_name = backend;
                worker_exits += 1;
            }
            Ok(Msg::Failure { error, worker_exit }) => {
                if worker_exit {
                    worker_exits += 1; // a failed worker never sends WorkerDone
                }
                fail_server(core, error, &mut failure, &mut states);
            }
            Ok(Msg::DispatcherExited) => {
                dispatcher_exited = true;
            }
            Err(RecvTimeoutError::Timeout) => {
                if t_ready.is_none()
                    && failure.is_none()
                    && clock.now().saturating_duration_since(t_start) > warmup_timeout
                {
                    let msg = format!(
                        "workers failed to warm up within {:.1}s ({ready_count} of \
                         {n_workers} ready)",
                        warmup_timeout.as_secs_f64()
                    );
                    fail_server(core, msg, &mut failure, &mut states);
                }
                let dispatched = core.total_dispatched.load(Ordering::Relaxed); // relaxed-ok: single-writer progress counter; terminal reads follow the channel
                if t_ready.is_some()
                    && failure.is_none()
                    && dispatched > agg.emitted
                    && clock.now().saturating_duration_since(last_progress) > stall_timeout
                {
                    let msg = format!(
                        "engine stalled: no progress for {:.1}s ({} of {} dispatched \
                         frames emitted)",
                        stall_timeout.as_secs_f64(),
                        agg.emitted,
                        dispatched
                    );
                    fail_server(core, msg, &mut failure, &mut states);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Every sender (dispatcher + workers) is gone.
                if failure.is_none()
                    && !(core.closing.load(Ordering::Relaxed) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
                        && dispatcher_exited
                        && worker_exits >= recover(&core.pool).spawned)
                {
                    let msg = "engine threads exited before completing the run".to_string();
                    fail_server(core, msg, &mut failure, &mut states);
                }
                break;
            }
        }
        // The dispatcher must have exited first: `spawned` is final once
        // the pool is closed, so the count cannot race a late scale_up.
        if dispatcher_exited
            && worker_exits >= recover(&core.pool).spawned
            && (core.closing.load(Ordering::Relaxed) || failure.is_some()) // relaxed-ok: control latch; consumers re-check via the activity event, which carries the edge
        {
            break;
        }
    }

    // Terminal aggregate (what the one-session wrappers report).
    for st in states.values_mut() {
        st.out = None;
    }
    per_worker.sort_by_key(|w| w.worker);
    let wall_s = t_ready.map(|t| clock.seconds_since(t)).unwrap_or(0.0);
    // Per-session QoS totals compose into the aggregate: drop counters
    // and SLO misses sum, latency histograms merge exactly.
    let mut dropped = 0u64;
    let mut dropped_quota = 0u64;
    let mut dropped_shed = 0u64;
    let mut slo_miss = 0u64;
    let mut accuracy_at_risk = 0u64;
    let mut tier_frames = [0u64; 3];
    let mut tier_ref_frames = [0u64; 3];
    let mut tier_agree = [0u64; 3];
    // Summed from the per-session accums (not the merged worker metrics)
    // so the aggregate is *exactly* the per-session sum.
    let mut queueing_sum = 0.0f64;
    let mut session_latency = LatencyHistogram::new();
    for s in recover(&core.sessions).iter() {
        dropped += s.rejected.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
        dropped_quota += s.rejected_quota.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
        dropped_shed += s.rejected_shed.load(Ordering::Relaxed); // relaxed-ok: monotonic counter; staleness tolerated, terminal reads follow the drain
        let a = recover(&s.accum);
        slo_miss += a.slo_miss;
        accuracy_at_risk += a.accuracy_at_risk;
        for t in 0..3 {
            tier_frames[t] += a.tier_frames[t]; // lint-allow(panic): fixed-length tier arrays, index < 3
            tier_ref_frames[t] += a.tier_ref_frames[t]; // lint-allow(panic): fixed-length tier arrays, index < 3
            tier_agree[t] += a.tier_agree[t]; // lint-allow(panic): fixed-length tier arrays, index < 3
        }
        queueing_sum += a.queueing_sum;
        session_latency.merge(&a.session_latency);
    }
    let outcome = match failure {
        Some(error) => Err(error),
        None => Ok((
            ServeReport {
                backend: backend_name.to_string(),
                frames: agg.emitted,
                dropped,
                dropped_quota,
                dropped_shed,
                slo_miss,
                accuracy_at_risk,
                tier_frames,
                tier_ref_frames,
                tier_agree,
                p99_latency_s: session_latency.quantile(0.99),
                wall_fps: if wall_s > 0.0 { agg.emitted as f64 / wall_s } else { 0.0 },
                mean_latency_s: merged.frame_latency_mean_s(),
                modeled_queueing_s: queueing_sum,
                mean_energy_j: merged.mean_energy_j(),
                modeled_kfps_per_watt: merged.modeled_kfps_per_watt(),
                mean_kept_patches: merged.mean_kept_patches(),
                mean_batch: merged.mean_batch(),
                mean_mask_iou: if agg.emitted > 0 { agg.iou_sum / agg.emitted as f64 } else { 0.0 },
                top1_accuracy: if agg.emitted > 0 {
                    agg.correct as f64 / agg.emitted as f64
                } else {
                    0.0
                },
                // Every worker that ever served, including scaled-up and
                // since-retired ones (`spawned` is final here — the
                // dispatcher closed the pool before this runs).
                workers: recover(&core.pool).spawned,
                per_worker,
            },
            merged,
        )),
    };
    *recover(&core.outcome) = Some(outcome);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BucketRouter;

    /// Minimal deterministic worker (no backend): routes from the
    /// ground-truth mask like the engine tests' mock.
    struct EchoWorker {
        router: BucketRouter,
        metrics: StageMetrics,
    }

    impl EchoWorker {
        fn new() -> Self {
            EchoWorker { router: BucketRouter::even(36, 4), metrics: StageMetrics::new() }
        }
    }

    impl FrameWorker for EchoWorker {
        fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
            let mask = frame.gt_mask(16);
            let kept = mask.kept().max(1);
            let bucket = self.router.route(kept);
            self.metrics.record_stage("total", 1e-4);
            self.metrics.record_frame(1e-5, kept);
            self.metrics.record_batch_size(1);
            let mut logits = vec![0.0f32; 10];
            logits[frame.label % 10] = 1.0;
            Ok(FrameResult {
                frame_index: frame.index,
                logits,
                mask,
                bucket,
                modeled_energy_j: 1e-5,
                latency_s: 1e-4,
                modeled_queueing_s: 0.0,
                batch_size: 1,
                tier: crate::quant::PrecisionTier::Int8,
                fp32_agreement: None,
            })
        }

        fn take_metrics(&mut self) -> StageMetrics {
            std::mem::take(&mut self.metrics)
        }
    }

    fn test_cfg(workers: usize) -> EngineConfig {
        let mut cfg = EngineConfig::new(workers, 16, 96);
        cfg.warmup_timeout_s = 10.0;
        cfg.stall_timeout_s = 5.0;
        cfg
    }

    #[test]
    fn serve_error_displays_each_variant() {
        assert!(ServeError::Closed.to_string().contains("closed"));
        assert!(ServeError::Failed("boom".into()).to_string().contains("boom"));
        assert!(ServeError::Poisoned("stats").to_string().contains("stats"));
    }

    #[test]
    fn poisoned_lock_surfaces_as_serve_error_not_a_panic() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // Public paths error gracefully…
        assert_eq!(guard(&m, "counter").unwrap_err(), ServeError::Poisoned("counter"));
        // …internal accounting recovers the plain data.
        assert_eq!(*recover(&m), 0);
    }

    #[test]
    fn session_options_builders_clamp() {
        let o = SessionOptions::named("cam").with_weight(0).with_queue_depth(0).with_window(5);
        assert_eq!(o.name, "cam");
        assert_eq!(o.weight, 1, "weight clamps to >= 1");
        assert_eq!(o.queue_depth, 1, "queue depth clamps to >= 1");
        assert_eq!(o.window, 5);
        assert_eq!(o.slo, None, "no SLO by default");
        assert_eq!(o.quota, Quota::unlimited(), "no quota by default");
        assert_eq!(
            o.precision,
            PrecisionPolicy::default(),
            "sessions default to the int8 fixed-precision policy"
        );
        let o = o
            .with_slo(Duration::from_millis(4))
            .with_quota(Quota::rate(30.0, 0).with_inflight(8))
            .with_precision(PrecisionPolicy::Auto);
        assert_eq!(o.slo, Some(Duration::from_millis(4)));
        assert_eq!(o.quota.max_inflight, 8);
        assert_eq!(o.quota.burst, 1, "rate burst clamps to >= 1");
        assert!(!o.quota.is_unlimited());
        assert_eq!(o.precision, PrecisionPolicy::Auto);
    }

    /// Build the shared session state the quota unit tests poke directly.
    fn shared_with_quota(quota: Quota, clock: &Clock) -> SessionShared {
        SessionShared {
            id: 0,
            name: "q".into(),
            weight: 1,
            window: 4,
            slo: None,
            quota,
            precision: PrecisionPolicy::default(),
            submitted: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            consumed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            rejected_shed: AtomicU64::new(0),
            bucket: Mutex::new(TokenBucket {
                tokens: quota.burst.max(1) as f64,
                last_refill: clock.now(),
            }),
            canceled: AtomicBool::new(false),
            accum: Mutex::new(SessionAccum::default()),
        }
    }

    #[test]
    fn token_bucket_quota_is_deterministic_on_a_manual_clock() {
        let (clock, manual) = Clock::manual();
        let s = shared_with_quota(Quota::rate(2.0, 1), &clock);
        assert!(s.admit_quota(&clock).is_ok(), "the bucket starts full (burst 1)");
        assert!(
            matches!(s.admit_quota(&clock), Err(QuotaDenied::Rate { .. })),
            "no time passed, no token"
        );
        // 2 fps → exactly one token per 500 ms of (manual) time.
        manual.advance(Duration::from_millis(500));
        assert!(s.admit_quota(&clock).is_ok());
        assert!(s.admit_quota(&clock).is_err());
        // A refund restores the token without any time passing (the
        // enqueue-failed path must not burn budget).
        s.refund_token();
        assert!(s.admit_quota(&clock).is_ok());
    }

    #[test]
    fn inflight_quota_frees_on_consumption() {
        let clock = Clock::system();
        let s = shared_with_quota(Quota::inflight(2), &clock);
        s.submitted.store(2, Ordering::Relaxed);
        assert!(matches!(s.admit_quota(&clock), Err(QuotaDenied::InFlight)));
        s.consumed.store(1, Ordering::Relaxed);
        assert!(s.admit_quota(&clock).is_ok(), "a drained result frees an in-flight slot");
    }

    #[test]
    fn wrr_sweep_grants_weight_per_turn_and_rotates() {
        let mut wrr = WrrAdmission::new();
        let weights = [2u32, 1];
        let mut granted = vec![0u64; 2];
        let g = wrr.sweep(&weights, |i| {
            granted[i] += 1;
            true
        });
        assert_eq!(g, 3, "one full sweep grants Σw admissions");
        assert_eq!(granted, vec![2, 1]);
        assert_eq!(wrr.turns(), 1);
        // A session that reports empty ends its turn without charging the
        // others.
        let g = wrr.sweep(&weights, |i| i != 0);
        assert_eq!(g, 1);
    }

    #[test]
    fn one_session_round_trip_in_order() {
        let server = Server::start(|_wid| Ok(EchoWorker::new()), test_cfg(2)).expect("server");
        let mut session = server.session(SessionOptions::named("cam")).expect("session");
        let mut src = VideoSource::new(96, 2, 7);
        for _ in 0..10 {
            session.submit(src.next_frame()).expect("submit");
        }
        session.close();
        let mut indices = Vec::new();
        for item in &mut session {
            indices.push(item.expect("streamed result").frame_index);
        }
        assert_eq!(indices.len(), 10);
        for pair in indices.windows(2) {
            assert!(pair[0] < pair[1], "session stream out of order: {indices:?}");
        }
        let report = session.report();
        assert_eq!(report.frames, 10);
        assert_eq!(report.backend, "custom");
        assert_eq!(report.tier_frames, [0, 10, 0], "every frame served at the default int8 tier");
        assert_eq!(report.tier_ref_frames, [0, 0, 0], "no fp32 reference probe configured");
        assert_eq!(report.slo_miss, 0, "no SLO declared, no misses");
        assert_eq!(report.dropped_quota, 0, "no quota declared, no policy drops");
        assert!(report.p99_latency_s >= 0.0);
        drop(session);
        let stats = server.stats().expect("stats");
        assert_eq!(stats.aggregate.frames, 10);
        assert_eq!(stats.sessions.len(), 1);
        assert!(stats.sessions[0].complete);
        assert!(!stats.sessions[0].canceled, "a drained session is complete, not canceled");
        let (agg, merged) = server.shutdown().expect("shutdown");
        assert_eq!(agg.frames, 10);
        assert_eq!(merged.frames(), 10);
        assert_eq!(agg.workers, 2);
        assert_eq!(agg.per_worker.len(), 2);
    }

    #[test]
    fn submit_after_close_is_rejected() {
        let server = Server::start(|_wid| Ok(EchoWorker::new()), test_cfg(1)).expect("server");
        let mut session = server.session(SessionOptions::default()).expect("session");
        let mut src = VideoSource::new(96, 1, 3);
        session.submit(src.next_frame()).expect("submit");
        session.close();
        assert_eq!(session.submit(src.next_frame()), Err(ServeError::Closed));
        assert_eq!(session.try_submit(src.next_frame()), PushOutcome::Closed);
        let report = session.finish().expect("drain");
        assert_eq!(report.frames, 1);
        server.shutdown().expect("shutdown");
    }

    #[test]
    fn sessions_rejected_after_shutdown_begins() {
        let server = Server::start(|_wid| Ok(EchoWorker::new()), test_cfg(1)).expect("server");
        let watch = server.watch();
        assert!(!watch.closing());
        server.core.closing.store(true, Ordering::Relaxed);
        assert!(watch.closing());
        assert_eq!(
            server.session(SessionOptions::default()).err(),
            Some(ServeError::Closed),
            "a closing server must not admit new sessions"
        );
        server.shutdown().expect("shutdown of an idle server");
    }

    #[test]
    fn lowest_free_core_picks_lowest_and_reuses_released() {
        assert_eq!(lowest_free_core(&[]), 0);
        assert_eq!(lowest_free_core(&[None, None]), 0);
        assert_eq!(lowest_free_core(&[Some(0), Some(1), None]), 2);
        // A retired worker's claim is cleared; its core is the next pick.
        assert_eq!(lowest_free_core(&[Some(0), Some(2)]), 1);
        assert_eq!(lowest_free_core(&[Some(1), Some(2)]), 0);
    }

    /// Spin (real time, bounded) until the live pool reaches `want`.
    fn wait_live(server: &Server, want: usize) {
        let t0 = std::time::Instant::now();
        while server.live_workers() != want {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "pool never reached {want} live workers (at {})",
                server.live_workers()
            );
            std::thread::yield_now();
        }
    }

    #[test]
    fn scale_up_and_down_resize_the_live_pool() {
        let mut cfg = test_cfg(1);
        cfg.max_workers = 3;
        let server = Server::start(|_wid| Ok(EchoWorker::new()), cfg).expect("server");
        server.wait_ready(Duration::from_secs(10)).expect("warmup");
        assert_eq!(server.live_workers(), 1);
        assert_eq!(server.scale_up().expect("grow to 2"), 2);
        assert_eq!(server.scale_up().expect("grow to 3"), 3);
        assert_eq!(server.scale_up(), Err(ScaleError::AtCapacity));
        assert_eq!(server.scale_down().expect("shrink toward 2"), 2);
        wait_live(&server, 2);
        let actions: Vec<ScaleAction> =
            server.scale_events().iter().map(|e| e.action.clone()).collect();
        assert_eq!(actions, vec![ScaleAction::Up, ScaleAction::Up, ScaleAction::Down]);
        let stats = server.stats().expect("stats");
        assert_eq!(stats.live_workers, 2);
        assert_eq!(
            stats.worker_health.iter().filter(|w| w.mode == WorkerMode::Retired).count(),
            1,
            "the retired worker keeps its final archived row"
        );
        let (agg, _) = server.shutdown().expect("shutdown");
        assert_eq!(agg.workers, 3, "every worker that ever served counts");
        assert_eq!(agg.per_worker.iter().filter(|w| w.retired).count(), 1);
    }

    #[test]
    fn a_lone_serving_worker_is_never_drained() {
        let server = Server::start(|_wid| Ok(EchoWorker::new()), test_cfg(1)).expect("server");
        server.wait_ready(Duration::from_secs(10)).expect("warmup");
        assert_eq!(server.scale_down(), Err(ScaleError::AtFloor));
        assert!(server.scale_events().is_empty(), "a refused scale is not an event");
        assert_eq!(server.live_workers(), 1);
        server.shutdown().expect("shutdown");
    }

    #[test]
    fn shed_thresholds_record_events_once() {
        let server = Server::start(|_wid| Ok(EchoWorker::new()), test_cfg(1)).expect("server");
        assert_eq!(server.shed_below(), 0);
        assert!(server.set_shed(2), "first threshold records");
        assert!(!server.set_shed(2), "same threshold is a no-op");
        assert_eq!(server.shed_below(), 2);
        assert!(server.clear_shed(), "clearing an active shed records");
        assert!(!server.clear_shed(), "clearing twice is a no-op");
        let actions: Vec<ScaleAction> =
            server.scale_events().iter().map(|e| e.action.clone()).collect();
        assert_eq!(
            actions,
            vec![ScaleAction::ShedOn { below_weight: 2 }, ScaleAction::ShedOff]
        );
        server.shutdown().expect("shutdown");
    }
}
