//! Frame queueing and bucket routing.
//!
//! RoI masking makes the backbone's sequence length data-dependent, but HLO
//! artifacts are fixed-shape. The coordinator therefore compiles the
//! backbone at a small set of *kept-patch buckets* and routes each frame to
//! the smallest bucket that fits, padding the remainder with zeroed,
//! validity-masked patch slots. This is the same shape-bucketing strategy
//! production LLM routers use for dynamic sequence lengths.

use crate::sensor::{Frame, VideoSource};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::Duration;

/// Routes a kept-patch count to a compiled bucket size.
#[derive(Debug, Clone)]
pub struct BucketRouter {
    /// Ascending bucket sizes; the last is the full patch count.
    buckets: Vec<usize>,
}

impl BucketRouter {
    pub fn new(mut buckets: Vec<usize>) -> Self {
        assert!(!buckets.is_empty(), "need at least one bucket");
        buckets.sort_unstable();
        buckets.dedup();
        BucketRouter { buckets }
    }

    /// Evenly spaced buckets up to `full` (e.g. full=36, steps=4 →
    /// [9, 18, 27, 36]).
    pub fn even(full: usize, steps: usize) -> Self {
        assert!(steps >= 1 && full >= steps);
        let buckets = (1..=steps).map(|i| full * i / steps).collect();
        Self::new(buckets)
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket that holds `kept` patches. Counts above the largest
    /// bucket clamp to it (callers then drop the lowest-score patches —
    /// cannot happen when the largest bucket is the full patch count).
    pub fn route(&self, kept: usize) -> usize {
        for &b in &self.buckets {
            if kept <= b {
                return b;
            }
        }
        *self.buckets.last().unwrap()
    }

    /// Padding waste ratio for a kept count (padded slots / bucket).
    pub fn waste(&self, kept: usize) -> f64 {
        let b = self.route(kept);
        if b == 0 {
            0.0
        } else {
            (b.saturating_sub(kept)) as f64 / b as f64
        }
    }
}

/// Bounded frame queue out of the sensor thread — feeding the inference
/// thread directly in single-pipeline serving, or the dispatcher in the
/// sharded engine (`coordinator::engine`), where it is the only point in
/// the system that drops frames. `try_push` drops the frame when full
/// (sensor backpressure: a saturated near-sensor pipeline drops frames
/// rather than buffering stale ones); callers count rejections to report
/// real drops, not frames merely in flight at shutdown.
#[derive(Debug)]
pub struct FrameQueue {
    tx: SyncSender<Frame>,
}

impl FrameQueue {
    /// Create the queue; returns (producer handle, consumer receiver).
    pub fn bounded(depth: usize) -> (FrameQueue, Receiver<Frame>) {
        let (tx, rx) = sync_channel(depth);
        (FrameQueue { tx }, rx)
    }

    /// Non-blocking push; returns false if the frame was dropped (queue
    /// full) or the consumer hung up.
    pub fn try_push(&self, frame: Frame) -> bool {
        !matches!(self.tx.try_send(frame), Err(TrySendError::Full(_) | TrySendError::Disconnected(_)))
    }

    /// Blocking push (used by paced sensors that must not drop).
    pub fn push(&self, frame: Frame) -> bool {
        self.tx.send(frame).is_ok()
    }
}

/// The sensor production loop shared by single-pipeline `serve` and the
/// sharded engine: produce frames as fast as the queue accepts them until
/// `stop` is set, idling while `go` is clear (consumers still warming up)
/// so warmup time can never inflate the rejection count. Every `try_push`
/// rejection — the only way the system drops a frame — increments
/// `rejected`.
pub fn sensor_loop(
    queue: FrameQueue,
    size: usize,
    num_objects: usize,
    seed: u64,
    go: &AtomicBool,
    stop: &AtomicBool,
    rejected: &AtomicU64,
) {
    let mut src = VideoSource::new(size, num_objects, seed);
    while !stop.load(Ordering::Relaxed) {
        if !go.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_micros(500));
            continue;
        }
        let f = src.next_frame();
        if !queue.try_push(f) {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            rejected.fetch_add(1, Ordering::Relaxed);
            // Yield briefly to let the consumer drain.
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

/// Receive with timeout helper for the inference loop.
pub fn recv_frame(rx: &Receiver<Frame>, timeout: Duration) -> Option<Frame> {
    match rx.recv_timeout(timeout) {
        Ok(f) => Some(f),
        Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensor::VideoSource;

    #[test]
    fn router_picks_smallest_fitting() {
        let r = BucketRouter::even(36, 4);
        assert_eq!(r.buckets(), &[9, 18, 27, 36]);
        assert_eq!(r.route(1), 9);
        assert_eq!(r.route(9), 9);
        assert_eq!(r.route(10), 18);
        assert_eq!(r.route(36), 36);
        assert_eq!(r.route(50), 36); // clamp
    }

    #[test]
    fn waste_bounded_below_bucket_gap() {
        let r = BucketRouter::even(36, 4);
        for kept in 1..=36 {
            assert!(r.waste(kept) < 1.0);
            let b = r.route(kept);
            assert!(b >= kept || b == 36);
        }
    }

    #[test]
    #[should_panic]
    fn empty_buckets_panic() {
        BucketRouter::new(vec![]);
    }

    #[test]
    fn queue_backpressure_drops_when_full() {
        let (q, rx) = FrameQueue::bounded(1);
        let mut src = VideoSource::new(32, 1, 1);
        assert!(q.try_push(src.next_frame()));
        assert!(!q.try_push(src.next_frame()), "second push must drop");
        let got = recv_frame(&rx, Duration::from_millis(10)).unwrap();
        assert_eq!(got.index, 0);
        assert!(q.try_push(src.next_frame()));
    }

    #[test]
    fn recv_times_out_cleanly() {
        let (_q, rx) = FrameQueue::bounded(1);
        assert!(recv_frame(&rx, Duration::from_millis(5)).is_none());
    }
}
