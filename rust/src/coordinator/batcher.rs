//! Frame queueing, bucket routing, and bucket-major micro-batching.
//!
//! RoI masking makes the backbone's sequence length data-dependent, but HLO
//! artifacts are fixed-shape. The coordinator therefore compiles the
//! backbone at a small set of *kept-patch buckets* and routes each frame to
//! the smallest bucket that fits, padding the remainder with zeroed,
//! validity-masked patch slots. This is the same shape-bucketing strategy
//! production LLM routers use for dynamic sequence lengths.
//!
//! The [`MicroBatcher`] completes that strategy on the execution side: a
//! fixed-shape bucket artifact only amortizes its dispatch overhead when it
//! runs over several frames per call, so routed frames accumulate in
//! per-bucket *lanes* and flush as one `Backend::execute_batch` group when
//! a lane fills (`max_batch`) or its oldest frame has waited `max_wait`
//! (the deadline that bounds tail latency under light load).

use crate::quant::PrecisionTier;
use crate::sensor::{Frame, VideoSource};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::{Duration, Instant};

/// Routes a kept-patch count to a compiled bucket size.
#[derive(Debug, Clone)]
pub struct BucketRouter {
    /// Ascending bucket sizes; the last is the full patch count.
    buckets: Vec<usize>,
}

impl BucketRouter {
    pub fn new(mut buckets: Vec<usize>) -> Self {
        assert!(!buckets.is_empty(), "need at least one bucket");
        buckets.sort_unstable();
        buckets.dedup();
        BucketRouter { buckets }
    }

    /// Evenly spaced buckets up to `full` (e.g. full=36, steps=4 →
    /// [9, 18, 27, 36]).
    pub fn even(full: usize, steps: usize) -> Self {
        assert!(steps >= 1 && full >= steps);
        let buckets = (1..=steps).map(|i| full * i / steps).collect();
        Self::new(buckets)
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket that holds `kept` patches. Counts above the largest
    /// bucket clamp to it (callers then drop the lowest-score patches —
    /// cannot happen when the largest bucket is the full patch count).
    pub fn route(&self, kept: usize) -> usize {
        for &b in &self.buckets {
            if kept <= b {
                return b;
            }
        }
        // The constructor rejects an empty ladder, so the clamp target
        // always exists; stay panic-free on the serving path regardless.
        self.buckets.last().copied().unwrap_or(kept)
    }

    /// Padding waste ratio for a kept count (padded slots / bucket).
    pub fn waste(&self, kept: usize) -> f64 {
        let b = self.route(kept);
        if b == 0 {
            0.0
        } else {
            (b.saturating_sub(kept)) as f64 / b as f64
        }
    }
}

/// Micro-batching policy: when does a bucket lane flush?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Flush a lane as soon as it holds this many frames (>= 1).
    pub max_batch: usize,
    /// Flush a non-empty lane once its **oldest** frame has waited this
    /// long — bounds per-frame latency when the lane fills slowly.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// The degenerate policy: every frame is its own batch (exactly the
    /// pre-batching serving behaviour).
    pub fn per_frame() -> Self {
        BatchPolicy { max_batch: 1, max_wait: Duration::ZERO }
    }

    /// Batch up to `max_batch` frames, waiting at most `max_wait` for a
    /// lane to fill.
    pub fn batched(max_batch: usize, max_wait: Duration) -> Self {
        BatchPolicy { max_batch: max_batch.max(1), max_wait }
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self::per_frame()
    }
}

/// One per-(bucket, tier) accumulation lane.
#[derive(Debug)]
struct Lane<T> {
    bucket: usize,
    /// Execution precision of every resident frame. A flushed group runs
    /// as one `execute_batch_tiered` call at one tier, so a 4-bit frame
    /// must never ride an 8-bit group's weight programming — lanes are
    /// bucket×tier-major.
    tier: PrecisionTier,
    items: Vec<T>,
    /// When the oldest resident item arrived (`None` = empty lane).
    since: Option<Instant>,
    /// Earliest per-item deadline among residents (SLO-derived): the lane
    /// matures at `min(since + max_wait, deadline)` — a latency-bound
    /// frame flushes its lane early instead of waiting out `max_wait`.
    deadline: Option<Instant>,
}

/// Bucket×tier-major micro-batcher: accumulates routed frames per
/// (bucket, precision-tier) lane and hands back `(bucket, group)` flushes
/// under a `max_batch`/`max_wait` deadline policy ([`BatchPolicy`]). Every
/// group is single-tier by construction; callers that batch mixed
/// precisions read the group's tier off its frames.
///
/// The batcher is deliberately clock-free: callers pass `now` into
/// [`MicroBatcher::push`]/[`MicroBatcher::poll`], which keeps the deadline
/// logic deterministic under test and lets the serving loop reuse one
/// `Instant` per iteration.
#[derive(Debug)]
pub struct MicroBatcher<T> {
    policy: BatchPolicy,
    lanes: Vec<Lane<T>>,
}

impl<T> MicroBatcher<T> {
    /// One lane per (bucket, tier) pair of the (validated) ladder — three
    /// tier lanes per bucket, so mixed-precision tenants can never share a
    /// flushed group.
    pub fn new(buckets: &[usize], policy: BatchPolicy) -> Self {
        assert!(!buckets.is_empty(), "need at least one bucket lane");
        MicroBatcher {
            policy,
            lanes: buckets
                .iter()
                .flat_map(|&b| {
                    PrecisionTier::ALL.iter().map(move |&tier| Lane {
                        bucket: b,
                        tier,
                        items: Vec::new(),
                        since: None,
                        deadline: None,
                    })
                })
                .collect(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    fn take(lane: &mut Lane<T>) -> (usize, Vec<T>) {
        lane.since = None;
        lane.deadline = None;
        (lane.bucket, std::mem::take(&mut lane.items))
    }

    /// When a lane matures: its `max_wait` deadline keyed to the oldest
    /// resident, or the earliest per-item SLO deadline — whichever is
    /// tighter.
    fn lane_deadline(&self, lane: &Lane<T>) -> Option<Instant> {
        let by_wait = lane.since.map(|s| s + self.policy.max_wait);
        match (by_wait, lane.deadline) {
            (Some(w), Some(d)) => Some(w.min(d)),
            (w, d) => w.or(d),
        }
    }

    /// Accumulate one routed frame in its bucket lane; returns the flushed
    /// `(bucket, group)` when the lane reaches `max_batch` (with
    /// `max_batch == 1` every push flushes — the degenerate per-frame
    /// case). Panics on a bucket outside the ladder, which the router can
    /// never produce.
    pub fn push(&mut self, bucket: usize, item: T, now: Instant) -> Option<(usize, Vec<T>)> {
        self.push_with_deadline(bucket, item, now, None)
    }

    /// [`MicroBatcher::push`] into an explicit precision-tier lane.
    pub fn push_tiered(
        &mut self,
        bucket: usize,
        tier: PrecisionTier,
        item: T,
        now: Instant,
    ) -> Option<(usize, Vec<T>)> {
        self.push_with_deadline_tiered(bucket, tier, item, now, None)
    }

    /// [`MicroBatcher::push`] for a frame carrying its own completion
    /// deadline (an SLO session's `accepted_at + slo`): the lane then
    /// matures at `min(oldest + max_wait, earliest item deadline)`, so a
    /// latency-bound frame is never held for the full `max_wait` — the
    /// deadline-aware flush that makes per-session SLOs enforceable.
    ///
    /// This is the **lane-based** form of the invariant, for callers that
    /// batch through `MicroBatcher` (the in-thread `FrameStream` path;
    /// property-gated in `rust/tests/property.rs`). The session server's
    /// workers group straight off their job queues instead of lanes, so
    /// they enforce the *same* maturity rule through the group-deadline
    /// `tighten()` in `coordinator::server`'s worker loop — change one
    /// and keep the other aligned.
    pub fn push_with_deadline(
        &mut self,
        bucket: usize,
        item: T,
        now: Instant,
        deadline: Option<Instant>,
    ) -> Option<(usize, Vec<T>)> {
        // The tierless entry is the INT8 lane — the fixed default tier, so
        // pre-mixed-precision callers keep their exact grouping behaviour.
        self.push_with_deadline_tiered(bucket, PrecisionTier::Int8, item, now, deadline)
    }

    /// [`MicroBatcher::push_with_deadline`] into an explicit tier lane.
    pub fn push_with_deadline_tiered(
        &mut self,
        bucket: usize,
        tier: PrecisionTier,
        item: T,
        now: Instant,
        deadline: Option<Instant>,
    ) -> Option<(usize, Vec<T>)> {
        let max = self.policy.max_batch.max(1);
        let lane = self
            .lanes
            .iter_mut()
            .find(|l| l.bucket == bucket && l.tier == tier)
            // lint-allow(panic): `bucket` comes from `route()` over this
            // batcher's own ladder and every bucket has a lane per tier,
            // so the lane always exists; a miss is a routing-table
            // corruption worth crashing on.
            .expect("routed bucket must be in the batcher's ladder");
        lane.items.push(item);
        lane.since.get_or_insert(now);
        lane.deadline = match (lane.deadline, deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if lane.items.len() >= max {
            Some(Self::take(lane))
        } else {
            None
        }
    }

    /// Flush the first matured lane: oldest frame waited at least
    /// `max_wait`, **or** an item's own deadline has arrived (SLO-derived
    /// early flush). Call repeatedly until `None`.
    pub fn poll(&mut self, now: Instant) -> Option<(usize, Vec<T>)> {
        let idx = self.lanes.iter().position(|l| {
            !l.items.is_empty()
                && self
                    .lane_deadline(l)
                    .is_some_and(|d| now >= d)
        })?;
        // lint-allow(panic): `idx` was produced by `position()` over
        // `self.lanes` on the line above.
        Some(Self::take(&mut self.lanes[idx]))
    }

    /// Earliest pending lane deadline (`max_wait` or per-item, whichever
    /// is tighter) — what a serving loop should bound its queue-receive
    /// timeout by.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.lanes
            .iter()
            .filter(|l| !l.items.is_empty())
            .filter_map(|l| self.lane_deadline(l))
            .min()
    }

    /// Flush the lane whose oldest frame has waited longest, regardless of
    /// deadline — the reassembly window's forcing move, and the drain step
    /// at end of stream.
    pub fn flush_oldest(&mut self) -> Option<(usize, Vec<T>)> {
        let idx = self
            .lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.since.is_some())
            .min_by_key(|(_, l)| l.since)
            .map(|(i, _)| i)?;
        // lint-allow(panic): `idx` was produced by `enumerate()` over
        // `self.lanes` on the lines above.
        Some(Self::take(&mut self.lanes[idx]))
    }

    /// Frames currently waiting in lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.items.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.items.is_empty())
    }
}

/// Outcome of a non-blocking queue push: the cases mean different things
/// to a sensor, and only [`PushOutcome::Full`] is a dropped frame in the
/// backpressure sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// The frame was enqueued.
    Queued,
    /// The queue was full — real backpressure; the frame was dropped
    /// (counted in `ServeReport::dropped`).
    Full,
    /// A per-session admission quota (max in-flight or token-bucket rate,
    /// `coordinator::server::Quota`) rejected the frame — a policy
    /// decision, not backpressure; counted separately in
    /// `ServeReport::dropped_quota`. Never produced by a plain
    /// [`FrameQueue`].
    Quota,
    /// Overload shedding rejected the frame: the autoscaler
    /// (`coordinator::autoscale`) hit its worker cap and is turning away
    /// the lowest-weight tenants until load falls. A fleet-level policy
    /// decision — not backpressure, not a per-session quota — counted
    /// separately in `ServeReport::dropped_shed`. Never produced by a
    /// plain [`FrameQueue`].
    Shed,
    /// The consumer hung up — shutdown, not backpressure; the frame went
    /// nowhere but must not count as a drop.
    Closed,
}

/// Bounded frame queue out of the sensor thread — feeding the inference
/// thread directly in single-pipeline serving, or the dispatcher in the
/// sharded engine (`coordinator::engine`), where it is the only point in
/// the system that drops frames. [`FrameQueue::try_push`] distinguishes a
/// full queue (sensor backpressure: a saturated near-sensor pipeline drops
/// frames rather than buffering stale ones — counted as a rejection) from
/// a disconnected consumer (shutdown — never counted), so a hung-up
/// receiver can no longer inflate the dropped-frame statistic.
#[derive(Debug)]
pub struct FrameQueue {
    tx: SyncSender<Frame>,
}

impl FrameQueue {
    /// Create the queue; returns (producer handle, consumer receiver).
    pub fn bounded(depth: usize) -> (FrameQueue, Receiver<Frame>) {
        let (tx, rx) = sync_channel(depth);
        (FrameQueue { tx }, rx)
    }

    /// Non-blocking push; see [`PushOutcome`] for the three cases.
    pub fn try_push(&self, frame: Frame) -> PushOutcome {
        match self.tx.try_send(frame) {
            Ok(()) => PushOutcome::Queued,
            Err(TrySendError::Full(_)) => PushOutcome::Full,
            Err(TrySendError::Disconnected(_)) => PushOutcome::Closed,
        }
    }

    /// Blocking push (used by paced sensors that must not drop).
    pub fn push(&self, frame: Frame) -> bool {
        self.tx.send(frame).is_ok()
    }
}

/// The sensor production loop shared by single-pipeline `serve` and the
/// sharded engine: produce frames as fast as the queue accepts them until
/// `stop` is set, idling while `go` is clear (consumers still warming up)
/// so warmup time can never inflate the rejection count. Every
/// [`PushOutcome::Full`] — the only way the system drops a frame —
/// increments `rejected`; a [`PushOutcome::Closed`] consumer ends the loop
/// without counting, because a receiver that hung up is shutdown, not
/// backpressure. All waiting goes through `clock` so a manual clock can
/// drive the loop deterministically.
pub fn sensor_loop(
    queue: FrameQueue,
    size: usize,
    num_objects: usize,
    seed: u64,
    clock: &super::clock::Clock,
    go: &AtomicBool,
    stop: &AtomicBool,
    rejected: &AtomicU64,
) {
    let mut src = VideoSource::new(size, num_objects, seed);
    // relaxed-ok: `stop` is a standalone control latch polled in a loop —
    // no payload is published under it, so ordering only affects how soon
    // the flip is observed, never correctness.
    while !stop.load(Ordering::Relaxed) {
        // relaxed-ok: `go` is the same kind of standalone control latch.
        if !go.load(Ordering::Relaxed) {
            clock.sleep(Duration::from_micros(500));
            continue;
        }
        let f = src.next_frame();
        match queue.try_push(f) {
            PushOutcome::Queued => {}
            // A plain FrameQueue has no admission quota or shed policy, so
            // Quota/Shed cannot occur here; treat them like Full for
            // robustness.
            PushOutcome::Full | PushOutcome::Quota | PushOutcome::Shed => {
                // relaxed-ok: same control latch as the loop condition.
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                // relaxed-ok: monotonic statistics counter; the reader
                // joins the producer thread before the final load, and the
                // join is the happens-before edge.
                rejected.fetch_add(1, Ordering::Relaxed);
                // Yield briefly to let the consumer drain.
                clock.sleep(Duration::from_micros(200));
            }
            PushOutcome::Closed => break,
        }
    }
}

/// Receive with timeout helper for the inference loop.
pub fn recv_frame(rx: &Receiver<Frame>, timeout: Duration) -> Option<Frame> {
    match rx.recv_timeout(timeout) {
        Ok(f) => Some(f),
        Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensor::VideoSource;

    #[test]
    fn router_picks_smallest_fitting() {
        let r = BucketRouter::even(36, 4);
        assert_eq!(r.buckets(), &[9, 18, 27, 36]);
        assert_eq!(r.route(1), 9);
        assert_eq!(r.route(9), 9);
        assert_eq!(r.route(10), 18);
        assert_eq!(r.route(36), 36);
        assert_eq!(r.route(50), 36); // clamp
    }

    #[test]
    fn waste_bounded_below_bucket_gap() {
        let r = BucketRouter::even(36, 4);
        for kept in 1..=36 {
            assert!(r.waste(kept) < 1.0);
            let b = r.route(kept);
            assert!(b >= kept || b == 36);
        }
    }

    #[test]
    #[should_panic]
    fn empty_buckets_panic() {
        BucketRouter::new(vec![]);
    }

    #[test]
    fn micro_batcher_flushes_on_size() {
        let t0 = Instant::now();
        let mut b = MicroBatcher::new(&[9, 36], BatchPolicy::batched(3, Duration::from_secs(1)));
        assert!(b.push(36, 'a', t0).is_none());
        assert!(b.push(9, 'x', t0).is_none(), "lanes accumulate independently");
        assert!(b.push(36, 'b', t0).is_none());
        let (bucket, group) = b.push(36, 'c', t0).expect("size flush");
        assert_eq!(bucket, 36);
        assert_eq!(group, vec!['a', 'b', 'c']);
        assert_eq!(b.pending(), 1, "the 9-lane still holds its frame");
        assert!(!b.is_empty());
    }

    #[test]
    fn micro_batcher_deadline_flush() {
        let t0 = Instant::now();
        let wait = Duration::from_millis(10);
        let mut b = MicroBatcher::new(&[9, 36], BatchPolicy::batched(4, wait));
        assert!(b.push(9, 1u32, t0).is_none());
        // Before the deadline: nothing matures.
        assert!(b.poll(t0 + Duration::from_millis(5)).is_none());
        assert_eq!(b.next_deadline(), Some(t0 + wait));
        // A later push must not extend the lane's deadline — it is keyed
        // to the *oldest* resident frame.
        assert!(b.push(9, 2u32, t0 + Duration::from_millis(5)).is_none());
        assert_eq!(b.next_deadline(), Some(t0 + wait));
        // At the deadline the lane flushes whole.
        let (bucket, group) = b.poll(t0 + wait).expect("deadline flush");
        assert_eq!(bucket, 9);
        assert_eq!(group, vec![1, 2]);
        assert!(b.is_empty());
        assert!(b.poll(t0 + Duration::from_secs(2)).is_none(), "empty lanes never mature");
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn micro_batcher_item_deadline_flushes_before_max_wait() {
        let t0 = Instant::now();
        let wait = Duration::from_secs(3600); // max_wait alone would hold it an hour
        let mut b = MicroBatcher::new(&[9, 36], BatchPolicy::batched(4, wait));
        let slo_deadline = t0 + Duration::from_millis(10);
        assert!(b.push_with_deadline(9, "slo", t0, Some(slo_deadline)).is_none());
        // The lane's effective deadline is the SLO one, not max_wait…
        assert_eq!(b.next_deadline(), Some(slo_deadline));
        assert!(b.poll(t0 + Duration::from_millis(9)).is_none(), "not yet due");
        // …and at the item deadline the lane flushes early.
        let (bucket, group) = b.poll(slo_deadline).expect("deadline-aware early flush");
        assert_eq!((bucket, group), (9, vec!["slo"]));
        assert!(b.is_empty());
    }

    #[test]
    fn micro_batcher_tightest_deadline_wins_and_resets_on_flush() {
        let t0 = Instant::now();
        let mut b = MicroBatcher::new(&[9], BatchPolicy::batched(8, Duration::from_secs(1)));
        let loose = t0 + Duration::from_millis(500);
        let tight = t0 + Duration::from_millis(20);
        assert!(b.push_with_deadline(9, 1u8, t0, Some(loose)).is_none());
        assert!(b.push_with_deadline(9, 2u8, t0, Some(tight)).is_none());
        // A later no-deadline push neither loosens nor tightens the lane.
        assert!(b.push(9, 3u8, t0 + Duration::from_millis(1)).is_none());
        assert_eq!(b.next_deadline(), Some(tight), "the tightest resident deadline binds");
        let (_, group) = b.poll(tight).expect("flush at the tight deadline");
        assert_eq!(group, vec![1, 2, 3], "the whole lane flushes together");
        // After the flush the lane's deadline state is cleared: a fresh
        // push is bounded by max_wait only.
        assert!(b.push(9, 4u8, tight).is_none());
        assert_eq!(b.next_deadline(), Some(tight + Duration::from_secs(1)));
    }

    #[test]
    fn micro_batcher_flush_oldest_forces_the_longest_waiter() {
        let t0 = Instant::now();
        let mut b = MicroBatcher::new(&[9, 18, 36], BatchPolicy::batched(8, Duration::from_secs(1)));
        assert!(b.flush_oldest().is_none(), "nothing to force on an empty batcher");
        assert!(b.push(18, "late", t0 + Duration::from_millis(2)).is_none());
        assert!(b.push(36, "early", t0).is_none());
        let (bucket, group) = b.flush_oldest().expect("forced flush");
        assert_eq!((bucket, group), (36, vec!["early"]));
        let (bucket, group) = b.flush_oldest().expect("second forced flush");
        assert_eq!((bucket, group), (18, vec!["late"]));
        assert!(b.is_empty());
    }

    #[test]
    fn per_frame_policy_flushes_every_push() {
        let t0 = Instant::now();
        let mut b = MicroBatcher::new(&[9, 36], BatchPolicy::per_frame());
        let (bucket, group) = b.push(9, 7u8, t0).expect("degenerate flush");
        assert_eq!((bucket, group), (9, vec![7u8]));
        assert!(b.is_empty());
    }

    #[test]
    fn lanes_are_bucket_and_tier_major() {
        use crate::quant::PrecisionTier::{Int4, Int8};
        let t0 = Instant::now();
        let mut b = MicroBatcher::new(&[9], BatchPolicy::batched(2, Duration::from_secs(1)));
        assert!(b.push_tiered(9, Int8, 'a', t0).is_none());
        assert!(
            b.push_tiered(9, Int4, 'x', t0).is_none(),
            "a 4-bit frame must not join the 8-bit lane"
        );
        let (bucket, group) = b.push_tiered(9, Int8, 'b', t0).expect("int8 lane fills alone");
        assert_eq!((bucket, group), (9, vec!['a', 'b']));
        assert_eq!(b.pending(), 1, "the int4 frame still waits in its own lane");
        let (bucket, group) = b.push_tiered(9, Int4, 'y', t0).expect("int4 lane fills alone");
        assert_eq!((bucket, group), (9, vec!['x', 'y']));
        assert!(b.is_empty());
        // The tierless entries are the INT8 lane: a legacy push completes
        // a group started with push_tiered(Int8).
        assert!(b.push_tiered(9, Int8, 'c', t0).is_none());
        let (_, group) = b.push(9, 'd', t0).expect("legacy push lands in the int8 lane");
        assert_eq!(group, vec!['c', 'd']);
    }

    #[test]
    #[should_panic]
    fn micro_batcher_rejects_unknown_bucket() {
        let mut b = MicroBatcher::new(&[9, 36], BatchPolicy::per_frame());
        let _ = b.push(17, (), Instant::now());
    }

    #[test]
    fn queue_backpressure_drops_when_full() {
        let (q, rx) = FrameQueue::bounded(1);
        let mut src = VideoSource::new(32, 1, 1);
        assert_eq!(q.try_push(src.next_frame()), PushOutcome::Queued);
        assert_eq!(q.try_push(src.next_frame()), PushOutcome::Full, "second push must drop");
        let got = recv_frame(&rx, Duration::from_millis(10)).unwrap();
        assert_eq!(got.index, 0);
        assert_eq!(q.try_push(src.next_frame()), PushOutcome::Queued);
    }

    #[test]
    fn disconnected_consumer_is_shutdown_not_backpressure() {
        let (q, rx) = FrameQueue::bounded(1);
        let mut src = VideoSource::new(32, 1, 1);
        drop(rx);
        assert_eq!(q.try_push(src.next_frame()), PushOutcome::Closed);
    }

    /// Regression: a hung-up receiver used to count every subsequent push
    /// as a dropped frame. The sensor loop must exit promptly on a closed
    /// queue with the rejection counter untouched.
    #[test]
    fn sensor_loop_exits_cleanly_when_consumer_hangs_up() {
        let (q, rx) = FrameQueue::bounded(2);
        drop(rx);
        let go = AtomicBool::new(true);
        let stop = AtomicBool::new(false);
        let rejected = AtomicU64::new(0);
        // Runs on this thread: a closed queue must break the loop on the
        // first push, long before any stop signal.
        let clock = super::super::clock::Clock::system();
        sensor_loop(q, 32, 1, 7, &clock, &go, &stop, &rejected);
        assert_eq!(
            rejected.load(Ordering::Relaxed),
            0,
            "shutdown must not masquerade as dropped frames"
        );
    }

    #[test]
    fn recv_times_out_cleanly() {
        let (_q, rx) = FrameQueue::bounded(1);
        assert!(recv_frame(&rx, Duration::from_millis(5)).is_none());
    }
}
