//! The L3 near-sensor serving coordinator — Opto-ViT's request path.
//!
//! The coordinator is generic over its execution substrate: every model
//! stage runs through the [`crate::runtime::Backend`] seam (`pjrt` =
//! compiled HLO on the PJRT client, `host` = pure-Rust reference compute,
//! `sim` = host numerics + modeled photonic timing), selected per run via
//! a [`crate::runtime::BackendFactory`]. No backend-specific symbol
//! appears in the pipeline or engine — artifact names are the contract.
//!
//! Single-pipeline serving (`serve`, [`pipeline`]):
//!
//! ```text
//! sensor thread ──frames──▶ bounded queue ──▶ inference thread
//!                                              │  MGNet (Backend)
//!                                              │  threshold → PatchMask
//!                                              │  gather kept patches
//!                                              │  bucket router (pad to bucket)
//!                                              │  ViT backbone (Backend)
//!                                              ▼  logits + metrics
//! ```
//!
//! Sharded serving (`serve_sharded`, [`engine`]) scales the host side to N
//! cores by putting a dispatcher between the sensor and N such pipelines:
//!
//! ```text
//!                         ┌─▶ worker 0 (own Pipeline + Backend) ─┐
//! sensor ─▶ dispatcher ───┼─▶ worker 1 (own Pipeline + Backend) ─┼─▶ reassembler
//!           (round-robin, │           …                          │   (in-order results,
//!            queue-depth  └─▶ worker N-1 ────────────────────────┘    merged StageMetrics,
//!            aware)                                                    per-worker utilization)
//! ```
//!
//! The dispatcher shards frames round-robin biased toward the worker with
//! the fewest in-flight frames; per-worker queues are bounded, so
//! backpressure propagates to the sensor queue, which is the only place
//! frames are dropped. The reassembler re-orders results by dispatch
//! sequence number, merges every worker's [`StageMetrics`], and fails the
//! run (rather than hanging) if any worker errors or panics.
//!
//! Python never appears here, and with the `host`/`sim` backends neither
//! do compiled artifacts — which is what lets CI exercise the full frame
//! path. Backends are not required to be `Send` (the PJRT client is not),
//! so each one lives on the thread that created it: the single-pipeline
//! path keeps it on one inference thread, and the engine constructs one
//! `Pipeline` *inside each worker thread* via its `BackendFactory` (see
//! [`engine::FrameWorker`]). The hot path is allocation-free in steady
//! state: per-frame buffers live in [`pipeline::FrameScratch`] and tensors
//! are handed to the backend as borrowed [`crate::runtime::TensorRef`]
//! views. [`pipeline::ServeReport`] names the backend that served the run;
//! under `sim` its latency column is modeled photonic-core time.

pub mod batcher;
pub mod engine;
pub mod pipeline;
pub mod stats;

pub use batcher::{BucketRouter, FrameQueue};
pub use engine::{serve_sharded, EngineConfig, FrameWorker};
pub use pipeline::{FrameResult, FrameScratch, Pipeline, PipelineConfig, ServeReport};
pub use stats::{StageMetrics, WorkerStats};
