//! The L3 near-sensor serving coordinator — Opto-ViT's request path.
//!
//! The coordinator is generic over its execution substrate: every model
//! stage runs through the [`crate::runtime::Backend`] seam (`pjrt` =
//! compiled HLO on the PJRT client, `host` = pure-Rust reference compute,
//! `sim` = host numerics + modeled photonic timing), selected per run via
//! a [`crate::runtime::BackendFactory`]. No backend-specific symbol
//! appears in the pipeline or engine — artifact names are the contract,
//! and execution is **batch-first**: the coordinator accumulates routed
//! frames bucket-major and drives `Backend::execute_batch` so dispatch
//! overhead (and, on the modeled accelerator, MR weight-bank programming)
//! amortizes across each micro-batch.
//!
//! Serving is **session-oriented**: a long-lived [`server::Server`] owns
//! the dispatcher → N workers → reassembler machinery once, and any number
//! of tenants (cameras) open independent [`server::Session`]s on top of
//! it — the near-sensor deployment shape, one accelerator shared by
//! continuous multi-sensor traffic:
//!
//! ```text
//! session "cam-0" ──┐ (bounded queue, weight w0)
//! session "cam-1" ──┤            ┌─▶ worker 0 (Pipeline + Backend,
//! session "cam-2" ──┼▶ admission │     bucket-major micro-batch) ─┐
//!        …          │  (weighted ├─▶ worker 1 …                   ├─▶ per-session
//!                   │   round-   │        …                       │   reassembly →
//!                   └─  robin)   └─▶ worker N-1 ──────────────────┘   in-order
//!                                                                     SessionStreams
//! ```
//!
//! Frames from all sessions interleave through the workers' shared
//! per-bucket micro-batch lanes (same-bucket frames from *different*
//! cameras complete in one `execute_batch` call); admission is weighted
//! round-robin ([`server::WrrAdmission`]) so a hot camera cannot starve
//! the rest; each session gets strictly in-order results, its own
//! `ServeReport`, isolated backpressure, and graceful close/cancel
//! independent of server shutdown. Worker threads are optionally
//! core-pinned ([`engine::EngineConfig::pin_workers`], [`affinity`]).
//!
//! **Time is a seam, and QoS is per session.** Every deadline, wait, and
//! timestamp in the serving stack reads a pluggable [`clock::Clock`]
//! ([`engine::EngineConfig::clock`]; [`clock::Clock::system`] in
//! production, a step-controlled [`clock::ManualClock`] in tests), and
//! every wait in the session server is a clock-aware [`clock::Event`] —
//! no `thread::sleep` polling anywhere in the serving stack (the
//! in-thread `serve` path's synthetic-sensor helper paces through
//! `Clock::sleep` — it has no server to be notified by). The seam is
//! machine-enforced: `cargo run -p invariant-lint` rejects any raw
//! `Instant::now()` / `thread::sleep` outside [`clock`] (see the
//! *Machine-checked invariants* section below). On top of that seam each
//! session
//! can declare QoS ([`server::SessionOptions`]): a latency **SLO**
//! (frames carry `accepted_at + slo` deadlines; the dispatcher's
//! earliest-deadline-first pre-pass admits the most imminent peeked
//! deadline ahead of plain round-robin order, a worker flushes its
//! micro-batch group early when the earliest one arrives, and misses are
//! counted per session in `ServeReport::slo_miss` with a submit→emit
//! `p99_latency_s`) and an admission **[`server::Quota`]** (max in-flight
//! + token-bucket rate; `try_submit` rejections count the distinct
//! `dropped_quota`). Under a manual clock all of this is exactly
//! assertable — the deterministic `rust/tests/qos.rs` gate.
//!
//! **Serving survives degraded optics.** The `sim` backend carries a
//! clock-driven per-worker fault schedule (MR thermal drift, crosstalk
//! growth, stuck cells, dead VCSEL lanes —
//! [`crate::photonics::FaultSchedule`]), distilled into a continuous
//! health score the serving stack routes on:
//!
//! ```text
//! FaultSchedule (per worker, seeded, Clock-driven)
//!      │ state_at(elapsed)
//!      ▼
//! DegradationState ──health()──▶ Backend::health() ─▶ worker publishes
//!  (drift, stuck,                 (BackendHealth)      HealthSlot (lock-free)
//!   dead lanes, xt)                                         │
//!                  ┌────────────────────────────────────────┤ dispatcher reads
//!                  ▼                                        ▼
//!        place_job: critical frames              health sweep: health <
//!        (SLO / high weight) avoid               recal_below → Draining →
//!        at-risk workers; rotation               worker drains, pays
//!        anchor is health-weighted               Backend::recalibrate()
//!        (HealthWeightedWrr, never               (modeled time + energy),
//!        starves a worker)                       rejoins Serving
//! ```
//!
//! Frames served by an at-risk worker count the session's
//! `ServeReport::accuracy_at_risk` (aggregate = per-session sum);
//! [`server::ServerStats::worker_health`] exposes the live per-worker
//! score, mode, and recal counts. [`engine::HealthPolicy`] tunes the
//! thresholds (`aware: false` restores health-blind routing — the
//! control arm of the deterministic `rust/tests/faults.rs` gate).
//!
//! The pre-session batch-job surfaces survive as documented wrappers:
//!
//! - [`pipeline::serve`] — the **in-thread degenerate case** (one
//!   synthetic-sensor tenant, one pipeline on the caller's thread):
//!   returns a [`pipeline::FrameStream`] of in-order results backed by
//!   the same `MicroBatcher` lanes and bounded reassembly window.
//! - [`engine::serve_sharded`] / [`engine::run`] — **one-session
//!   wrappers** over [`server::Server`]: start the server, feed one
//!   session from the synthetic sensor, drain it in order, shut down into
//!   the aggregate report.
//!
//! Python never appears here, and with the `host`/`sim` backends neither
//! do compiled artifacts — which is what lets CI exercise the full frame
//! path (including multi-session serving, `rust/tests/sessions.rs`).
//! Backends are not required to be `Send` (the PJRT client is not), so
//! each one lives on the thread that created it: the server constructs
//! one `Pipeline` *inside each worker thread* via its `BackendFactory`.
//! The one-frame hot path is allocation-free in steady state
//! ([`pipeline::FrameScratch`] + borrowed [`crate::runtime::TensorRef`]
//! views); batched frames stage owned copies in [`pipeline::RoutedFrame`]s
//! so lanes can wait while routing continues. [`pipeline::ServeReport`]
//! names the backend that served the run and the mean micro-batch size;
//! under `sim` its latency column is modeled photonic-core time, recorded
//! per stage (`modeled_mgnet` / `modeled_backbone` / `modeled_queueing`).
//!
//! **Load-dependent modeled latency (queueing co-sim).** When the `sim`
//! backend is armed with a [`crate::runtime::QueueingPlan`] (`optovit
//! serve --backend sim` with `--cores` / `--arrival-fps`), each worker
//! replays the scheduler's per-frame task graph through the crate's
//! discrete-event simulator ([`crate::cosim`]) at each frame's *actual*
//! arrival time, so modeled latency includes waiting for busy cores
//! under the real arrival process:
//!
//! ```text
//! micro-batcher frame ─▶ arrival stamp (serving Clock, or paced k/fps)
//!                              │
//!                              ▼
//!                  cosim::QueueSim (one per worker)
//!                  per-core event queues: busy ? wait : start
//!                              │
//!                              ▼
//!                  `modeled_queueing` stage ─▶ ModeledStages::queueing_s
//!                  (FrameResult / ServeReport::modeled_queueing_s,
//!                   per-session exact sums, per-worker means)
//! ```
//!
//! At zero load the replay collapses bitwise to the closed-form
//! `steady_state_frame_ns` (the `rust/tests/cosim.rs` anchor); under
//! load the waiting term makes modeled latency depend on offered load —
//! the effect a static per-kept-count latency cache cannot express.
//!
//! **The pool is elastic.** With [`engine::EngineConfig::max_workers`]
//! above the starting size, a live [`server::Server`] can be resized
//! without a restart: [`server::Server::scale_up`] spawns a worker into
//! the lowest free pool slot (claiming the lowest free core when
//! `pin_workers` is on), [`server::Server::scale_down`] flags the
//! highest serving slot `Retiring` — it drains in-flight work, archives
//! a final `retired` stats row (totals stay monotone), and leaves; a
//! lone serving worker is never drained. [`autoscale::AutoScaler`]
//! closes the loop: ticked on the caller's cadence, it reads one
//! [`server::ServerStats`] snapshot (per-worker queue-depth gauges,
//! delta SLO miss rate, p99 trend) and walks a hysteresis ladder —
//! scale up under load, shed the lowest-weight tenants
//! ([`server::Server::set_shed`], counted in the distinct
//! `ServeReport::dropped_shed`) when capped, scale down when calm —
//! with every decision in the [`autoscale::ScaleEvent`] log.
//! [`loadgen`] is the proving ground: open-loop scripted arrival
//! scenarios (step / 10x burst / diurnal / seeded Poisson) swept
//! through synthetic sessions, fully deterministic under a manual
//! clock (the `rust/tests/storm.rs` gate and the `serve_storm` bench's
//! `BENCH_storm.json` offered-vs-achieved curves).
//!
//! # Machine-checked invariants
//!
//! The serving stack's discipline is enforced by tooling, not review
//! convention — `rust/tools/invariant-lint` (a required CI step, `cargo
//! run -p invariant-lint`) scans this tree and fails the build on:
//!
//! 1. **Clock seam** — no raw `Instant::now()` / `SystemTime::now()` /
//!    `thread::sleep` outside [`clock`] and `#[cfg(test)]` code. Time
//!    flows through [`clock::Clock`] or it does not flow at all (a
//!    deliberate wall-clock read carries a `// lint-allow(clock): <reason>`
//!    justification, e.g. the benchmark timer).
//! 2. **No-panic serving path** — `unwrap` / `expect` / `panic!` /
//!    slice-indexing in the five hot-path files ([`server`],
//!    [`pipeline`], [`engine`], [`batcher`], [`autoscale`]) is a build
//!    failure unless tagged `// lint-allow(panic): <why it cannot
//!    fire>`; fallible paths return [`server::ServeError`] instead.
//! 3. **Atomics-ordering audit** — every `Ordering::Relaxed` carries a
//!    `// relaxed-ok: <why no ordering is needed>` or is upgraded to
//!    Acquire/Release. The one protocol that genuinely publishes data
//!    across threads without a lock — [`health::HealthSlot`] — uses
//!    Release stores with Acquire readers, and its interleavings are
//!    exhaustively model-checked by loom (`rust/tests/loom_models.rs`,
//!    run under `RUSTFLAGS="--cfg loom"`, its own CI lane) through the
//!    [`crate::util::sync`] seam; the generation-counted [`clock::Event`]
//!    wait's no-missed-notify property is model-checked the same way.
//! 4. **Accounting convention** — every `ServeReport` counter appears in
//!    both the per-session accumulator and the aggregate-sum path, so a
//!    new counter cannot silently miss one of the two books.
//!
//! The linter's rule semantics are themselves pinned by seeded fixture
//! trees (`rust/tools/invariant-lint/tests/`): one of every violation
//! must be found at its exact line, and the repaired twin must scan to
//! zero.
//!
//! | module | role |
//! |---|---|
//! | [`clock`] | the time seam: pluggable `Clock` (system / manual) + clock-aware `Event` waits |
//! | [`batcher`] | bucket router, per-bucket micro-batch lanes (deadline-aware), bounded frame queues |
//! | [`pipeline`] | the frame pipeline (MGNet → mask → route → backbone), in-thread streaming `serve` |
//! | [`server`] | the session-oriented server: multi-tenant sessions, fair admission (`WrrAdmission`), per-session QoS (SLO / `Quota`), health-aware placement + recal windows (`HealthWeightedWrr`), elastic pool (`scale_up` / `scale_down` / `set_shed`), streams/reports |
//! | [`health`] | the lock-free per-worker `HealthSlot` publication cell (Release/Acquire protocol, loom-model-checked) |
//! | [`autoscale`] | the SLO-driven elasticity controller: `ScalePolicy` hysteresis bands + cooldowns, `AutoScaler::tick`, the `ScaleEvent` log |
//! | [`loadgen`] | open-loop load generation: scripted arrival `Scenario`s (step / burst / diurnal / Poisson), `PacedWorker`, the deterministic `run_scenario` storm driver |
//! | [`engine`] | `FrameWorker`/`EngineConfig` (incl. the serving clock and `max_workers` pool capacity) + the one-session batch-job wrappers (`run`, `serve_sharded`) |
//! | [`affinity`] | best-effort worker-thread core pinning (`sched_setaffinity`) |
//! | [`stats`] | per-stage metrics, merge-able across workers; latency histograms; per-worker utilization + live queue-depth gauges |

pub mod affinity;
pub mod autoscale;
pub mod batcher;
pub mod clock;
pub mod engine;
pub mod health;
pub mod loadgen;
pub mod pipeline;
pub mod server;
pub mod stats;

pub use autoscale::{AutoScaler, ScaleAction, ScaleEvent, ScalePolicy};
pub use batcher::{BatchPolicy, BucketRouter, FrameQueue, MicroBatcher, PushOutcome};
pub use clock::{Clock, Event, ManualClock};
pub use health::HealthSlot;
pub use engine::{serve_sharded, serve_sharded_with, EngineConfig, FrameWorker, HealthPolicy};
pub use loadgen::{
    run_scenario, Arrival, PacedWorker, Scenario, ScenarioKind, StormConfig, StormOutcome,
    StormSample,
};
pub use pipeline::{
    serve, FrameResult, FrameScratch, FrameStream, Pipeline, PipelineConfig, RoutedFrame,
    ServeOptions, ServeReport,
};
pub use server::{
    spawn_synthetic_sensor, HealthWeightedWrr, Quota, ScaleError, ServeError, Server,
    ServerStats, ServerWatch, Session, SessionOptions, SessionStats, SessionStream,
    SessionSubmitter, WrrAdmission,
};
pub use stats::{LatencyHistogram, StageMetrics, WorkerHealthStats, WorkerMode, WorkerStats};
