//! The L3 near-sensor serving coordinator — Opto-ViT's request path.
//!
//! The coordinator is generic over its execution substrate: every model
//! stage runs through the [`crate::runtime::Backend`] seam (`pjrt` =
//! compiled HLO on the PJRT client, `host` = pure-Rust reference compute,
//! `sim` = host numerics + modeled photonic timing), selected per run via
//! a [`crate::runtime::BackendFactory`]. No backend-specific symbol
//! appears in the pipeline or engine — artifact names are the contract,
//! and execution is **batch-first**: the coordinator accumulates routed
//! frames bucket-major and drives `Backend::execute_batch` so dispatch
//! overhead (and, on the modeled accelerator, MR weight-bank programming)
//! amortizes across each micro-batch.
//!
//! Single-pipeline serving is **streaming** ([`pipeline::serve`] returns a
//! [`pipeline::FrameStream`] — an iterator of in-order results; the
//! terminal [`pipeline::ServeReport`] is derived from the drained stream):
//!
//! ```text
//! sensor thread ──frames──▶ bounded queue ──▶ FrameStream
//!                                              │  MGNet (Backend) → mask → route
//!                                              │  MicroBatcher lanes (per bucket,
//!                                              │    max_batch / max_wait deadline)
//!                                              │  ViT backbone (Backend::execute_batch,
//!                                              │    one call per flushed lane)
//!                                              ▼  in-order FrameResults
//!                                                 (bounded reassembly window)
//! ```
//!
//! Sharded serving (`serve_sharded`, [`engine`]) scales the host side to N
//! cores by putting a dispatcher between the sensor and N such pipelines:
//!
//! ```text
//!                         ┌─▶ worker 0 (Pipeline + Backend, micro-batch) ─┐
//! sensor ─▶ dispatcher ───┼─▶ worker 1 (Pipeline + Backend, micro-batch) ─┼─▶ reassembler
//!           (round-robin, │           …                                   │   (in-order sink,
//!            queue-depth  └─▶ worker N-1 ─────────────────────────────────┘    bounded window,
//!            aware)                                                            merged StageMetrics)
//! ```
//!
//! The dispatcher shards frames round-robin biased toward the worker with
//! the fewest in-flight frames; per-worker queues are bounded, so
//! backpressure propagates to the sensor queue, which is the only place
//! frames are dropped (a hung-up consumer is shutdown, never a drop — see
//! [`batcher::PushOutcome`]). Each worker collects micro-batches from its
//! queue ([`engine::EngineConfig::batch`]) and processes them with one
//! bucket-major `process_batch` call. The reassembler re-orders results by
//! dispatch sequence number inside a bounded window, merges every worker's
//! [`StageMetrics`], and fails the run (rather than hanging) if any worker
//! errors or panics.
//!
//! Python never appears here, and with the `host`/`sim` backends neither
//! do compiled artifacts — which is what lets CI exercise the full frame
//! path. Backends are not required to be `Send` (the PJRT client is not),
//! so each one lives on the thread that created it: the single-pipeline
//! path keeps it on one inference thread, and the engine constructs one
//! `Pipeline` *inside each worker thread* via its `BackendFactory` (see
//! [`engine::FrameWorker`]). The one-frame hot path is allocation-free in
//! steady state: per-frame buffers live in [`pipeline::FrameScratch`] and
//! tensors are handed to the backend as borrowed
//! [`crate::runtime::TensorRef`] views; batched frames stage owned copies
//! in [`pipeline::RoutedFrame`]s so lanes can wait while routing
//! continues. [`pipeline::ServeReport`] names the backend that served the
//! run and the mean micro-batch size; under `sim` its latency column is
//! modeled photonic-core time, recorded per stage (`modeled_mgnet` /
//! `modeled_backbone`).

pub mod batcher;
pub mod engine;
pub mod pipeline;
pub mod stats;

pub use batcher::{BatchPolicy, BucketRouter, FrameQueue, MicroBatcher, PushOutcome};
pub use engine::{serve_sharded, serve_sharded_with, EngineConfig, FrameWorker};
pub use pipeline::{
    serve, FrameResult, FrameScratch, FrameStream, Pipeline, PipelineConfig, RoutedFrame,
    ServeOptions, ServeReport,
};
pub use stats::{StageMetrics, WorkerStats};
