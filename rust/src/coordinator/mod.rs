//! The L3 near-sensor serving coordinator — Opto-ViT's request path.
//!
//! ```text
//! sensor thread ──frames──▶ bounded queue ──▶ inference thread
//!                                              │  MGNet (PJRT)
//!                                              │  threshold → PatchMask
//!                                              │  gather kept patches
//!                                              │  bucket router (pad to bucket)
//!                                              │  ViT backbone (PJRT)
//!                                              ▼  logits + metrics
//! ```
//!
//! Python never appears here: both model stages execute pre-compiled HLO
//! artifacts through [`crate::runtime::Runtime`]. Because `PjRtClient` is
//! not `Send`, the runtime lives on the inference thread; the sensor runs
//! on its own thread with a bounded `sync_channel` providing backpressure.

pub mod batcher;
pub mod pipeline;
pub mod stats;

pub use batcher::{BucketRouter, FrameQueue};
pub use pipeline::{FrameResult, Pipeline, PipelineConfig, ServeReport};
pub use stats::StageMetrics;
