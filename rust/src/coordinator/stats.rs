//! Per-stage latency/throughput/energy metrics for the serving pipeline.

use crate::util::stats::Accumulator;
use std::collections::BTreeMap;
use std::time::Instant;

/// Latency metrics for the named pipeline stages plus modeled energy.
#[derive(Debug, Default)]
pub struct StageMetrics {
    stages: BTreeMap<String, Accumulator>,
    /// Modeled accelerator energy per frame (J).
    energy: Accumulator,
    /// Kept-patch counts.
    kept: Accumulator,
    start: Option<Instant>,
    frames: u64,
}

impl StageMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the start of the serving run (for wall-clock throughput).
    pub fn start_run(&mut self) {
        self.start = Some(Instant::now());
    }

    /// Record a stage latency in seconds.
    pub fn record_stage(&mut self, stage: &str, seconds: f64) {
        self.stages.entry(stage.to_string()).or_default().push(seconds);
    }

    /// Record one completed frame with its modeled energy and kept patches.
    pub fn record_frame(&mut self, energy_j: f64, kept_patches: usize) {
        self.energy.push(energy_j);
        self.kept.push(kept_patches as f64);
        self.frames += 1;
    }

    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Wall-clock frames/s since `start_run`.
    pub fn wall_fps(&self) -> f64 {
        match self.start {
            Some(t0) if self.frames > 0 => self.frames as f64 / t0.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Mean modeled energy per frame (J).
    pub fn mean_energy_j(&self) -> f64 {
        self.energy.mean()
    }

    /// Modeled KFPS/W from the mean frame energy.
    pub fn modeled_kfps_per_watt(&self) -> f64 {
        let e = self.mean_energy_j();
        if e <= 0.0 {
            0.0
        } else {
            1.0 / e / 1000.0
        }
    }

    pub fn mean_kept_patches(&self) -> f64 {
        self.kept.mean()
    }

    /// Mean latency of one stage (seconds).
    pub fn stage_mean_s(&self, stage: &str) -> f64 {
        self.stages.get(stage).map(|a| a.mean()).unwrap_or(0.0)
    }

    /// `(stage, mean_s, max_s, count)` rows for reporting.
    pub fn stage_rows(&self) -> Vec<(String, f64, f64, u64)> {
        self.stages
            .iter()
            .map(|(k, a)| (k.clone(), a.mean(), a.max(), a.count()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = StageMetrics::new();
        m.record_stage("mgnet", 0.002);
        m.record_stage("mgnet", 0.004);
        m.record_stage("backbone", 0.010);
        m.record_frame(1e-5, 12);
        m.record_frame(2e-5, 14);
        assert_eq!(m.frames(), 2);
        assert!((m.stage_mean_s("mgnet") - 0.003).abs() < 1e-12);
        assert!((m.mean_energy_j() - 1.5e-5).abs() < 1e-12);
        assert!((m.mean_kept_patches() - 13.0).abs() < 1e-12);
        assert!((m.modeled_kfps_per_watt() - 1.0 / 1.5e-5 / 1000.0).abs() < 1e-6);
        assert_eq!(m.stage_rows().len(), 2);
    }

    #[test]
    fn unknown_stage_is_zero() {
        let m = StageMetrics::new();
        assert_eq!(m.stage_mean_s("nope"), 0.0);
        assert_eq!(m.modeled_kfps_per_watt(), 0.0);
    }
}
