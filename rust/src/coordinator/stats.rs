//! Per-stage latency/throughput/energy metrics for the serving pipeline.

use crate::util::stats::Accumulator;
use std::collections::BTreeMap;
use std::time::Instant;

/// Latency metrics for the named pipeline stages plus modeled energy.
#[derive(Debug, Default)]
pub struct StageMetrics {
    stages: BTreeMap<String, Accumulator>,
    /// Modeled accelerator energy per frame (J).
    energy: Accumulator,
    /// Kept-patch counts.
    kept: Accumulator,
    /// Size of the micro-batch each frame rode in (1 on the per-frame
    /// path), frame-weighted.
    batch: Accumulator,
    start: Option<Instant>,
    frames: u64,
}

impl StageMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the start of the serving run (for run-relative throughput).
    /// Callers holding a [`super::clock::Clock`] pass `clock.now()` so run
    /// timing lives on the same timeline as every serving deadline —
    /// there is deliberately no zero-argument variant reading the wall
    /// clock (the invariant linter's clock-seam rule would reject one).
    pub fn start_run_at(&mut self, now: Instant) {
        self.start = Some(now);
    }

    /// Record a stage latency in seconds. Steady-state recording is
    /// allocation-free: the stage name is only copied to the heap the first
    /// time it is seen.
    pub fn record_stage(&mut self, stage: &str, seconds: f64) {
        if let Some(acc) = self.stages.get_mut(stage) {
            acc.push(seconds);
        } else {
            let mut acc = Accumulator::new();
            acc.push(seconds);
            self.stages.insert(stage.to_string(), acc);
        }
    }

    /// Record one completed frame with its modeled energy and kept patches.
    pub fn record_frame(&mut self, energy_j: f64, kept_patches: usize) {
        self.energy.push(energy_j);
        self.kept.push(kept_patches as f64);
        self.frames += 1;
    }

    /// Record the micro-batch size one frame was executed in (1 on the
    /// per-frame path). Frame-weighted, so `mean_batch` answers "how many
    /// frames shared this frame's dispatch on average".
    pub fn record_batch_size(&mut self, size: usize) {
        self.batch.push(size as f64);
    }

    /// Mean micro-batch size across recorded frames (0.0 before any
    /// frame).
    pub fn mean_batch(&self) -> f64 {
        self.batch.mean()
    }

    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Seconds since `start_run_at` against a caller-supplied `now` (the
    /// clock seam: pass `clock.now()`; 0.0 if never started).
    pub fn run_elapsed_s_at(&self, now: Instant) -> f64 {
        self.start.map(|t| now.saturating_duration_since(t).as_secs_f64()).unwrap_or(0.0)
    }

    /// Frames/s since `start_run_at` against a caller-supplied `now` (the
    /// clock seam: pass `clock.now()`).
    pub fn wall_fps_at(&self, now: Instant) -> f64 {
        let elapsed = self.run_elapsed_s_at(now);
        if self.frames > 0 && elapsed > 0.0 {
            self.frames as f64 / elapsed
        } else {
            0.0
        }
    }

    /// Mean modeled energy per frame (J).
    pub fn mean_energy_j(&self) -> f64 {
        self.energy.mean()
    }

    /// Modeled KFPS/W from the mean frame energy.
    pub fn modeled_kfps_per_watt(&self) -> f64 {
        kfps_per_watt(self.mean_energy_j())
    }

    pub fn mean_kept_patches(&self) -> f64 {
        self.kept.mean()
    }

    /// Whether any sample was recorded under `stage`.
    pub fn has_stage(&self, stage: &str) -> bool {
        self.stages.contains_key(stage)
    }

    /// Mean *reported* per-frame latency: the `"modeled"` stage when a
    /// simulating backend charged accelerator time; otherwise the
    /// `"latency"` stage (host wall-clock **including** micro-batch lane
    /// wait, recorded by the batched pipeline path); otherwise plain
    /// `"total"` wall-clock. Keeping the stages separate preserves
    /// busy-time/utilization accounting, which is always compute-only
    /// wall-clock (`"total"`).
    pub fn frame_latency_mean_s(&self) -> f64 {
        if self.has_stage("modeled") {
            self.stage_mean_s("modeled")
        } else if self.has_stage("latency") {
            self.stage_mean_s("latency")
        } else {
            self.stage_mean_s("total")
        }
    }

    /// Mean latency of one stage (seconds).
    pub fn stage_mean_s(&self, stage: &str) -> f64 {
        self.stages.get(stage).map(|a| a.mean()).unwrap_or(0.0)
    }

    /// Total recorded time of one stage (seconds) — e.g. the "total" stage
    /// sum is the busy time of the pipeline that recorded it.
    pub fn stage_sum_s(&self, stage: &str) -> f64 {
        self.stages.get(stage).map(|a| a.sum()).unwrap_or(0.0)
    }

    /// Fold another pipeline's metrics into this one. Merging the
    /// per-worker metrics of a sharded run yields exactly the metrics a
    /// single pipeline would have recorded over the union of their frames
    /// (means, extrema, variances, and counts all compose).
    pub fn merge(&mut self, other: &StageMetrics) {
        for (stage, acc) in &other.stages {
            self.stages.entry(stage.clone()).or_default().merge(acc);
        }
        self.energy.merge(&other.energy);
        self.kept.merge(&other.kept);
        self.batch.merge(&other.batch);
        self.frames += other.frames;
        // Earliest start wins so wall_fps spans the whole merged run.
        self.start = match (self.start, other.start) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
    }

    /// `(stage, mean_s, max_s, count)` rows for reporting.
    pub fn stage_rows(&self) -> Vec<(String, f64, f64, u64)> {
        self.stages
            .iter()
            .map(|(k, a)| (k.clone(), a.mean(), a.max(), a.count()))
            .collect()
    }
}

/// Modeled KFPS/W from a mean per-frame energy (J) — the one domain
/// formula shared by [`StageMetrics::modeled_kfps_per_watt`] and the
/// per-session report builder in `coordinator::server`. Non-positive
/// energy (no frames yet) reports 0.
pub fn kfps_per_watt(mean_energy_j: f64) -> f64 {
    if mean_energy_j <= 0.0 {
        0.0
    } else {
        1.0 / mean_energy_j / 1000.0
    }
}

/// Fixed-footprint log-scale latency histogram for per-session tail
/// accounting (`ServeReport::p99_latency_s`).
///
/// Sessions are long-lived and unbounded, so quantiles cannot keep every
/// sample; this trades exactness for a constant 1 KiB of state: bucket 0
/// holds everything below 1 µs, then 16 buckets per decade
/// (each ~15.5% wide) up to ~100 s. Quantiles report the **lower bound**
/// of the hit bucket, so the estimate never exaggerates a tail. Merging
/// histograms (cross-session aggregate) is exact bucket-wise addition.
///
/// Both `bucket()` and `lower_bound()` derive from **one** precomputed
/// edge table. They used to be computed independently (`log10` one way,
/// `powf` the other), and the float round-trip is not monotone at bucket
/// edges: a sample just above an edge could land in a bucket whose
/// recomputed lower bound *exceeded* the sample, silently violating the
/// conservative-quantile guarantee the `qos`/`storm` p99 assertions rely
/// on. With a shared table, `lower_bound(bucket(x)) <= x` holds by
/// construction for every `x`.
#[derive(Debug, Clone, Copy)]
pub struct LatencyHistogram {
    counts: [u64; Self::BUCKETS],
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    const BUCKETS: usize = 128;
    /// Lower edge of bucket 1 (bucket 0 is `[0, FLOOR_S)`).
    const FLOOR_S: f64 = 1e-6;
    /// Buckets per decade above the floor.
    const PER_DECADE: f64 = 16.0;

    pub fn new() -> Self {
        LatencyHistogram { counts: [0; Self::BUCKETS], total: 0 }
    }

    /// The shared bucket-edge table: `edges()[i]` is bucket `i`'s lower
    /// bound, with `edges()[0] = 0.0` and `edges()[1] = FLOOR_S` (so
    /// bucket 0 covers exactly the documented `[0, FLOOR_S)` range —
    /// a sample of `FLOOR_S` itself belongs to bucket 1).
    fn edges() -> &'static [f64; Self::BUCKETS] {
        static EDGES: std::sync::OnceLock<[f64; LatencyHistogram::BUCKETS]> =
            std::sync::OnceLock::new();
        EDGES.get_or_init(|| {
            let mut e = [0.0f64; Self::BUCKETS];
            for (i, v) in e.iter_mut().enumerate().skip(1) {
                *v = Self::FLOOR_S * 10f64.powf((i - 1) as f64 / Self::PER_DECADE);
            }
            e
        })
    }

    fn bucket(seconds: f64) -> usize {
        // NaN / negative / zero all land in bucket 0.
        if seconds.is_nan() || seconds <= 0.0 {
            return 0;
        }
        // The edge table is sorted and `edges()[0] = 0.0 <= seconds`, so
        // the partition point is at least 1 and `- 1` cannot underflow;
        // clamping keeps the overflow tail in the last bucket.
        let b = Self::edges().partition_point(|&edge| edge <= seconds) - 1;
        b.min(Self::BUCKETS - 1)
    }

    /// Lower bound of a bucket (0.0 for bucket 0) — read from the same
    /// table `bucket()` searched, so the pair is monotone by construction.
    fn lower_bound(bucket: usize) -> f64 {
        Self::edges()[bucket.min(Self::BUCKETS - 1)]
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        self.counts[Self::bucket(seconds)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Quantile estimate (`q` in `[0, 1]`): the lower bound of the bucket
    /// holding the rank-`ceil(q * n)` sample. 0.0 with no samples.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::lower_bound(i);
            }
        }
        Self::lower_bound(Self::BUCKETS - 1)
    }

    /// Fold another histogram in (exact: bucket-wise addition).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Per-worker utilization summary for a (possibly sharded) serving run.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    /// Worker index (0 for the single-threaded `serve` path).
    pub worker: usize,
    /// Frames this worker processed.
    pub frames: u64,
    /// Time spent inside `process_frame` (seconds).
    pub busy_s: f64,
    /// Mean modeled queueing delay per frame (seconds) charged by this
    /// worker's discrete-event co-sim — the `"modeled_queueing"` stage
    /// mean. 0.0 unless a queueing plan is armed on the `sim` backend.
    pub queueing_s: f64,
    /// `busy_s` over the worker's active wall-clock window, in `[0, 1]`.
    pub utilization: f64,
    /// Host core this worker's thread was pinned to
    /// (`EngineConfig::pin_workers`); `None` when pinning was off,
    /// unsupported on this platform, or refused by the kernel.
    pub core: Option<usize>,
    /// Final optical-health score of this worker's backend in `[0, 1]`
    /// (`1.0` for substrates without a fault model).
    pub health: f64,
    /// Recalibration windows this worker completed.
    pub recals: u64,
    /// Frames this worker served while its backend was accuracy-at-risk.
    pub at_risk_frames: u64,
    /// Frames dispatched to this worker but not yet completed at the
    /// moment the stats row was taken — the live queue-depth gauge the
    /// autoscaler reads. Always 0 in a worker's *final* row (a worker
    /// only exits once its queue is drained).
    pub queue_depth: u64,
    /// Whether this row belongs to a worker retired by a scale-down.
    /// Retired rows are kept so `ServerStats` totals (frames, recals,
    /// queueing) stay monotone across pool resizes.
    pub retired: bool,
}

/// What a worker is doing with respect to hardware health and pool
/// membership — the recalibration state machine the health-aware
/// dispatcher drives (`Serving → Draining → Recalibrating → Serving`)
/// plus the scale-down path (`Serving → Retiring → Retired`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerMode {
    /// In rotation, eligible for new frames.
    Serving,
    /// Flagged for recalibration: receives no new frames, finishing its
    /// in-flight work.
    Draining,
    /// Drained and paying the modeled recalibration window.
    Recalibrating,
    /// Flagged for retirement by a scale-down: receives no new frames,
    /// finishing its in-flight work before leaving the pool.
    Retiring,
    /// Out of the pool. The worker's final stats row is retained (flagged
    /// `retired`) so server totals stay monotone.
    Retired,
}

impl WorkerMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkerMode::Serving => "serving",
            WorkerMode::Draining => "draining",
            WorkerMode::Recalibrating => "recal",
            WorkerMode::Retiring => "retiring",
            WorkerMode::Retired => "retired",
        }
    }
}

/// Live per-worker hardware-health snapshot, surfaced by
/// `Server::stats()` while a run is in flight.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerHealthStats {
    /// Worker index.
    pub worker: usize,
    /// Latest published health score in `[0, 1]`.
    pub health: f64,
    /// Current recalibration state.
    pub mode: WorkerMode,
    /// Whether the worker's backend currently reports accuracy-at-risk.
    pub at_risk: bool,
    /// Recalibration windows completed so far.
    pub recals: u64,
    /// Modeled energy charged for those windows (joules).
    pub recal_energy_j: f64,
    /// Frames served while accuracy-at-risk.
    pub at_risk_frames: u64,
    /// Health snapshots the worker has published (≥ 1 once the worker has
    /// polled its backend; useful for tests synchronizing on publication).
    pub updates: u64,
    /// Frames dispatched to this worker but not yet completed — the live
    /// queue-depth gauge (the autoscaler's load signal). 0 for retired
    /// workers.
    pub queue_depth: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = StageMetrics::new();
        m.record_stage("mgnet", 0.002);
        m.record_stage("mgnet", 0.004);
        m.record_stage("backbone", 0.010);
        m.record_frame(1e-5, 12);
        m.record_frame(2e-5, 14);
        assert_eq!(m.mean_batch(), 0.0, "no batch sizes recorded yet");
        m.record_batch_size(1);
        m.record_batch_size(3);
        assert!((m.mean_batch() - 2.0).abs() < 1e-12);
        assert_eq!(m.frames(), 2);
        assert!((m.stage_mean_s("mgnet") - 0.003).abs() < 1e-12);
        assert!((m.mean_energy_j() - 1.5e-5).abs() < 1e-12);
        assert!((m.mean_kept_patches() - 13.0).abs() < 1e-12);
        assert!((m.modeled_kfps_per_watt() - 1.0 / 1.5e-5 / 1000.0).abs() < 1e-6);
        assert_eq!(m.stage_rows().len(), 2);
    }

    #[test]
    fn unknown_stage_is_zero() {
        let m = StageMetrics::new();
        assert_eq!(m.stage_mean_s("nope"), 0.0);
        assert_eq!(m.stage_sum_s("nope"), 0.0);
        assert_eq!(m.modeled_kfps_per_watt(), 0.0);
        assert!(!m.has_stage("nope"));
    }

    #[test]
    fn modeled_stage_overrides_reported_latency() {
        let mut m = StageMetrics::new();
        m.record_stage("total", 0.010);
        assert!((m.frame_latency_mean_s() - 0.010).abs() < 1e-15, "wall-clock by default");
        // The batched path's wait-inclusive "latency" stage beats plain
        // compute time...
        m.record_stage("latency", 0.015);
        assert!((m.frame_latency_mean_s() - 0.015).abs() < 1e-15, "lane wait must be reported");
        // ...and a simulating backend's modeled time beats both.
        m.record_stage("modeled", 2e-6);
        assert!(m.has_stage("modeled"));
        assert!(
            (m.frame_latency_mean_s() - 2e-6).abs() < 1e-18,
            "a simulating backend's modeled latency wins"
        );
        // Busy-time accounting stays wall-clock regardless.
        assert!((m.stage_sum_s("total") - 0.010).abs() < 1e-15);
    }

    #[test]
    fn latency_histogram_quantiles_are_conservative() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), 0.0, "empty histogram reports 0");
        for _ in 0..99 {
            h.record(1e-3);
        }
        h.record(0.050);
        assert_eq!(h.count(), 100);
        // p50 sits in the 1 ms bucket; the estimate is that bucket's lower
        // bound, so it never exceeds the true value.
        let p50 = h.quantile(0.50);
        assert!(p50 > 0.0 && p50 <= 1e-3, "p50 {p50}");
        // p99 is still the 1 ms bucket (rank 99 of 100)…
        assert!(h.quantile(0.99) <= 1e-3);
        // …and p100 reaches the 50 ms outlier's bucket.
        let p100 = h.quantile(1.0);
        assert!(p100 > 1e-3 && p100 <= 0.050, "p100 {p100}");
    }

    #[test]
    fn latency_histogram_handles_degenerate_samples_and_merges() {
        let mut a = LatencyHistogram::new();
        a.record(0.0);
        a.record(-1.0);
        a.record(f64::NAN);
        assert_eq!(a.quantile(1.0), 0.0, "degenerate samples land in bucket 0");
        let mut b = LatencyHistogram::new();
        b.record(2e-3);
        let mut merged = a;
        merged.merge(&b);
        assert_eq!(merged.count(), 4);
        assert!(merged.quantile(1.0) > 0.0);
        assert_eq!(merged.quantile(0.25), 0.0);
    }

    /// Per-session histograms are merged in whatever order sessions
    /// finish, and the aggregate in `ServerStats` is rebuilt on every
    /// call — merging must be order-insensitive and exact, or the
    /// autoscaler's miss-rate/p99 signals would depend on session order.
    #[test]
    fn latency_histogram_merge_is_associative_and_exact() {
        // Three "sessions" with overlapping but distinct latency ranges,
        // including degenerate samples.
        let streams: [&[f64]; 3] = [
            &[1e-3, 2e-3, 5e-3, 1e-3, 0.0],
            &[5e-4, 5e-2, 1e-3, f64::NAN],
            &[2e-2, 2e-2, 3e-6, -1.0, 8e-3, 1e-1],
        ];
        let mut parts = [LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new()];
        let mut whole = LatencyHistogram::new();
        for (h, s) in parts.iter_mut().zip(streams.iter()) {
            for &v in *s {
                h.record(v);
                whole.record(v);
            }
        }
        // (a ⊕ b) ⊕ c  vs  a ⊕ (b ⊕ c)  vs  c ⊕ a ⊕ b — all orders, plus
        // the single-recorder ground truth, must agree on every quantile.
        let mut left = parts[0];
        left.merge(&parts[1]);
        left.merge(&parts[2]);
        let mut right_inner = parts[1];
        right_inner.merge(&parts[2]);
        let mut right = parts[0];
        right.merge(&right_inner);
        let mut rotated = parts[2];
        rotated.merge(&parts[0]);
        rotated.merge(&parts[1]);
        assert_eq!(left.count(), whole.count());
        assert_eq!(right.count(), whole.count());
        assert_eq!(rotated.count(), whole.count());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let expect = whole.quantile(q);
            assert_eq!(left.quantile(q), expect, "left-fold q={q}");
            assert_eq!(right.quantile(q), expect, "right-fold q={q}");
            assert_eq!(rotated.quantile(q), expect, "rotated q={q}");
        }
    }

    /// The monotonicity property the old log10/powf round-trip violated
    /// at bucket edges: for *every* sample, the lower bound of its bucket
    /// never exceeds it, and therefore no quantile estimate can exceed
    /// the true sample maximum.
    #[test]
    fn latency_histogram_lower_bounds_never_exceed_samples() {
        let mut rng = crate::util::rng::Rng::new(0x2507_07044);
        let mut h = LatencyHistogram::new();
        let mut max_sample = 0.0f64;
        for i in 0..10_000 {
            // Log-uniform across the histogram's whole dynamic range
            // (~1e-8 .. ~1e2 s), plus exact bucket edges every few
            // samples — the adversarial inputs for edge round-tripping.
            let s = if i % 7 == 0 {
                LatencyHistogram::lower_bound(rng.below(LatencyHistogram::BUCKETS))
            } else {
                10f64.powf(rng.uniform(-8.0, 2.0))
            };
            assert!(
                LatencyHistogram::lower_bound(LatencyHistogram::bucket(s)) <= s,
                "lower_bound(bucket({s:e})) exceeded the sample"
            );
            h.record(s);
            max_sample = max_sample.max(s);
        }
        assert_eq!(h.count(), 10_000);
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0] {
            let est = h.quantile(q);
            assert!(est <= max_sample, "quantile({q}) = {est:e} > max {max_sample:e}");
        }
        // Edge self-consistency: every bucket's lower bound maps back to
        // that bucket (exact, because both sides read one table).
        for b in 0..LatencyHistogram::BUCKETS {
            assert_eq!(LatencyHistogram::bucket(LatencyHistogram::lower_bound(b)), b);
        }
        // The documented bucket-0 range is [0, FLOOR_S): the floor itself
        // belongs to bucket 1 (the old code put it in bucket 0).
        assert_eq!(LatencyHistogram::bucket(LatencyHistogram::FLOOR_S), 1);
        assert_eq!(LatencyHistogram::bucket(LatencyHistogram::FLOOR_S * 0.999), 0);
    }

    /// Merging an empty histogram is the identity, in either direction.
    #[test]
    fn latency_histogram_empty_merge_is_identity() {
        let mut h = LatencyHistogram::new();
        for v in [1e-3, 4e-3, 2e-2] {
            h.record(v);
        }
        let before: Vec<f64> = [0.5, 0.99, 1.0].iter().map(|&q| h.quantile(q)).collect();
        let mut merged = h;
        merged.merge(&LatencyHistogram::new());
        let mut from_empty = LatencyHistogram::new();
        from_empty.merge(&h);
        for (i, &q) in [0.5, 0.99, 1.0].iter().enumerate() {
            assert_eq!(merged.quantile(q), before[i]);
            assert_eq!(from_empty.quantile(q), before[i]);
        }
        assert_eq!(merged.count(), 3);
        assert_eq!(from_empty.count(), 3);
    }

    #[test]
    fn clock_parameterized_run_timing_matches_supplied_now() {
        let mut m = StageMetrics::new();
        let t0 = Instant::now();
        m.start_run_at(t0);
        m.record_frame(1e-5, 10);
        let now = t0 + std::time::Duration::from_secs(2);
        assert!((m.run_elapsed_s_at(now) - 2.0).abs() < 1e-9);
        assert!((m.wall_fps_at(now) - 0.5).abs() < 1e-9);
        // Before the start (racing a manual-clock snapshot): clamps to 0.
        assert_eq!(m.run_elapsed_s_at(t0), 0.0);
        assert_eq!(m.wall_fps_at(t0), 0.0);
    }

    #[test]
    fn merge_equals_single_recorder() {
        // Record the same sample stream either into one recorder or split
        // across three workers and merged — results must match exactly.
        let samples = [
            ("mgnet", 0.002),
            ("backbone", 0.010),
            ("mgnet", 0.004),
            ("backbone", 0.012),
            ("mgnet", 0.003),
            ("backbone", 0.008),
        ];
        let mut whole = StageMetrics::new();
        let mut parts = [StageMetrics::new(), StageMetrics::new(), StageMetrics::new()];
        for (i, &(stage, s)) in samples.iter().enumerate() {
            whole.record_stage(stage, s);
            parts[i % 3].record_stage(stage, s);
        }
        for (i, e) in [1e-5, 2e-5, 3e-5, 4e-5].iter().enumerate() {
            whole.record_frame(*e, 10 + i);
            parts[i % 3].record_frame(*e, 10 + i);
            whole.record_batch_size(1 + i % 2);
            parts[i % 3].record_batch_size(1 + i % 2);
        }
        let mut merged = StageMetrics::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.frames(), whole.frames());
        assert!((merged.stage_mean_s("mgnet") - whole.stage_mean_s("mgnet")).abs() < 1e-15);
        assert!((merged.stage_sum_s("backbone") - whole.stage_sum_s("backbone")).abs() < 1e-15);
        assert!((merged.mean_energy_j() - whole.mean_energy_j()).abs() < 1e-18);
        assert!((merged.mean_kept_patches() - whole.mean_kept_patches()).abs() < 1e-12);
        assert!((merged.mean_batch() - whole.mean_batch()).abs() < 1e-12);
        let wr = whole.stage_rows();
        let mr = merged.stage_rows();
        assert_eq!(wr.len(), mr.len());
        for (w, m) in wr.iter().zip(&mr) {
            assert_eq!(w.0, m.0);
            assert!((w.1 - m.1).abs() < 1e-15, "mean mismatch for {}", w.0);
            assert_eq!(w.2, m.2, "max mismatch for {}", w.0);
            assert_eq!(w.3, m.3, "count mismatch for {}", w.0);
        }
    }
}
