//! Best-effort worker-thread core pinning.
//!
//! The sharded engine's worker threads each own a full pipeline + backend,
//! so on a multi-core host the scheduler migrating a worker mid-run costs
//! cache locality exactly where the serving hot path is allocation-free and
//! cache-resident. [`pin_current_thread`] pins the calling thread to one
//! core via `sched_setaffinity(2)` on Linux (declared directly against
//! libc — the offline crate set has no `libc` crate) and is a documented
//! no-op everywhere else. Pinning is *best-effort*: a denied or failed
//! syscall degrades to the unpinned behaviour, never to an error — the
//! engine records the outcome per worker in
//! [`super::stats::WorkerStats::core`].

/// Host cores available to this process (at least 1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Mirrors glibc's cpu_set_t: 1024 bits of cpu mask.
#[cfg(target_os = "linux")]
const SET_WORDS: usize = 1024 / 64;

#[cfg(target_os = "linux")]
extern "C" {
    // pid 0 = the calling thread.
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
}

/// CPU ids the calling thread is currently allowed to run on, in
/// ascending order. CPU ids need not be contiguous from 0 — under a
/// container cpuset or `taskset` the permitted set can be e.g. `{2, 3}`,
/// so pinning must pick from this list, never from `0..n`.
#[cfg(target_os = "linux")]
fn allowed_cpus() -> Vec<usize> {
    let mut mask = [0u64; SET_WORDS];
    let rc = unsafe { sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) };
    if rc != 0 {
        return Vec::new();
    }
    let mut cpus = Vec::new();
    for (word_idx, &word) in mask.iter().enumerate() {
        for bit in 0..64 {
            if word & (1u64 << bit) != 0 {
                cpus.push(word_idx * 64 + bit);
            }
        }
    }
    cpus
}

/// Pin the calling thread to the `core % |allowed|`-th CPU of its allowed
/// set (so worker 0, 1, 2, … spread round-robin over whatever cpuset the
/// process actually has). Returns the CPU id actually pinned to, or
/// `None` when pinning is unsupported on this platform or the kernel
/// refused the mask (best-effort: the caller keeps running unpinned).
#[cfg(target_os = "linux")]
pub fn pin_current_thread(core: usize) -> Option<usize> {
    let allowed = allowed_cpus();
    if allowed.is_empty() {
        return None;
    }
    let target = allowed[core % allowed.len()];
    if target / 64 >= SET_WORDS {
        return None;
    }
    let mut mask = [0u64; SET_WORDS];
    mask[target / 64] = 1u64 << (target % 64);
    let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    (rc == 0).then_some(target)
}

/// Non-Linux platforms: pinning is a documented no-op.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_core: usize) -> Option<usize> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_one_core() {
        assert!(available_cores() >= 1);
    }

    /// Pin from a scratch thread so the test runner's own thread keeps its
    /// default affinity.
    #[test]
    #[cfg(target_os = "linux")]
    fn pins_within_the_allowed_cpu_set() {
        let allowed = allowed_cpus();
        assert!(!allowed.is_empty(), "a running thread always has at least one allowed CPU");
        // Pinning to the 0th allowed CPU must succeed — the target comes
        // from the thread's own permitted mask, so cpuset-restricted
        // containers pin too (ids need not start at 0).
        let pinned = std::thread::spawn(|| pin_current_thread(0)).join().expect("pin thread");
        assert_eq!(pinned, Some(allowed[0]));
        // Out-of-range worker ids wrap over the allowed set.
        let n = allowed.len();
        let wrapped =
            std::thread::spawn(move || pin_current_thread(n * 7 + 1)).join().expect("pin");
        assert_eq!(wrapped, Some(allowed[1 % n]));
    }

    #[test]
    #[cfg(not(target_os = "linux"))]
    fn non_linux_is_a_noop() {
        assert_eq!(pin_current_thread(0), None);
    }
}
