//! Lock-free per-worker health publication.
//!
//! [`HealthSlot`] is the cell a worker thread publishes its backend's
//! degradation signal into on every wake; the dispatcher reads it to
//! route critical frames away from at-risk workers and to schedule
//! recalibration windows, and [`super::server::Server::stats`] snapshots
//! it for reporting. It is deliberately all-atomics (no lock): the
//! dispatcher reads it inside the placement loop, and a worker mid-batch
//! must never block a routing decision.
//!
//! # Publication protocol (model-checked)
//!
//! [`HealthSlot::publish`] writes the health payload first (Relaxed),
//! then the `at_risk` routing flag with **Release**, then the `updates`
//! tick with **Release**. Readers take the flag with **Acquire**
//! ([`HealthSlot::at_risk`], [`HealthSlot::snapshot`]) before any payload
//! read, so a reader that observes `at_risk == true` is guaranteed to
//! also observe the degraded health value that caused it — the standard
//! message-passing pattern. Same for `updates`: a reader that observes
//! tick `n` (Acquire) sees everything publish `n` wrote, which is what
//! lets tests synchronize on "the worker has republished" without
//! sleeping.
//!
//! These ordering choices are not argued in prose only: the loom model in
//! `rust/tests/loom_models.rs` (run under `RUSTFLAGS="--cfg loom"`)
//! exhaustively explores the worker/dispatcher interleavings against this
//! exact type via the [`crate::util::sync`] seam and fails if any
//! weakening (e.g. Relaxed on the flag) lets a reader route on a flag
//! whose payload is not yet visible.
//!
//! The remaining Relaxed fields are single-writer statistics counters and
//! the mode latch, whose cross-thread edges ride the activity
//! [`super::clock::Event`] and pool mutex — each carries its own
//! `relaxed-ok` justification below (enforced by `invariant-lint`).

use super::stats::{WorkerHealthStats, WorkerMode};
use crate::util::sync::{AtomicBool, AtomicU64, AtomicU8, Ordering};

/// Per-worker hardware-health cell. `health` and `recal_energy` hold
/// `f64` bit patterns in `AtomicU64`s.
pub struct HealthSlot {
    /// Published health score in `[0, 1]` (`f64` bits; starts at 1.0 and
    /// stays there for backends without a fault model). Payload of the
    /// publication protocol — ordered by the `at_risk`/`updates`
    /// Release stores, never read for routing on its own.
    health: AtomicU64,
    /// [`WorkerMode`] discriminant — the recalibration state machine
    /// (`Serving → Draining → Recalibrating → Serving`).
    mode: AtomicU8,
    /// Completed recalibration cycles (drain → pay → rejoin).
    recals: AtomicU64,
    /// Last published accuracy-at-risk flag. The Release/Acquire flag of
    /// the publication protocol.
    at_risk: AtomicBool,
    /// Frames this worker completed (health accounting mirror).
    frames: AtomicU64,
    /// Frames completed while the backend reported accuracy-at-risk.
    at_risk_frames: AtomicU64,
    /// Modeled recalibration energy paid so far (`f64` bits, joules).
    recal_energy: AtomicU64,
    /// Publish ticks — lets tests synchronize on "the worker has
    /// (re)published its health" without sleeping.
    updates: AtomicU64,
}

impl HealthSlot {
    pub fn new() -> Self {
        HealthSlot {
            health: AtomicU64::new(1.0f64.to_bits()),
            mode: AtomicU8::new(WorkerMode::Serving as u8),
            recals: AtomicU64::new(0),
            at_risk: AtomicBool::new(false),
            frames: AtomicU64::new(0),
            at_risk_frames: AtomicU64::new(0),
            recal_energy: AtomicU64::new(0.0f64.to_bits()),
            updates: AtomicU64::new(0),
        }
    }

    /// Publish a fresh `(health, at_risk)` pair and advance the `updates`
    /// tick. Returns whether the health score *changed* (the caller
    /// notifies the activity event on change so the dispatcher re-sweeps
    /// promptly).
    ///
    /// Ordering: payload first (Relaxed), then flag and tick with
    /// Release — see the module docs and the loom model.
    pub fn publish(&self, health: f64, at_risk: bool) -> bool {
        let bits = health.to_bits();
        // relaxed-ok: payload store; made visible by the Release stores
        // on `at_risk` and `updates` below (loom-checked).
        let old = self.health.swap(bits, Ordering::Relaxed);
        self.at_risk.store(at_risk, Ordering::Release);
        self.updates.fetch_add(1, Ordering::Release);
        old != bits
    }

    /// Advance the `updates` tick without touching the published pair
    /// (workers whose backend has no health signal still prove liveness).
    pub fn tick(&self) {
        self.updates.fetch_add(1, Ordering::Release);
    }

    /// The accuracy-at-risk routing flag (Acquire: a `true` guarantees
    /// the degraded payload behind it is visible).
    pub fn at_risk(&self) -> bool {
        self.at_risk.load(Ordering::Acquire)
    }

    pub fn health_value(&self) -> f64 {
        // relaxed-ok: payload load; coherent with the flag when sequenced
        // after an Acquire `at_risk`/`updates` read, and a plain
        // monotonic gauge read otherwise.
        f64::from_bits(self.health.load(Ordering::Relaxed))
    }

    pub fn mode(&self) -> WorkerMode {
        // relaxed-ok: mode transitions hand off through the activity
        // event's lock (dispatcher flags Draining, worker drives the
        // rest), so the latch itself needs no ordering.
        match self.mode.load(Ordering::Relaxed) {
            1 => WorkerMode::Draining,
            2 => WorkerMode::Recalibrating,
            3 => WorkerMode::Retiring,
            4 => WorkerMode::Retired,
            _ => WorkerMode::Serving,
        }
    }

    pub fn set_mode(&self, mode: WorkerMode) {
        // relaxed-ok: see `mode` — the activity event notification that
        // follows every transition carries the edge.
        self.mode.store(mode as u8, Ordering::Relaxed);
    }

    /// Re-arm the slot for a fresh worker spawned into it after the
    /// previous occupant retired (the retired occupant's final row lives
    /// in `ServerCore::retired_health`, so nothing is lost). `updates`
    /// keeps counting across occupants — tests synchronize on it being
    /// monotone.
    pub fn reset(&self) {
        // relaxed-ok(fn): the spawner holds the pool mutex while
        // re-arming, and the new worker thread is created after — thread
        // spawn is the happens-before edge to the only other writer.
        self.health.store(1.0f64.to_bits(), Ordering::Relaxed);
        self.mode.store(WorkerMode::Serving as u8, Ordering::Relaxed);
        self.recals.store(0, Ordering::Relaxed);
        self.at_risk.store(false, Ordering::Relaxed);
        self.frames.store(0, Ordering::Relaxed);
        self.at_risk_frames.store(0, Ordering::Relaxed);
        self.recal_energy.store(0.0f64.to_bits(), Ordering::Relaxed);
    }

    /// Count `n` completed frames against this worker, `at_risk` ones
    /// separately.
    pub fn record_frames(&self, n: u64, at_risk: bool) {
        // relaxed-ok(fn): single-writer statistics counters (the worker
        // thread); readers are stats snapshots that tolerate a stale
        // count, and the terminal read follows the worker join.
        self.frames.fetch_add(n, Ordering::Relaxed);
        if at_risk {
            self.at_risk_frames.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// One completed recalibration cycle (drain → pay → rejoin).
    pub fn complete_recal(&self) {
        // relaxed-ok: single-writer statistics counter (worker thread).
        self.recals.fetch_add(1, Ordering::Relaxed);
    }

    pub fn recals(&self) -> u64 {
        // relaxed-ok: statistics snapshot; staleness is acceptable.
        self.recals.load(Ordering::Relaxed)
    }

    pub fn at_risk_frames(&self) -> u64 {
        // relaxed-ok: statistics snapshot; staleness is acceptable.
        self.at_risk_frames.load(Ordering::Relaxed)
    }

    pub fn recal_energy_j(&self) -> f64 {
        // relaxed-ok: statistics snapshot; staleness is acceptable.
        f64::from_bits(self.recal_energy.load(Ordering::Relaxed))
    }

    /// CAS-add onto the `f64`-bits energy cell (writers: worker thread
    /// only, but stats snapshots race the add, hence the loop).
    pub fn add_recal_energy(&self, joules: f64) {
        // relaxed-ok(fn): single-writer accumulate; the CAS loop is for
        // atomicity of read-modify-write against snapshot readers, not
        // for ordering — no payload rides on this cell.
        let mut cur = self.recal_energy.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + joules).to_bits();
            match self.recal_energy.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Reporting snapshot. The `at_risk` Acquire read comes first so the
    /// payload reads behind it are coherent with the flag.
    pub fn snapshot(&self, worker: usize, queue_depth: u64) -> WorkerHealthStats {
        let at_risk = self.at_risk();
        // Acquire: observing tick `n` synchronizes with publish `n`, so a
        // test that waits on `updates` sees everything that publish wrote.
        let updates = self.updates.load(Ordering::Acquire);
        WorkerHealthStats {
            worker,
            health: self.health_value(),
            mode: self.mode(),
            at_risk,
            recals: self.recals(),
            recal_energy_j: self.recal_energy_j(),
            at_risk_frames: self.at_risk_frames(),
            updates,
            queue_depth,
        }
    }
}

impl Default for HealthSlot {
    fn default() -> Self {
        Self::new()
    }
}
