//! Open-loop load generation for the serving stack: scripted arrival
//! scenarios (step, burst, diurnal sine, seeded-Poisson jitter) swept
//! through hundreds of synthetic camera sessions against a [`Server`],
//! optionally under [`AutoScaler`] control — the harness behind the
//! `serve_storm` bench (`BENCH_storm.json`) and a building block of the
//! `rust/tests/storm.rs` gate.
//!
//! **Open-loop** means arrival times come from the scenario's rate
//! curve, not from the server's completion pace — the generator keeps
//! offering frames when the pool falls behind, which is exactly what
//! makes offered-vs-achieved curves (and shed/drop counts) meaningful.
//! **Deterministic** means everything the server observes lives on a
//! [`ManualClock`] owned by [`run_scenario`]: arrivals are precomputed
//! ([`Scenario::arrivals`], seeded where random), the driver submits the
//! due slice of them each simulated tick, lets placement/completions
//! quiesce, ticks the autoscaler, then advances the clock by one tick.
//! Workers model service time by *sleeping on the serving clock*
//! ([`PacedWorker`]), so each worker completes at most one micro-batch
//! per tick — the capacity a scenario's fps is written against.
//!
//! ```text
//! Scenario rate curve ─▶ arrivals (precomputed, deterministic)
//!        │ per tick: due slice
//!        ▼
//! try_submit per session ─▶ Server (ManualClock) ─▶ drain try_next
//!        │                        │
//!        │                        ├─ AutoScaler::tick (optional)
//!        ▼                        ▼
//! StormSample per interval   ScaleEvent log, dropped/_quota/_shed
//! ```

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::autoscale::{AutoScaler, ScaleEvent, ScalePolicy};
use crate::coordinator::clock::Clock;
use crate::coordinator::engine::{EngineConfig, FrameWorker};
use crate::coordinator::pipeline::FrameResult;
use crate::coordinator::server::{Server, Session, SessionOptions};
use crate::coordinator::stats::{StageMetrics, WorkerMode};
use crate::coordinator::BucketRouter;
use crate::quant::{PrecisionPolicy, PrecisionTier};
use crate::sensor::{Frame, VideoSource};
use crate::util::rng::Rng;

/// The shape of a scenario's offered-load curve (frames/sec, summed
/// across all sessions; [`Scenario::arrivals`] spreads them round-robin).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioKind {
    /// Constant `base_fps` until `at_s`, then constant `step_fps`.
    Step { base_fps: f64, step_fps: f64, at_s: f64 },
    /// `base_fps`, multiplied by `mult` inside `[from_s, to_s)` — the
    /// 10x-spike shape the autoscaler gate rides.
    Burst { base_fps: f64, mult: f64, from_s: f64, to_s: f64 },
    /// `base_fps * (1 + amplitude * sin(2πt / period_s))`, floored at
    /// zero — a compressed day/night cycle.
    Diurnal { base_fps: f64, amplitude: f64, period_s: f64 },
    /// Poisson arrivals at `mean_fps` (seeded exponential inter-arrival
    /// times — jittered but exactly reproducible).
    Poisson { mean_fps: f64, seed: u64 },
}

/// One scripted sweep: a rate curve, how long to run it, and how many
/// sessions share it.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub kind: ScenarioKind,
    /// Simulated length of the sweep, seconds.
    pub duration_s: f64,
    /// Sessions the arrivals are spread over (round-robin).
    pub sessions: usize,
}

/// One arrival the driver owes the server: simulated time + session.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub t_s: f64,
    pub session: usize,
}

impl Scenario {
    pub fn step(name: impl Into<String>, sessions: usize, duration_s: f64, base_fps: f64, step_fps: f64, at_s: f64) -> Self {
        Scenario { name: name.into(), kind: ScenarioKind::Step { base_fps, step_fps, at_s }, duration_s, sessions: sessions.max(1) }
    }

    pub fn burst(name: impl Into<String>, sessions: usize, duration_s: f64, base_fps: f64, mult: f64, from_s: f64, to_s: f64) -> Self {
        Scenario { name: name.into(), kind: ScenarioKind::Burst { base_fps, mult, from_s, to_s }, duration_s, sessions: sessions.max(1) }
    }

    pub fn diurnal(name: impl Into<String>, sessions: usize, duration_s: f64, base_fps: f64, amplitude: f64, period_s: f64) -> Self {
        Scenario { name: name.into(), kind: ScenarioKind::Diurnal { base_fps, amplitude, period_s }, duration_s, sessions: sessions.max(1) }
    }

    pub fn poisson(name: impl Into<String>, sessions: usize, duration_s: f64, mean_fps: f64, seed: u64) -> Self {
        Scenario { name: name.into(), kind: ScenarioKind::Poisson { mean_fps, seed }, duration_s, sessions: sessions.max(1) }
    }

    /// Offered load (total fps across sessions) at simulated time `t_s`.
    pub fn offered_fps(&self, t_s: f64) -> f64 {
        match self.kind {
            ScenarioKind::Step { base_fps, step_fps, at_s } => {
                if t_s < at_s { base_fps } else { step_fps }
            }
            ScenarioKind::Burst { base_fps, mult, from_s, to_s } => {
                if t_s >= from_s && t_s < to_s { base_fps * mult } else { base_fps }
            }
            ScenarioKind::Diurnal { base_fps, amplitude, period_s } => {
                let phase = 2.0 * std::f64::consts::PI * t_s / period_s.max(1e-9);
                (base_fps * (1.0 + amplitude * phase.sin())).max(0.0)
            }
            ScenarioKind::Poisson { mean_fps, .. } => mean_fps,
        }
    }

    /// The full deterministic arrival schedule, sorted by time, sessions
    /// assigned round-robin. Deterministic kinds integrate the rate curve
    /// (1 ms steps, emitting whenever the accumulated mass crosses 1);
    /// Poisson draws seeded exponential inter-arrival gaps. Same
    /// scenario, same schedule — every run.
    pub fn arrivals(&self) -> Vec<Arrival> {
        let mut out = Vec::new();
        let mut next_session = 0usize;
        let mut push = |t_s: f64, next_session: &mut usize| {
            out.push(Arrival { t_s, session: *next_session });
            *next_session = (*next_session + 1) % self.sessions;
        };
        match self.kind {
            ScenarioKind::Poisson { mean_fps, seed } => {
                if mean_fps > 0.0 {
                    let mut rng = Rng::new(seed);
                    let mut t = 0.0f64;
                    loop {
                        // Exponential inter-arrival: -ln(1 - U) / λ.
                        let u = rng.next_f64();
                        t += -(1.0 - u).ln() / mean_fps;
                        if t >= self.duration_s {
                            break;
                        }
                        push(t, &mut next_session);
                    }
                }
            }
            _ => {
                let dt = 1e-3;
                let mut acc = 0.0f64;
                let mut t = 0.0f64;
                while t < self.duration_s {
                    acc += self.offered_fps(t) * dt;
                    while acc >= 1.0 {
                        acc -= 1.0;
                        push(t, &mut next_session);
                    }
                    t += dt;
                }
            }
        }
        out
    }
}

/// A [`FrameWorker`] that models service time by sleeping `service` on
/// the serving clock before echoing the frame's ground truth (the
/// `EchoWorker` shape). Under the harness's manual clock a worker
/// therefore completes exactly one micro-batch per clock tick it is
/// busy — a deterministic, load-independent capacity model that makes
/// "the pool is saturated at N fps" an arithmetic statement.
pub struct PacedWorker {
    clock: Clock,
    service: Duration,
    router: BucketRouter,
    metrics: StageMetrics,
}

impl PacedWorker {
    pub fn new(clock: Clock, service: Duration) -> Self {
        PacedWorker {
            clock,
            service,
            router: BucketRouter::even(36, 4),
            metrics: StageMetrics::new(),
        }
    }
}

/// The load model has no MGNet stage, so `Auto` has no ROI density to
/// read: a fixed session tier is honored for tier accounting, `Auto`
/// degrades to the int8 default (same rule as a mask-less pipeline).
fn modeled_tier(frame: &Frame) -> PrecisionTier {
    match frame.precision {
        PrecisionPolicy::Fixed(tier) => tier,
        PrecisionPolicy::Auto => PrecisionTier::Int8,
    }
}

impl FrameWorker for PacedWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        if !self.service.is_zero() {
            self.clock.sleep(self.service);
        }
        let mask = frame.gt_mask(16);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        let service_s = self.service.as_secs_f64();
        self.metrics.record_stage("total", service_s.max(1e-6));
        self.metrics.record_frame(1e-5, kept);
        self.metrics.record_batch_size(1);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: service_s,
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: modeled_tier(frame),
            fp32_agreement: None,
        })
    }

    /// One modeled service interval per *micro-batch* (not per frame):
    /// batching amortizes, so a worker's capacity is `max_batch` frames
    /// per clock tick.
    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        if !self.service.is_zero() {
            self.clock.sleep(self.service);
        }
        let n = frames.len().max(1);
        let service_s = self.service.as_secs_f64();
        frames
            .iter()
            .map(|frame| {
                let mask = frame.gt_mask(16);
                let kept = mask.kept().max(1);
                let bucket = self.router.route(kept);
                self.metrics.record_stage("total", (service_s / n as f64).max(1e-6));
                self.metrics.record_frame(1e-5, kept);
                self.metrics.record_batch_size(n);
                let mut logits = vec![0.0f32; 10];
                logits[frame.label % 10] = 1.0;
                Ok(FrameResult {
                    frame_index: frame.index,
                    logits,
                    mask,
                    bucket,
                    modeled_energy_j: 1e-5,
                    latency_s: service_s,
                    modeled_queueing_s: 0.0,
                    batch_size: n,
                    tier: modeled_tier(frame),
                    fp32_agreement: None,
                })
            })
            .collect()
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }

    fn backend_name(&self) -> &'static str {
        "paced"
    }
}

/// Driver knobs for [`run_scenario`].
#[derive(Debug, Clone)]
pub struct StormConfig {
    /// Simulated tick: the clock advances by this between submit rounds
    /// (also the autoscaler cadence).
    pub tick: Duration,
    /// Emit one [`StormSample`] every this many ticks.
    pub sample_every: u32,
    /// Modeled per-batch service time of each [`PacedWorker`].
    pub service: Duration,
    /// Per-session submit→emit SLO to score misses against (optional).
    pub slo: Option<Duration>,
    /// Autoscaling policy; `None` runs the fixed-pool control arm.
    pub autoscale: Option<ScalePolicy>,
}

impl Default for StormConfig {
    fn default() -> Self {
        StormConfig {
            tick: Duration::from_millis(100),
            sample_every: 5,
            service: Duration::from_millis(80),
            slo: Some(Duration::from_millis(500)),
            autoscale: None,
        }
    }
}

/// One point on the offered-vs-achieved curve.
#[derive(Debug, Clone, PartialEq)]
pub struct StormSample {
    /// Simulated seconds since the sweep started.
    pub t_s: f64,
    /// Scenario rate at `t_s` (total fps across sessions).
    pub offered_fps: f64,
    /// Emission rate over the last sample interval (simulated time).
    pub achieved_fps: f64,
    /// Aggregate submit→emit p99 so far, seconds (serving clock).
    pub p99_s: f64,
    /// Live workers at sample time.
    pub live_workers: usize,
    /// Total queued (placed, unfinished) frames across live workers.
    pub queue_depth: u64,
    /// Shedding threshold in force (0 = off).
    pub shed_below: u32,
}

/// Everything one sweep produced.
#[derive(Debug, Clone)]
pub struct StormOutcome {
    pub scenario: String,
    pub samples: Vec<StormSample>,
    /// Frames emitted end-to-end.
    pub frames: u64,
    pub dropped: u64,
    pub dropped_quota: u64,
    pub dropped_shed: u64,
    pub slo_miss: u64,
    /// Final live pool size.
    pub live_workers: usize,
    pub scale_events: Vec<ScaleEvent>,
}

/// Drain every session's buffered results without blocking; returns how
/// many were pulled. Real-time backoff only spins the *driver* — nothing
/// the server observes leaves the manual clock.
fn drain(sessions: &mut [Session]) -> u64 {
    let mut pulled = 0u64;
    for s in sessions.iter_mut() {
        while let Some(item) = s.try_next() {
            let _ = item;
            pulled += 1;
        }
    }
    pulled
}

/// Drain until the server visibly quiesces: no new results for a few
/// consecutive probes (the dispatcher/workers run on OS threads, so the
/// driver waits them out in real time — bounded by a 30 s wall bailout
/// that only a hung server hits).
fn settle(sessions: &mut [Session]) -> u64 {
    // lint-allow(clock): the driver holds the *manual* clock frozen while
    // real OS worker threads finish in wall time — waiting them out (and
    // the hung-server bailout) must read real time, or it would spin
    // forever on a clock nobody advances.
    let t0 = std::time::Instant::now();
    let mut pulled = 0u64;
    let mut idle = 0u32;
    while idle < 10 && t0.elapsed() < Duration::from_secs(30) {
        let got = drain(sessions);
        pulled += got;
        if got > 0 {
            idle = 0;
        } else {
            idle += 1;
            // lint-allow(clock): same wall-time wait as `t0` above.
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    pulled
}

/// Run one scenario against a fresh [`Server`] of [`PacedWorker`]s on an
/// internally-owned [`ManualClock`](crate::coordinator::ManualClock).
/// Per simulated tick: submit the due arrivals (`try_submit` — drops,
/// quota and shed rejections are the server's to count), let placement
/// and completions quiesce, tick the autoscaler (if any), advance the
/// clock. Sessions get weights alternating 1 and 2 so the shedding
/// ladder has a lowest class to reject first.
pub fn run_scenario(mut cfg: EngineConfig, storm: &StormConfig, scenario: &Scenario) -> Result<StormOutcome> {
    let (clock, manual) = Clock::manual();
    cfg.clock = clock.clone();
    let service = storm.service;
    let worker_clock = clock.clone();
    let server = Server::start(
        move |_wid| Ok(PacedWorker::new(worker_clock.clone(), service)),
        cfg,
    )?;
    server.wait_ready(Duration::from_secs(3600))?;

    let mut sessions: Vec<Session> = Vec::with_capacity(scenario.sessions);
    for i in 0..scenario.sessions {
        let mut opts = SessionOptions::named(format!("cam-{i}"))
            .with_weight(1 + (i % 2) as u32)
            .with_queue_depth(64)
            .with_window(64);
        if let Some(slo) = storm.slo {
            opts = opts.with_slo(slo);
        }
        sessions.push(server.session(opts)?);
    }
    let mut scaler = storm.autoscale.clone().map(|p| AutoScaler::new(p, clock.clone()));

    // One frame template, cloned per arrival: the load generator measures
    // the serving fabric, not the renderer.
    let template = VideoSource::new(96, 2, 7).next_frame();
    let arrivals = scenario.arrivals();
    let mut next_arrival = 0usize;

    let tick_s = storm.tick.as_secs_f64().max(1e-9);
    let ticks = (scenario.duration_s / tick_s).ceil() as u64;
    let mut samples = Vec::new();
    let mut frames_at_last_sample = 0u64;
    let mut t_last_sample = 0.0f64;
    let mut emitted = 0u64;

    for tick_idx in 0..ticks {
        let t_s = tick_idx as f64 * tick_s;
        // Offer every arrival due within this tick. Rejections (Full /
        // Quota / Shed / Closed) are deliberately not retried — open
        // loop — and land in the server's drop counters.
        while next_arrival < arrivals.len() && arrivals[next_arrival].t_s < t_s + tick_s {
            let a = arrivals[next_arrival];
            let _ = sessions[a.session].try_submit(template.clone());
            next_arrival += 1;
        }
        emitted += settle(&mut sessions);
        if let Some(sc) = scaler.as_mut() {
            sc.tick(&server)?;
        }
        manual.advance(storm.tick);
        emitted += settle(&mut sessions);

        if storm.sample_every > 0 && (tick_idx + 1) % storm.sample_every as u64 == 0 {
            let stats = server.stats()?;
            let now_s = (tick_idx + 1) as f64 * tick_s;
            let span = (now_s - t_last_sample).max(tick_s);
            let queue_depth: u64 = stats
                .worker_health
                .iter()
                .filter(|w| w.mode != WorkerMode::Retired)
                .map(|w| w.queue_depth)
                .sum();
            samples.push(StormSample {
                t_s: now_s,
                offered_fps: scenario.offered_fps(t_s),
                achieved_fps: (stats.aggregate.frames - frames_at_last_sample) as f64 / span,
                p99_s: stats.aggregate.p99_latency_s,
                live_workers: stats.live_workers,
                queue_depth,
                shed_below: stats.shed_below,
            });
            frames_at_last_sample = stats.aggregate.frames;
            t_last_sample = now_s;
        }
    }

    // Close every session, then keep advancing until the backlog drains
    // (bounded: the backlog is finite and every tick completes at least
    // one batch per live worker).
    for s in sessions.iter_mut() {
        s.close();
    }
    for _ in 0..(ticks + arrivals.len() as u64 + 16) {
        emitted += settle(&mut sessions);
        let stats = server.stats()?;
        if stats.sessions.iter().all(|s| s.complete || s.canceled) {
            break;
        }
        manual.advance(storm.tick);
    }
    let _ = emitted;

    let stats = server.stats()?;
    let outcome = StormOutcome {
        scenario: scenario.name.clone(),
        samples,
        frames: stats.aggregate.frames,
        dropped: stats.aggregate.dropped,
        dropped_quota: stats.aggregate.dropped_quota,
        dropped_shed: stats.aggregate.dropped_shed,
        slo_miss: stats.aggregate.slo_miss,
        live_workers: stats.live_workers,
        scale_events: stats.scale_events.clone(),
    };
    drop(sessions);
    server.shutdown()?;
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_arrivals_integrate_the_rate_curve_exactly() {
        // 2 fps for 5 s then 10 fps for 5 s → 10 + 50 arrivals.
        let s = Scenario::step("step", 4, 10.0, 2.0, 10.0, 5.0);
        let arr = s.arrivals();
        assert_eq!(arr.len(), 60);
        assert!(arr.windows(2).all(|w| w[0].t_s <= w[1].t_s), "sorted by time");
        // Round-robin session assignment covers every session.
        for sess in 0..4 {
            assert!(arr.iter().any(|a| a.session == sess));
        }
        let before = arr.iter().filter(|a| a.t_s < 5.0).count();
        assert_eq!(before, 10, "the low-rate half contributes exactly 2 fps * 5 s");
    }

    #[test]
    fn burst_multiplies_only_inside_the_window() {
        let s = Scenario::burst("burst", 1, 30.0, 1.0, 10.0, 10.0, 20.0);
        assert_eq!(s.offered_fps(5.0), 1.0);
        assert_eq!(s.offered_fps(10.0), 10.0);
        assert_eq!(s.offered_fps(19.99), 10.0);
        assert_eq!(s.offered_fps(20.0), 1.0);
        // 10 s * 1 fps + 10 s * 10 fps + 10 s * 1 fps.
        assert_eq!(s.arrivals().len(), 120);
    }

    #[test]
    fn poisson_arrivals_are_seeded_and_reproducible() {
        let a = Scenario::poisson("p", 3, 60.0, 5.0, 42).arrivals();
        let b = Scenario::poisson("p", 3, 60.0, 5.0, 42).arrivals();
        assert_eq!(a, b, "same seed, same schedule");
        let c = Scenario::poisson("p", 3, 60.0, 5.0, 43).arrivals();
        assert_ne!(a, c, "different seed, different jitter");
        // Mean rate is honored within a loose statistical band.
        assert!(a.len() > 200 && a.len() < 400, "≈300 expected, got {}", a.len());
    }

    #[test]
    fn diurnal_curve_floors_at_zero_and_oscillates() {
        let s = Scenario::diurnal("d", 1, 40.0, 4.0, 1.5, 40.0);
        assert_eq!(s.offered_fps(0.0), 4.0);
        assert!(s.offered_fps(10.0) > 4.0, "peak above base");
        assert_eq!(s.offered_fps(30.0), 0.0, "trough clamps at zero");
    }
}
