//! Discrete-event queueing co-simulation of the five-core photonic
//! scheduler: modeled latency that includes *waiting*, not just service.
//!
//! [`crate::arch::scheduler`] maps one frame onto the accelerator (list
//! scheduling over the Fig. 5 task DAG) and answers "how long does a frame
//! take on idle hardware" — a pure **service-time** model. Under load that
//! is the wrong number: frames arrive while earlier frames still occupy MR
//! banks, optical cores, and the EPU, and real latency includes the time
//! spent queued behind them. This module replays the mapped task graph
//! under an arbitrary arrival process:
//!
//! ```text
//! micro-batcher ──► arrival events (serving Clock stamps or a paced trace)
//!                         │
//!                         ▼
//!             per-core FIFO queues ([`CoreQueue`] × N + [`EpuQueue`]:
//!             serial light path, 2-deep ping-pong MR banks — exactly
//!             the PipelineScheduler resource rules)
//!                         │
//!                         ▼
//!             per-frame [`FrameSpan`] {service, queueing, completion}
//!             ──► "modeled_queueing" stage in StageMetrics / ServeReport
//! ```
//!
//! **Map once, then simulate under traffic** (the compiler → metasim → sim
//! split of hardware-emulation flows): [`FrameGraph`] builds the one-frame
//! task list per token count once, and [`QueueSim`] replays it per arrival,
//! carrying every resource availability horizon across frames. Because the
//! schedule builder emits identical task sequences per frame with strictly
//! intra-frame dependencies, replaying frame after frame over shared
//! resource state performs the *same float operations* as scheduling one
//! concatenated multi-frame build — so at zero offered load the co-sim
//! collapses to the closed-form model: a frame arriving to idle hardware
//! reports queueing of exactly `0.0`, and back-to-back arrivals reproduce
//! [`crate::arch::AttentionSchedule::steady_state_frame_ns`] bitwise (the
//! `tests/cosim.rs` anchors).
//!
//! Everything here is pure arithmetic over `f64` virtual nanoseconds — no
//! threads, no wall clock, no allocation per frame in steady state — so
//! every co-sim number is deterministic, and the serving integration
//! (`runtime::sim::SimBackend::modeled_queueing_s`) stays exact under
//! `ManualClock`. [`sweep::simulate`] drives the operating-point studies
//! (cores × batch × offered load → latency/KFPS-per-W curves comparable to
//! the paper's Fig. 9/11); the `operating_point` bench writes them to
//! `BENCH_cosim.json`.

pub mod des;
pub mod graph;
pub mod queue;
pub mod sweep;

pub use des::{FrameSpan, QueueSim};
pub use graph::FrameGraph;
pub use queue::{CoreQueue, EpuQueue, EventHeap};
pub use sweep::{percentile, simulate, OperatingPoint, OperatingPointReport};
