//! The discrete-event queueing simulator itself.
//!
//! [`QueueSim`] owns the per-resource queues and a cache of mapped
//! [`FrameGraph`]s (one per token count). Each [`QueueSim::arrive`] call
//! replays one frame's task list over the live resource state with every
//! dependency-free readiness floored at the arrival time — the exact
//! `PipelineScheduler::schedule` recurrence, generalized from "everything
//! ready at t=0" to "everything ready at t=arrival". Cross-frame coupling
//! flows *only* through the [`CoreQueue`]/[`EpuQueue`] horizons, mirroring
//! the hardware: a frame queues behind whatever the accelerator is still
//! doing, and nothing else.
//!
//! Exactness properties (asserted in `tests/cosim.rs`):
//! - a frame arriving to fully idle hardware reports `queueing_ns == 0.0`
//!   exactly, and the very first frame's latency is bitwise the one-frame
//!   schedule makespan;
//! - frames all arriving at t=0 perform the same float operations as one
//!   concatenated multi-frame `schedule()` build, so completion-horizon
//!   deltas reproduce `AttentionSchedule::steady_state_frame_ns` bitwise.

use std::collections::BTreeMap;

use crate::arch::scheduler::{Deps, Resource};
use crate::arch::CoreParams;
use crate::vit::VitConfig;

use super::graph::FrameGraph;
use super::queue::{CoreQueue, EpuQueue};

/// Modeled timing of one simulated frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameSpan {
    /// When the frame arrived (virtual ns).
    pub arrival_ns: f64,
    /// When its last task's compute finished (virtual ns).
    pub completion_ns: f64,
    /// Idle-hardware service time of its graph (ns).
    pub service_ns: f64,
    /// Waiting charged by contention: `(completion - arrival) - service`,
    /// clamped at zero — and **exactly** `0.0` when the frame arrived to
    /// idle hardware.
    pub queueing_ns: f64,
}

impl FrameSpan {
    /// Modeled time in system: queueing plus service.
    pub fn latency_ns(&self) -> f64 {
        self.completion_ns - self.arrival_ns
    }
}

/// Deterministic queueing co-simulator over the mapped frame graphs.
#[derive(Debug)]
pub struct QueueSim {
    cfg: VitConfig,
    params: CoreParams,
    /// Mapped-once task graphs, keyed by token count.
    graphs: BTreeMap<usize, FrameGraph>,
    cores: Vec<CoreQueue>,
    epu: EpuQueue,
    /// Per-task compute-end scratch for the current replay (reused across
    /// frames; no steady-state allocation).
    end_scratch: Vec<f64>,
    frames: u64,
    last_arrival_ns: f64,
}

impl QueueSim {
    /// A fresh (idle) simulator for `cfg` on a `params` accelerator.
    pub fn new(cfg: VitConfig, params: CoreParams) -> Self {
        QueueSim {
            cfg,
            params,
            graphs: BTreeMap::new(),
            cores: vec![CoreQueue::default(); params.num_cores],
            epu: EpuQueue::default(),
            end_scratch: Vec::new(),
            frames: 0,
            last_arrival_ns: 0.0,
        }
    }

    /// Frames simulated so far.
    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Latest resource availability horizon (ns): when the accelerator
    /// drains if nothing else arrives.
    pub fn horizon_ns(&self) -> f64 {
        self.cores.iter().map(|c| c.free_ns).fold(self.epu.free_ns, f64::max)
    }

    /// Idle-hardware service time for `n_tokens` (maps the graph if this
    /// token count is new).
    pub fn service_ns(&mut self, n_tokens: usize) -> f64 {
        self.ensure_graph(n_tokens);
        self.graphs[&n_tokens].service_ns
    }

    /// Drop all queued work (mapped graphs are kept — they are static).
    pub fn reset(&mut self) {
        for c in &mut self.cores {
            c.reset();
        }
        self.epu.free_ns = 0.0;
        self.frames = 0;
        self.last_arrival_ns = 0.0;
    }

    fn ensure_graph(&mut self, n_tokens: usize) {
        if !self.graphs.contains_key(&n_tokens) {
            let g = FrameGraph::map(&self.cfg, n_tokens, self.params);
            self.graphs.insert(n_tokens, g);
        }
    }

    /// Simulate one frame of `n_tokens` arriving at `arrival_ns`.
    /// Arrivals must be fed in non-decreasing time order (the FIFO queue
    /// discipline assumes it; the serving clock and paced traces are both
    /// monotone).
    pub fn arrive(&mut self, arrival_ns: f64, n_tokens: usize) -> FrameSpan {
        debug_assert!(
            arrival_ns >= self.last_arrival_ns,
            "arrivals must be time-ordered: {arrival_ns} < {}",
            self.last_arrival_ns
        );
        self.ensure_graph(n_tokens);
        self.last_arrival_ns = arrival_ns;
        self.frames += 1;
        let g = &self.graphs[&n_tokens];
        let idle = self.epu.idle_at(arrival_ns) && self.cores.iter().all(|c| c.idle_at(arrival_ns));

        // Dependency-gated readiness, floored at the arrival: a task with
        // no deps is ready the moment its frame arrives (deps are always
        // intra-frame, hence >= arrival already).
        fn dep_end(deps: &Deps, end: &[f64], arrival_ns: f64) -> f64 {
            let mut m = arrival_ns;
            deps.for_each(|d| m = m.max(end[d]));
            m
        }

        let end = &mut self.end_scratch;
        end.clear();
        end.reserve(g.tasks.len());
        let mut completion = arrival_ns;
        for t in &g.tasks {
            match t.resource {
                Resource::Core(c) => {
                    let q = &mut self.cores[c];
                    let tune_ready = dep_end(&t.tune_after, end, arrival_ns);
                    // Tuning needs a free bank of the 2-deep ping-pong
                    // pair: the next-to-last task's compute must be done.
                    let tune_start = tune_ready.max(q.bank_end_ns[0]);
                    let tune_end = tune_start + t.tune_ns;
                    let compute_ready = dep_end(&t.compute_after, end, arrival_ns);
                    let compute_start = tune_end.max(compute_ready).max(q.free_ns);
                    let compute_end = compute_start + t.compute_ns;
                    q.free_ns = compute_end;
                    q.bank_end_ns = [q.bank_end_ns[1], compute_end];
                    q.busy_ns += compute_end - compute_start;
                    completion = completion.max(compute_end);
                    end.push(compute_end);
                }
                Resource::Epu => {
                    let start = dep_end(&t.compute_after, end, arrival_ns).max(self.epu.free_ns);
                    let compute_end = start + t.compute_ns;
                    self.epu.free_ns = compute_end;
                    self.epu.busy_ns += t.compute_ns;
                    completion = completion.max(compute_end);
                    end.push(compute_end);
                }
            }
        }

        // Idle hardware means no contention by construction: report an
        // exact zero rather than the FP residue of `(a + x) - a - x`
        // reassociation. Busy arrivals clamp the (monotone-nonnegative)
        // difference against ulp noise the same way.
        let queueing_ns = if idle {
            0.0
        } else {
            ((completion - arrival_ns) - g.service_ns).max(0.0)
        };
        FrameSpan { arrival_ns, completion_ns: completion, service_ns: g.service_ns, queueing_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AttentionSchedule;
    use crate::vit::VitVariant;

    fn tiny() -> VitConfig {
        VitConfig::variant(VitVariant::Tiny, 96, 10)
    }

    #[test]
    fn first_frame_is_bitwise_the_idle_makespan() {
        let p = CoreParams::default();
        let mut sim = QueueSim::new(tiny(), p);
        let expect = AttentionSchedule::decomposed(&tiny(), 18, p, 1).schedule(p.num_cores).1;
        let span = sim.arrive(0.0, 18);
        assert_eq!(span.latency_ns(), expect.makespan_ns);
        assert_eq!(span.queueing_ns, 0.0);
        assert_eq!(span.service_ns, expect.makespan_ns);
        assert_eq!(sim.frames(), 1);
    }

    #[test]
    fn back_to_back_arrivals_reproduce_steady_state_bitwise() {
        let p = CoreParams::default();
        let mut sim = QueueSim::new(tiny(), p);
        let c0 = sim.arrive(0.0, 18).completion_ns;
        let c1 = sim.arrive(0.0, 18).completion_ns;
        let c2 = sim.arrive(0.0, 18).completion_ns;
        // Horizon deltas of the concatenated replay == the closed-form
        // steady-state figure, bitwise (same float ops in the same order).
        let steady = AttentionSchedule::steady_state_frame_ns(&tiny(), 18, p, true);
        assert_eq!(c2 - c1, steady);
        assert!(c1 > c0 && c0 > 0.0);
    }

    #[test]
    fn idle_arrivals_have_exactly_zero_queueing() {
        let p = CoreParams::default();
        let mut sim = QueueSim::new(tiny(), p);
        let service = sim.service_ns(18);
        // Space arrivals far beyond the drain horizon: every frame lands
        // on idle hardware.
        let mut t = 0.0;
        for _ in 0..4 {
            let span = sim.arrive(t, 18);
            assert_eq!(span.queueing_ns, 0.0);
            let lat = span.latency_ns();
            assert!(
                (lat - service).abs() <= service * 1e-9,
                "idle latency {lat} != service {service}"
            );
            t = sim.horizon_ns() + 10.0 * service;
        }
    }

    #[test]
    fn simultaneous_arrivals_queue_strictly() {
        let p = CoreParams::default();
        let mut sim = QueueSim::new(tiny(), p);
        let a = sim.arrive(0.0, 18);
        let b = sim.arrive(0.0, 18);
        assert_eq!(a.queueing_ns, 0.0);
        assert!(b.queueing_ns > 0.0, "second frame of a burst must wait: {b:?}");
        assert!(b.latency_ns() > a.latency_ns());
        assert!(sim.horizon_ns() >= b.completion_ns);
    }

    #[test]
    fn replay_is_bitwise_deterministic() {
        let run = || {
            let mut sim = QueueSim::new(tiny(), CoreParams::default());
            let mut out = Vec::new();
            let mut t = 0.0;
            for i in 0..12 {
                // Mixed token counts and a bursty, irregular trace.
                let n = [9, 18, 36][i % 3];
                out.push(sim.arrive(t, n));
                if i % 3 != 0 {
                    t += 1500.0 * (i as f64);
                }
            }
            out
        };
        assert_eq!(run(), run(), "same trace must replay bit-identically");
    }

    #[test]
    fn reset_returns_to_idle() {
        let p = CoreParams::default();
        let mut sim = QueueSim::new(tiny(), p);
        let first = sim.arrive(0.0, 18);
        sim.arrive(0.0, 18);
        assert!(sim.horizon_ns() > 0.0);
        sim.reset();
        assert_eq!(sim.frames(), 0);
        let again = sim.arrive(0.0, 18);
        assert_eq!(again.latency_ns(), first.latency_ns());
        assert_eq!(again.queueing_ns, 0.0);
    }
}
