//! Map-once frame task graphs: the static half of the co-simulation.
//!
//! A [`FrameGraph`] is the one-frame task list the Fig. 5 scheduler
//! produces for a token count, mapped **once** and then replayed per
//! arrival by [`super::des::QueueSim`]. The schedule builder emits every
//! frame identically (dependencies are strictly intra-frame; cross-frame
//! coupling is resource state only), which is what makes the replay exact.

use crate::arch::scheduler::AttentionSchedule;
use crate::arch::scheduler::Task;
use crate::arch::CoreParams;
use crate::vit::VitConfig;

/// One frame's mapped task DAG plus its idle-hardware makespan.
#[derive(Debug)]
pub struct FrameGraph {
    /// Token count this graph was mapped for.
    pub n_tokens: usize,
    /// Tasks in topological (submission) order, dependencies expressed as
    /// indices into this same vector.
    pub tasks: Vec<Task>,
    /// Idle-hardware makespan (ns): the frame's **service time** — latency
    /// when it arrives to an empty accelerator. Queueing is everything a
    /// loaded replay adds on top.
    pub service_ns: f64,
}

impl FrameGraph {
    /// Map one frame of the decomposed (Eq. 2, Fig. 5) flow at `n_tokens`
    /// through `cfg.depth` encoder blocks. Called once per token count;
    /// replays never rebuild it.
    pub fn map(cfg: &VitConfig, n_tokens: usize, params: CoreParams) -> Self {
        let sched = AttentionSchedule::decomposed(cfg, n_tokens, params, 1);
        let (_, stats) = sched.schedule(params.num_cores);
        FrameGraph { n_tokens, tasks: sched.tasks, service_ns: stats.makespan_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::VitVariant;

    fn tiny() -> VitConfig {
        VitConfig::variant(VitVariant::Tiny, 96, 10)
    }

    #[test]
    fn maps_one_frame_with_positive_service() {
        let g = FrameGraph::map(&tiny(), 18, CoreParams::default());
        assert!(!g.tasks.is_empty());
        assert!(g.service_ns > 0.0);
        assert_eq!(g.n_tokens, 18);
        // One-frame build: every task belongs to frame 0.
        assert!(g.tasks.iter().all(|t| t.name.frame == 0));
    }

    #[test]
    fn service_grows_with_tokens() {
        let p = CoreParams::default();
        let small = FrameGraph::map(&tiny(), 9, p).service_ns;
        let large = FrameGraph::map(&tiny(), 36, p).service_ns;
        assert!(large > small, "{large} !> {small}");
    }
}
