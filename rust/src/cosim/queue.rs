//! Per-resource queues and the event heap: the dynamic half's plumbing.
//!
//! Tasks are replayed onto each resource in FIFO (mapped) order, so a
//! resource queue collapses to its **availability horizons**: when the
//! light path frees ([`CoreQueue::free_ns`]) and when each bank of the
//! 2-deep ping-pong MR pair frees ([`CoreQueue::bank_end_ns`]). The max/+
//! recurrence over those horizons is exactly the per-task event processing
//! of the `PipelineScheduler`, in O(1) per task.
//!
//! [`EventHeap`] is a deterministic min-heap over `(virtual time, FIFO
//! sequence)` used where event streams genuinely interleave — the
//! operating-point sweep merges frame arrival and completion events with
//! it to track queue occupancy over time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// FIFO queue state of one optical core.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoreQueue {
    /// When the core's light path (compute) frees (ns).
    pub free_ns: f64,
    /// When each bank of the ping-pong MR pair frees: `[next-to-last,
    /// last]` compute end on this core — tuning of a new task may not
    /// start before `bank_end_ns[0]`.
    pub bank_end_ns: [f64; 2],
    /// Accumulated compute-busy time (ns), for utilization accounting.
    pub busy_ns: f64,
}

impl CoreQueue {
    /// Whether the core is idle at virtual time `t_ns` (no queued or
    /// running work; bank horizons never exceed `free_ns`).
    pub fn idle_at(&self, t_ns: f64) -> bool {
        self.free_ns <= t_ns
    }

    /// Drop queued work, keeping utilization counters.
    pub fn reset(&mut self) {
        self.free_ns = 0.0;
        self.bank_end_ns = [0.0; 2];
    }
}

/// FIFO queue state of the electronic processing unit.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpuQueue {
    /// When the EPU frees (ns).
    pub free_ns: f64,
    /// Accumulated busy time (ns).
    pub busy_ns: f64,
}

impl EpuQueue {
    /// Whether the EPU is idle at virtual time `t_ns`.
    pub fn idle_at(&self, t_ns: f64) -> bool {
        self.free_ns <= t_ns
    }
}

/// One queued event: total-ordered by `(time, insertion sequence)`, so
/// ties break FIFO and the pop order is deterministic.
#[derive(Debug)]
struct Entry<T> {
    time_ns: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap and we want the earliest
        // event first.
        other
            .time_ns
            .total_cmp(&self.time_ns)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event min-heap: events pop in virtual-time
/// order, FIFO within a timestamp.
#[derive(Debug, Default)]
pub struct EventHeap<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> EventHeap<T> {
    pub fn new() -> Self {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `payload` at virtual time `time_ns`.
    pub fn push(&mut self, time_ns: f64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time_ns, seq, payload });
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time_ns, e.payload))
    }

    /// Virtual time of the earliest pending event.
    pub fn peek_time_ns(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time_ns)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_heap_pops_in_time_order_fifo_on_ties() {
        let mut h: EventHeap<&str> = EventHeap::new();
        h.push(5.0, "late");
        h.push(1.0, "first");
        h.push(3.0, "tie-a");
        h.push(3.0, "tie-b");
        assert_eq!(h.len(), 4);
        assert_eq!(h.peek_time_ns(), Some(1.0));
        let order: Vec<&str> = std::iter::from_fn(|| h.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, ["first", "tie-a", "tie-b", "late"]);
        assert!(h.is_empty());
    }

    #[test]
    fn queues_report_idleness() {
        let mut c = CoreQueue::default();
        assert!(c.idle_at(0.0));
        c.free_ns = 10.0;
        c.bank_end_ns = [4.0, 10.0];
        assert!(!c.idle_at(9.0));
        assert!(c.idle_at(10.0));
        c.reset();
        assert!(c.idle_at(0.0));
        let e = EpuQueue { free_ns: 2.0, busy_ns: 2.0 };
        assert!(!e.idle_at(1.0));
        assert!(e.idle_at(2.0));
    }
}
