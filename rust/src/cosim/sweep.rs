//! Operating-point sweeps: cores × batch × offered load → latency and
//! throughput curves (the Fig. 9/11-style studies, under queueing).
//!
//! [`simulate`] drives a [`QueueSim`] with a synthetic arrival process —
//! groups of `batch` frames arriving together, group gaps either
//! deterministic or seeded-exponential (Poisson) — and summarizes the
//! per-frame [`FrameSpan`]s into an [`OperatingPointReport`]. Offered
//! load is expressed as a fraction of the saturation rate
//! (`1 / steady_state_frame_ns`), so `load = 1.0` means "frames offered
//! exactly as fast as the pipelined accelerator can drain them". The
//! `operating_point` bench serializes these reports to `BENCH_cosim.json`.

use crate::arch::scheduler::AttentionSchedule;
use crate::arch::CoreParams;
use crate::util::rng::Rng;
use crate::vit::VitConfig;

use super::des::QueueSim;
use super::queue::EventHeap;

/// One point of the cores × batch × load grid.
#[derive(Debug, Clone, Copy)]
pub struct OperatingPoint {
    /// Optical core count (≥ 5: the Fig. 5 flow needs five).
    pub cores: usize,
    /// Frames per arrival burst (the micro-batch width being modeled).
    pub batch: usize,
    /// Offered load as a fraction of the saturation rate (> 0; may exceed
    /// 1.0 to model overload).
    pub load: f64,
    /// Frames to simulate.
    pub frames: usize,
    /// Token count per frame (post-RoI).
    pub n_tokens: usize,
    /// `Some(seed)`: seeded-exponential (Poisson) burst gaps; `None`:
    /// deterministic uniform spacing.
    pub arrival_seed: Option<u64>,
}

/// Summary of one simulated operating point.
#[derive(Debug, Clone, Copy)]
pub struct OperatingPointReport {
    pub cores: usize,
    pub batch: usize,
    pub load: f64,
    pub frames: usize,
    /// Saturation throughput at this core count / token count (kilo-fps).
    pub saturation_kfps: f64,
    /// Offered arrival rate (kilo-fps).
    pub offered_kfps: f64,
    /// Achieved throughput: frames over the first-arrival → last-completion
    /// span (kilo-fps).
    pub achieved_kfps: f64,
    pub mean_latency_ns: f64,
    pub p50_latency_ns: f64,
    pub p99_latency_ns: f64,
    pub max_latency_ns: f64,
    pub mean_queueing_ns: f64,
    pub p99_queueing_ns: f64,
    /// Peak frames simultaneously in system (queued + in service).
    pub peak_in_flight: usize,
}

/// Nearest-rank percentile over an **ascending-sorted** slice
/// (`q` in `[0, 1]`; deterministic, no interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Simulate one operating point. Deterministic: the same `op` always
/// produces the same report (arrivals are a pure function of `op`).
pub fn simulate(cfg: &VitConfig, op: &OperatingPoint) -> OperatingPointReport {
    assert!(op.load > 0.0, "offered load must be positive");
    assert!(op.frames > 0 && op.batch > 0);
    let params = CoreParams { num_cores: op.cores, ..CoreParams::default() };
    let steady_ns = AttentionSchedule::steady_state_frame_ns(cfg, op.n_tokens, params, true);
    let interval_ns = steady_ns / op.load;
    let gap_mean_ns = interval_ns * op.batch as f64;
    let mut sim = QueueSim::new(*cfg, params);
    let mut rng = op.arrival_seed.map(Rng::new);

    let mut latencies = Vec::with_capacity(op.frames);
    let mut queueing = Vec::with_capacity(op.frames);
    let mut events: EventHeap<i64> = EventHeap::new();
    let mut t = 0.0f64;
    let mut last_completion = 0.0f64;
    let mut done = 0usize;
    while done < op.frames {
        let burst = op.batch.min(op.frames - done);
        for _ in 0..burst {
            let span = sim.arrive(t, op.n_tokens);
            latencies.push(span.latency_ns());
            queueing.push(span.queueing_ns);
            events.push(span.arrival_ns, 1);
            events.push(span.completion_ns, -1);
            last_completion = last_completion.max(span.completion_ns);
            done += 1;
        }
        let gap = match rng.as_mut() {
            // Inverse-CDF exponential over the open unit interval
            // (`next_f64` is in [0,1), so `1 - u` never hits zero).
            Some(r) => -(1.0 - r.next_f64()).ln() * gap_mean_ns,
            None => gap_mean_ns,
        };
        t += gap;
    }

    // Merge arrival/completion event streams to track occupancy.
    let mut in_flight = 0i64;
    let mut peak = 0i64;
    while let Some((_, delta)) = events.pop() {
        in_flight += delta;
        peak = peak.max(in_flight);
    }

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let mean_latency_ns = mean(&latencies);
    let mean_queueing_ns = mean(&queueing);
    latencies.sort_by(f64::total_cmp);
    queueing.sort_by(f64::total_cmp);
    let span_s = (last_completion * 1e-9).max(f64::MIN_POSITIVE);
    OperatingPointReport {
        cores: op.cores,
        batch: op.batch,
        load: op.load,
        frames: op.frames,
        saturation_kfps: 1e9 / steady_ns / 1e3,
        offered_kfps: op.load * 1e9 / steady_ns / 1e3,
        achieved_kfps: op.frames as f64 / span_s / 1e3,
        mean_latency_ns,
        p50_latency_ns: percentile(&latencies, 0.50),
        p99_latency_ns: percentile(&latencies, 0.99),
        max_latency_ns: latencies[latencies.len() - 1],
        mean_queueing_ns,
        p99_queueing_ns: percentile(&queueing, 0.99),
        peak_in_flight: peak.max(0) as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::VitVariant;

    fn tiny() -> VitConfig {
        VitConfig::variant(VitVariant::Tiny, 96, 10)
    }

    fn point(load: f64) -> OperatingPoint {
        OperatingPoint {
            cores: 5,
            batch: 4,
            load,
            frames: 120,
            n_tokens: 18,
            arrival_seed: Some(7),
        }
    }

    #[test]
    fn overload_queues_and_underload_drains() {
        let calm = simulate(&tiny(), &point(0.2));
        let storm = simulate(&tiny(), &point(1.5));
        assert!(storm.mean_queueing_ns > calm.mean_queueing_ns);
        assert!(storm.p99_latency_ns > calm.p99_latency_ns);
        assert!(storm.peak_in_flight > calm.peak_in_flight);
        // Overload cannot beat saturation; underload tracks the offer
        // (loose bound: Poisson gap sums jitter the horizon).
        assert!(storm.achieved_kfps <= storm.saturation_kfps * 1.01);
        assert!(calm.achieved_kfps <= calm.offered_kfps * 1.5);
        assert!(calm.frames == 120 && storm.frames == 120);
    }

    #[test]
    fn reports_are_deterministic() {
        let a = simulate(&tiny(), &point(0.8));
        let b = simulate(&tiny(), &point(0.8));
        assert_eq!(a.p99_latency_ns, b.p99_latency_ns);
        assert_eq!(a.mean_latency_ns, b.mean_latency_ns);
        assert_eq!(a.achieved_kfps, b.achieved_kfps);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&[5.0], 0.99), 5.0);
    }
}
