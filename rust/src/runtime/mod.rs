//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto` —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`): a [`Runtime`] must be created
//! and used on a single thread. The coordinator owns one on its dedicated
//! inference thread.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A host-side f32 tensor (row-major) with explicit dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Self {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "dims {dims:?} don't match data len {}", data.len());
        Tensor { data, dims }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { data: vec![v], dims: vec![] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// A borrowed tensor view: `&[f32]` data + explicit dims, both living in the
/// caller. [`Runtime::execute`] takes these so the serving hot path can hand
/// over scratch buffers without an owned copy per frame (the PJRT literal is
/// built directly from the slice).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TensorRef<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

impl<'a> TensorRef<'a> {
    pub fn new(data: &'a [f32], dims: &'a [i64]) -> Self {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "dims {dims:?} don't match data len {}", data.len());
        TensorRef { data, dims }
    }
}

/// Anything [`Runtime::execute`] accepts as an input: an owned [`Tensor`]
/// or a borrowed [`TensorRef`].
pub trait AsTensorRef {
    fn tensor_ref(&self) -> TensorRef<'_>;
}

impl AsTensorRef for Tensor {
    fn tensor_ref(&self) -> TensorRef<'_> {
        TensorRef { data: &self.data, dims: &self.dims }
    }
}

impl AsTensorRef for TensorRef<'_> {
    fn tensor_ref(&self) -> TensorRef<'_> {
        *self
    }
}

/// PJRT-backed executor over a directory of `*.hlo.txt` artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            executables: HashMap::new(),
        })
    }

    /// Artifact names available on disk (file stems of `*.hlo.txt`).
    pub fn available(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.artifact_dir) {
            for e in rd.flatten() {
                let p = e.path();
                if let Some(name) = p.file_name().and_then(|s| s.to_str()) {
                    if let Some(stem) = name.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }

    /// Load + compile an artifact (cached). Compilation happens once per
    /// name per process — never on the steady-state request path.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact '{}' not found at {} — run `make artifacts` first",
                name,
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).with_context(|| format!("compiling artifact '{name}'"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` with the given inputs (owned [`Tensor`]s or
    /// borrowed [`TensorRef`]s); returns all tuple outputs as flat f32
    /// vectors (artifacts are lowered with `return_tuple=True`).
    pub fn execute<T: AsTensorRef>(&mut self, name: &str, inputs: &[T]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exe = self.executables.get(name).expect("just loaded");
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            let t = t.tensor_ref();
            let lit = xla::Literal::vec1(t.data);
            let lit = if t.dims.is_empty() {
                lit
            } else {
                lit.reshape(t.dims)
                    .with_context(|| format!("reshaping input to {:?}", t.dims))?
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{name}'"))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple().context("artifact output is not a tuple")?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().context("non-f32 artifact output")?);
        }
        Ok(out)
    }

    /// Convenience: execute and return the single output.
    pub fn execute1<T: AsTensorRef>(&mut self, name: &str, inputs: &[T]) -> Result<Vec<f32>> {
        let mut outs = self.execute(name, inputs)?;
        if outs.len() != 1 {
            bail!("artifact '{name}' returned {} outputs, expected 1", outs.len());
        }
        Ok(outs.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_checks_dims() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.len(), 4);
    }

    #[test]
    #[should_panic]
    fn tensor_dim_mismatch_panics() {
        Tensor::new(vec![1.0; 3], vec![2, 2]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let mut rt = Runtime::new("/nonexistent-artifacts").unwrap();
        let err = rt.execute::<Tensor>("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn tensor_ref_views_tensor() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let r = t.tensor_ref();
        assert_eq!(r.data, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.dims, &[2, 2]);
        // TensorRef is itself AsTensorRef (Copy round-trip).
        assert_eq!(r.tensor_ref(), r);
        let dims = [4i64];
        let direct = TensorRef::new(&t.data, &dims);
        assert_eq!(direct.data.len(), 4);
    }

    #[test]
    #[should_panic]
    fn tensor_ref_dim_mismatch_panics() {
        let data = [1.0f32; 3];
        TensorRef::new(&data, &[2, 2]);
    }

    #[test]
    fn available_lists_hlo_files() {
        let dir = std::env::temp_dir().join("optovit-rt-test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("b.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("c.other"), "x").unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.available(), vec!["a".to_string(), "b".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
