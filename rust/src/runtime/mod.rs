//! Execution backends: the seam between the serving coordinator and
//! whatever actually runs the model stages.
//!
//! The paper's evaluation spans three substrates — real inference, host
//! reference compute, and the analytic photonic architecture model — and
//! this module exposes all three behind one object-safe [`Backend`] trait:
//!
//! | backend | type | numerics | latency | needs artifacts |
//! |---|---|---|---|---|
//! | `pjrt` | `PjrtBackend` (behind the `pjrt` cargo feature) | compiled HLO on the CPU PJRT client | host wall-clock | yes (`make artifacts`) |
//! | `host` | [`HostBackend`] | pure-Rust reference ViT/MGNet (quantized, seeded) | host wall-clock | no |
//! | `sim`  | [`SimBackend`] | host reference numerics | modeled photonic-core delay ([`crate::arch`]/[`crate::energy`]), plus queueing under load when a [`QueueingPlan`] arms the [`crate::cosim`] replay | no |
//!
//! Artifact *names* (`mgnet_96`, `vit_tiny_96_n36` — the `.hlo.txt` stems
//! emitted by `python/compile/aot.py`) are the ABI shared by every backend:
//! PJRT resolves them on disk, the host/sim backends materialize them from
//! [`crate::vit`] configs.
//!
//! The execution contract is **batch-first**: [`Backend::execute_batch`]
//! runs one artifact over N frames per call (the serving coordinator's
//! bucket-major micro-batches), [`Backend::execute`] is the degenerate
//! one-frame case, and all three backends implement the batched entry
//! natively:
//!
//! | backend | native `execute_batch` | what amortizes across the batch |
//! |---|---|---|
//! | `pjrt` | resolves + compiles the artifact once, drives one cached executable back-to-back | per-call artifact resolution + cache lookup |
//! | `host` | resolves the module once, reuses its scratch across the batch | module lookup + spec dispatch |
//! | `sim`  | host numerics + batched photonic delay/energy model | MR weight-bank programming (weight DAC + weight memory traffic) |
//!
//! None of the implementations is `Send` by contract (the PJRT client is
//! `Rc`-backed), so sharded serving constructs one backend per worker
//! thread through a [`BackendFactory`] — see [`crate::coordinator::engine`].

pub mod host;
// The PJRT substrate links the vendored `xla` crate, which most build
// environments don't carry — the whole module sits behind the `pjrt`
// cargo feature (off by default). `BackendKind::Pjrt` stays visible
// either way so CLIs can parse `--backend pjrt` and report a clear
// "rebuild with --features pjrt" error instead of a parse failure.
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Result};

pub use host::{parse_artifact, ArtifactSpec, HostBackend, HostConfig};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use sim::SimBackend;

use crate::quant::PrecisionTier;

/// A host-side f32 tensor (row-major) with explicit dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Self {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "dims {dims:?} don't match data len {}", data.len());
        Tensor { data, dims }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { data: vec![v], dims: vec![] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// A borrowed tensor view: `&[f32]` data + explicit dims, both living in the
/// caller. [`Backend::execute`] takes these so the serving hot path can hand
/// over scratch buffers without an owned copy per frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TensorRef<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

impl<'a> TensorRef<'a> {
    pub fn new(data: &'a [f32], dims: &'a [i64]) -> Self {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "dims {dims:?} don't match data len {}", data.len());
        TensorRef { data, dims }
    }
}

/// Anything the PJRT backend's inherent `execute` accepts as an input: an
/// owned [`Tensor`] or a borrowed [`TensorRef`].
pub trait AsTensorRef {
    fn tensor_ref(&self) -> TensorRef<'_>;
}

impl AsTensorRef for Tensor {
    fn tensor_ref(&self) -> TensorRef<'_> {
        TensorRef { data: &self.data, dims: &self.dims }
    }
}

impl AsTensorRef for TensorRef<'_> {
    fn tensor_ref(&self) -> TensorRef<'_> {
        *self
    }
}

/// Per-stage modeled frame latency reported by a simulating backend
/// ([`SimBackend`]): the MGNet front end and the backbone are separate
/// stages on the five-core accelerator, and the serving metrics record
/// them separately (`"modeled_mgnet"` / `"modeled_backbone"`), plus the
/// load-dependent queueing delay charged by the scheduler co-sim
/// (`"modeled_queueing"` — see [`crate::cosim`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModeledStages {
    /// MGNet front-end latency (0 on unmasked runs — MGNet never executes).
    pub mgnet_s: f64,
    /// Backbone latency at the frame's kept-patch count.
    pub backbone_s: f64,
    /// Queueing delay under the arrival process (0 unless a queueing
    /// co-simulation is armed — [`Backend::modeled_stages_s`] itself
    /// reports pure *service* stages; the pipeline fills this in from
    /// [`Backend::modeled_queueing_s`] so service figures stay cacheable
    /// while waiting time never is).
    pub queueing_s: f64,
}

impl ModeledStages {
    /// End-to-end modeled frame latency: waiting plus service.
    pub fn total_s(&self) -> f64 {
        self.mgnet_s + self.backbone_s + self.queueing_s
    }
}

/// Snapshot of a backend's optical-hardware condition, reported by
/// substrates that model degradation ([`SimBackend`] with a fault schedule
/// enabled). The serving dispatcher routes on [`BackendHealth::health`]
/// and schedules recalibration windows when it decays — see
/// `coordinator::server`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackendHealth {
    /// Continuous health score in `[0, 1]` (1.0 = pristine optics), from
    /// [`crate::photonics::DegradationState::health`].
    pub health: f64,
    /// Accumulated MR resonance drift since the last recalibration (nm).
    pub drift_nm: f64,
    /// Stuck weight cells currently present.
    pub stuck_cells: usize,
    /// Dead VCSEL lanes currently present.
    pub dead_lanes: usize,
    /// Whether frames served right now should be counted accuracy-at-risk
    /// (health below [`crate::photonics::AT_RISK_HEALTH`]).
    pub at_risk: bool,
}

/// Modeled cost of one recalibration window, paid by a degraded worker
/// while drained (from [`crate::energy::AcceleratorModel::recalibration_cost`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecalCost {
    /// Wall time the worker is out of rotation (seconds).
    pub time_s: f64,
    /// Energy charged to the worker's recal accounting (joules).
    pub energy_j: f64,
}

/// An execution substrate for the serving pipeline: loads artifacts by name
/// and executes them over borrowed tensor views.
///
/// The contract is **batch-first**: [`Backend::execute_batch`] is the
/// primitive the serving coordinator drives (the bucket router hands every
/// flushed micro-batch to one call), and [`Backend::execute`] is the
/// degenerate one-frame case. All three shipped backends implement
/// `execute_batch` natively; the default implementation loops `execute`
/// so third-party backends keep working unchanged.
///
/// Implementations are single-threaded by contract (none is required to be
/// `Send`); sharded serving builds one instance per worker thread via
/// [`BackendFactory`]. The trait is object-safe, so `dyn Backend` works
/// where static dispatch is inconvenient.
pub trait Backend {
    /// Stable identifier (`"pjrt"` / `"host"` / `"sim"`), carried into
    /// `ServeReport` and bench output.
    fn name(&self) -> &'static str;

    /// Whether this backend requires compiled HLO artifacts on disk.
    fn needs_artifacts(&self) -> bool;

    /// Load/prepare an artifact (cached; never on the steady-state path).
    fn load(&mut self, artifact: &str) -> Result<()>;

    fn is_loaded(&self, artifact: &str) -> bool;

    /// Execute an artifact; returns all tuple outputs as flat f32 vectors.
    /// Loads the artifact first if needed.
    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>>;

    /// Execute an artifact over a **batch** of input sets (one inner slice
    /// per frame, all at the artifact's fixed shape) and return one output
    /// set per frame, in batch order. This is the serving coordinator's
    /// primitive: the bucket-major micro-batcher hands every flushed group
    /// to a single `execute_batch` call so per-dispatch overhead (artifact
    /// resolution, module lookup, input staging setup) amortizes across
    /// the batch.
    ///
    /// The default implementation loops [`Backend::execute`] — numerically
    /// the contract is that `execute_batch` over B frames is exactly B
    /// sequential `execute` calls (asserted bitwise for the host backend in
    /// `rust/tests/batch_backend.rs`). All three shipped backends override
    /// it natively.
    fn execute_batch(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let mut out = Vec::with_capacity(batch.len());
        for inputs in batch {
            out.push(self.execute(artifact, inputs)?);
        }
        Ok(out)
    }

    /// [`Backend::execute_batch`] at an explicit [`PrecisionTier`] — the
    /// mixed-precision serving entry. The contract mirrors the batch one:
    /// every frame in `batch` runs at `tier` (the micro-batcher groups
    /// bucket×tier-major, so a 4-bit frame never rides an 8-bit group's
    /// weight programming). The default ignores the tier and delegates to
    /// [`Backend::execute_batch`] — correct for substrates with a single
    /// physical precision (PJRT's compiled HLO, third-party backends);
    /// the host and sim backends override it with per-tier quantized
    /// reference modules.
    fn execute_batch_tiered(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
        _tier: PrecisionTier,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        self.execute_batch(artifact, batch)
    }

    /// Convenience: execute and return the single output.
    fn execute1(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<f32>> {
        let mut outs = self.execute(artifact, inputs)?;
        if outs.len() != 1 {
            bail!("artifact '{artifact}' returned {} outputs, expected 1", outs.len());
        }
        Ok(outs.pop().unwrap())
    }

    /// Modeled per-stage frame latency at a kept-patch count, for backends
    /// that simulate accelerator timing. `first_in_batch` tells the model
    /// whether this frame pays the weight-programming cost (streaming the
    /// stationary weights into the MR banks) or rides a bucket-major batch
    /// whose first frame already programmed them — follower frames model
    /// strictly less latency, which is how batched photonic dispatch
    /// amortizes. `None` (the default) means latency is whatever the host
    /// wall-clock measures.
    fn modeled_stages_s(
        &mut self,
        _kept_patches: usize,
        _use_mask: bool,
        _first_in_batch: bool,
    ) -> Option<ModeledStages> {
        None
    }

    /// [`Backend::modeled_stages_s`] at an explicit [`PrecisionTier`]:
    /// lower-precision tiers stream fewer weight-programming bits into the
    /// MR banks, so the batch-leader share of modeled latency shrinks with
    /// the tier while follower frames are unchanged. The default ignores
    /// the tier (single-precision substrates); the sim backend overrides
    /// it with tier-scaled weight-streaming delay.
    fn modeled_stages_s_tiered(
        &mut self,
        kept_patches: usize,
        use_mask: bool,
        first_in_batch: bool,
        _tier: PrecisionTier,
    ) -> Option<ModeledStages> {
        self.modeled_stages_s(kept_patches, use_mask, first_in_batch)
    }

    /// Modeled end-to-end frame latency (seconds) at a kept-patch count —
    /// the single-frame total of [`Backend::modeled_stages_s`].
    fn modeled_frame_latency_s(&mut self, kept_patches: usize, use_mask: bool) -> Option<f64> {
        self.modeled_stages_s(kept_patches, use_mask, true).map(|s| s.total_s())
    }

    /// Advance the backend's queueing co-simulation by one frame arrival
    /// and return the modeled **queueing delay** (seconds) that frame
    /// spends waiting for the accelerator, on top of the service time
    /// [`Backend::modeled_stages_s`] reports. Stateful by design: each
    /// call feeds one arrival event (stamped from the serving clock, or a
    /// paced trace) into the discrete-event model, so waiting reflects the
    /// actual load. The default — and any backend without a co-sim —
    /// charges no waiting.
    fn modeled_queueing_s(&mut self, _kept_patches: usize, _use_mask: bool) -> f64 {
        0.0
    }

    /// Current optical-hardware condition, for backends that model
    /// degradation over clock time. `None` (the default) means the
    /// substrate has no fault model and the dispatcher treats the worker
    /// as permanently healthy.
    fn health(&mut self) -> Option<BackendHealth> {
        None
    }

    /// Recalibrate degraded optics: reset the fault state to pristine and
    /// return the modeled cost of doing so. `None` (the default) means
    /// there is nothing to recalibrate. Callers are expected to keep the
    /// worker drained for `RecalCost::time_s` of clock time and charge
    /// `RecalCost::energy_j` — the backend itself rejoins healthy
    /// immediately.
    fn recalibrate(&mut self) -> Option<RecalCost> {
        None
    }
}

/// Which backend to construct — the value behind `--backend pjrt|host|sim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Pjrt,
    Host,
    Sim,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] = [BackendKind::Pjrt, BackendKind::Host, BackendKind::Sim];

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Host => "host",
            BackendKind::Sim => "sim",
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "host" => Ok(BackendKind::Host),
            "sim" => Ok(BackendKind::Sim),
            other => Err(format!("unknown backend '{other}' (choices: pjrt|host|sim)")),
        }
    }
}

/// Constructs one backend instance per worker thread. The factory itself
/// crosses threads (`Sync`); the backends it creates never do — each call
/// happens *inside* the worker that will own the instance, which is what
/// lets non-`Send` substrates like PJRT shard across cores.
pub trait BackendFactory: Sync {
    type Backend: Backend;

    /// Build the backend for worker `worker`. Implementations must produce
    /// numerically identical backends for every worker (sharding must not
    /// change results), so `worker` is for diagnostics, not seeding.
    ///
    /// **One documented exception:** when a factory carries a [`FaultPlan`]
    /// (degraded-optics simulation), each worker gets an independently
    /// seeded degradation timeline derived from `worker` — physical copies
    /// of the accelerator fail independently, and that is exactly what the
    /// fleet-level fault gates exercise. Fault-free construction remains
    /// worker-independent.
    fn create(&self, worker: usize) -> Result<Self::Backend>;
}

/// Configuration for per-worker degraded-optics simulation, carried by
/// [`AnyFactory`]: worker `w` gets a [`crate::photonics::FaultSchedule`]
/// seeded with `seed + w * 0x9E3779B97F4A7C15` (so fleets are reproducible
/// from one seed while workers degrade independently) evaluated against
/// `clock` time.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Base seed for the fleet's degradation timelines.
    pub seed: u64,
    /// MR thermal drift accumulation rate (nm/s of uptime).
    pub drift_nm_per_s: f64,
    /// The serving clock the schedules are evaluated against — pass the
    /// same clock as `EngineConfig::clock` so `ManualClock` tests drive
    /// degradation deterministically.
    pub clock: crate::coordinator::clock::Clock,
}

impl FaultPlan {
    /// The per-worker schedule seed (golden-ratio stride over the base
    /// seed, mirroring the doc on [`FaultPlan`]).
    pub fn worker_seed(&self, worker: usize) -> u64 {
        self.seed.wrapping_add((worker as u64).wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// Configuration for the scheduler queueing co-simulation
/// ([`crate::cosim`]), carried by [`AnyFactory`] and honored by the `sim`
/// kind only: each worker's backend gets its own discrete-event replay of
/// the mapped task graph (one modeled accelerator per worker), so modeled
/// latency includes waiting time under that worker's arrival process.
#[derive(Debug, Clone)]
pub struct QueueingPlan {
    /// Optical core count of the modeled accelerator (≥ 5 — the Fig. 5
    /// flow needs five; `--cores`).
    pub cores: usize,
    /// `Some(fps)`: paced virtual arrivals — frame `k` arrives at `k/fps`
    /// seconds, a deterministic offered-load trace (`--arrival-fps`).
    /// `None`: arrivals are stamped from `clock` as frames reach the
    /// backend, i.e. the actual serving arrival process.
    pub pace_fps: Option<f64>,
    /// The serving clock arrivals are stamped from when `pace_fps` is
    /// `None` — pass the same clock as `EngineConfig::clock` so
    /// `ManualClock` tests drive queueing deterministically.
    pub clock: crate::coordinator::clock::Clock,
}

/// Factory for [`PjrtBackend`]s over one artifact directory.
#[cfg(feature = "pjrt")]
#[derive(Debug, Clone)]
pub struct PjrtFactory {
    pub artifact_dir: String,
}

#[cfg(feature = "pjrt")]
impl PjrtFactory {
    pub fn new(artifact_dir: impl Into<String>) -> Self {
        PjrtFactory { artifact_dir: artifact_dir.into() }
    }
}

#[cfg(feature = "pjrt")]
impl BackendFactory for PjrtFactory {
    type Backend = PjrtBackend;

    fn create(&self, _worker: usize) -> Result<PjrtBackend> {
        PjrtBackend::new(&self.artifact_dir)
    }
}

/// Factory for [`HostBackend`]s sharing one [`HostConfig`].
#[derive(Debug, Clone, Copy, Default)]
pub struct HostFactory(pub HostConfig);

impl BackendFactory for HostFactory {
    type Backend = HostBackend;

    fn create(&self, _worker: usize) -> Result<HostBackend> {
        Ok(HostBackend::new(self.0))
    }
}

/// Factory for [`SimBackend`]s sharing one [`HostConfig`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SimFactory(pub HostConfig);

impl BackendFactory for SimFactory {
    type Backend = SimBackend;

    fn create(&self, _worker: usize) -> Result<SimBackend> {
        Ok(SimBackend::new(self.0))
    }
}

/// Statically-dispatched "any of the three" backend, for call sites that
/// pick the substrate at runtime (CLI, examples, the scaling bench).
pub enum AnyBackend {
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtBackend),
    Host(HostBackend),
    Sim(SimBackend),
}

impl Backend for AnyBackend {
    fn name(&self) -> &'static str {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => b.name(),
            AnyBackend::Host(b) => b.name(),
            AnyBackend::Sim(b) => b.name(),
        }
    }

    fn needs_artifacts(&self) -> bool {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => b.needs_artifacts(),
            AnyBackend::Host(b) => b.needs_artifacts(),
            AnyBackend::Sim(b) => b.needs_artifacts(),
        }
    }

    fn load(&mut self, artifact: &str) -> Result<()> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => Backend::load(b, artifact),
            AnyBackend::Host(b) => b.load(artifact),
            AnyBackend::Sim(b) => b.load(artifact),
        }
    }

    fn is_loaded(&self, artifact: &str) -> bool {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => Backend::is_loaded(b, artifact),
            AnyBackend::Host(b) => b.is_loaded(artifact),
            AnyBackend::Sim(b) => b.is_loaded(artifact),
        }
    }

    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => Backend::execute(b, artifact, inputs),
            AnyBackend::Host(b) => b.execute(artifact, inputs),
            AnyBackend::Sim(b) => b.execute(artifact, inputs),
        }
    }

    fn execute_batch(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => Backend::execute_batch(b, artifact, batch),
            AnyBackend::Host(b) => b.execute_batch(artifact, batch),
            AnyBackend::Sim(b) => b.execute_batch(artifact, batch),
        }
    }

    fn execute_batch_tiered(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
        tier: PrecisionTier,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => Backend::execute_batch_tiered(b, artifact, batch, tier),
            AnyBackend::Host(b) => b.execute_batch_tiered(artifact, batch, tier),
            AnyBackend::Sim(b) => b.execute_batch_tiered(artifact, batch, tier),
        }
    }

    fn modeled_stages_s(
        &mut self,
        kept_patches: usize,
        use_mask: bool,
        first_in_batch: bool,
    ) -> Option<ModeledStages> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => b.modeled_stages_s(kept_patches, use_mask, first_in_batch),
            AnyBackend::Host(b) => b.modeled_stages_s(kept_patches, use_mask, first_in_batch),
            AnyBackend::Sim(b) => b.modeled_stages_s(kept_patches, use_mask, first_in_batch),
        }
    }

    fn modeled_stages_s_tiered(
        &mut self,
        kept_patches: usize,
        use_mask: bool,
        first_in_batch: bool,
        tier: PrecisionTier,
    ) -> Option<ModeledStages> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => {
                b.modeled_stages_s_tiered(kept_patches, use_mask, first_in_batch, tier)
            }
            AnyBackend::Host(b) => {
                b.modeled_stages_s_tiered(kept_patches, use_mask, first_in_batch, tier)
            }
            AnyBackend::Sim(b) => {
                b.modeled_stages_s_tiered(kept_patches, use_mask, first_in_batch, tier)
            }
        }
    }

    fn modeled_queueing_s(&mut self, kept_patches: usize, use_mask: bool) -> f64 {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => b.modeled_queueing_s(kept_patches, use_mask),
            AnyBackend::Host(b) => b.modeled_queueing_s(kept_patches, use_mask),
            AnyBackend::Sim(b) => b.modeled_queueing_s(kept_patches, use_mask),
        }
    }

    fn health(&mut self) -> Option<BackendHealth> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => b.health(),
            AnyBackend::Host(b) => b.health(),
            AnyBackend::Sim(b) => b.health(),
        }
    }

    fn recalibrate(&mut self) -> Option<RecalCost> {
        match self {
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(b) => b.recalibrate(),
            AnyBackend::Host(b) => b.recalibrate(),
            AnyBackend::Sim(b) => b.recalibrate(),
        }
    }
}

/// Factory for [`AnyBackend`], selected by [`BackendKind`] at runtime.
#[derive(Debug, Clone)]
pub struct AnyFactory {
    pub kind: BackendKind,
    /// Artifact directory (used by the `pjrt` kind only).
    pub artifact_dir: String,
    /// Host/sim reference-model configuration.
    pub host: HostConfig,
    /// Degraded-optics simulation (honored by the `sim` kind only): each
    /// worker's backend gets an independently seeded fault schedule.
    pub faults: Option<FaultPlan>,
    /// Scheduler queueing co-simulation (honored by the `sim` kind only):
    /// each worker's backend models its own arrival queue.
    pub queueing: Option<QueueingPlan>,
}

impl AnyFactory {
    pub fn new(kind: BackendKind, artifact_dir: impl Into<String>) -> Self {
        AnyFactory {
            kind,
            artifact_dir: artifact_dir.into(),
            host: HostConfig::default(),
            faults: None,
            queueing: None,
        }
    }

    /// Enable per-worker degraded-optics simulation (see [`FaultPlan`]).
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Enable the per-worker queueing co-simulation (see [`QueueingPlan`]).
    pub fn with_queueing(mut self, plan: QueueingPlan) -> Self {
        self.queueing = Some(plan);
        self
    }
}

impl BackendFactory for AnyFactory {
    type Backend = AnyBackend;

    fn create(&self, worker: usize) -> Result<AnyBackend> {
        Ok(match self.kind {
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => AnyBackend::Pjrt(PjrtBackend::new(&self.artifact_dir)?),
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => bail!(
                "backend 'pjrt' was compiled out — rebuild with `--features pjrt` \
                 (needs the vendored xla crate), or serve with `--backend host|sim`"
            ),
            BackendKind::Host => AnyBackend::Host(HostBackend::new(self.host)),
            BackendKind::Sim => {
                let mut b = SimBackend::new(self.host);
                if let Some(plan) = &self.faults {
                    let schedule = crate::photonics::FaultSchedule::seeded(
                        plan.worker_seed(worker),
                        plan.drift_nm_per_s,
                    );
                    b.enable_faults(schedule, plan.clock.clone());
                }
                if let Some(plan) = &self.queueing {
                    b.enable_queueing(plan.cores, plan.pace_fps, plan.clock.clone());
                }
                AnyBackend::Sim(b)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_checks_dims() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.len(), 4);
    }

    #[test]
    #[should_panic]
    fn tensor_dim_mismatch_panics() {
        Tensor::new(vec![1.0; 3], vec![2, 2]);
    }

    #[test]
    fn tensor_ref_views_tensor() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let r = t.tensor_ref();
        assert_eq!(r.data, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.dims, &[2, 2]);
        // TensorRef is itself AsTensorRef (Copy round-trip).
        assert_eq!(r.tensor_ref(), r);
        let dims = [4i64];
        let direct = TensorRef::new(&t.data, &dims);
        assert_eq!(direct.data.len(), 4);
    }

    #[test]
    #[should_panic]
    fn tensor_ref_dim_mismatch_panics() {
        let data = [1.0f32; 3];
        TensorRef::new(&data, &[2, 2]);
    }

    #[test]
    fn backend_kind_roundtrip() {
        for kind in BackendKind::ALL {
            assert_eq!(kind.as_str().parse::<BackendKind>(), Ok(kind));
            assert_eq!(kind.to_string(), kind.as_str());
        }
        let err = "tpu".parse::<BackendKind>().unwrap_err();
        assert!(err.contains("pjrt|host|sim"), "{err}");
    }

    #[test]
    fn any_factory_builds_the_requested_kind() {
        let host = HostConfig { depth_limit: Some(1), ..HostConfig::default() };
        for (kind, name) in [(BackendKind::Host, "host"), (BackendKind::Sim, "sim")] {
            let f = AnyFactory {
                kind,
                artifact_dir: "/nonexistent".into(),
                host,
                faults: None,
                queueing: None,
            };
            let b = f.create(0).expect("factory");
            assert_eq!(b.name(), name);
            assert!(!b.needs_artifacts());
        }
        let f = AnyFactory {
            kind: BackendKind::Pjrt,
            artifact_dir: "/nonexistent".into(),
            host,
            faults: None,
            queueing: None,
        };
        #[cfg(feature = "pjrt")]
        {
            let b = f.create(0).expect("pjrt factory");
            assert_eq!(b.name(), "pjrt");
            assert!(b.needs_artifacts());
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let err = f.create(0).unwrap_err().to_string();
            assert!(err.contains("--features pjrt"), "{err}");
        }
    }

    /// Minimal third-party backend relying on the *default* `execute_batch`
    /// (loop over `execute`): the degenerate path must stay equivalent.
    struct EchoBackend {
        calls: usize,
    }

    impl Backend for EchoBackend {
        fn name(&self) -> &'static str {
            "echo"
        }
        fn needs_artifacts(&self) -> bool {
            false
        }
        fn load(&mut self, _artifact: &str) -> Result<()> {
            Ok(())
        }
        fn is_loaded(&self, _artifact: &str) -> bool {
            true
        }
        fn execute(&mut self, _artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
            self.calls += 1;
            Ok(inputs.iter().map(|t| t.data.to_vec()).collect())
        }
    }

    #[test]
    fn default_execute_batch_loops_execute() {
        let mut b = EchoBackend { calls: 0 };
        let (x, y) = ([1.0f32, 2.0], [3.0f32, 4.0]);
        let dims = [2i64];
        let fa = [TensorRef::new(&x, &dims)];
        let fb = [TensorRef::new(&y, &dims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&fa, &fb];
        let out = b.execute_batch("any", &batch).expect("default batch");
        assert_eq!(b.calls, 2, "default impl must loop execute once per frame");
        assert_eq!(out, vec![vec![vec![1.0, 2.0]], vec![vec![3.0, 4.0]]]);
        // No simulated timing on the default hooks.
        assert_eq!(b.modeled_stages_s(4, true, true), None);
        assert_eq!(b.modeled_frame_latency_s(4, true), None);
    }

    /// The default tiered hooks ignore the tier and delegate, so a
    /// single-precision third-party backend keeps working under the
    /// mixed-precision coordinator unchanged.
    #[test]
    fn default_tiered_hooks_delegate_to_untiered() {
        let mut b = EchoBackend { calls: 0 };
        let x = [1.0f32, 2.0];
        let dims = [2i64];
        let fa = [TensorRef::new(&x, &dims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&fa];
        for tier in PrecisionTier::ALL {
            let out = b.execute_batch_tiered("any", &batch, tier).expect("tiered batch");
            assert_eq!(out, vec![vec![vec![1.0, 2.0]]]);
            assert_eq!(b.modeled_stages_s_tiered(4, true, true, tier), None);
        }
        assert_eq!(b.calls, 3, "default tiered impl must loop execute per frame");
    }

    #[test]
    fn any_backend_batch_matches_sequential() {
        const PD: usize = 16 * 16 * 3;
        let host = HostConfig { depth_limit: Some(1), ..HostConfig::default() };
        let factory = AnyFactory {
            kind: BackendKind::Host,
            artifact_dir: String::new(),
            host,
            faults: None,
            queueing: None,
        };
        let mut any = factory.create(0).expect("any factory");
        let xa: Vec<f32> = (0..4 * PD).map(|i| (i % 7) as f32 / 7.0).collect();
        let xb: Vec<f32> = (0..4 * PD).map(|i| (i % 11) as f32 / 11.0).collect();
        let dims = [4i64, PD as i64];
        let fa = [TensorRef::new(&xa, &dims)];
        let fb = [TensorRef::new(&xb, &dims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&fa, &fb];
        let batched = any.execute_batch("mgnet_32", &batch).expect("batched exec");
        let sa = any.execute("mgnet_32", &fa).expect("seq a");
        let sb = any.execute("mgnet_32", &fb).expect("seq b");
        assert_eq!(batched, vec![sa, sb], "AnyBackend batch must match sequential bitwise");
    }

    #[test]
    fn any_backend_dispatches_to_host() {
        const PD: usize = 16 * 16 * 3;
        let host = HostConfig { depth_limit: Some(1), ..HostConfig::default() };
        let mut b = HostFactory(host).create(0).expect("host factory");
        let x: Vec<f32> = (0..4 * PD).map(|i| (i % 7) as f32 / 7.0).collect();
        let dims = [4i64, PD as i64];
        let scores = b.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).expect("exec");
        assert_eq!(scores.len(), 4);
        assert!(b.is_loaded("mgnet_32"));
        // The same call through `AnyBackend` gives identical numerics.
        let mut any = AnyFactory {
            kind: BackendKind::Host,
            artifact_dir: String::new(),
            host,
            faults: None,
            queueing: None,
        }
        .create(0)
        .expect("any factory");
        let scores_any = any.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).expect("exec");
        assert_eq!(scores, scores_any);
    }
}
