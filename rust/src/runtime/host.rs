//! Pure-Rust host execution backend: a reference MGNet + ViT forward pass
//! that needs **no compiled artifacts** and no Python.
//!
//! [`HostBackend`] answers the same artifact names the PJRT backend loads
//! from disk (`mgnet_<size>`, `vit_<variant>_<size>_n<bucket>` — the
//! `.hlo.txt` stem grammar of `python/compile/aot.py` is the ABI), but
//! materializes each one as an in-memory transformer built from
//! [`VitConfig`]/[`MgnetConfig`] with deterministic weights drawn from
//! [`crate::util::rng::Rng`]. Weights and matmul-boundary activations are
//! fake-quantized through [`crate::quant`] to the same 8-bit grid the
//! photonic weight banks and ADC/DAC interfaces impose, so the numerics
//! exercise the quantized serving path end to end.
//!
//! The weights are *untrained* (mask quality and accuracy are chance-level);
//! what this backend provides is the full fixed-shape dataflow — patch
//! embedding, positional gather by `pos_idx`, validity-masked attention over
//! zero-padded bucket slots, cls-token head — with real content-dependent
//! outputs, deterministically reproducible from a seed and identical across
//! worker threads. That is exactly what CI, the serving tests, and the
//! scaling bench need where HLO artifacts are absent.
//!
//! Steady-state execution is allocation-free except for the returned output
//! vector: every activation buffer lives in a per-module scratch sized at
//! [`Backend::load`] time.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use super::{Backend, TensorRef};
use crate::quant::{PrecisionTier, QuantParams};
use crate::util::rng::Rng;
use crate::vit::{MgnetConfig, VitConfig, VitVariant};

/// Configuration of the pure-Rust host reference backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostConfig {
    /// Weight-init seed. Module weights are derived from
    /// `(seed, artifact name)`, never from the worker index, so every
    /// worker of a sharded run builds bit-identical modules and routing is
    /// stable under sharding.
    pub seed: u64,
    /// Classifier width of backbone artifacts (the artifact name encodes
    /// variant/size/bucket but not the head width). Must match the serving
    /// `PipelineConfig::num_classes` or logits will be the wrong width —
    /// call sites that own both configs wire it through (see `cmd_serve`).
    pub num_classes: usize,
    /// Optional cap on encoder depth. The reference numerics are defined at
    /// any depth; tests cap it (e.g. `Some(1)`) to keep debug-mode CI fast.
    /// `None` runs the full configured depth.
    pub depth_limit: Option<usize>,
    /// Weight/activation quantization bits (8 matches the paper's photonic
    /// weight banks and ADC/DAC interfaces).
    pub bits: u32,
}

impl Default for HostConfig {
    fn default() -> Self {
        // Seed spells the source paper's arXiv id (2507.07044).
        HostConfig { seed: 0x2507_07044, num_classes: 10, depth_limit: None, bits: 8 }
    }
}

/// What an artifact name denotes, parsed from the shared `.hlo.txt` stem
/// grammar (`PipelineConfig::mgnet_artifact` / `backbone_artifact`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactSpec {
    /// `mgnet_<size>`: the mask generator over the full patch grid.
    Mgnet { image_size: usize },
    /// `vit_<variant>_<size>_n<bucket>`: a backbone compiled at one
    /// kept-patch bucket.
    Backbone { variant: VitVariant, image_size: usize, bucket: usize },
}

/// Parse an artifact name into its [`ArtifactSpec`].
pub fn parse_artifact(name: &str) -> Result<ArtifactSpec> {
    const PATCH_PX: usize = 16;
    if let Some(rest) = name.strip_prefix("mgnet_") {
        let image_size: usize =
            rest.parse().with_context(|| format!("artifact '{name}': bad image size"))?;
        ensure!(
            image_size >= PATCH_PX && image_size % PATCH_PX == 0,
            "artifact '{name}': image size {image_size} not divisible by patch size {PATCH_PX}"
        );
        return Ok(ArtifactSpec::Mgnet { image_size });
    }
    if let Some(rest) = name.strip_prefix("vit_") {
        let mut parts = rest.split('_');
        let variant = parts
            .next()
            .and_then(VitVariant::from_name)
            .with_context(|| format!("artifact '{name}': unknown ViT variant"))?;
        let image_size: usize = parts
            .next()
            .with_context(|| format!("artifact '{name}': missing image size"))?
            .parse()
            .with_context(|| format!("artifact '{name}': bad image size"))?;
        let bucket: usize = parts
            .next()
            .and_then(|s| s.strip_prefix('n'))
            .with_context(|| format!("artifact '{name}': missing 'n<bucket>' suffix"))?
            .parse()
            .with_context(|| format!("artifact '{name}': bad bucket"))?;
        ensure!(parts.next().is_none(), "artifact '{name}': trailing segments");
        ensure!(
            image_size >= PATCH_PX && image_size % PATCH_PX == 0,
            "artifact '{name}': image size {image_size} not divisible by patch size {PATCH_PX}"
        );
        let full = (image_size / PATCH_PX) * (image_size / PATCH_PX);
        ensure!(
            (1..=full).contains(&bucket),
            "artifact '{name}': bucket {bucket} outside 1..={full}"
        );
        return Ok(ArtifactSpec::Backbone { variant, image_size, bucket });
    }
    bail!("unknown artifact name '{name}' (expected 'mgnet_<size>' or 'vit_<variant>_<size>_n<bucket>')")
}

/// Per-artifact weight seed: stable across workers and processes.
fn artifact_seed(base: u64, name: &str) -> u64 {
    // FNV-1a over the name, folded into the base seed.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    base ^ h
}

/// Fake-quantize a buffer in place on its own max-abs 8-bit (or `bits`)
/// grid — the DAC boundary every operand crosses before an optical matmul.
/// `bits >= 32` is the fp-reference sentinel ([`PrecisionTier::Fp32`]):
/// no converter grid at all, the buffer passes through untouched.
fn quantize_acts(buf: &mut [f32], bits: u32) {
    if bits >= 32 {
        return;
    }
    QuantParams::calibrate(buf, bits).fake_quantize_slice(buf);
}

/// A dense affine layer, `out = x W^T + b`, weights fake-quantized at init.
#[derive(Debug)]
struct Linear {
    w: Vec<f32>,
    b: Vec<f32>,
    in_dim: usize,
    out_dim: usize,
}

impl Linear {
    fn init(rng: &mut Rng, in_dim: usize, out_dim: usize, bits: u32) -> Self {
        let bound = (6.0 / (in_dim + out_dim) as f64).sqrt() as f32;
        let mut w = vec![0.0f32; out_dim * in_dim];
        rng.fill_uniform_f32(&mut w, -bound, bound);
        quantize_acts(&mut w, bits);
        Linear { w, b: vec![0.0; out_dim], in_dim, out_dim }
    }

    /// Forward `tokens` rows of `x` into `out` (both exactly sized).
    fn forward(&self, x: &[f32], tokens: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), tokens * self.in_dim);
        debug_assert_eq!(out.len(), tokens * self.out_dim);
        for (xi, oi) in x.chunks_exact(self.in_dim).zip(out.chunks_exact_mut(self.out_dim)) {
            for (o, y) in oi.iter_mut().enumerate() {
                let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
                let mut acc = self.b[o];
                for (a, wv) in xi.iter().zip(row) {
                    acc += a * wv;
                }
                *y = acc;
            }
        }
    }
}

/// One pre-LN transformer encoder block.
#[derive(Debug)]
struct Block {
    qkv: Linear,
    proj: Linear,
    fc1: Linear,
    fc2: Linear,
}

impl Block {
    fn init(rng: &mut Rng, d: usize, ffn: usize, bits: u32) -> Self {
        Block {
            qkv: Linear::init(rng, d, 3 * d, bits),
            proj: Linear::init(rng, d, d, bits),
            fc1: Linear::init(rng, d, ffn, bits),
            fc2: Linear::init(rng, ffn, d, bits),
        }
    }
}

/// Reusable activation buffers, sized once at module build time so the
/// steady-state forward pass never touches the heap.
#[derive(Debug)]
struct Scratch {
    /// Token stream, `(T, d)`.
    x: Vec<f32>,
    /// LayerNorm / projection output staging, `(T, d)`.
    norm: Vec<f32>,
    /// Fused q/k/v activations, `(T, 3d)`.
    qkv: Vec<f32>,
    /// Attention output / FFN output staging, `(T, d)`.
    attn_out: Vec<f32>,
    /// One row of attention scores, `(T,)`.
    attn_row: Vec<f32>,
    /// FFN hidden activations, `(T, ffn)`.
    mlp: Vec<f32>,
    /// Per-token validity (cls + real patch slots true, padding false).
    valid: Vec<bool>,
}

impl Scratch {
    fn new(t_max: usize, d: usize, ffn: usize) -> Self {
        Scratch {
            x: vec![0.0; t_max * d],
            norm: vec![0.0; t_max * d],
            qkv: vec![0.0; t_max * 3 * d],
            attn_out: vec![0.0; t_max * d],
            attn_row: vec![0.0; t_max],
            mlp: vec![0.0; t_max * ffn],
            valid: vec![false; t_max],
        }
    }
}

/// Parameter-free LayerNorm (γ=1, β=0 — the freshly-initialized values)
/// over `tokens` rows of width `d`.
fn layer_norm_all(src: &[f32], dst: &mut [f32], d: usize) {
    const EPS: f32 = 1e-5;
    for (xi, oi) in src.chunks_exact(d).zip(dst.chunks_exact_mut(d)) {
        let mean = xi.iter().sum::<f32>() / d as f32;
        let var = xi.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (o, &v) in oi.iter_mut().zip(xi) {
            *o = (v - mean) * inv;
        }
    }
}

/// Tanh-approximated GELU, in place.
fn gelu_slice(xs: &mut [f32]) {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    for x in xs.iter_mut() {
        let v = *x;
        *x = 0.5 * v * (1.0 + (C * (v + 0.044_715 * v * v * v)).tanh());
    }
}

/// In-place softmax over one score row (`-inf` entries contribute zero).
fn softmax_row(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// One block's forward pass over `t` tokens (pre-LN residual layout), with
/// invalid tokens masked out of every attention softmax.
fn block_forward(blk: &Block, cfg: &VitConfig, s: &mut Scratch, t: usize, bits: u32) {
    let d = cfg.embed_dim;
    let heads = cfg.num_heads;
    let hd = cfg.embed_dim / cfg.num_heads;
    let ffn = cfg.ffn_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let Scratch { x, norm, qkv, attn_out, attn_row, mlp, valid } = s;
    let (x, norm) = (&mut x[..t * d], &mut norm[..t * d]);
    let qkv_buf = &mut qkv[..t * 3 * d];
    let attn_out = &mut attn_out[..t * d];
    let attn_row = &mut attn_row[..t];
    let mlp = &mut mlp[..t * ffn];

    // Attention sublayer: x += proj(attn(ln1(x))).
    layer_norm_all(x, norm, d);
    quantize_acts(norm, bits);
    blk.qkv.forward(norm, t, qkv_buf);
    attn_out.fill(0.0);
    for h in 0..heads {
        let (qo, ko, vo) = (h * hd, d + h * hd, 2 * d + h * hd);
        for tq in 0..t {
            let q = &qkv_buf[tq * 3 * d + qo..tq * 3 * d + qo + hd];
            for tk in 0..t {
                attn_row[tk] = if valid[tk] {
                    let k = &qkv_buf[tk * 3 * d + ko..tk * 3 * d + ko + hd];
                    q.iter().zip(k).map(|(a, b)| a * b).sum::<f32>() * scale
                } else {
                    f32::NEG_INFINITY
                };
            }
            softmax_row(attn_row);
            let out = &mut attn_out[tq * d + h * hd..tq * d + h * hd + hd];
            for (tk, &w) in attn_row.iter().enumerate() {
                if w > 0.0 {
                    let v = &qkv_buf[tk * 3 * d + vo..tk * 3 * d + vo + hd];
                    for (o, &vv) in out.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        }
    }
    quantize_acts(attn_out, bits);
    blk.proj.forward(attn_out, t, norm);
    for (xi, &r) in x.iter_mut().zip(norm.iter()) {
        *xi += r;
    }

    // FFN sublayer: x += fc2(gelu(fc1(ln2(x)))).
    layer_norm_all(x, norm, d);
    quantize_acts(norm, bits);
    blk.fc1.forward(norm, t, mlp);
    gelu_slice(mlp);
    quantize_acts(mlp, bits);
    blk.fc2.forward(mlp, t, attn_out);
    for (xi, &r) in x.iter_mut().zip(attn_out.iter()) {
        *xi += r;
    }
}

/// One materialized artifact: a ViT (or the one-block MGNet-as-ViT) with
/// deterministic quantized weights and preallocated scratch.
#[derive(Debug)]
struct HostVit {
    cfg: VitConfig,
    /// Encoder blocks actually run (`min(cfg.depth, depth_limit)`).
    blocks: Vec<Block>,
    embed: Linear,
    /// Learned-token stand-in for the cls embedding, `(d,)`.
    cls: Vec<f32>,
    /// Positional table over the *full* grid, `(num_patches + 1, d)`;
    /// bucket slots gather rows by their original grid index.
    pos: Vec<f32>,
    head: Linear,
    bits: u32,
    scratch: Scratch,
}

impl HostVit {
    fn build(cfg: VitConfig, t_max: usize, seed: u64, depth_limit: Option<usize>, bits: u32) -> Self {
        let mut rng = Rng::new(seed);
        let d = cfg.embed_dim;
        let depth = depth_limit.map_or(cfg.depth, |l| cfg.depth.min(l.max(1)));
        let embed = Linear::init(&mut rng, cfg.patch_dim(), d, bits);
        let mut cls = vec![0.0f32; d];
        rng.fill_uniform_f32(&mut cls, -0.02, 0.02);
        quantize_acts(&mut cls, bits);
        let mut pos = vec![0.0f32; cfg.seq_len() * d];
        rng.fill_uniform_f32(&mut pos, -0.02, 0.02);
        quantize_acts(&mut pos, bits);
        let blocks = (0..depth).map(|_| Block::init(&mut rng, d, cfg.ffn_dim(), bits)).collect();
        let head = Linear::init(&mut rng, d, cfg.num_classes, bits);
        let scratch = Scratch::new(t_max, d, cfg.ffn_dim());
        HostVit { cfg, blocks, embed, cls, pos, head, bits, scratch }
    }

    /// Forward `n` patch rows (+ implicit cls token). `pos_idx`/`valid`
    /// are the bucket-slot staging tensors; `None` means the full identity
    /// grid with every slot valid (the MGNet input layout). The returned
    /// logits vector is the only per-call allocation.
    fn forward(&mut self, patches: &[f32], n: usize, pos_idx: Option<&[f32]>, valid: Option<&[f32]>) -> Result<Vec<f32>> {
        let d = self.cfg.embed_dim;
        let full = self.cfg.num_patches();
        ensure!(n >= 1 && n <= full, "token count {n} outside 1..={full}");
        let t = n + 1;
        let s = &mut self.scratch;
        s.x[..d].copy_from_slice(&self.cls);
        self.embed.forward(patches, n, &mut s.x[d..t * d]);
        for slot in 0..n {
            let p = match pos_idx {
                Some(pi) => {
                    let p = pi[slot];
                    ensure!(
                        p.is_finite() && p >= 0.0 && (p as usize) < full,
                        "pos_idx[{slot}] = {p} outside the {full}-patch grid"
                    );
                    p as usize
                }
                None => slot,
            };
            let prow = &self.pos[(1 + p) * d..(2 + p) * d];
            for (xi, &pv) in s.x[(1 + slot) * d..(2 + slot) * d].iter_mut().zip(prow) {
                *xi += pv;
            }
        }
        for (xi, &pv) in s.x[..d].iter_mut().zip(&self.pos[..d]) {
            *xi += pv;
        }
        s.valid[0] = true;
        for slot in 0..n {
            s.valid[1 + slot] = valid.map_or(true, |v| v[slot] > 0.5);
        }
        // Zero the embedded rows of invalid slots. Activation quantization
        // calibrates max-abs over whole buffers, so any padded-slot content
        // left here would shift every valid token's quantization grid —
        // breaking the invariant that padding can never reach the logits.
        // Zeroed rows make all downstream buffers padding-independent.
        for slot in 0..n {
            if !s.valid[1 + slot] {
                s.x[(1 + slot) * d..(2 + slot) * d].fill(0.0);
            }
        }
        quantize_acts(&mut s.x[..t * d], self.bits);
        for blk in &self.blocks {
            block_forward(blk, &self.cfg, &mut self.scratch, t, self.bits);
        }
        // Classifier head on the cls token only: padded slots can never
        // reach the logits except through (masked) attention.
        layer_norm_all(&self.scratch.x[..d], &mut self.scratch.norm[..d], d);
        quantize_acts(&mut self.scratch.norm[..d], self.bits);
        let mut logits = vec![0.0f32; self.head.out_dim];
        self.head.forward(&self.scratch.norm[..d], 1, &mut logits);
        Ok(logits)
    }
}

/// One loaded artifact with its per-tier reference modules, indexed by
/// [`PrecisionTier::index`]. Every tier shares the same weight seed — the
/// tiers are the *same* model seen through different converter grids, which
/// is exactly what makes the per-tier output-agreement deltas meaningful.
/// The INT8 slot materializes at [`Backend::load`] time (the untiered
/// path); INT4 and the fp32 agreement reference build lazily on first
/// tiered execution, so single-precision serving pays nothing for them.
#[derive(Debug)]
struct HostModule {
    spec: ArtifactSpec,
    tiers: [Option<HostVit>; 3],
}

/// Pure-Rust reference implementation of [`Backend`]. See the module docs.
#[derive(Debug)]
pub struct HostBackend {
    cfg: HostConfig,
    modules: HashMap<String, HostModule>,
}

impl HostBackend {
    pub fn new(cfg: HostConfig) -> Self {
        HostBackend { cfg, modules: HashMap::new() }
    }

    pub fn config(&self) -> &HostConfig {
        &self.cfg
    }

    /// Converter bits a tier runs at: INT4 is 4, INT8 is the backend's
    /// configured `bits` (so the tiered INT8 path stays bit-identical to
    /// untiered execution even under a non-default `HostConfig::bits`),
    /// and Fp32 is the ≥32 sentinel [`quantize_acts`] passes through.
    fn tier_bits(&self, tier: PrecisionTier) -> u32 {
        match tier {
            PrecisionTier::Int4 => 4,
            PrecisionTier::Int8 => self.cfg.bits,
            PrecisionTier::Fp32 => 32,
        }
    }

    fn build_vit(&self, name: &str, spec: ArtifactSpec, bits: u32) -> HostVit {
        let seed = artifact_seed(self.cfg.seed, name);
        match spec {
            ArtifactSpec::Mgnet { image_size } => {
                // The MGNet is a one-block ViT whose head scores every
                // patch of the full grid from the cls token.
                let cfg = MgnetConfig::classification(image_size).as_vit();
                HostVit::build(cfg, cfg.seq_len(), seed, self.cfg.depth_limit, bits)
            }
            ArtifactSpec::Backbone { variant, image_size, bucket } => {
                let cfg = VitConfig::variant(variant, image_size, self.cfg.num_classes);
                HostVit::build(cfg, bucket + 1, seed, self.cfg.depth_limit, bits)
            }
        }
    }

    /// Make sure `artifact` has its `tier` module materialized.
    fn ensure_tier(&mut self, artifact: &str, tier: PrecisionTier) -> Result<()> {
        if !self.modules.contains_key(artifact) {
            let spec = parse_artifact(artifact)?;
            self.modules
                .insert(artifact.to_string(), HostModule { spec, tiers: [None, None, None] });
        }
        let spec = self.modules[artifact].spec;
        if self.modules[artifact].tiers[tier.index()].is_none() {
            let vit = self.build_vit(artifact, spec, self.tier_bits(tier));
            self.modules.get_mut(artifact).expect("just inserted").tiers[tier.index()] = Some(vit);
        }
        Ok(())
    }

    /// Resolve `(spec, vit)` for a tier, building it on first use.
    fn module_mut(
        &mut self,
        artifact: &str,
        tier: PrecisionTier,
    ) -> Result<(ArtifactSpec, &mut HostVit)> {
        self.ensure_tier(artifact, tier)?;
        let m = self.modules.get_mut(artifact).expect("just ensured");
        Ok((m.spec, m.tiers[tier.index()].as_mut().expect("just ensured")))
    }
}

/// One frame through a resolved module: arity/shape validation + forward.
/// Shared by `execute` and the native `execute_batch`, so batched results
/// are bitwise-identical to sequential ones by construction.
fn run_artifact(
    spec: &ArtifactSpec,
    vit: &mut HostVit,
    artifact: &str,
    inputs: &[TensorRef<'_>],
) -> Result<Vec<Vec<f32>>> {
    let patch_dim = vit.cfg.patch_dim();
    let out = match *spec {
        ArtifactSpec::Mgnet { .. } => {
            let n = vit.cfg.num_patches();
            ensure!(inputs.len() == 1, "mgnet artifact takes 1 input, got {}", inputs.len());
            ensure!(
                inputs[0].data.len() == n * patch_dim,
                "mgnet input has {} values, expected {}x{}",
                inputs[0].data.len(),
                n,
                patch_dim
            );
            vit.forward(inputs[0].data, n, None, None)
        }
        ArtifactSpec::Backbone { bucket, .. } => {
            ensure!(
                inputs.len() == 3,
                "backbone artifact takes (patches, pos_idx, valid), got {} inputs",
                inputs.len()
            );
            ensure!(
                inputs[0].data.len() == bucket * patch_dim,
                "backbone patches have {} values, expected {}x{}",
                inputs[0].data.len(),
                bucket,
                patch_dim
            );
            ensure!(
                inputs[1].data.len() == bucket && inputs[2].data.len() == bucket,
                "pos_idx/valid must each have {bucket} slots"
            );
            vit.forward(inputs[0].data, bucket, Some(inputs[1].data), Some(inputs[2].data))
        }
    }
    .with_context(|| format!("host execution of artifact '{artifact}'"))?;
    Ok(vec![out])
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn load(&mut self, artifact: &str) -> Result<()> {
        self.ensure_tier(artifact, PrecisionTier::Int8)
    }

    fn is_loaded(&self, artifact: &str) -> bool {
        self.modules.contains_key(artifact)
    }

    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
        let (spec, vit) = self.module_mut(artifact, PrecisionTier::Int8)?;
        run_artifact(&spec, vit, artifact, inputs)
    }

    /// Native batched execution: the module (and its preallocated scratch)
    /// is resolved **once** for the whole batch, then the reference forward
    /// runs back-to-back over every frame — the host-side analogue of
    /// keeping the photonic weight banks programmed across a bucket-major
    /// batch. Numerics are bitwise-identical to sequential `execute` calls
    /// (same `run_artifact` body, same scratch reuse discipline).
    fn execute_batch(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        self.execute_batch_tiered(artifact, batch, PrecisionTier::Int8)
    }

    /// Tiered batched execution: same discipline as `execute_batch`, over
    /// the tier's own quantized module (same weight seed, different
    /// converter grid). INT8 is bitwise the untiered path; INT4 re-grids
    /// weights and matmul-boundary activations to 4 bits; Fp32 bypasses
    /// fake-quantization entirely (the electronic reference the agreement
    /// deltas compare against).
    fn execute_batch_tiered(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
        tier: PrecisionTier,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let (spec, vit) = self.module_mut(artifact, tier)?;
        batch.iter().map(|inputs| run_artifact(&spec, vit, artifact, inputs)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // All tests run on a 32px grid (2x2 patches, 5 tokens with cls) to
    // keep debug-mode forwards cheap.
    const PD: usize = 16 * 16 * 3;

    fn cfg1() -> HostConfig {
        HostConfig { depth_limit: Some(1), ..HostConfig::default() }
    }

    fn patches(n: usize, fill: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n * PD).map(fill).collect()
    }

    #[test]
    fn parses_artifact_grammar() {
        assert_eq!(parse_artifact("mgnet_96").unwrap(), ArtifactSpec::Mgnet { image_size: 96 });
        assert_eq!(
            parse_artifact("vit_tiny_96_n36").unwrap(),
            ArtifactSpec::Backbone { variant: VitVariant::Tiny, image_size: 96, bucket: 36 }
        );
        assert_eq!(
            parse_artifact("vit_large_224_n196").unwrap(),
            ArtifactSpec::Backbone { variant: VitVariant::Large, image_size: 224, bucket: 196 }
        );
        for bad in [
            "mgnet_97",         // not patch-divisible
            "mgnet_x",          // not a number
            "vit_giant_96_n9",  // unknown variant
            "vit_tiny_96",      // missing bucket
            "vit_tiny_96_n0",   // bucket below 1
            "vit_tiny_96_n37",  // bucket above the full grid
            "vit_tiny_96_n9_x", // trailing segment
            "resnet_50",        // unknown family
        ] {
            assert!(parse_artifact(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn unknown_artifact_is_error() {
        let mut b = HostBackend::new(cfg1());
        assert!(b.load("resnet_50").is_err());
        assert!(!b.is_loaded("resnet_50"));
    }

    #[test]
    fn identity_and_loading() {
        let mut b = HostBackend::new(cfg1());
        assert_eq!(b.name(), "host");
        assert!(!b.needs_artifacts());
        assert!(!b.is_loaded("mgnet_32"));
        b.load("mgnet_32").unwrap();
        assert!(b.is_loaded("mgnet_32"));
        assert_eq!(b.modeled_frame_latency_s(2, true), None);
    }

    #[test]
    fn mgnet_scores_full_grid() {
        let mut b = HostBackend::new(cfg1());
        let x = patches(4, |i| (i % 17) as f32 / 17.0);
        let dims = [4i64, PD as i64];
        let scores = b.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        assert_eq!(scores.len(), 4, "one score per grid patch");
        assert!(scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn deterministic_across_instances_and_calls() {
        let x = patches(2, |i| (i % 13) as f32 / 13.0);
        let pos = [0.0f32, 3.0];
        let valid = [1.0f32, 1.0];
        let dims = [2i64, PD as i64];
        let vdims = [2i64];
        let ins =
            [TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)];
        let mut a = HostBackend::new(cfg1());
        let mut b = HostBackend::new(cfg1());
        let la = a.execute1("vit_tiny_32_n2", &ins).unwrap();
        let lb = b.execute1("vit_tiny_32_n2", &ins).unwrap();
        assert_eq!(la, lb, "same seed must give identical logits");
        assert_eq!(la, a.execute1("vit_tiny_32_n2", &ins).unwrap(), "execution must be pure");
        let mut c = HostBackend::new(HostConfig { seed: 99, ..cfg1() });
        let lc = c.execute1("vit_tiny_32_n2", &ins).unwrap();
        assert_ne!(la, lc, "different seeds must give different weights");
        assert_eq!(la.len(), cfg1().num_classes);
    }

    #[test]
    fn execute_batch_is_bitwise_sequential() {
        let xa = patches(2, |i| (i % 13) as f32 / 13.0);
        let xb = patches(2, |i| (i % 5) as f32 / 5.0);
        let dims = [2i64, PD as i64];
        let vdims = [2i64];
        let pos = [0.0f32, 3.0];
        let valid = [1.0f32, 1.0];
        let fa =
            [TensorRef::new(&xa, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)];
        let fb =
            [TensorRef::new(&xb, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&fa, &fb, &fa];
        let mut b = HostBackend::new(cfg1());
        let batched = b.execute_batch("vit_tiny_32_n2", &batch).expect("batched");
        let sa = b.execute("vit_tiny_32_n2", &fa).expect("seq a");
        let sb = b.execute("vit_tiny_32_n2", &fb).expect("seq b");
        assert_eq!(batched.len(), 3);
        assert_eq!(batched[0], sa);
        assert_eq!(batched[1], sb);
        assert_eq!(batched[2], sa, "repeated frame in a batch must be pure");
        // A bad frame anywhere in the batch fails the whole call.
        let short = [TensorRef::new(&xa, &dims)];
        let bad: Vec<&[TensorRef<'_>]> = vec![&fa, &short];
        assert!(b.execute_batch("vit_tiny_32_n2", &bad).is_err());
    }

    #[test]
    fn tiered_int8_is_bitwise_the_untiered_path() {
        let x = patches(2, |i| (i % 13) as f32 / 13.0);
        let dims = [2i64, PD as i64];
        let vdims = [2i64];
        let pos = [0.0f32, 3.0];
        let valid = [1.0f32, 1.0];
        let f =
            [TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&f];
        let mut b = HostBackend::new(cfg1());
        let untiered = b.execute_batch("vit_tiny_32_n2", &batch).expect("untiered");
        let tiered = b
            .execute_batch_tiered("vit_tiny_32_n2", &batch, PrecisionTier::Int8)
            .expect("tiered int8");
        assert_eq!(untiered, tiered, "INT8 tier must be bitwise the untiered path");
    }

    #[test]
    fn tiers_share_weights_but_differ_in_grid() {
        let x = patches(2, |i| (i % 13) as f32 / 13.0);
        let dims = [2i64, PD as i64];
        let vdims = [2i64];
        let pos = [0.0f32, 3.0];
        let valid = [1.0f32, 1.0];
        let f =
            [TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&f];
        let mut b = HostBackend::new(cfg1());
        let mut by_tier = Vec::new();
        for tier in PrecisionTier::ALL {
            let out =
                b.execute_batch_tiered("vit_tiny_32_n2", &batch, tier).expect("tiered exec");
            assert_eq!(out[0][0].len(), cfg1().num_classes);
            assert!(out[0][0].iter().all(|v| v.is_finite()), "{tier} logits must be finite");
            // Tiered execution is pure, like everything else here.
            let again =
                b.execute_batch_tiered("vit_tiny_32_n2", &batch, tier).expect("tiered exec");
            assert_eq!(out, again, "{tier} execution must be pure");
            by_tier.push(out[0][0].clone());
        }
        assert_ne!(by_tier[0], by_tier[1], "4-bit grid must perturb the logits vs 8-bit");
        assert_ne!(by_tier[1], by_tier[2], "fp32 reference must differ from the 8-bit grid");
    }

    #[test]
    fn fp_sentinel_bypasses_activation_quantization() {
        let mut q = [0.1f32, 0.33, -0.7];
        let raw = q;
        quantize_acts(&mut q, 32);
        assert_eq!(q, raw, "bits >= 32 must leave the buffer untouched");
        quantize_acts(&mut q, 4);
        assert_ne!(q, raw, "a real converter grid must move off-grid values");
    }

    #[test]
    fn padded_slots_cannot_reach_the_logits() {
        // Bucket 4, only 2 valid slots: garbage in the padded slots must
        // not change the logits — validity masking is load-bearing.
        let dims = [4i64, PD as i64];
        let vdims = [4i64];
        let pos = [0.0f32, 3.0, 0.0, 0.0];
        let valid = [1.0f32, 1.0, 0.0, 0.0];
        let mut x = patches(4, |i| (i % 13) as f32 / 13.0);
        for v in &mut x[2 * PD..] {
            *v = 0.0;
        }
        let mut b = HostBackend::new(cfg1());
        let zero_pad = b
            .execute1(
                "vit_tiny_32_n4",
                &[TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)],
            )
            .unwrap();
        for v in &mut x[2 * PD..] {
            *v = 7.5;
        }
        let garbage_pad = b
            .execute1(
                "vit_tiny_32_n4",
                &[TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)],
            )
            .unwrap();
        assert_eq!(zero_pad, garbage_pad, "padded slots leaked into the logits");
    }

    #[test]
    fn depth_limit_changes_numerics_but_not_shape() {
        let x = patches(2, |i| (i % 11) as f32 / 11.0);
        let dims = [2i64, PD as i64];
        let vdims = [2i64];
        let pos = [0.0f32, 1.0];
        let valid = [1.0f32, 1.0];
        let ins =
            [TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)];
        let mut shallow = HostBackend::new(cfg1());
        let mut full = HostBackend::new(HostConfig { depth_limit: None, ..cfg1() });
        let ls = shallow.execute1("vit_tiny_32_n2", &ins).unwrap();
        let lf = full.execute1("vit_tiny_32_n2", &ins).unwrap();
        assert_eq!(ls.len(), lf.len());
        assert_ne!(ls, lf, "Tiny runs 12 blocks at full depth, 1 when capped");
        assert!(lf.iter().all(|v| v.is_finite()), "full-depth forward must stay finite");
    }

    #[test]
    fn input_arity_and_shape_are_validated() {
        let mut b = HostBackend::new(cfg1());
        let x = patches(2, |_| 0.1);
        let dims = [2i64, PD as i64];
        // Backbone with a single input.
        assert!(b.execute("vit_tiny_32_n2", &[TensorRef::new(&x, &dims)]).is_err());
        // MGNet with the wrong patch count.
        assert!(b.execute("mgnet_32", &[TensorRef::new(&x, &dims)]).is_err());
        // pos_idx outside the grid.
        let pos = [0.0f32, 9.0];
        let valid = [1.0f32, 1.0];
        let vdims = [2i64];
        let err = b
            .execute(
                "vit_tiny_32_n2",
                &[TensorRef::new(&x, &dims), TensorRef::new(&pos, &vdims), TensorRef::new(&valid, &vdims)],
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("pos_idx"), "{err:#}");
    }
}
