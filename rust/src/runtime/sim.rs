//! Analytic photonic-simulation backend: [`HostBackend`] numerics, with
//! per-frame latency charged from the accelerator architecture model
//! instead of host wall-clock.
//!
//! This is the execution substrate the paper's evaluation actually reports:
//! the Fig. 9/11 delay model ([`crate::arch`] schedule + component
//! constants) decides how long a frame takes on the five-core photonic
//! accelerator, while the host merely computes the reference numerics. A
//! `--backend sim` serving run therefore produces a `ServeReport` whose
//! latency column is photonic-core time (energy was always modeled, for
//! every backend), making near-sensor operating points comparable across
//! machines regardless of host speed.
//!
//! Modeled latencies are cached per kept-patch count: the delay schedule is
//! orders of magnitude more expensive than the energy model (see
//! `AcceleratorModel::frame_energy`), so it must never run per frame.

use anyhow::Result;

use super::host::{ArtifactSpec, HostBackend, HostConfig};
use super::{Backend, TensorRef};
use crate::energy::AcceleratorModel;
use crate::vit::{MgnetConfig, VitConfig};

/// [`Backend`] that wraps [`HostBackend`] for execution and overlays
/// modeled photonic frame latency.
#[derive(Debug)]
pub struct SimBackend {
    inner: HostBackend,
    model: AcceleratorModel,
    /// Backbone/MGNet configs, captured from the artifact names at load
    /// time (the first loaded backbone defines the operating point).
    backbone: Option<VitConfig>,
    mgnet: Option<MgnetConfig>,
    /// Modeled masked-path latency by kept-patch count (index = kept).
    masked_latency_s: Vec<Option<f64>>,
    /// Modeled unmasked full-grid latency.
    full_latency_s: Option<f64>,
}

impl SimBackend {
    pub fn new(host: HostConfig) -> Self {
        Self::with_model(host, AcceleratorModel::default())
    }

    pub fn with_model(host: HostConfig, model: AcceleratorModel) -> Self {
        SimBackend {
            inner: HostBackend::new(host),
            model,
            backbone: None,
            mgnet: None,
            masked_latency_s: Vec::new(),
            full_latency_s: None,
        }
    }

    /// The architecture model charging the latency.
    pub fn model(&self) -> &AcceleratorModel {
        &self.model
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn load(&mut self, artifact: &str) -> Result<()> {
        self.inner.load(artifact)?;
        match super::host::parse_artifact(artifact)? {
            ArtifactSpec::Mgnet { image_size } => {
                self.mgnet.get_or_insert(MgnetConfig::classification(image_size));
            }
            ArtifactSpec::Backbone { variant, image_size, .. } => {
                let classes = self.inner.config().num_classes;
                self.backbone.get_or_insert(VitConfig::variant(variant, image_size, classes));
            }
        }
        Ok(())
    }

    fn is_loaded(&self, artifact: &str) -> bool {
        self.inner.is_loaded(artifact)
    }

    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
        if !self.inner.is_loaded(artifact) {
            // Route implicit loads through `Self::load` so the config
            // capture above cannot be bypassed.
            self.load(artifact)?;
        }
        self.inner.execute(artifact, inputs)
    }

    fn modeled_frame_latency_s(&mut self, kept_patches: usize, use_mask: bool) -> Option<f64> {
        let vit = self.backbone?;
        if !use_mask {
            if self.full_latency_s.is_none() {
                let r = self.model.frame_report("sim", &vit, vit.num_patches(), true);
                self.full_latency_s = Some(r.delay.total_s());
            }
            return self.full_latency_s;
        }
        let mg = self.mgnet?;
        let kept = kept_patches.clamp(1, vit.num_patches());
        if self.masked_latency_s.len() <= kept {
            self.masked_latency_s.resize(kept + 1, None);
        }
        if self.masked_latency_s[kept].is_none() {
            let r = self.model.masked_report("sim", &vit, &mg, kept);
            self.masked_latency_s[kept] = Some(r.delay.total_s());
        }
        self.masked_latency_s[kept]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimBackend {
        SimBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() })
    }

    #[test]
    fn no_latency_before_any_backbone_loads() {
        let mut s = sim();
        assert_eq!(s.modeled_frame_latency_s(4, true), None);
        assert_eq!(s.name(), "sim");
        assert!(!s.needs_artifacts());
    }

    #[test]
    fn modeled_latency_matches_architecture_model() {
        let mut s = sim();
        s.load("mgnet_32").unwrap();
        s.load("vit_tiny_32_n4").unwrap();
        let vit = VitConfig::variant(crate::vit::VitVariant::Tiny, 32, 10);
        let mg = MgnetConfig::classification(32);
        let model = AcceleratorModel::default();
        let masked = s.modeled_frame_latency_s(2, true).expect("masked latency");
        assert_eq!(masked, model.masked_report("x", &vit, &mg, 2).delay.total_s());
        // Cached second query returns the identical value.
        assert_eq!(s.modeled_frame_latency_s(2, true), Some(masked));
        let full = s.modeled_frame_latency_s(4, false).expect("full latency");
        assert_eq!(full, model.frame_report("x", &vit, vit.num_patches(), true).delay.total_s());
        assert!(masked > 0.0 && full > 0.0);
    }

    #[test]
    fn latency_grows_with_kept_patches() {
        let mut s = sim();
        s.load("mgnet_32").unwrap();
        s.load("vit_tiny_32_n4").unwrap();
        let l1 = s.modeled_frame_latency_s(1, true).unwrap();
        let l4 = s.modeled_frame_latency_s(4, true).unwrap();
        assert!(l4 > l1, "more kept patches must model more latency ({l1} !< {l4})");
        // Out-of-range kept counts clamp instead of panicking.
        assert_eq!(s.modeled_frame_latency_s(0, true), Some(l1));
        assert_eq!(s.modeled_frame_latency_s(99, true), Some(l4));
    }

    #[test]
    fn execution_delegates_to_host_numerics() {
        const PD: usize = 16 * 16 * 3;
        let x: Vec<f32> = (0..4 * PD).map(|i| (i % 13) as f32 / 13.0).collect();
        let dims = [4i64, PD as i64];
        let mut s = sim();
        let mut h = HostBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() });
        let scores_sim = s.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        let scores_host = h.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        assert_eq!(scores_sim, scores_host, "sim must reuse the host reference numerics");
    }
}
